"""The docs-consistency gate, as a tier-1 test.

`tools/check_docs.py` is the source of truth (CI also runs it
standalone, before test deps exist); this wrapper makes a stale README
fail `pytest` locally too, and unit-tests the parser helpers so a
source-layout refactor that silently empties the required-name sets is
caught as a failure rather than a vacuous pass.
"""
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "tools"))

import check_docs  # noqa: E402


def test_docs_consistent():
    proc = subprocess.run(
        [sys.executable, str(ROOT / "tools" / "check_docs.py")],
        capture_output=True, text=True)
    assert proc.returncode == 0, f"docs drifted:\n{proc.stdout}{proc.stderr}"


def test_parser_sees_the_real_config_surface():
    fields = check_docs.serveconfig_fields(check_docs.SCHEDULER)
    # spot-check axes from every group: scheduling, pool, engine-, sim-only
    for must in ("policy", "preemption", "admission", "num_device_blocks",
                 "max_tokens_per_request", "forecast_horizon"):
        assert must in fields
    assert set(check_docs.policy_names(check_docs.SCHEDULER)) == {
        "fcfs", "prefix_aware", "deadline"}
    assert set(check_docs.policy_names(check_docs.ROUTER)) == {
        "round_robin", "least_loaded", "prefix_affinity", "slo_aware"}


def test_broken_link_detection(tmp_path):
    doc = tmp_path / "X.md"
    doc.write_text("see [good](X.md) and [bad](nope/missing.md) "
                   "and [web](https://example.com/x.md)")
    problems = check_docs.broken_links(doc)
    assert len(problems) == 1 and "nope/missing.md" in problems[0]
