"""KV-accounting sanitizer tests: clean runs stay silent on both
backends, each injected bug class (double free, refcount leak, ledger
mismatch) is caught with its invariant id, and the opt-in wiring
(ServeConfig.sanitize / REPRO_SANITIZE) installs the shadow model."""
import dataclasses

import pytest

from repro.configs.llama2_7b import CONFIG as LLAMA2_7B
from repro.core.sanitizer import KVSanitizer, SanitizerError
from repro.serving.costmodel import L20
from repro.serving.scheduler import ServeConfig
from repro.serving.session import ServingSession
from repro.serving.sim import ServingSimulator, SimConfig
from repro.serving.workload import shared_prefix, sharegpt_like


def _mid_flight_sim(n_steps=60, **kw):
    """A sanitized sim paused mid-flight: live tables, cache entries
    (when prefix_cache is on), and ledger traffic all populated."""
    cfg = SimConfig(policy="layerkv", num_device_blocks=2048,
                    num_host_blocks=1 << 13, sanitize=True, **kw)
    reqs = shared_prefix(12, rate=50.0, seed=0) \
        if kw.get("prefix_cache") else sharegpt_like(12, rate=50.0, seed=0)
    sim = ServingSimulator(LLAMA2_7B, L20, cfg)
    sess = ServingSession(sim)
    for r in reqs:
        sess.submit(r, arrival=r.arrival)
    for _ in range(n_steps):
        if not sess.step():
            break
    assert sim.core.sanitizer is not None
    assert sim.bm.tables, "need live allocations mid-flight"
    return sim


# ------------------------------------------------------------ clean runs --

def test_clean_run_passes_and_checks_fire():
    cfg = SimConfig(policy="layerkv", num_device_blocks=2048,
                    num_host_blocks=1 << 13, sanitize=True)
    sim = ServingSimulator(LLAMA2_7B, L20, cfg)
    sim.run(sharegpt_like(20, rate=20.0, seed=1))
    san = sim.core.sanitizer
    assert san is not None and san.n_checks > 0
    assert san.n_full_checks > 0, "deep tier never ran"
    assert san.n_events > 0, "shadow model observed no mutations"
    # S5 held all run: every h2d charge was movement-backed
    assert san.charged_h2d == pytest.approx(san.expected_h2d)
    san.check(sim.core, full=True)  # idle baseline (S8) re-asserts


def test_clean_run_with_preemption_and_prefix_cache():
    cfg = SimConfig(policy="layerkv", num_device_blocks=2048,
                    num_host_blocks=1 << 13, sanitize=True,
                    chunked=True, prefix_cache=True,
                    preemption=True, admission="deadline")
    sim = ServingSimulator(LLAMA2_7B, L20, cfg)
    sim.run(shared_prefix(20, rate=50.0, seed=2))
    san = sim.core.sanitizer
    san.check(sim.core, full=True)
    assert san.charged_h2d == pytest.approx(san.expected_h2d)
    assert san.charged_d2h >= san.expected_d2h - 1.0


def test_sanitizer_off_by_default():
    # the conftest fixture forces sanitize on for sim-backend tests, so
    # probe the dataclass default rather than a simulator instance
    fields = {f.name: f for f in dataclasses.fields(ServeConfig)}
    assert fields["sanitize"].default is False


def test_env_var_opt_in(monkeypatch):
    # undo the conftest force so the env var is the ONLY opt-in path
    orig = getattr(ServingSimulator.__init__, "_orig", None)
    if orig is not None:
        monkeypatch.setattr(ServingSimulator, "__init__", orig)
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    cfg = SimConfig(policy="layerkv")
    assert cfg.sanitize is False
    sim = ServingSimulator(LLAMA2_7B, L20, cfg)
    assert sim.core.sanitizer is not None


# ------------------------------------------------- injected bug classes --

def test_double_free_caught():
    sim = _mid_flight_sim()
    san = sim.core.sanitizer
    san.inject_double_free()
    with pytest.raises(SanitizerError, match="S1"):
        san.check(sim.core, full=True)


def test_refcount_leak_caught():
    sim = _mid_flight_sim(prefix_cache=True, chunked=True)
    san = sim.core.sanitizer
    san.inject_refcount_leak()
    with pytest.raises(SanitizerError, match="S4"):
        san.check(sim.core, full=True)


def test_ledger_mismatch_caught():
    sim = _mid_flight_sim()
    san = sim.core.sanitizer
    san.inject_ledger_mismatch()
    with pytest.raises(SanitizerError, match="S5"):
        san.check(sim.core, full=True)


def test_mutation_time_trap_double_free_via_api():
    """Freeing through the pool API twice trips the shadow at the event
    itself, not at the next check."""
    sim = _mid_flight_sim()
    pool = sim.bm.pools["device"]
    owned = next(iter(pool._owner))
    # first free is legal; the second is the historical bug class
    pool.free([owned])
    with pytest.raises(SanitizerError, match="double free"):
        pool.free([owned])


# ------------------------------------------------------------ real engine --

@pytest.mark.slow
def test_engine_backend_sanitized():
    """The shadow model rides the REAL engine too: tight device pool
    forces offload/reload traffic and every step is checked."""
    import jax
    from repro.configs import get_smoke_config
    from repro.serving.engine import EngineConfig, LayerKVEngine
    from repro.serving.request import Request
    import numpy as np

    cfg = dataclasses.replace(get_smoke_config("granite-3-2b"),
                              dtype="float32")
    r0 = np.random.RandomState(11)
    reqs = []
    for i in range(4):
        plen = int(r0.randint(24, 40))
        reqs.append(Request(
            rid=f"r{i}", prompt_len=plen,
            output_len=int(r0.randint(8, 14)), arrival=0.0,
            prompt=[int(x) for x in r0.randint(0, cfg.vocab_size, plen)]))
    eng = LayerKVEngine(
        cfg, None,
        EngineConfig(policy="layerkv", slo_aware=False,
                     num_device_blocks=24, num_host_blocks=512,
                     block_size=8, sanitize=True),
        rng=jax.random.PRNGKey(42))
    done = eng.run(reqs)
    assert len(done) == 4
    san = eng.core.sanitizer
    assert isinstance(san, KVSanitizer) and san.n_checks > 0
    san.check(eng.core, full=True)
    assert san.charged_h2d == pytest.approx(san.expected_h2d)
