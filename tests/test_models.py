"""Per-architecture smoke tests (reduced configs) + decode consistency +
SSM chunked-vs-recurrent equivalence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models import build_model

RNG = jax.random.PRNGKey(0)


def _cfg(arch):
    return dataclasses.replace(get_smoke_config(arch), dtype="float32")


def _batch(cfg, B, S, params=None, tokens=None):
    toks = tokens if tokens is not None else jax.random.randint(
        RNG, (B, S), 0, cfg.vocab_size)
    b = {"tokens": toks, "labels": toks}
    if cfg.family == "vlm":
        b["embeds"] = (params["embed"][toks] if params is not None
                       else jax.random.normal(RNG, (B, S, cfg.d_model)) * .02)
        b["mrope_pos"] = jnp.broadcast_to(jnp.arange(S)[None, None],
                                          (3, B, S))
    if cfg.family == "encdec":
        b["enc_embeds"] = jax.random.normal(
            RNG, (B, cfg.encoder_len, cfg.d_model)) * 0.02
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    """One forward + one train step on CPU: shapes + finiteness."""
    cfg = _cfg(arch)
    model = build_model(cfg)
    params = model.init(RNG)
    B, S = 2, 32
    batch = _batch(cfg, B, S, params)
    logits, _ = model.train_logits(params, batch)
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all())
    loss, grads = jax.value_and_grad(
        lambda p: model.loss(p, batch)[0])(params)
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_teacher_forcing(arch):
    """Prefill(S) + N decode steps == prefill(S+N) last logits."""
    cfg = _cfg(arch)
    model = build_model(cfg)
    params = model.init(RNG)
    B, S, N = 2, 16, 3
    toks = jax.random.randint(RNG, (B, S + N), 0, cfg.vocab_size)
    kw = dict(dropless=True) if cfg.family == "moe" else {}
    ref_logits, _ = model.prefill(params, _batch(cfg, B, S + N, params,
                                                 toks),
                                  model.init_cache(B, 64), **kw)
    cache = model.init_cache(B, 64)
    lg, cache = model.prefill(params, _batch(cfg, B, S, params,
                                             toks[:, :S]), cache, **kw)
    for i in range(S, S + N):
        lg, cache = model.decode(params, toks[:, i], cache)
    rel = float(jnp.max(jnp.abs(lg - ref_logits))) \
        / (float(jnp.max(jnp.abs(ref_logits))) + 1e-9)
    assert rel < 5e-3, f"{arch}: rel err {rel}"


def test_sliding_window_ring_buffer():
    """Dense decode with a ring buffer == full-cache attention restricted
    to the window."""
    cfg = dataclasses.replace(_cfg("granite-3-2b"), sliding_window=16)
    model = build_model(cfg)
    params = model.init(RNG)
    B, S, N = 1, 24, 8
    toks = jax.random.randint(RNG, (B, S + N), 0, cfg.vocab_size)
    # windowed: ring cache of 16
    cache_w = model.init_cache(B, 16)
    assert int(cache_w["window"]) == 16
    lg_w, cache_w = model.prefill(params, _batch(cfg, B, S, params,
                                                 toks[:, :S]), cache_w)
    for i in range(S, S + N):
        lg_w, cache_w = model.decode(params, toks[:, i], cache_w)
    assert bool(jnp.isfinite(lg_w).all())


def test_mamba_chunked_vs_recurrent():
    """Mamba2 SSD chunked prefill == token-by-token recurrence."""
    from repro.models import ssm
    cfg = _cfg("zamba2-2.7b")
    key = jax.random.PRNGKey(1)
    p = ssm.init_mamba(cfg, key, jnp.float32)
    B, S = 2, 24
    x = jax.random.normal(key, (B, S, cfg.d_model)) * 0.5
    y_par, (state_par, conv_par) = ssm.mamba_forward(cfg, p, x, chunk=8)
    # recurrent
    d_in, H, P, N, G = ssm.mamba_dims(cfg)
    state = jnp.zeros((B, H, N, P), jnp.float32)
    conv = jnp.zeros((B, cfg.ssm.conv_dim - 1, d_in + 2 * G * N),
                     jnp.float32)
    ys = []
    for t in range(S):
        y, (state, conv) = ssm.mamba_decode(cfg, p, x[:, t:t + 1], state,
                                            conv)
        ys.append(y)
    y_rec = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_rec),
                               atol=2e-4, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(state_par), np.asarray(state),
                               atol=2e-4, rtol=2e-3)


def test_mlstm_chunked_vs_recurrent():
    from repro.models import ssm
    cfg = _cfg("xlstm-1.3b")
    key = jax.random.PRNGKey(2)
    p = ssm.init_mlstm(cfg, key, jnp.float32)
    B, S = 2, 16
    x = jax.random.normal(key, (B, S, cfg.d_model)) * 0.5
    y_par, st_par = ssm.mlstm_forward(cfg, p, x, chunk=4)
    st = None
    ys = []
    for t in range(S):
        y, st = ssm.mlstm_decode(cfg, p, x[:, t:t + 1], st) if st is not None \
            else ssm.mlstm_decode(cfg, p, x[:, t:t + 1], _zero_mlstm(cfg, B))
        ys.append(y)
    y_rec = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_rec),
                               atol=3e-4, rtol=3e-3)


def _zero_mlstm(cfg, B):
    from repro.models import ssm
    d_in, H, hd = ssm.mlstm_dims(cfg)
    return (jnp.zeros((B, H, hd, hd), jnp.float32),
            jnp.zeros((B, H, hd), jnp.float32),
            jnp.full((B, H), -1e30, jnp.float32))


def test_moe_dropless_exactness():
    """Dropless MoE: every token gets its full top-k expert mix."""
    from repro.models import moe
    cfg = _cfg("deepseek-moe-16b")
    key = jax.random.PRNGKey(3)
    p = moe.init_moe(cfg, key, jnp.float32)
    x = jax.random.normal(key, (2, 8, cfg.d_model)) * 0.5
    out, aux = moe.moe_ffn(cfg, p, x, dropless=True)
    assert float(aux["dropped_frac"]) == 0.0
    # brute-force reference: per-token dense expert mix
    T = 2 * 8
    xf = x.reshape(T, -1)
    logits = xf @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gv, gi = jax.lax.top_k(probs, cfg.moe.top_k)
    gv = gv / gv.sum(-1, keepdims=True)
    ref = jnp.zeros_like(xf)
    for t in range(T):
        acc = jnp.zeros((cfg.d_model,))
        for j in range(cfg.moe.top_k):
            e = int(gi[t, j])
            h = jax.nn.silu(xf[t] @ p["we_gate"][e]) * (xf[t] @ p["we_up"][e])
            acc = acc + gv[t, j] * (h @ p["we_down"][e])
        ref = ref.at[t].set(acc)
    shared = jax.nn.silu(xf @ p["shared"]["wg"]) * (xf @ p["shared"]["wu"])
    ref = ref + shared @ p["shared"]["wd"]
    np.testing.assert_allclose(np.asarray(out.reshape(T, -1)),
                               np.asarray(ref), atol=2e-4, rtol=2e-3)


def test_int8_kv_cache_decode():
    """int8 KV (the paper's named future work, §Perf pair 3): decode
    logits stay close to bf16-cache decode and argmax tokens match."""
    cfg = _cfg("codeqwen1.5-7b")
    cfgq = dataclasses.replace(cfg, kv_quant=True)
    m, mq = build_model(cfg), build_model(cfgq)
    params = m.init(RNG)
    B, S = 2, 24
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + 4), 0,
                              cfg.vocab_size)
    mk = lambda t: {"tokens": t, "labels": t}
    lg, cache = m.prefill(params, mk(toks[:, :S]), m.init_cache(B, 64))
    lgq, cacheq = mq.prefill(params, mk(toks[:, :S]), mq.init_cache(B, 64))
    for i in range(S, S + 4):
        lg, cache = m.decode(params, toks[:, i], cache)
        lgq, cacheq = mq.decode(params, toks[:, i], cacheq)
    rel = float(jnp.max(jnp.abs(lgq - lg))) / float(jnp.max(jnp.abs(lg)))
    assert rel < 5e-2
    assert bool((jnp.argmax(lgq, -1) == jnp.argmax(lg, -1)).all())
