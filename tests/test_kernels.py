"""Per-kernel validation: shape/dtype sweeps vs the pure-jnp oracles,
executed in Pallas interpret mode on CPU."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_prefill import flash_attention_pallas
from repro.kernels.paged_attention import paged_attention_pallas

KEY = jax.random.PRNGKey(0)


def _qkv(B, Sq, Skv, H, KV, D, dtype):
    kq, kk, kv = jax.random.split(KEY, 3)
    q = jax.random.normal(kq, (B, Sq, H, D)).astype(dtype)
    k = jax.random.normal(kk, (B, Skv, KV, D)).astype(dtype)
    v = jax.random.normal(kv, (B, Skv, KV, D)).astype(dtype)
    return q, k, v


# ---------------------------------------------------------------- flash ----

@pytest.mark.parametrize("H,KV", [(8, 8), (8, 2), (4, 1), (28, 4)])
@pytest.mark.parametrize("S", [128, 384])
def test_flash_gqa_shapes(H, KV, S):
    q, k, v = _qkv(2, S, S, H, KV, 64, jnp.float32)
    out = flash_attention_pallas(q, k, v, causal=True, block_q=64,
                                 block_k=64)
    expect = ref.mha_reference(q, k, v, causal=True)
    np.testing.assert_allclose(out, expect, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5),
                                       (jnp.bfloat16, 3e-2)])
def test_flash_dtypes(dtype, tol):
    q, k, v = _qkv(1, 256, 256, 4, 2, 128, dtype)
    out = flash_attention_pallas(q, k, v, causal=True)
    expect = ref.mha_reference(q, k, v, causal=True)
    np.testing.assert_allclose(out.astype(jnp.float32),
                               expect.astype(jnp.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("window", [1, 17, 64, 1000])
def test_flash_sliding_window(window):
    q, k, v = _qkv(2, 256, 256, 4, 4, 64, jnp.float32)
    out = flash_attention_pallas(q, k, v, causal=True, window=window,
                                 block_q=64, block_k=64)
    expect = ref.mha_reference(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(out, expect, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("bq,bk", [(32, 128), (128, 32), (256, 256)])
def test_flash_block_shapes(bq, bk):
    q, k, v = _qkv(1, 256, 256, 4, 2, 64, jnp.float32)
    out = flash_attention_pallas(q, k, v, causal=True, block_q=bq,
                                 block_k=bk)
    expect = ref.mha_reference(q, k, v, causal=True)
    np.testing.assert_allclose(out, expect, atol=2e-5, rtol=2e-5)


def test_flash_ref_chunked_matches_unchunked():
    q, k, v = _qkv(2, 512, 512, 8, 2, 64, jnp.float32)
    a = ref.flash_attention_reference(q, k, v, causal=True, q_chunk=128,
                                      kv_chunk=64)
    b = ref.mha_reference(q, k, v, causal=True)
    np.testing.assert_allclose(a, b, atol=2e-5, rtol=2e-5)


def test_flash_ref_kv_len_mask():
    q, k, v = _qkv(3, 64, 64, 4, 4, 32, jnp.float32)
    kv_len = jnp.array([3, 33, 64])
    a = ref.flash_attention_reference(q, k, v, causal=True, kv_len=kv_len,
                                      q_chunk=32, kv_chunk=32)
    b = ref.mha_reference(q, k, v, causal=True, kv_len=kv_len)
    np.testing.assert_allclose(a, b, atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------- paged ----

@pytest.mark.parametrize("H,KV", [(8, 8), (8, 2), (16, 1), (12, 4)])
@pytest.mark.parametrize("BS", [8, 16])
def test_paged_attention_shapes(H, KV, BS):
    B, D, NB, MAXB = 3, 64, 64, 6
    kq, kp = jax.random.split(KEY)
    q = jax.random.normal(kq, (B, H, D), jnp.float32)
    pool = jax.random.normal(kp, (NB, BS, 2, KV, D), jnp.float32)
    tab = jax.random.permutation(KEY, NB)[:B * MAXB].reshape(B, MAXB)
    tab = tab.astype(jnp.int32)
    kv_len = jnp.array([1, BS * 2 + 3, BS * MAXB], jnp.int32)
    out = paged_attention_pallas(q, pool, tab, kv_len)
    expect = ref.paged_attention_reference(q, pool, tab, kv_len)
    np.testing.assert_allclose(out, expect, atol=2e-5, rtol=2e-5)


def test_paged_attention_bf16():
    B, H, KV, D, NB, BS, MAXB = 2, 8, 2, 128, 32, 16, 4
    kq, kp = jax.random.split(KEY)
    q = jax.random.normal(kq, (B, H, D)).astype(jnp.bfloat16)
    pool = jax.random.normal(kp, (NB, BS, 2, KV, D)).astype(jnp.bfloat16)
    tab = jnp.arange(B * MAXB, dtype=jnp.int32).reshape(B, MAXB)
    kv_len = jnp.array([17, 64], jnp.int32)
    out = paged_attention_pallas(q, pool, tab, kv_len)
    expect = ref.paged_attention_reference(q, pool, tab, kv_len)
    np.testing.assert_allclose(out.astype(jnp.float32),
                               expect.astype(jnp.float32),
                               atol=3e-2, rtol=3e-2)


def test_paged_matches_dense_decode():
    """Paged attention over scattered blocks == dense-cache decode."""
    B, H, KV, D, BS = 2, 8, 4, 32, 8
    S = 40
    MAXB = S // BS
    NB = B * MAXB + 7
    kq, kk, kv = jax.random.split(KEY, 3)
    q = jax.random.normal(kq, (B, H, D), jnp.float32)
    k = jax.random.normal(kk, (B, S, KV, D), jnp.float32)
    v = jax.random.normal(kv, (B, S, KV, D), jnp.float32)
    # scatter into a shuffled pool
    perm = np.random.RandomState(0).permutation(NB)[:B * MAXB]
    pool = np.zeros((NB, BS, 2, KV, D), np.float32)
    tab = perm.reshape(B, MAXB)
    for b in range(B):
        for i in range(MAXB):
            pool[tab[b, i], :, 0] = np.asarray(k[b, i * BS:(i + 1) * BS])
            pool[tab[b, i], :, 1] = np.asarray(v[b, i * BS:(i + 1) * BS])
    kv_len = jnp.array([S - 5, S], jnp.int32)
    out = paged_attention_pallas(q, jnp.asarray(pool),
                                 jnp.asarray(tab, jnp.int32), kv_len)
    expect = ref.decode_attention_reference(q[:, None], k, v, kv_len)[:, 0]
    np.testing.assert_allclose(out, expect, atol=2e-5, rtol=2e-5)


# --------------------------------------------------------------- rmsnorm ---

@pytest.mark.parametrize("shape", [(4, 128), (2, 7, 256), (3, 33, 512)])
@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-5),
                                       (jnp.bfloat16, 2e-2)])
def test_rmsnorm_kernel(shape, dtype, tol):
    from repro.kernels.rmsnorm import rmsnorm_pallas
    from repro.models.layers import rmsnorm
    x = jax.random.normal(KEY, shape).astype(dtype)
    w = (jax.random.normal(jax.random.PRNGKey(9), shape[-1:]) * 0.1
         + 1.0).astype(dtype)
    out = rmsnorm_pallas(x, w, block_rows=8)
    expect = rmsnorm(x, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               atol=tol, rtol=tol)
