"""Session-API redesign coverage.

Five layers of guarantees:
  * config unification — ONE `ServeConfig` accepted verbatim by both the
    engine and the simulator (the drift guard), with the old
    EngineConfig/SimConfig names as thin shims over it;
  * online-vs-offline equivalence — the SAME arrivals driven through
    live `submit()` calls produce exactly the metrics (sim) and exactly
    the tokens (engine) of the old batch `run()`, across all five
    scheduling axes;
  * cancellation invariants — cancelling a request in ANY phase unwinds
    everything it has in flight (refcounted/COW prefix blocks with
    sharers kept intact, mid-prefill chunk state, host-resident
    offloaded layers); pool accounting returns to baseline (hypothesis
    properties + engine integration);
  * admission policies — `prefix_aware` ordering (bounded-window aging,
    hits first) and its congestion win over FCFS without miss
    starvation;
  * session mechanics — stream cursors, pending-arrival cancellation,
    duplicate-rid rejection, backpressure (AdmissionImpossible only for
    permanently unservable requests).
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.llama2_7b import CONFIG as LLAMA2_7B
from repro.core import DEVICE, HOST
from repro.serving.costmodel import L20
from repro.serving.engine import EngineConfig, LayerKVEngine
from repro.serving.request import Phase, Request
from repro.serving.scheduler import (
    AdmissionImpossible, FCFSAdmission, PrefixAwareAdmission, ServeConfig,
)
from repro.serving.session import ServingSession
from repro.serving.sim import ServingSimulator, SimConfig
from repro.serving.workload import shared_prefix, sharegpt_like


# ------------------------------------------------------ config unification --

def test_config_drift_guard():
    """THE drift guard: engine and simulator accept the IDENTICAL
    ServeConfig field set — one config class, constructed once, drives
    both backends. If either backend grows a knob the other cannot see,
    this test is where it shows up."""
    every_field = dict(
        policy="layerkv", slo_aware=True, chunked=True, prefix_cache=True,
        fused=True, preemption=True, admission="prefix_aware",
        sanitize=True, shed_overload=True, shed_grace_frac=0.5,
        admission_age_frac=0.7, trace=True,
        num_device_blocks=2048, num_host_blocks=4096, block_size=16,
        max_batch_size=32, max_prefill_tokens=256, chunk_floor=8,
        max_tokens_per_request=2048, proactive=True,
        collective_reserve_frac=0.1, forecast_horizon=16,
        forecast_threshold_frac=0.02, gpu_mem_util=0.8,
        max_model_len=8192, route_by_tokens=True)
    # every declared field is exercised above — extend this dict when
    # ServeConfig grows
    assert set(every_field) == \
        {f.name for f in dataclasses.fields(ServeConfig)}
    sc = ServeConfig(**every_field)
    sim = ServingSimulator(LLAMA2_7B, L20, sc)
    assert sim.sim is sc
    cfg = dataclasses.replace(get_smoke_config("granite-3-2b"),
                              dtype="float32")
    eng = LayerKVEngine(cfg, None, dataclasses.replace(
        sc, num_device_blocks=64, num_host_blocks=256, block_size=8))
    assert isinstance(eng.ec, ServeConfig)
    # both backends drive the SAME SchedulerCore machinery
    assert type(eng.core) is type(sim.core)


def test_config_shims_return_serve_config():
    e = EngineConfig(chunk_size=24, num_device_blocks=40)
    assert isinstance(e, ServeConfig)
    assert e.max_prefill_tokens == 24 and e.num_device_blocks == 40
    assert EngineConfig().num_device_blocks == 128      # old engine default
    s = SimConfig(policy="vllm")
    assert isinstance(s, ServeConfig)
    assert s.max_batch_size == 256 and s.chunk_floor == 16  # old sim defaults
    assert s.num_device_blocks == 0                     # 0 = derive


def test_config_validation():
    with pytest.raises(ValueError, match="fused"):
        ServeConfig(fused=True, chunked=False).validate()
    with pytest.raises(ValueError, match="admission"):
        ServeConfig(admission="mystery").validate()


# --------------------------------------------- online-vs-offline (sim) -----

SIM_AXES = {
    "vllm_excl": dict(policy="vllm"),
    "layerkv_excl_slo": dict(policy="layerkv", slo_aware=True),
    "layerkv_chunked": dict(policy="layerkv", chunked=True),
    "chunked_prefix": dict(policy="layerkv", chunked=True,
                           prefix_cache=True),
    "chunked_prefix_fused": dict(policy="layerkv", chunked=True,
                                 prefix_cache=True, fused=True),
}


def _two_bursts(n=40, gap=1e6):
    """Two arrival bursts separated by a huge idle gap: burst 2 can be
    submitted online AFTER burst 1 drains, yet before the clock reaches
    its arrivals — the online schedule is then exactly the offline one."""
    a = shared_prefix(n // 2, rate=4.0, scenario="system_prompt",
                      share_ratio=0.5, prompt_len=512, output_len=64,
                      seed=3)
    b = shared_prefix(n // 2, rate=4.0, scenario="rag_template",
                      share_ratio=0.5, prompt_len=512, output_len=64,
                      seed=4)
    for i, r in enumerate(b):
        r.rid = f"b{i}"
        r.arrival += gap
    return a, b


def _key(m):
    return (m.mean_ttft, m.p99_ttft, m.mean_tpot, m.makespan,
            m.tokens_out, m.preemptions, m.prefix_hit_tokens)


@pytest.mark.parametrize("axes", list(SIM_AXES), ids=list(SIM_AXES))
def test_sim_online_equals_offline(axes):
    """Same arrivals via live submit() == the old batch run(), exactly,
    on every scheduling axis."""
    kw = SIM_AXES[axes]
    a, b = _two_bursts()
    off = ServingSimulator(LLAMA2_7B, L20, SimConfig(**kw)).run(a + b)

    a2, b2 = _two_bursts()
    sim = ServingSimulator(LLAMA2_7B, L20, SimConfig(**kw))
    sess = ServingSession(sim)
    for r in a2:
        sess.submit(r, arrival=r.arrival)
    while sim.step():          # drain burst 1 interactively
        pass
    assert sim.clock() < b2[0].arrival
    for r in b2:               # submitted online, mid-session
        sess.submit(r, arrival=r.arrival)
    sess.drain()
    assert _key(sim.metrics()) == _key(off)


def test_sim_run_is_a_session_wrapper():
    """run() and an explicit submit-everything session are the same
    code path with the same results."""
    reqs = sharegpt_like(30, rate=3.0, seed=11)
    m1 = ServingSimulator(LLAMA2_7B, L20,
                          SimConfig(policy="layerkv", chunked=True)
                          ).run(reqs)
    sim = ServingSimulator(LLAMA2_7B, L20,
                           SimConfig(policy="layerkv", chunked=True))
    sess = ServingSession(sim)
    for r in sharegpt_like(30, rate=3.0, seed=11):
        sess.submit(r, arrival=r.arrival)
    sess.drain()
    assert _key(sim.metrics()) == _key(m1)


# ------------------------------------------------------- session mechanics --

def _sim(**kw):
    return ServingSimulator(LLAMA2_7B, L20, SimConfig(**kw))


def test_stream_yields_every_token_once():
    sim = _sim(policy="layerkv")
    sess = ServingSession(sim)
    h = sess.submit(Request(rid="x", prompt_len=256, output_len=12))
    toks = list(sess.stream(h))
    assert toks == list(range(12))       # sim streams ordinals
    assert h.take_new() == []            # cursor consumed everything
    assert h.finished and not h.cancelled


def test_duplicate_rid_rejected():
    sess = ServingSession(_sim())
    sess.submit(Request(rid="dup", prompt_len=64, output_len=4))
    with pytest.raises(ValueError, match="dup"):
        sess.submit(Request(rid="dup", prompt_len=64, output_len=4))


def test_cancel_pending_arrival_never_runs():
    sim = _sim()
    sess = ServingSession(sim)
    run = sess.submit(Request(rid="a", prompt_len=64, output_len=4))
    parked = sess.submit(Request(rid="b", prompt_len=64, output_len=4),
                         arrival=1e9)
    assert sess.backlog == 2
    assert parked.cancel()
    done = sess.drain()
    assert [r.rid for r in done] == ["a"]
    assert parked.cancelled and parked.request.tokens_out == 0
    assert run.finished


def test_cancel_is_idempotent_and_false_after_finish():
    sim = _sim()
    sess = ServingSession(sim)
    h = sess.submit(Request(rid="a", prompt_len=64, output_len=4))
    assert h.cancel() is True
    assert h.cancel() is False           # already cancelled
    h2 = sess.submit(Request(rid="b", prompt_len=64, output_len=4))
    sess.drain()
    assert h2.finished
    assert h2.cancel() is False          # finished requests stay finished
    assert h2.request.phase is Phase.FINISHED


def test_reap_releases_retained_state():
    """Long-lived sessions: reaping a done handle drops every retained
    reference (handles map + done/cancelled lists), so per-request state
    does not accumulate for the life of the session; the rid becomes
    reusable."""
    sim = _sim()
    sess = ServingSession(sim)
    h = sess.submit(Request(rid="a", prompt_len=64, output_len=4))
    assert sess.reap(h) is None          # not done yet: no-op
    c = sess.submit(Request(rid="c", prompt_len=64, output_len=4))
    c.cancel()
    sess.drain()
    assert sess.reap(h).rid == "a"
    assert sess.reap(c).rid == "c"
    assert not sess.handles and not sim.done and not sim.core.cancelled
    # finish_time is stamped on every cancel path, heap-cancels included
    parked = sess.submit(Request(rid="p", prompt_len=64, output_len=4),
                         arrival=1e12)
    parked.cancel()
    assert parked.request.finish_time >= 0.0
    # a reaped rid can be resubmitted on the same session
    h2 = sess.submit(Request(rid="a", prompt_len=64, output_len=4))
    sess.drain()
    assert h2.finished


def test_backpressure_waits_instead_of_wedging():
    """A temporarily unadmittable request just waits for in-flight work;
    only a PERMANENTLY unservable one raises AdmissionImpossible."""
    sim = _sim(policy="vllm", num_device_blocks=LLAMA2_7B.n_layers * 8)
    sess = ServingSession(sim)
    # two requests that cannot fit together: the second waits (no
    # RuntimeError), admits after the first finishes
    h1 = sess.submit(Request(rid="a", prompt_len=100, output_len=4))
    h2 = sess.submit(Request(rid="b", prompt_len=100, output_len=4))
    done = sess.drain()
    assert len(done) == 2 and h1.finished and h2.finished
    # a request larger than the whole pool can NEVER be served
    big = sess.submit(Request(rid="c", prompt_len=4096, output_len=4))
    with pytest.raises(AdmissionImpossible, match="c"):
        sess.drain()
    assert not big.finished


# ------------------------------------------------------ cancel invariants --

def _baseline(sim):
    bm = sim.bm
    bm.check()
    return (bm.num_free(DEVICE) == bm.pools[DEVICE].num_blocks
            and bm.num_free(HOST) == bm.pools[HOST].num_blocks
            and not bm.live_requests())


def test_cancel_every_phase_restores_baseline():
    """Cancel a request in each lifecycle phase (waiting / mid-prefill
    chunk / decoding with host-resident layers); pool accounting returns
    to baseline and the block manager invariants hold throughout."""
    sim = _sim(policy="layerkv", chunked=True, prefix_cache=True,
               num_device_blocks=2048, num_host_blocks=1 << 14,
               max_prefill_tokens=128)
    sess = ServingSession(sim)
    reqs = shared_prefix(6, rate=100.0, scenario="system_prompt",
                         share_ratio=0.5, prompt_len=640, output_len=64,
                         seed=5)
    hs = [sess.submit(r, arrival=r.arrival) for r in reqs]
    sess.step()
    phases = {h.phase for h in hs}
    assert Phase.PREFILL in phases       # mid-prefill chunk state exists
    assert hs[-1].cancel()               # waiting or just-started
    for _ in range(30):
        sess.step()
    mid = [h for h in hs if h.phase is Phase.DECODE]
    assert mid, "some request must be mid-decode by step 31"
    assert mid[0].cancel()               # decoding, possibly host layers
    sess.drain()
    sim.bm.drop_cache()                  # release retained prefix blocks
    assert _baseline(sim)
    m = sim.metrics()
    assert m.n_cancelled == 2 and m.n_requests == 4


def test_cancel_sharer_keeps_other_sharers_blocks():
    """Cancelling one sharer never frees or migrates the prefix blocks
    another sharer still maps — the survivor decodes to completion."""
    sim = _sim(policy="layerkv", chunked=True, prefix_cache=True,
               num_device_blocks=4096)
    sess = ServingSession(sim)
    reqs = shared_prefix(2, rate=1000.0, scenario="system_prompt",
                         share_ratio=0.8, prompt_len=512, output_len=32,
                         seed=7)
    ha = sess.submit(reqs[0], arrival=0.0)
    sess.step()                          # a prefills and registers first
    hb = sess.submit(reqs[1])            # b arrives online, hits a's prefix
    while not (ha.phase is Phase.DECODE and hb.phase is Phase.DECODE):
        assert sess.step()
    assert hb.request.cached_prompt_len > 0, "b must share a's prefix"
    shared_blocks = [(a.pool, b)
                     for a in sim.bm.tables[hb.rid].values()
                     for b in a.blocks]
    assert ha.cancel()
    sim.bm.check()                       # refcounts consistent post-cancel
    # every block b maps is still pool-allocated (never freed with a)
    for pool, blk in shared_blocks:
        assert blk in sim.bm.pools[pool]._owner
    sess.drain()
    assert hb.finished and hb.request.tokens_out == 32


# Hypothesis property versions of the cancel invariants (random victim /
# timing / axes-arm schedules) live in tests/test_core_properties.py,
# which degrades to a skip on minimal installs without hypothesis.


# ----------------------------------------------------- admission policies --

class _FakeCore:
    def __init__(self, hits):
        self._hits = hits

    def cached_hint(self, r):
        return self._hits.get(r.rid, 0)


def _req(rid, arrival, slo=3.0):
    return Request(rid=rid, prompt_len=64, output_len=8, arrival=arrival,
                   ttft_slo=slo)


def test_fcfs_order_is_identity():
    rs = [_req("a", 0.0), _req("b", 1.0), _req("c", 0.5)]
    assert FCFSAdmission().order(rs, 10.0, _FakeCore({})) == rs


def test_prefix_aware_hits_overtake_within_window():
    """A hit overtakes misses that arrived up to age_frac*ttft_slo before
    it — and NOT misses older than the window (bounded reordering)."""
    pol = PrefixAwareAdmission(age_frac=0.5)   # window = 1.5s at slo 3.0
    old_miss = _req("old", 0.0)
    miss = _req("m", 2.0)
    hit = _req("h", 3.0)
    core = _FakeCore({"h": 128})
    # hit's virtual arrival = 1.5: after old (0.0), before m (2.0)
    assert pol.order([old_miss, miss, hit], 4.0, core) \
        == [old_miss, hit, miss]
    # a miss more than the window ahead is never overtaken: a hit at 2.0
    # (virtual 0.5) stays behind the miss at 0.0
    core2 = _FakeCore({"h": 128})
    assert pol.order([_req("old", 0.0), _req("h", 2.0)], 4.0, core2) \
        == [_req("old", 0.0), _req("h", 2.0)]


def test_prefix_aware_degenerates_to_fcfs_without_hits():
    pol = PrefixAwareAdmission()
    rs = [_req("a", 0.0), _req("b", 1.0), _req("c", 2.0)]
    assert pol.order(rs, 5.0, _FakeCore({})) == rs


def test_prefix_aware_beats_fcfs_under_congestion():
    """The ROADMAP open item, closed: on a congested shared-prefix
    workload with cache-cold traffic mixed in, prefix-aware admission
    beats FCFS mean TTFT — and the aging bound keeps every cache-miss
    request served (no starvation), with bounded extra miss latency."""
    def run(admission):
        reqs = shared_prefix(80, rate=8.0, scenario="system_prompt",
                             share_ratio=0.5, prompt_len=1024,
                             output_len=256, seed=13, unique_frac=0.3)
        sim = _sim(policy="layerkv", chunked=True, prefix_cache=True,
                   admission=admission, admission_age_frac=2.0)
        m = sim.run(reqs)
        miss = [r.ttft for r in sim.done if r.cached_prompt_len == 0]
        return m, miss

    fcfs, fcfs_miss = run("fcfs")
    padm, padm_miss = run("prefix_aware")
    assert fcfs.n_requests == padm.n_requests == 80   # nobody starves
    assert len(padm_miss) == len(fcfs_miss) > 0
    assert padm.mean_ttft < fcfs.mean_ttft            # the headline win
    # bounded miss penalty: the worst miss is not starved into oblivion
    assert max(padm_miss) < 2.0 * max(fcfs_miss)


# ------------------------------------------------------------ real engine --

def _engine(cfg, **kw):
    kw.setdefault("policy", "layerkv")
    kw.setdefault("slo_aware", False)
    kw.setdefault("num_device_blocks", 40)
    return LayerKVEngine(
        cfg, None,
        EngineConfig(num_host_blocks=512, block_size=8, **kw),
        rng=jax.random.PRNGKey(42))


def _workload(cfg, n=4, shared_len=24, seed=0):
    r0 = np.random.RandomState(seed)
    pre = [int(x) for x in r0.randint(0, cfg.vocab_size, shared_len)]
    reqs = []
    for i in range(n):
        sfx = [int(x) for x in
               r0.randint(0, cfg.vocab_size, int(r0.randint(8, 24)))]
        reqs.append(Request(
            rid=f"r{i}", prompt_len=shared_len + len(sfx),
            output_len=int(r0.randint(6, 10)), arrival=float(i) * 1e-6,
            prompt=pre + sfx))
    return reqs


ENGINE_AXES = {
    "vllm_excl": dict(policy="vllm", num_device_blocks=1024),
    "layerkv_excl_slo": dict(slo_aware=True, num_device_blocks=30),
    "layerkv_chunked": dict(chunked=True, chunk_size=16),
    "chunked_prefix": dict(chunked=True, chunk_size=16,
                           prefix_cache=True),
    "chunked_prefix_fused": dict(chunked=True, chunk_size=16,
                                 prefix_cache=True, fused=True),
}


@pytest.mark.slow
@pytest.mark.parametrize("axes", list(ENGINE_AXES), ids=list(ENGINE_AXES))
def test_engine_online_tokens_equal_offline(axes):
    """THE online guarantee: the same requests submitted live —
    mid-session, out of arrival order, interleaved with steps — generate
    exactly the tokens of the old batch run(), on every axis arm."""
    cfg = dataclasses.replace(get_smoke_config("granite-3-2b"),
                              dtype="float32")
    kw = ENGINE_AXES[axes]
    offline = _engine(cfg, **kw).run(_workload(cfg))
    out_off = {r.rid: r.generated for r in offline}

    eng = _engine(cfg, **kw)
    sess = ServingSession(eng)
    reqs = _workload(cfg)
    # half up front (reverse submission order), a few live iterations,
    # then the rest arrives ONLINE while the first half is in flight
    for r in sorted(reqs[:2], key=lambda q: -q.arrival):
        sess.submit(r, arrival=r.arrival)
    for _ in range(2):
        sess.step()
    for r in reqs[2:]:
        sess.submit(r, arrival=r.arrival)
    done = sess.drain()
    assert {r.rid: r.generated for r in done} == out_off


@pytest.mark.slow
def test_engine_cancel_mid_prefill_chunk_bufs_and_sharers():
    """Engine cancellation unwinds mid-prefill chunk state: the cached
    chunk prefix buffers are dropped (the _chunk_bufs lifecycle audit),
    the surviving sharer's tokens match a run where the cancelled
    request never existed, and the pools return to baseline."""
    cfg = dataclasses.replace(get_smoke_config("granite-3-2b"),
                              dtype="float32")
    r0 = np.random.RandomState(1)
    pre = [int(x) for x in r0.randint(0, cfg.vocab_size, 24)]

    def mk(rid, seed, out=8):
        sfx = [int(x) for x in
               np.random.RandomState(seed).randint(0, cfg.vocab_size, 14)]
        return Request(rid=rid, prompt_len=38, output_len=out,
                       prompt=pre + sfx)

    kw = dict(chunked=True, chunk_size=16, prefix_cache=True)
    solo = _engine(cfg, **kw).run([mk("b", 7)])[0].generated

    eng = _engine(cfg, **kw)
    sess = ServingSession(eng)
    ha = sess.submit(mk("a", 3, out=12))
    hb = sess.submit(mk("b", 7))
    sess.step()
    assert ha.phase is Phase.PREFILL     # a is mid-chunk
    assert eng._chunk_bufs               # with live prefix buffers
    assert ha.cancel()
    assert not eng._chunk_bufs           # dropped on the cancel path
    assert list(sess.stream(hb)) == solo
    sess.drain()
    assert eng._chunk_bufs == {}         # and empty after drain
    eng.bm.check()
    eng.bm.drop_cache()
    assert eng.bm.num_free(DEVICE) == eng.bm.pools[DEVICE].num_blocks


@pytest.mark.slow
def test_engine_chunk_bufs_empty_after_plain_drain():
    """Regression (lifecycle audit): a long-lived session that chunks
    many prompts leaves NO entries in _chunk_bufs after drain — entries
    drop on the final chunk of every request."""
    cfg = dataclasses.replace(get_smoke_config("granite-3-2b"),
                              dtype="float32")
    eng = _engine(cfg, chunked=True, chunk_size=16)
    done = eng.run(_workload(cfg, n=5, seed=2))
    assert max(r.n_chunks for r in done) > 1, "workload must chunk"
    assert eng._chunk_bufs == {}
