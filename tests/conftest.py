import os

import pytest

# smoke tests and benches must see ONE device (the dry-run sets its own
# flag in a separate process); keep jax quiet and deterministic
os.environ.setdefault("JAX_PLATFORMS", "cpu")


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_between_modules():
    """Drop compiled executables between test modules. The full suite
    jit-compiles a few hundred distinct signatures; letting them all
    accumulate in one XLA CPU client can crash the native compiler late
    in the run (single-process, single-core containers). Modules rarely
    share shapes, so per-module clearing costs little recompilation."""
    yield
    try:
        import jax
        jax.clear_caches()
    except Exception:  # jax absent or too old — cache growth is its problem
        pass
