import os

# smoke tests and benches must see ONE device (the dry-run sets its own
# flag in a separate process); keep jax quiet and deterministic
os.environ.setdefault("JAX_PLATFORMS", "cpu")


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
