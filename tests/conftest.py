import os

import pytest

# smoke tests and benches must see ONE device (the dry-run sets its own
# flag in a separate process); keep jax quiet and deterministic
os.environ.setdefault("JAX_PLATFORMS", "cpu")


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")


@pytest.fixture(autouse=True)
def _force_sim_sanitizer(monkeypatch):
    """Run every sim-backend test with the KV-accounting sanitizer on:
    the shadow model (src/repro/core/sanitizer.py) then asserts the
    S1-S8 invariants after every scheduler step of every test. The
    config is mutated IN PLACE (not replaced) so tests asserting
    `sim.sim is sc` identity keep holding."""
    from repro.serving.sim import ServingSimulator
    orig = ServingSimulator.__init__

    def patched(self, cfg, hw, sim, *args, **kwargs):
        sim.sanitize = True
        orig(self, cfg, hw, sim, *args, **kwargs)

    patched._orig = orig  # tests that need the unforced ctor restore this
    monkeypatch.setattr(ServingSimulator, "__init__", patched)


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_between_modules():
    """Drop compiled executables between test modules. The full suite
    jit-compiles a few hundred distinct signatures; letting them all
    accumulate in one XLA CPU client can crash the native compiler late
    in the run (single-process, single-core containers). Modules rarely
    share shapes, so per-module clearing costs little recompilation."""
    yield
    try:
        import jax
        jax.clear_caches()
    except Exception:  # jax absent or too old — cache growth is its problem
        pass
