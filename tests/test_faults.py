"""Fault-tolerance subsystem coverage (serving/faults.py + the cluster's
detection/recovery machinery).

Five layers of guarantees:
  * plan mechanics — the `FaultPlan` grammar, seeded generation, and
    validation errors; a plan is immutable and time-ordered;
  * fault-free identity — a cluster with the fault machinery ARMED
    (liveness timeout set, a plan whose events all target a replica the
    cluster doesn't have) produces bit-identical metrics to a cluster
    with no fault arguments at all: every fault code path is
    unreachable until a fault actually fires (lint rule FAULT001);
  * deterministic replay — the same plan over the same workload yields
    a bit-identical recovery log, fault trace, metrics and finish
    order, run after run;
  * lossless recovery — under crash/revive, wedge + liveness kill,
    transient dispatch failure, host exhaustion, slowdown and link
    stall, NO request is lost or duplicated: every stream delivers each
    token exactly once across any number of kills (sim ordinals and
    real engine ids both), and total delivered tokens match the
    fault-free run;
  * graceful degradation — blocked requests shed with TYPED reasons
    (PoolInfeasible / HostPoolExhausted / DispatchFailed) instead of
    wedging, and `SimMetrics.class_report` attributes the degradation
    to the priority classes it actually landed on.

The hypothesis property (random plans x routers x replica counts) lives
in tests/test_core_properties.py.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.llama2_7b import CONFIG as LLAMA2_7B
from repro.core import DEVICE, HOST
from repro.core.sanitizer import SanitizerError
from repro.serving.cluster import ClusterSession
from repro.serving.costmodel import L20
from repro.serving.engine import EngineConfig, LayerKVEngine
from repro.serving.faults import FaultEvent, FaultPlan
from repro.serving.request import Request
from repro.serving.router import PrefixAffinityRouting
from repro.serving.session import ServingSession
from repro.serving.sim import ServingSimulator, SimConfig
from repro.serving.workload import multi_tenant


def _sim(**kw):
    base = dict(policy="layerkv", chunked=True, prefix_cache=True,
                num_device_blocks=2048, num_host_blocks=1 << 14)
    base.update(kw)
    return ServingSimulator(LLAMA2_7B, L20, SimConfig(**base))


def _burst(n=40):
    """Bursty multi-tenant arrivals spanning roughly t=4.5..33s — the
    fault stamps below land squarely inside the busy window."""
    return multi_tenant(n, rate=16.0, n_tenants=3, prompt_len=512,
                        output_len=48, seed=7)


def _cluster(plan=None, n_rep=3, **kw):
    return ClusterSession([_sim() for _ in range(n_rep)],
                          router="round_robin", fault_plan=plan, **kw)


def _pools_at_baseline(cl):
    for s in cl.sessions:
        bm = s.backend.bm
        bm.drop_cache()
        bm.check()
        assert bm.num_free(DEVICE) == bm.pools[DEVICE].num_blocks
        assert bm.num_free(HOST) == bm.pools[HOST].num_blocks
        assert not bm.live_requests()


@pytest.fixture(scope="module")
def baseline():
    """The fault-free reference run every recovery arm is held to."""
    cl = ClusterSession([ServingSimulator(LLAMA2_7B, L20, SimConfig(
        policy="layerkv", chunked=True, prefix_cache=True,
        num_device_blocks=2048, num_host_blocks=1 << 14, sanitize=True))
        for _ in range(3)], router="round_robin")
    done = cl.run(_burst())
    return [r.rid for r in done], cl.metrics()


# ----------------------------------------------------------- plan mechanics --

def test_fault_plan_parse_grammar():
    plan = FaultPlan.parse(
        "crash@0.5:r0:recover=1.0; wedge@0.2:r1:dur=0.3;"
        "slowdown@0.4:r2:dur=0.6:factor=3.5;"
        "host_exhaust@0.7:r0:dur=0.2:blocks=128", n_replicas=3)
    assert len(plan) == 4
    # time-ordered regardless of spec order
    assert [e.t for e in plan.events] == [0.2, 0.4, 0.5, 0.7]
    crash = next(e for e in plan.events if e.kind == "crash")
    assert crash.replica == 0 and crash.recover_after == 1.0
    slow = next(e for e in plan.events if e.kind == "slowdown")
    assert slow.factor == 3.5 and slow.duration == 0.6
    hx = next(e for e in plan.events if e.kind == "host_exhaust")
    assert hx.blocks == 128
    assert any("wedge r1" in line for line in plan.describe())


def test_fault_plan_parse_errors():
    with pytest.raises(ValueError, match="missing '@time'"):
        FaultPlan.parse("crash:r0")
    with pytest.raises(ValueError, match="missing ':rN' replica"):
        FaultPlan.parse("crash@0.5")
    with pytest.raises(ValueError, match="unknown fault option"):
        FaultPlan.parse("crash@0.5:r0:bogus=1")
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultPlan([FaultEvent(0.1, "meteor", 0)])
    with pytest.raises(ValueError, match="before t=0"):
        FaultPlan([FaultEvent(-0.1, "crash", 0)])
    with pytest.raises(ValueError, match="unknown random-plan option"):
        FaultPlan.parse("random:3:zap=1")


def test_fault_plan_random_is_seeded():
    a = FaultPlan.random(7, 3, n_events=5)
    b = FaultPlan.random(7, 3, n_events=5)
    assert a.describe() == b.describe()
    assert a.describe() != FaultPlan.random(8, 3, n_events=5).describe()
    # random crashes always carry a recovery (no permanent sinkholes)
    for e in FaultPlan.random(11, 2, n_events=20, kinds=["crash"]).events:
        assert e.recover_after >= 0
    assert len(FaultPlan.parse("random:7:n=5", n_replicas=3)) == 5


# ------------------------------------------------------- fault-free identity --

def test_armed_but_idle_machinery_is_bit_identical(baseline):
    """Liveness detection armed + a plan whose events all target a
    replica this cluster doesn't have: no fault ever fires, and the
    run is bit-identical to a cluster with no fault arguments."""
    rids, base = baseline
    plan = FaultPlan.parse("crash@1.0:r7:recover=1.0", n_replicas=8)
    cl = _cluster(plan=plan, liveness_timeout=30.0)
    done = cl.run(_burst())
    assert [r.rid for r in done] == rids
    assert cl.metrics() == base
    assert cl.faults.trace == [] and cl.recovery_log == []


# ------------------------------------------------------------ crash recovery --

def test_crash_recovery_lossless(baseline):
    """A replica crash mid-burst: its live work is salvaged, unwound
    (sanitizer S9 holds inside kill()), re-dispatched and finished —
    every request completes, total delivered tokens match the
    fault-free run, and the replica revives cold."""
    rids, base = baseline
    plan = FaultPlan.parse("crash@5.2:r0:recover=2.0", n_replicas=3)
    cl = _cluster(plan=plan)
    done = cl.run(_burst())
    m = cl.metrics()
    assert sorted(r.rid for r in done) == sorted(rids)
    assert m.n_replica_kills == 1 and m.n_replica_recoveries == 1
    assert m.n_redispatched >= 1
    assert m.n_shed == 0
    assert m.tokens_out == base.tokens_out
    # per-request conservation: salvaged + reserved remainder == 48
    assert all(r.tokens_out + r.tokens_salvaged == 48 for r in done)
    assert cl.alive[0], "crash carried recover=2.0; replica must revive"
    assert any("kill r0 (fault)" in line for line in cl.recovery_log)
    assert any("revive r0" in line for line in cl.recovery_log)
    _pools_at_baseline(cl)


def test_crash_recovery_replays_bit_identically():
    """Determinism: the same plan over the same workload produces a
    bit-identical recovery log, fault trace, metrics and finish order."""
    def run():
        plan = FaultPlan.parse(
            "crash@5.2:r0:recover=2.0;dispatch_fail@4.5:r1:dur=2.0",
            n_replicas=3)
        cl = _cluster(plan=plan)
        done = cl.run(_burst())
        return (cl.recovery_log, cl.faults.trace, cl.metrics(),
                [r.rid for r in done])

    log_a, trace_a, m_a, order_a = run()
    log_b, trace_b, m_b, order_b = run()
    assert log_a == log_b and trace_a == trace_b
    assert m_a == m_b and order_a == order_b


def test_manual_kill_and_revive_lossless(baseline):
    """The manual path (operator action, no plan): kill a replica with
    live work, revive it later; nothing is lost and kill is idempotent
    on a corpse."""
    rids, _ = baseline
    cl = _cluster()
    hs = [cl.submit(r, arrival=r.arrival) for r in _burst()]
    while not any(h.replica == 0 and h.request.tokens_out for h in hs):
        assert cl.step()
    cl.kill(0)
    assert not cl.alive[0] and cl.n_kills == 1
    cl.kill(0)                       # idempotent on a dead replica
    assert cl.n_kills == 1
    cl.revive(0)
    assert cl.alive[0] and cl.n_recoveries == 1
    done = cl.drain()
    assert sorted(r.rid for r in done) == sorted(rids)
    assert all(r.tokens_out + r.tokens_salvaged == 48 for r in done)
    _pools_at_baseline(cl)


def test_sim_stream_survives_kill_no_gap_no_duplicate():
    """Stream exactness across a kill: a consumer polling `take_new`
    through a mid-stream replica failure sees each ordinal exactly once
    — the salvaged backlog drains first, then the restarted remainder,
    rebased so 0..23 appears with no gap and no repeat."""
    cl = ClusterSession([_sim() for _ in range(2)], router="round_robin")
    hs = [cl.submit(Request(rid=f"r{i}", prompt_len=256, output_len=24,
                            arrival=0.001 * i), arrival=0.001 * i)
          for i in range(4)]
    streams = {h.rid: [] for h in hs}

    def pump():
        for h in hs:
            streams[h.rid].extend(h.take_new())

    while not any(h.replica == 0 and streams[h.rid] for h in hs):
        assert cl.step()
        pump()
    cl.kill(0)
    pump()
    while cl.step():
        pump()
    cl.drain()
    pump()
    for h in hs:
        assert streams[h.rid] == list(range(24)), h.rid
        assert h.request.tokens_out + h.request.tokens_salvaged == 24
    assert cl.n_kills == 1
    assert any(h.request.n_redispatched for h in hs)


# -------------------------------------------------- wedge / liveness kill ----

def test_wedge_liveness_detection_kills_and_recovers(baseline):
    """A wedged replica is declared dead by MISSING HEARTBEAT (its next
    due event lags the shared clock past the timeout), not by oracle
    knowledge of the injected fault; its work re-dispatches losslessly."""
    rids, base = baseline
    plan = FaultPlan.parse("wedge@5.0:r0:dur=60.0", n_replicas=3)
    cl = _cluster(plan=plan, liveness_timeout=0.5)
    done = cl.run(_burst())
    m = cl.metrics()
    assert sorted(r.rid for r in done) == sorted(rids)
    assert m.n_replica_kills == 1 and m.n_shed == 0
    assert m.tokens_out == base.tokens_out
    assert not cl.alive[0]           # liveness kill carries no revival
    assert any("liveness" in line for line in cl.recovery_log)
    _pools_at_baseline(cl)


def test_wedge_without_liveness_rides_out_the_window(baseline):
    """No detector armed: the cluster waits the wedge out (virtual time
    advances past the window) and still finishes everything — slower,
    never wedged."""
    rids, base = baseline
    plan = FaultPlan.parse("wedge@5.0:r0:dur=3.0", n_replicas=3)
    cl = _cluster(plan=plan)
    done = cl.run(_burst())
    m = cl.metrics()
    assert sorted(r.rid for r in done) == sorted(rids)
    assert m.n_replica_kills == 0 and m.n_shed == 0
    assert m.tokens_out == base.tokens_out
    assert m.makespan >= base.makespan


# ------------------------------------------------ transient dispatch faults --

def test_dispatch_fail_retries_with_backoff_then_succeeds(baseline):
    """A transient dispatch-failure window: affected arrivals retry
    with exponential backoff and ALL eventually land — zero sheds."""
    rids, base = baseline
    plan = FaultPlan.parse("dispatch_fail@4.5:r0:dur=2.0", n_replicas=3)
    cl = _cluster(plan=plan)
    done = cl.run(_burst())
    m = cl.metrics()
    assert sorted(r.rid for r in done) == sorted(rids)
    assert m.n_retries >= 1 and m.n_shed == 0
    assert m.tokens_out == base.tokens_out
    assert any("retry" not in line for line in cl.recovery_log) \
        or cl.recovery_log == []     # retries are counters, not log spam


def test_dispatch_retries_exhaust_to_typed_shed():
    """Bounded retry: a request that cannot dispatch within its budget
    is SHED with the typed DispatchFailed reason — the cluster reports
    it (handle, metrics, class_report) instead of spinning or wedging."""
    plan = FaultPlan.parse("dispatch_fail@0.0:r0:dur=1000.0",
                           n_replicas=1)
    cl = _cluster(plan=plan, n_rep=1, max_dispatch_retries=3,
                  retry_backoff=0.01)
    h = cl.submit(Request(rid="doomed", prompt_len=64, output_len=4,
                          priority=1), arrival=0.5)
    done = cl.drain()
    assert done == [] and h.shed and h.done
    assert h.request.shed_reason == "DispatchFailed"
    m = cl.metrics()
    assert m.n_shed == 1 and m.shed_reasons == ["DispatchFailed"]
    assert m.n_retries == 4          # 3 backoff spins + the final straw
    report = m.class_report()
    assert report[1]["n_shed"] == 1 and report[1]["n_retries"] == 4
    assert cl.reap(h).rid == "doomed"
    assert not cl.shed and not cl.handles


def test_no_live_replica_sheds_after_retry_budget():
    """All replicas dead (manual kill, no plan): arrivals burn their
    retry budget against an empty cluster and shed typed."""
    cl = _cluster(n_rep=1, max_dispatch_retries=2, retry_backoff=0.01)
    cl.kill(0)
    h = cl.submit(Request(rid="a", prompt_len=64, output_len=4))
    cl.drain()
    assert h.shed and h.request.shed_reason == "DispatchFailed"
    assert cl.metrics().n_shed == 1


# --------------------------------------- host exhaustion / slowdown / stall --

def test_host_exhaust_backpressures_losslessly(baseline):
    """The whole host pool vanishes for 3s mid-burst: admission
    backpressures until the window clears, then everything finishes;
    the reserve returns to zero (inert again)."""
    rids, base = baseline
    plan = FaultPlan.parse("host_exhaust@5.0:r0:dur=3.0", n_replicas=3)
    cl = _cluster(plan=plan)
    done = cl.run(_burst())
    m = cl.metrics()
    assert sorted(r.rid for r in done) == sorted(rids)
    assert m.n_replica_kills == 0 and m.n_shed == 0
    assert m.tokens_out == base.tokens_out
    assert all(c.fault_host_reserve == 0 for c in cl.cores)


def test_slowdown_and_link_stall_are_stragglers_not_corpses(baseline):
    """A slowdown stretches the replica's virtual time and a link stall
    reserves its offload channel: both degrade latency, neither loses
    work or triggers recovery."""
    rids, base = baseline
    plan = FaultPlan.parse(
        "slowdown@5.0:r0:dur=3.0:factor=3.0;link_stall@6.0:r1:dur=1.0",
        n_replicas=3)
    cl = _cluster(plan=plan)
    done = cl.run(_burst())
    m = cl.metrics()
    assert sorted(r.rid for r in done) == sorted(rids)
    assert m.n_replica_kills == 0 and m.n_shed == 0
    assert m.tokens_out == base.tokens_out
    assert m.makespan >= base.makespan


# ---------------------------------------------------------- graceful drain ---

def test_drain_replica_graceful_retire(baseline):
    """`drain_replica` re-routes queued work, lets in-flight work
    finish in place (zero recompute — nothing is re-dispatched through
    the restart path), and retires the replica once empty."""
    rids, base = baseline
    cl = _cluster()
    hs = [cl.submit(r, arrival=r.arrival) for r in _burst()]
    while not any(h.replica == 0 and h.request.tokens_out for h in hs):
        assert cl.step()
    cl.drain_replica(0)
    done = cl.drain()
    assert sorted(r.rid for r in done) == sorted(rids)
    assert not cl.alive[0]
    assert cl.metrics().n_redispatched == 0   # graceful != kill
    assert cl.metrics().tokens_out == base.tokens_out
    assert any("drain r0" in line for line in cl.recovery_log)
    assert any("retired r0" in line for line in cl.recovery_log)
    _pools_at_baseline(cl)


# ------------------------------------------------------- template re-homing --

def test_template_rehoming_after_kill():
    """Prefix affinity survives a kill: the hot template's re-dispatched
    requests all land on ONE recovery replica (the first re-dispatch
    records the home, the rest follow it) — never scattered."""
    cl = ClusterSession(
        [_sim() for _ in range(3)],
        router=PrefixAffinityRouting(spill_frac=float("inf")))
    reqs = multi_tenant(24, rate=60.0, n_tenants=1, prompt_len=512,
                        output_len=64, seed=11)
    hs = [cl.submit(r, arrival=r.arrival) for r in reqs]
    while not any(h.replica is not None and h.request.tokens_out
                  for h in hs):
        assert cl.step()
    home = next(h.replica for h in hs if h.replica is not None)
    cl.kill(home)
    done = cl.drain()
    assert len(done) == 24
    redisp = [h for h in hs if h.request.n_redispatched]
    assert redisp, "the kill must have displaced live template work"
    landed = {h.replica for h in redisp}
    assert len(landed) == 1 and home not in landed
    assert set(cl._template_home.values()) == landed


# --------------------------------------------------- graceful degradation ----

def test_shed_overload_pool_infeasible_instead_of_wedge():
    """The test_cluster backpressure scenario, with `shed_overload` on:
    the never-fits request is shed typed (PoolInfeasible) and the drain
    COMPLETES — same workload, no AdmissionImpossible."""
    cl = ClusterSession(
        [_sim(policy="vllm", chunked=False, prefix_cache=False,
              num_device_blocks=LLAMA2_7B.n_layers * 8,
              shed_overload=True)
         for _ in range(2)],
        router="least_loaded")
    ok = [cl.submit(Request(rid=f"r{i}", prompt_len=100, output_len=4))
          for i in range(4)]
    big = cl.submit(Request(rid="huge", prompt_len=4096, output_len=4))
    done = cl.drain()
    assert all(h.finished for h in ok) and len(done) == 4
    assert big.shed and big.request.shed_reason == "PoolInfeasible"
    m = cl.metrics()
    assert m.n_shed == 1 and m.shed_reasons == ["PoolInfeasible"]


def test_shed_reason_host_pool_exhausted_under_fault_pressure():
    """A feasible request starved past its deadline while the host pool
    is fault-reserved sheds with the HostPoolExhausted reason — the
    typed report distinguishes fault pressure from plain infeasibility."""
    sim = _sim(shed_overload=True, shed_grace_frac=0.0)
    sess = ServingSession(sim)
    sess.submit(Request(rid="a", prompt_len=512, output_len=16))
    for _ in range(4):
        sess.step()          # a reaches DECODE before the fault lands
    sim.core.fault_host_reserve = 1 << 14   # injected host pressure
    starved = Request(rid="b", prompt_len=512, output_len=4,
                      ttft_slo=0.001)
    sess.submit(starved)
    done = sess.drain()
    # b's layer-wise prefill offload cannot reach the host pool; once
    # aged past its (tight) deadline it sheds typed — a is untouched
    assert [r.rid for r in done] == ["a"]
    assert starved.shed_reason == "HostPoolExhausted"
    assert [r.rid for r in sim.core.shed] == ["b"]


# ------------------------------------------------------------- sanitizer S9 --

def test_s9_recovery_baseline_detects_leftover_state():
    """The S9 tier is STRICTER than a live full check: any queued
    request or surviving KV table after a kill-unwind is a failure."""
    sim = _sim()
    sess = ServingSession(sim)
    sess.submit(Request(rid="a", prompt_len=64, output_len=4))
    san = sim.core.sanitizer
    assert san is not None
    with pytest.raises(SanitizerError, match="S9 recovery"):
        san.check_recovery_baseline(sim.core)
    sess.drain()
    san.check_recovery_baseline(sim.core)    # clean after drain


# ---------------------------------------------------------------- real engine --

def _engine(cfg, **kw):
    kw.setdefault("policy", "layerkv")
    kw.setdefault("slo_aware", False)
    return LayerKVEngine(
        cfg, None,
        EngineConfig(num_host_blocks=512, block_size=8, **kw),
        rng=jax.random.PRNGKey(42))


def _eng_workload(cfg, n=4, shared_len=24, seed=2):
    r0 = np.random.RandomState(seed)
    pre = [int(x) for x in r0.randint(0, cfg.vocab_size, shared_len)]
    reqs = []
    for i in range(n):
        sfx = [int(x) for x in
               r0.randint(0, cfg.vocab_size, int(r0.randint(8, 24)))]
        reqs.append(Request(
            rid=f"r{i}", prompt_len=shared_len + len(sfx),
            output_len=int(r0.randint(6, 10)), arrival=float(i) * 1e-6,
            prompt=pre + sfx))
    return reqs


@pytest.mark.slow
def test_engine_kill_streams_bit_identical_tokens():
    """Token exactness on the REAL engine: a mid-stream kill folds the
    delivered ids into the prompt, and greedy decode of the remainder
    continues bit-identically — every stream equals a fault-free solo
    run of the same prompt, with no gap and no repeat."""
    cfg = dataclasses.replace(get_smoke_config("granite-3-2b"),
                              dtype="float32")
    kw = dict(chunked=True, chunk_size=16, prefix_cache=True,
              num_device_blocks=1024)
    reference = {}
    for r in _eng_workload(cfg):
        reference[r.rid] = [int(t) for t in
                            _engine(cfg, **kw).run([r])[0].generated]

    cl = ClusterSession([_engine(cfg, **kw) for _ in range(2)],
                        router="round_robin")
    hs = [cl.submit(r, arrival=r.arrival) for r in _eng_workload(cfg)]
    streams = {h.rid: [] for h in hs}

    def pump():
        for h in hs:
            streams[h.rid].extend(h.take_new())

    while not any(h.replica == 0 and streams[h.rid] for h in hs):
        assert cl.step()
        pump()
    cl.kill(0)
    while cl.step():
        pump()
    cl.drain()
    pump()
    assert cl.n_kills == 1
    assert any(h.request.n_redispatched for h in hs)
    for h in hs:
        assert streams[h.rid] == reference[h.rid], h.rid
    for s in cl.sessions:
        s.backend.bm.drop_cache()
        s.backend.bm.check()
        assert s.backend.bm.num_free(DEVICE) == \
            s.backend.bm.pools[DEVICE].num_blocks
