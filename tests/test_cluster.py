"""Cluster serving subsystem coverage.

Five layers of guarantees:
  * cluster-of-1 identity — a `ClusterSession` over ONE backend is
    bit-identical to a bare `ServingSession`: exact metrics on the
    simulator (all five scheduling axes x all four routing policies)
    and exact tokens on the real engine (all five axes; router varied
    across arms). Routing policies are read-only observers of the
    scheduler cores, and this is the test that pins it;
  * losslessness — no request is lost or duplicated under ANY routing
    policy with cancellation mixed in (seeded random routing+cancel
    schedules here; the hypothesis property lives in
    tests/test_core_properties.py, which degrades to a skip on minimal
    installs);
  * prefix_affinity mechanics — template rendezvous, load-based
    spillover under a hot template, promptless fallback;
  * cross-replica cancellation — cancel routes to the owning replica,
    unwinds through the PR 4 path, and pre-dispatch cancels never touch
    a replica;
  * metrics pooling — `SimMetrics.merge` concatenates raw series and
    recomputes percentiles over the pool (hand-computed ranks; never
    the average of per-replica p99s).
"""
import dataclasses
import random

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.llama2_7b import CONFIG as LLAMA2_7B
from repro.core import DEVICE, HOST
from repro.serving.cluster import ClusterSession
from repro.serving.costmodel import L20
from repro.serving.engine import EngineConfig, LayerKVEngine
from repro.serving.request import Request
from repro.serving.router import (
    ROUTING_POLICIES, PrefixAffinityRouting, RoundRobinRouting,
    make_routing_policy,
)
from repro.serving.scheduler import AdmissionImpossible
from repro.serving.session import ServingSession
from repro.serving.sim import ServingSimulator, SimConfig, SimMetrics
from repro.serving.workload import multi_tenant, shared_prefix

ALL_ROUTERS = sorted(ROUTING_POLICIES)


def _sim(**kw):
    return ServingSimulator(LLAMA2_7B, L20, SimConfig(**kw))


# ------------------------------------------------------------ router seam --

def test_make_routing_policy():
    assert make_routing_policy("round_robin").name == "round_robin"
    # instances are fresh (round_robin's cursor is stateful)
    assert make_routing_policy("round_robin") is not \
        make_routing_policy("round_robin")
    pol = PrefixAffinityRouting(spill_frac=0.1)
    assert make_routing_policy(pol) is pol
    with pytest.raises(ValueError, match="mystery"):
        make_routing_policy("mystery")


def test_round_robin_stripes():
    pol = RoundRobinRouting()
    cores = [None, None, None]
    r = Request(rid="r", prompt_len=8, output_len=1)
    assert [pol.choose(r, cores, 0.0) for _ in range(6)] \
        == [0, 1, 2, 0, 1, 2]


def test_load_stats_counts_demand():
    sim = _sim(policy="vllm", num_device_blocks=LLAMA2_7B.n_layers * 64)
    sess = ServingSession(sim)
    ls0 = sim.core.load_stats()
    assert ls0.kv_demand == 0 and ls0.occupancy == 0.0
    sess.submit(Request(rid="a", prompt_len=64, output_len=4))
    ls1 = sim.core.load_stats()
    # vllm policy: the queued request needs blocks for ALL layers
    assert ls1.n_waiting == 1
    assert ls1.queued_blocks == \
        sim.bm.blocks_for_tokens(64) * LLAMA2_7B.n_layers
    sess.step()          # prefill admitted: demand moves queued -> active
    ls2 = sim.core.load_stats()
    assert ls2.n_waiting == 0 and ls2.n_inflight == 1
    assert ls2.active_blocks > 0 and ls2.occupancy > 0.0
    sess.drain()
    assert sim.core.load_stats().kv_demand == 0


def test_load_stats_token_demand():
    """`token_demand` tracks outstanding compute: the queued prefill
    suffix before admission, prompt+generated context once in flight,
    zero after drain."""
    sim = _sim(num_device_blocks=LLAMA2_7B.n_layers * 64)
    sess = ServingSession(sim)
    assert sim.core.load_stats().token_demand == 0
    sess.submit(Request(rid="a", prompt_len=64, output_len=4))
    ls1 = sim.core.load_stats()
    assert ls1.queued_tokens == 64 and ls1.active_tokens == 0
    sess.step()
    ls2 = sim.core.load_stats()
    assert ls2.queued_tokens == 0 and ls2.active_tokens >= 64
    sess.drain()
    assert sim.core.load_stats().token_demand == 0


def test_route_by_tokens_rekeys_least_loaded():
    """The `route_by_tokens` knob re-keys least_loaded dispatch on
    token demand. Replica 0 carries the bigger BLOCK demand, replica 1
    the bigger TOKEN demand — the two keys disagree, and the knob picks
    which one wins. Default (off) is the paper's block-demand JSQ."""
    from repro.serving.router import LeastLoadedRouting, _least
    from repro.serving.scheduler import LoadStats

    blocky = LoadStats(n_waiting=1, n_inflight=0, queued_blocks=100,
                       active_blocks=0, free_blocks=10, total_blocks=10,
                       queued_tokens=10, active_tokens=0)
    tokeny = LoadStats(n_waiting=1, n_inflight=0, queued_blocks=10,
                       active_blocks=0, free_blocks=10, total_blocks=10,
                       queued_tokens=800, active_tokens=0)
    assert _least([blocky, tokeny]) == 1           # blocks: replica 0 worse
    assert _least([blocky, tokeny], by_tokens=True) == 0

    # end-to-end: the policy reads the knob off the cores it routes over
    off, on = _sim(), _sim(route_by_tokens=True)
    assert off.core.sc.route_by_tokens is False    # default stays off
    pol = make_routing_policy("least_loaded")
    assert isinstance(pol, LeastLoadedRouting)
    r = Request(rid="probe", prompt_len=32, output_len=4)
    # two idle replicas: both keys tie, lowest index wins either way
    assert pol.choose(r, [off.core, off.core], 0.0) == 0
    assert pol.choose(r, [on.core, on.core], 0.0) == 0


def test_admit_eta_orders_by_backlog():
    """A replica with queued prefill work reports a later admission ETA
    than an empty one — the slo_aware router's ranking key."""
    idle, busy = _sim(), _sim()
    ServingSession(idle)
    bsess = ServingSession(busy)
    for i in range(4):
        bsess.submit(Request(rid=f"q{i}", prompt_len=2048, output_len=64))
    r = Request(rid="new", prompt_len=512, output_len=32)
    assert busy.core.admit_eta(r, 0.0) > idle.core.admit_eta(r, 0.0) >= 0.0


# --------------------------------------------- cluster-of-1 identity (sim) --

SIM_AXES = {
    "vllm_excl": dict(policy="vllm"),
    "layerkv_excl_slo": dict(policy="layerkv", slo_aware=True),
    "layerkv_chunked": dict(policy="layerkv", chunked=True),
    "chunked_prefix": dict(policy="layerkv", chunked=True,
                           prefix_cache=True),
    "chunked_prefix_fused": dict(policy="layerkv", chunked=True,
                                 prefix_cache=True, fused=True),
}


def _mixed_burst(n=30):
    return shared_prefix(n, rate=4.0, scenario="rag_template",
                         share_ratio=0.5, prompt_len=512, output_len=64,
                         seed=3)


@pytest.mark.parametrize("axes", list(SIM_AXES), ids=list(SIM_AXES))
def test_cluster_of_one_identity_sim(axes):
    """THE identity guarantee, metrics side: a 1-replica cluster
    reproduces the bare session's SimMetrics exactly (full dataclass
    equality — every raw series, counter and stamp) on every scheduling
    axis, under every routing policy. Pins that policies never perturb
    the schedule they observe."""
    kw = SIM_AXES[axes]
    bare = _sim(**kw)
    bare.run(_mixed_burst())
    base = bare.metrics()
    for router in ALL_ROUTERS:
        cl = ClusterSession([_sim(**kw)], router=router)
        done = cl.run(_mixed_burst())
        assert cl.metrics() == base, f"router={router}"
        assert [r.rid for r in done] == [r.rid for r in bare.done]


@pytest.mark.parametrize("axes", list(SIM_AXES), ids=list(SIM_AXES))
def test_cluster_of_one_identity_online_submission(axes):
    """Identity also holds for live mid-session submission (the online
    path: some arrivals submitted after the cluster has advanced)."""
    kw = SIM_AXES[axes]
    reqs = _mixed_burst()
    bare = _sim(**kw)
    bare.run([dataclasses.replace(r) for r in reqs])

    cl = ClusterSession([_sim(**kw)], router="least_loaded")
    for r in reqs[: len(reqs) // 2]:
        cl.submit(r, arrival=r.arrival)
    for _ in range(5):
        cl.step()
    for r in reqs[len(reqs) // 2:]:
        cl.submit(r, arrival=r.arrival)
    cl.drain()
    assert cl.metrics() == bare.metrics()


# ------------------------------------------------------------ losslessness --

@pytest.mark.parametrize("router", ALL_ROUTERS)
def test_no_request_lost_or_duplicated(router):
    """Seeded random schedules (the hypothesis property in
    test_core_properties.py fuzzes further): under every routing policy,
    with cancels landing in every phase, each submitted request ends up
    EXACTLY once across replica done/cancelled lists + the cluster's
    pre-dispatch cancel list, and every replica pool returns to
    baseline."""
    for seed in range(3):
        rng = random.Random(seed)
        n_rep = rng.choice([2, 3])
        cl = ClusterSession(
            [_sim(policy="layerkv", chunked=True, prefix_cache=True,
                  num_device_blocks=2048, num_host_blocks=1 << 14)
             for _ in range(n_rep)],
            router=router)
        reqs = multi_tenant(14, rate=40.0, n_tenants=3, prompt_len=320,
                            output_len=32, seed=seed)
        hs = [cl.submit(r, arrival=r.arrival) for r in reqs]
        victims = rng.sample(hs, 4)
        for v in victims:
            for _ in range(rng.randrange(12)):
                cl.step()
            v.cancel()
        cl.drain()
        done = [r for s in cl.sessions for r in s.core.done]
        cncl = [r for s in cl.sessions for r in s.core.cancelled] \
            + cl.cancelled
        seen = sorted(r.rid for r in done) + sorted(r.rid for r in cncl)
        assert sorted(seen) == sorted(r.rid for r in reqs), \
            f"lost/duplicated under {router} seed {seed}"
        assert all(h.done for h in hs)
        assert cl.metrics().n_cancelled == len(cncl)
        for s in cl.sessions:
            bm = s.backend.bm
            bm.drop_cache()
            bm.check()
            assert bm.num_free(DEVICE) == bm.pools[DEVICE].num_blocks
            assert bm.num_free(HOST) == bm.pools[HOST].num_blocks
            assert not bm.live_requests()


# -------------------------------------------------- prefix affinity --------

def _hot_template(n=24, rate=60.0):
    """One tenant only: every prompt shares the same hot template."""
    return multi_tenant(n, rate=rate, n_tenants=1, prompt_len=512,
                        output_len=64, seed=11)


def test_prefix_affinity_rendezvous_concentrates():
    """Without load pressure (huge spill threshold) every request of a
    template rendezvouses on ONE replica — including the very first
    requests, before anything is registered (hash-chain fallback)."""
    cl = ClusterSession(
        [_sim(policy="layerkv", chunked=True, prefix_cache=True)
         for _ in range(3)],
        router=PrefixAffinityRouting(spill_frac=float("inf")))
    cl.run(_hot_template())
    assert sorted(s.dispatched for s in cl.stats) == [0, 0, 24]


def test_prefix_affinity_spillover_under_hot_template():
    """The spillover threshold: when the affinity replica's KV-block
    backlog exceeds spill_frac of its pool, the hot template spills to
    the least-loaded replica instead of hotspotting — and total service
    is still lossless."""
    def run(spill_frac):
        cl = ClusterSession(
            [_sim(policy="layerkv", chunked=True, prefix_cache=True,
                  num_device_blocks=4096)
             for _ in range(3)],
            router=PrefixAffinityRouting(spill_frac=spill_frac))
        done = cl.run(_hot_template())
        assert len(done) == 24
        return [s.dispatched for s in cl.stats], cl.metrics()

    sticky, m_sticky = run(float("inf"))
    spill, m_spill = run(0.02)
    assert sum(1 for d in sticky if d > 0) == 1
    assert sum(1 for d in spill if d > 0) >= 2, \
        "a congested hot template must spill off its home replica"
    # spilling relieves the hotspot's queueing delay
    assert m_spill.mean_ttft < m_sticky.mean_ttft


def test_prefix_affinity_promptless_falls_back_to_least_loaded():
    """Requests without token ids (length-only sim workloads) cannot
    rendezvous; they route by load instead of crashing or defaulting to
    replica 0 forever."""
    cl = ClusterSession([_sim() for _ in range(2)],
                        router="prefix_affinity")
    for i in range(6):
        cl.submit(Request(rid=f"r{i}", prompt_len=256, output_len=8))
    done = cl.drain()
    assert len(done) == 6
    assert all(s.dispatched > 0 for s in cl.stats)


# ------------------------------------------------------- cancellation ------

def test_cross_replica_cancel_unwind():
    """Cancellation routes to the owning replica: cancelling a request
    mid-flight on replica A never disturbs replica B's in-flight work,
    and A's pools return to baseline while B's survivor finishes."""
    cl = ClusterSession(
        [_sim(policy="layerkv", chunked=True, prefix_cache=True,
              num_device_blocks=2048, num_host_blocks=1 << 14)
         for _ in range(2)],
        router="round_robin")
    reqs = shared_prefix(4, rate=100.0, scenario="system_prompt",
                         share_ratio=0.5, prompt_len=640, output_len=64,
                         seed=5)
    hs = [cl.submit(r, arrival=r.arrival) for r in reqs]
    while not all(h._inner is not None for h in hs):
        assert cl.step()
    for _ in range(8):
        cl.step()
    assert {h.replica for h in hs} == {0, 1}  # round_robin spread them
    victim = next(h for h in hs if h.replica == 0)
    assert victim.cancel()
    assert victim.cancelled
    assert victim.request in cl.sessions[0].core.cancelled
    assert not cl.sessions[1].core.cancelled  # B untouched
    done = cl.drain()
    assert sorted(r.rid for r in done) == \
        sorted(h.rid for h in hs if h is not victim)
    for s in cl.sessions:
        s.backend.bm.drop_cache()
        s.backend.bm.check()
        assert s.backend.bm.num_free(DEVICE) == \
            s.backend.bm.pools[DEVICE].num_blocks


def test_cancel_before_dispatch_never_touches_a_replica():
    """A future-arrival request cancelled before the shared clock
    reaches it is unwound inside the cluster: no replica session ever
    sees it, and metrics still count the cancellation."""
    cl = ClusterSession([_sim() for _ in range(2)], router="round_robin")
    run = cl.submit(Request(rid="a", prompt_len=64, output_len=4))
    parked = cl.submit(Request(rid="b", prompt_len=64, output_len=4),
                       arrival=1e9)
    assert parked._inner is None
    assert parked.cancel()
    assert parked.cancel() is False          # idempotent
    assert parked.request.finish_time >= 0.0
    done = cl.drain()
    assert [r.rid for r in done] == ["a"] and run.finished
    assert all(not s.core.cancelled for s in cl.sessions)
    assert cl.metrics().n_cancelled == 1
    assert cl.reap(parked).rid == "b"
    assert cl.reap(run).rid == "a"
    assert not cl.handles and not cl.cancelled


# --------------------------------------------------- session mechanics -----

def test_duplicate_rid_rejected_cluster_wide():
    cl = ClusterSession([_sim(), _sim()], router="round_robin")
    cl.submit(Request(rid="dup", prompt_len=64, output_len=4))
    with pytest.raises(ValueError, match="dup"):
        # round_robin would have sent it to the OTHER replica; the rid
        # namespace is still cluster-global
        cl.submit(Request(rid="dup", prompt_len=64, output_len=4))


def test_cluster_stream_yields_every_token_once():
    cl = ClusterSession([_sim(), _sim()], router="least_loaded")
    other = cl.submit(Request(rid="x", prompt_len=256, output_len=8))
    h = cl.submit(Request(rid="y", prompt_len=256, output_len=12))
    toks = list(cl.stream(h))
    assert toks == list(range(12))       # sim streams ordinals
    assert h.take_new() == []
    cl.drain()
    assert other.finished


def test_cluster_backpressure_and_wedge():
    """A request no replica can EVER fit raises AdmissionImpossible from
    the owning replica at drain; other replicas drain first."""
    cl = ClusterSession(
        [_sim(policy="vllm", num_device_blocks=LLAMA2_7B.n_layers * 8)
         for _ in range(2)],
        router="least_loaded")
    ok = [cl.submit(Request(rid=f"r{i}", prompt_len=100, output_len=4))
          for i in range(4)]
    big = cl.submit(Request(rid="huge", prompt_len=4096, output_len=4))
    with pytest.raises(AdmissionImpossible, match="huge"):
        cl.drain()
    assert all(h.finished for h in ok)   # the wedge stalls nobody else
    assert not big.finished


def test_wedged_replica_does_not_freeze_future_dispatch():
    """Liveness: a wedged replica's frozen clock must not gate the
    dispatch of parked FUTURE arrivals — they dispatch when they become
    the earliest LIVE event, land on the healthy replica (least_loaded
    sees the wedged queue's block demand), and the wedge itself still
    surfaces at drain."""
    cl = ClusterSession(
        [_sim(policy="vllm", num_device_blocks=LLAMA2_7B.n_layers * 8)
         for _ in range(2)],
        router="least_loaded")
    big = cl.submit(Request(rid="huge", prompt_len=4096, output_len=4))
    ok = [cl.submit(Request(rid=f"r{i}", prompt_len=100, output_len=4),
                    arrival=0.5 + 0.01 * i)
          for i in range(3)]
    with pytest.raises(AdmissionImpossible, match="huge"):
        cl.drain()
    assert all(h.finished for h in ok), \
        "future arrivals starved behind the wedged replica's clock"
    assert not big.finished


def test_heterogeneous_pool_geometry():
    """Replicas need not be identical: a cluster over one big and one
    tiny replica serves a mixed workload, with the big prompts landing
    where they fit (least_loaded counts blocks, not requests)."""
    big = _sim(policy="vllm", num_device_blocks=LLAMA2_7B.n_layers * 64)
    tiny = _sim(policy="vllm", num_device_blocks=LLAMA2_7B.n_layers * 8)
    cl = ClusterSession([tiny, big], router="least_loaded")
    done = cl.run([Request(rid=f"r{i}", prompt_len=800, output_len=4,
                           arrival=0.01 * i) for i in range(3)])
    assert len(done) == 3
    # 800 tokens never fits tiny's 8-blocks-per-layer pool
    assert cl.stats[0].dispatched == 0 and cl.stats[1].dispatched == 3


# ------------------------------------------------------- metrics pooling ---

def _metrics(ttft, **kw):
    base = dict(ttft=ttft, queuing=[0.0] * len(ttft),
                prefill_lat=[0.1] * len(ttft), tpot=[0.05] * len(ttft),
                finish_times=list(ttft), tokens_out=10 * len(ttft),
                makespan=max(ttft, default=0.0), slo_violations=0,
                n_requests=len(ttft), preemptions=0)
    base.update(kw)
    return SimMetrics(**base)


def test_merge_pools_raw_series_hand_computed():
    """Hand-computed pooled ranks: replica A has 49 fast requests, B has
    one disastrous straggler. Pooled nearest-rank p99 over the 50-sample
    pool is the ceil(0.99*50) = 50th smallest — the straggler itself —
    while the average of per-replica p99s ((0.49 + 50)/2 = 25.245) hides
    half of it. merge() must produce the pooled rank."""
    a = _metrics([0.010 * (i + 1) for i in range(49)])
    b = _metrics([50.0], makespan=50.0)
    assert a.p99_ttft == pytest.approx(0.49)  # ceil(0.99*49)=49th of A
    m = SimMetrics.merge([a, b])
    assert m.n_requests == 50
    assert m.p99_ttft == 50.0                 # pooled rank: the straggler
    assert (a.p99_ttft + b.p99_ttft) / 2 == pytest.approx(25.245)
    # pooled mean = (sum_a + 50) / 50, computed by hand:
    # sum_a = 0.01 * 49*50/2 = 12.25
    assert m.mean_ttft == pytest.approx((12.25 + 50.0) / 50)
    assert m.makespan == 50.0 and m.tokens_out == 500


def test_merge_counters_and_empty():
    a = _metrics([1.0], preemptions=2, slo_violations=1,
                 prefix_hit_tokens=10, prefix_lookup_tokens=20,
                 chunk_iters=3, max_iter_prefill_tokens=64, n_cancelled=1)
    b = _metrics([2.0], preemptions=1, prefix_hit_tokens=5,
                 prefix_lookup_tokens=5, chunk_iters=4,
                 max_iter_prefill_tokens=32, n_cancelled=2)
    m = SimMetrics.merge([a, b])
    assert m.preemptions == 3 and m.slo_violations == 1
    assert m.prefix_hit_tokens == 15 and m.prefix_lookup_tokens == 25
    assert m.chunk_iters == 7 and m.max_iter_prefill_tokens == 64
    assert m.n_cancelled == 3
    empty = SimMetrics.merge([])
    assert empty.n_requests == 0 and empty.makespan == 0.0
    assert empty.mean_ttft == 0.0 and empty.p99_ttft == 0.0
    # single-part merge is the identity (the cluster-of-1 guarantee
    # leans on this)
    assert SimMetrics.merge([a]) == a


# ------------------------------------------------------------ real engine --

def _engine(cfg, **kw):
    kw.setdefault("policy", "layerkv")
    kw.setdefault("slo_aware", False)
    kw.setdefault("num_device_blocks", 40)
    return LayerKVEngine(
        cfg, None,
        EngineConfig(num_host_blocks=512, block_size=8, **kw),
        rng=jax.random.PRNGKey(42))


def _workload(cfg, n=4, shared_len=24, seed=0):
    r0 = np.random.RandomState(seed)
    pre = [int(x) for x in r0.randint(0, cfg.vocab_size, shared_len)]
    reqs = []
    for i in range(n):
        sfx = [int(x) for x in
               r0.randint(0, cfg.vocab_size, int(r0.randint(8, 24)))]
        reqs.append(Request(
            rid=f"r{i}", prompt_len=shared_len + len(sfx),
            output_len=int(r0.randint(6, 10)), arrival=float(i) * 1e-6,
            prompt=pre + sfx))
    return reqs


# each axes arm exercises a different router, so the engine identity
# sweep covers all four policies without quadrupling its (slow) runtime
ENGINE_AXES = {
    "vllm_excl": (dict(policy="vllm", num_device_blocks=1024),
                  "round_robin"),
    "layerkv_excl_slo": (dict(slo_aware=True, num_device_blocks=30),
                         "least_loaded"),
    "layerkv_chunked": (dict(chunked=True, chunk_size=16), "slo_aware"),
    "chunked_prefix": (dict(chunked=True, chunk_size=16,
                            prefix_cache=True), "prefix_affinity"),
    "chunked_prefix_fused": (dict(chunked=True, chunk_size=16,
                                  prefix_cache=True, fused=True),
                             "prefix_affinity"),
}


@pytest.mark.slow
@pytest.mark.parametrize("axes", list(ENGINE_AXES), ids=list(ENGINE_AXES))
def test_cluster_of_one_engine_tokens_identical(axes):
    """THE identity guarantee, token side: a 1-replica cluster generates
    exactly the bare engine's tokens on every scheduling axis."""
    cfg = dataclasses.replace(get_smoke_config("granite-3-2b"),
                              dtype="float32")
    kw, router = ENGINE_AXES[axes]
    bare = _engine(cfg, **kw).run(_workload(cfg))
    out = {r.rid: r.generated for r in bare}
    cl = ClusterSession([_engine(cfg, **kw)], router=router)
    done = cl.run(_workload(cfg))
    assert {r.rid: r.generated for r in done} == out


@pytest.mark.slow
def test_two_engine_replicas_cancel_and_tokens():
    """Two real-engine replicas with identical weights: every surviving
    request's tokens match a solo run of the same prompt (dispatch
    never changes what a replica computes), a cross-replica cancel
    unwinds cleanly, and both pools drain to baseline."""
    cfg = dataclasses.replace(get_smoke_config("granite-3-2b"),
                              dtype="float32")
    kw = dict(chunked=True, chunk_size=16, prefix_cache=True)
    solo = {}
    for r in _workload(cfg, n=5, seed=2):
        solo[r.rid] = _engine(cfg, **kw).run([r])[0].generated

    cl = ClusterSession([_engine(cfg, **kw), _engine(cfg, **kw)],
                        router="round_robin")
    hs = [cl.submit(r, arrival=r.arrival)
          for r in _workload(cfg, n=5, seed=2)]
    for _ in range(3):
        cl.step()
    victim = hs[-1]
    assert victim.cancel()
    done = cl.drain()
    assert len(done) == 4
    assert {h.replica for h in hs if h is not victim} == {0, 1}
    for r in done:
        assert r.generated == solo[r.rid]
    for s in cl.sessions:
        s.backend.bm.drop_cache()
        s.backend.bm.check()
        assert s.backend.bm.num_free(DEVICE) == \
            s.backend.bm.pools[DEVICE].num_blocks
