"""repro-lint framework tests: each rule trips on exactly its known-bad
corpus twin and stays quiet on the known-good one, the suppression
machinery works (and rejects undocumented/stale suppressions), and the
real tree is clean at HEAD."""
import re
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]
RUN = REPO / "tools" / "analyze" / "run.py"
CORPUS = REPO / "tests" / "lint_corpus"


def lint(*paths):
    proc = subprocess.run(
        [sys.executable, str(RUN), *[str(p) for p in paths]],
        capture_output=True, text=True, cwd=REPO)
    return proc.returncode, proc.stdout


def rule_hits(out, rule_id):
    return [ln for ln in out.splitlines() if f" {rule_id} " in ln]


CASES = [
    # (rule id, bad target, good target, expected hit count,
    #  expected 1-based lines)
    ("PL001", CORPUS / "pl001" / "kernels" / "bad_kernel.py",
     CORPUS / "pl001" / "kernels" / "good_kernel.py", 3, (11, 14, 17)),
    ("JIT001", CORPUS / "jit001" / "bad",
     CORPUS / "jit001" / "good", 3, (26, 27, 29)),
    ("SEAM001", CORPUS / "seam001" / "bad_policy.py",
     CORPUS / "seam001" / "good_policy.py", 3, (15, 17, 18)),
    ("CFG001", CORPUS / "cfg001" / "bad",
     CORPUS / "cfg001" / "good", 2, (11, 13)),
    ("PHASE001", CORPUS / "phase001" / "bad",
     CORPUS / "phase001" / "good", 2, (14, 24)),
    ("FAULT001", CORPUS / "fault001" / "bad.py",
     CORPUS / "fault001" / "good.py", 3, (13, 17, 21)),
    ("OBS001", CORPUS / "obs001" / "serving" / "bad.py",
     CORPUS / "obs001" / "serving" / "good.py", 3, (12, 16, 21)),
    ("UNIT001", CORPUS / "unit001" / "bad" / "accounting.py",
     CORPUS / "unit001" / "good" / "accounting.py", 3, (14, 18, 22)),
    ("MC001", CORPUS / "mc001" / "bad" / "scheduler.py",
     CORPUS / "mc001" / "good" / "scheduler.py", 6,
     (60, 61, 61, 61, 61, 79)),
]


@pytest.mark.parametrize(
    "rule_id,bad,good,count,lines", CASES,
    ids=[c[0].lower() for c in CASES])
def test_rule_trips_on_bad_quiet_on_good(rule_id, bad, good, count,
                                         lines):
    rc, out = lint(bad)
    assert rc == 1
    hits = rule_hits(out, rule_id)
    assert len(hits) == count, out
    # exactly the targeted rule fires — nothing else in the corpus file
    assert len(out.splitlines()) == count, out
    got_lines = tuple(
        int(re.search(r":(\d+): ", h).group(1)) for h in hits)
    assert got_lines == lines, out

    rc, out = lint(good)
    assert rc == 0
    assert out == "", out


def test_head_is_clean():
    """The acceptance gate: repro-lint over the real tree — source,
    benchmarks, tooling and tests — exits 0 (corpus twins excluded by
    the directory walk)."""
    rc, out = lint(REPO / "src", REPO / "benchmarks",
                   REPO / "tools", REPO / "tests")
    assert rc == 0, out


def test_list_rules_names_all_nine():
    proc = subprocess.run(
        [sys.executable, str(RUN), "--list-rules"],
        capture_output=True, text=True, cwd=REPO)
    listed = {ln.split()[0] for ln in proc.stdout.splitlines()}
    assert {"PL001", "JIT001", "SEAM001", "CFG001", "PHASE001",
            "FAULT001", "OBS001", "UNIT001", "MC001"} <= listed


def test_model_checker_is_deterministic():
    """Two uncached runs over the known-bad twin produce byte-identical
    reports: BFS order, dedup and traces are all deterministic."""
    bad = CORPUS / "mc001" / "bad" / "scheduler.py"
    runs = [lint("--no-cache", bad) for _ in range(2)]
    assert runs[0] == runs[1]
    assert runs[0][0] == 1


def test_github_format_and_json():
    bad = CORPUS / "unit001" / "bad" / "accounting.py"
    rc, out = lint("--format=github", bad)
    assert rc == 1
    first = out.splitlines()[0]
    assert first.startswith("::error file=") and ",line=14," in first \
        and "title=UNIT001" in first
    rc, out = lint("--json", bad)
    assert rc == 1
    import json
    hits = json.loads(out)
    assert [h["line"] for h in hits] == [14, 18, 22]
    assert all(h["rule"] == "UNIT001" for h in hits)


def test_result_cache_warm_run_identical(tmp_path):
    """A warm (fully cached) run reports exactly what the cold run did;
    touching the file invalidates its entry."""
    import shutil
    f = tmp_path / "kernels" / "k.py"
    f.parent.mkdir()
    shutil.copy(CORPUS / "pl001" / "kernels" / "bad_kernel.py", f)
    cold = lint(f)
    warm = lint(f)
    assert cold == warm and cold[0] == 1
    # edit the file: the stale entry must not be served
    f.write_text("x = 1\n")
    rc, out = lint(f)
    assert rc == 0 and out == ""


# ------------------------------------------------- suppression machinery --

BAD_WHEN = """\
from jax.experimental import pallas as pl


def kernel(o_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        {line}
"""


def _kernel_file(tmp_path, body_line):
    d = tmp_path / "kernels"
    d.mkdir()
    f = d / "k.py"
    f.write_text(BAD_WHEN.format(line=body_line))
    return f


def test_inline_suppression_with_reason_silences(tmp_path):
    f = _kernel_file(
        tmp_path,
        "o_ref[0] = pl.program_id(1)  "
        "# repro-lint: disable=PL001 -- corpus: proving suppression")
    rc, out = lint(f)
    assert rc == 0, out


def test_comment_block_above_suppresses(tmp_path):
    f = _kernel_file(
        tmp_path,
        "# repro-lint: disable=PL001 -- block-comment form\n"
        "        # (second comment line of the same block)\n"
        "        o_ref[0] = pl.program_id(1)")
    rc, out = lint(f)
    assert rc == 0, out


def test_suppression_without_reason_is_rejected(tmp_path):
    f = _kernel_file(
        tmp_path,
        "o_ref[0] = pl.program_id(1)  # repro-lint: disable=PL001")
    rc, out = lint(f)
    assert rc == 1
    assert rule_hits(out, "LINT000"), out
    assert rule_hits(out, "PL001"), out  # and the hit still reports


def test_unused_suppression_is_flagged(tmp_path):
    f = _kernel_file(
        tmp_path,
        "o_ref[0] = i  # repro-lint: disable=PL001 -- nothing here")
    rc, out = lint(f)
    assert rc == 1
    assert rule_hits(out, "LINT001"), out


def test_file_level_suppression(tmp_path):
    f = _kernel_file(
        tmp_path,
        "o_ref[0] = pl.program_id(1)")
    f.write_text("# repro-lint: file-disable=PL001 -- corpus file\n"
                 + f.read_text())
    rc, out = lint(f)
    assert rc == 0, out
