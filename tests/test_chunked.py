"""Chunked-prefill coverage (the third axis of the scheduling matrix).

Three layers of guarantees:
  * simulator invariants — the per-iteration token budget bounds chunk
    work (no decode starvation), and at high load chunked TTFT is no
    worse than the exclusive-prefill step semantics;
  * engine losslessness — with chunking on, generated tokens match the
    unchunked engine exactly, in all three scheduling modes (vllm,
    layerkv exclusive, layerkv chunked) and under tight pools that force
    real offload/reload traffic mid-prefill;
  * `interleave_offload_layers` edge cases under per-chunk admission.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.llama2_7b import CONFIG as LLAMA2_7B
from repro.core import interleave_offload_layers
from repro.serving.costmodel import L20, CostModel
from repro.serving.engine import EngineConfig, LayerKVEngine
from repro.serving.request import Request
from repro.serving.sim import ServingSimulator, SimConfig
from repro.serving.workload import sharegpt_like

RATE = 8.0  # congested regime: queue pressure on every arrival


def _sim(policy, chunked, n=150, **kw):
    return ServingSimulator(
        LLAMA2_7B, L20,
        SimConfig(policy=policy, chunked=chunked, **kw)).run(
        sharegpt_like(n, rate=RATE, seed=7))


# ------------------------------------------------------------- simulator ---

def test_sim_chunked_respects_token_budget():
    """No decode starvation: one iteration never carries more prefill
    tokens than max_prefill_tokens, for either policy."""
    for policy in ("vllm", "layerkv"):
        m = _sim(policy, True, max_prefill_tokens=512)
        assert m.chunk_iters > 0
        assert 0 < m.max_iter_prefill_tokens <= 512


def test_sim_chunked_ttft_not_worse_at_high_load():
    """Chunk costs telescope (no extra prefill compute) and decode hides
    under chunk compute, so at high arrival rates TTFT can only improve
    vs the exclusive-prefill step semantics — for both policies."""
    for policy in ("vllm", "layerkv"):
        excl = _sim(policy, False)
        chnk = _sim(policy, True)
        assert chnk.p99_ttft <= excl.p99_ttft + 1e-9
        assert chnk.mean_ttft <= excl.mean_ttft + 1e-9


def test_sim_chunked_beats_exclusive_vllm_tail():
    """The acceptance bar: layerkv+chunked p99 TTFT strictly below the
    exclusive-prefill vLLM baseline at high arrival rates."""
    mv = _sim("vllm", False)
    mc = _sim("layerkv", True)
    assert mc.p99_ttft < mv.p99_ttft


def test_sim_chunked_block_accounting_clean():
    sim = ServingSimulator(LLAMA2_7B, L20,
                           SimConfig(policy="layerkv", chunked=True))
    sim.run(sharegpt_like(60, rate=3.0, seed=11))
    sim.bm.check()
    assert sim.bm.num_free("device") == sim.bm.pools["device"].num_blocks
    assert not sim.bm.live_requests()


def test_chunk_cost_telescopes():
    """CostModel.chunk_prefill_time sums exactly to Eq.3's whole-prompt
    cost for ANY chunking — chunking moves compute, never adds it."""
    cm = CostModel(LLAMA2_7B, L20)
    for total, sizes in [(1024, [256] * 4), (1000, [512, 488]),
                         (777, [1] + [97] * 8)]:
        assert sum(sizes) == total
        acc, p = 0.0, 0
        for c in sizes:
            acc += cm.chunk_prefill_time(c, p)
            p += c
        assert acc == pytest.approx(cm.prefill_time(total), rel=1e-12)
    assert cm.chunk_prefill_time(0, 123) == 0.0


# ------------------------------------------- interleaving, per-chunk Eq.4 --

def test_interleave_retain_all_and_none():
    assert interleave_offload_layers(7, 7) == []
    assert interleave_offload_layers(7, 0) == list(range(7))
    assert interleave_offload_layers(1, 0) == [0]
    assert interleave_offload_layers(1, 1) == []


def test_interleave_clamps_out_of_range():
    assert interleave_offload_layers(4, 9) == []      # retain > L
    assert interleave_offload_layers(4, -3) == [0, 1, 2, 3]


def test_interleave_single_offload_positions():
    # L-1 retained: exactly one offloaded layer, a valid index, stable
    for L in range(2, 12):
        off = interleave_offload_layers(L, L - 1)
        assert len(off) == 1 and 0 <= off[0] < L


def test_interleave_stable_across_chunk_admissions():
    """Per-chunk admission re-derives the offload set from the SAME
    retain_n every chunk; the split must be deterministic and disjoint
    so chunk K never writes a layer chunk K-1 placed elsewhere."""
    for L in (1, 2, 5, 8, 31):
        for retain in range(0, L + 1):
            a = interleave_offload_layers(L, retain)
            b = interleave_offload_layers(L, retain)
            assert a == b
            retain_set = set(range(L)) - set(a)
            assert len(retain_set) == retain
            assert retain_set.isdisjoint(a)


# ------------------------------------------------------------ real engine --

def _workload(cfg, n, plen_range, out_range, seed=0):
    r0 = np.random.RandomState(seed)
    reqs = []
    for i in range(n):
        plen = int(r0.randint(*plen_range))
        reqs.append(Request(
            rid=f"r{i}", prompt_len=plen,
            output_len=int(r0.randint(*out_range)), arrival=0.0,
            prompt=[int(x) for x in r0.randint(0, cfg.vocab_size, plen)]))
    return reqs


def _run_engine(cfg, policy, ndb, reqs, chunked=False, chunk_size=24):
    eng = LayerKVEngine(
        cfg, None,
        EngineConfig(policy=policy, slo_aware=False,
                     num_device_blocks=ndb, num_host_blocks=512,
                     block_size=8, chunked=chunked, chunk_size=chunk_size),
        rng=jax.random.PRNGKey(42))
    done = eng.run(reqs)
    return {r.rid: r.generated for r in done}, eng


@pytest.mark.slow
def test_engine_chunked_lossless_vs_unchunked():
    """THE chunked guarantee: splitting a prompt into scheduler-sized
    chunks (appended into the paged pools at token offsets, causal-masked
    against the cached prefix) never changes generated tokens."""
    cfg = dataclasses.replace(get_smoke_config("granite-3-2b"),
                              dtype="float32")
    mk = lambda: _workload(cfg, 5, (28, 52), (8, 16))
    out_u, _ = _run_engine(cfg, "layerkv", 40, mk(), chunked=False)
    out_c, eng = _run_engine(cfg, "layerkv", 40, mk(), chunked=True)
    assert max(r.n_chunks for r in eng.done) > 1, \
        "workload must actually chunk"
    assert out_u == out_c


@pytest.mark.slow
def test_engine_chunked_lossless_under_offload():
    """All three scheduling modes agree under a tight pool that forces
    offload+reload traffic DURING chunked prefill."""
    cfg = dataclasses.replace(get_smoke_config("granite-3-2b"),
                              dtype="float32")
    mk = lambda: _workload(cfg, 5, (28, 52), (8, 16), seed=2)
    out_v, _ = _run_engine(cfg, "vllm", 1024, mk())           # mode 1
    out_l, _ = _run_engine(cfg, "layerkv", 30, mk())          # mode 2
    out_c, eng = _run_engine(cfg, "layerkv", 30, mk(), chunked=True)  # 3
    n_off = len([t for t in eng.off.ledger.log if t.kind == "offload"])
    n_rel = len([t for t in eng.off.ledger.log if t.kind == "reload"])
    assert n_off > 0 and n_rel > 0, "pool must be tight enough to offload"
    assert out_v == out_l == out_c
