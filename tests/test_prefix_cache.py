"""Ref-counted cross-request prefix caching + the KV-accounting fixes.

Four layers of guarantees:
  * block-manager semantics — content-addressed sharing, refcounts, COW,
    LRU reclaim with host demotion, detach-on-evict;
  * simulator behaviour — shared-prefix workloads hit, TTFT improves on a
    >=50%-shared workload, hit-rate accounting is sane, and the _promote
    h2d double-accounting fix holds (each migrated byte hits the ledger
    exactly once and is excluded from per-step host streaming);
  * satellites — p99 ceil-rank, Transfer.start records actual start,
    derive_device_blocks raises a named config error, LinkLedger.reserve
    defers chunked transfers (§3.1.3);
  * real-engine losslessness — with the cache on, generated tokens are
    IDENTICAL to the cache-off engine on shared-prefix workloads,
    including under tight pools that force offload/eviction around the
    shared blocks.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.llama2_7b import CONFIG as LLAMA2_7B
from repro.core import DEVICE, HOST, LayerwiseBlockManager, LinkLedger
from repro.serving.costmodel import L20
from repro.serving.engine import EngineConfig, LayerKVEngine
from repro.serving.request import Request
from repro.serving.sim import (
    DeviceMemoryError, ServingSimulator, SimConfig, SimMetrics,
    derive_device_blocks,
)
from repro.serving.workload import shared_prefix


# -------------------------------------------------------- block manager ----

def _bm(ndev=32, nhost=16, bs=4, L=2):
    return LayerwiseBlockManager(ndev, nhost, bs, L, prefix_cache=True)


def test_bm_register_then_hit_shares_blocks():
    bm = _bm()
    prompt = list(range(10))  # 2 full blocks + tail
    for l in range(2):
        bm.alloc_layer("A", l, len(prompt), DEVICE)
    assert bm.register_prefix("A", prompt) == 4  # 2 blocks x 2 layers
    acq = bm.acquire_prefix("B", prompt)
    assert acq is not None and acq.cached_len == 8
    a_blocks = bm.allocation("A", 0).blocks[:2]
    b_blocks = bm.allocation("B", 0).blocks[:2]
    assert a_blocks == b_blocks  # physically shared
    assert bm.layer_shared("A", 0) and bm.layer_shared("B", 0)
    bm.check()


def test_bm_full_prompt_hit_is_capped_and_cows():
    """A prompt that matches entirely still recomputes its last token —
    the block holding it is copy-on-write, the original never mutated."""
    bm = _bm()
    prompt = list(range(8))  # exactly 2 full blocks
    for l in range(2):
        bm.alloc_layer("A", l, 8, DEVICE)
    bm.register_prefix("A", prompt)
    acq = bm.acquire_prefix("B", prompt)
    assert acq.cached_len == 7          # capped at len-1
    assert len(acq.cow_copies) == 2     # one per layer
    for l, src, dst in acq.cow_copies:
        assert src != dst
        assert src in bm.allocation("A", l).blocks
        assert dst in bm.allocation("B", l).blocks
        assert src not in bm.allocation("B", l).blocks
    bm.check()


def test_bm_shared_never_freed_while_referenced():
    bm = _bm()
    prompt = list(range(8))
    for l in range(2):
        bm.alloc_layer("A", l, 8, DEVICE)
    bm.register_prefix("A", prompt)
    bm.acquire_prefix("B", prompt + [99])  # full 8-token hit
    shared = list(bm.allocation("B", 0).blocks)
    bm.free_request("A")
    # B still maps the blocks: they must remain pool-allocated
    for b in shared:
        assert b in bm.pools[DEVICE]._owner
    bm.check()
    bm.free_request("B")
    bm.check()
    # now unreferenced: retained as reclaimable cache, num_free sees them
    assert bm.num_free(DEVICE) == 32
    assert bm.pools[DEVICE].num_free < 32


def test_bm_lru_reclaim_demotes_to_host_then_promotes():
    bm = _bm(ndev=8, nhost=16, bs=4, L=1)
    copies = []
    bm.on_copy = lambda sp, s, dp, d: copies.append((sp, dp))
    prompt = list(range(8))  # 2 full blocks, 1 layer
    bm.alloc_layer("A", 0, 8, DEVICE)
    bm.register_prefix("A", prompt)
    bm.free_request("A")  # 2 reclaimable cache blocks
    bm.alloc_layer("B", 0, 8 * 4, DEVICE)  # exhausts the pool -> reclaim
    assert (DEVICE, HOST) in copies, "expected demotion d2h copies"
    bm.check()
    # entries now on host: a new acquire promotes them back
    bm.free_request("B")
    acq = bm.acquire_prefix("C", prompt + [42])
    assert acq is not None and acq.promotions
    assert (HOST, DEVICE) in copies
    assert bm.allocation("C", 0).pool == DEVICE
    bm.check()


def test_bm_detach_evicts_without_breaking_sharer():
    bm = _bm()
    prompt = list(range(8))
    for l in range(2):
        bm.alloc_layer("A", l, 8, DEVICE)
    bm.register_prefix("A", prompt)
    bm.acquire_prefix("B", prompt + [7, 7, 7])
    for l in range(2):
        bm.extend_layer("B", l, 3)
    # move_layer without detach refuses; with detach it copies out
    with pytest.raises(ValueError):
        bm.move_layer("B", 0, HOST)
    src, dst = bm.move_layer("B", 0, HOST, detach=True)
    assert len(src) == len(dst)
    assert bm.allocation("B", 0).pool == HOST
    # A's mapping is untouched and still cache-registered
    assert bm.allocation("A", 0).pool == DEVICE
    assert not bm.layer_shared("A", 0)  # B detached; A is sole owner
    bm.check()
    bm.free_request("A")
    bm.free_request("B")
    bm.check()


def test_bm_check_catches_double_ownership():
    bm = _bm()
    bm.alloc_layer("A", 0, 8, DEVICE)
    blocks = bm.allocation("A", 0).blocks
    # forge an unregistered double-mapping: check() must catch it
    bm.tables.setdefault("EVIL", {})[0] = type(bm.allocation("A", 0))(
        DEVICE, list(blocks), 8)
    with pytest.raises(AssertionError, match="double-owned|refcount"):
        bm.check()


def test_bm_miss_when_cache_disabled():
    bm = LayerwiseBlockManager(8, 8, 4, 1, prefix_cache=False)
    assert bm.match_prefix(list(range(16))) == 0
    assert bm.cache is None


# ------------------------------------------------------------- simulator ---

def _shared_reqs(n=80, ratio=0.6, seed=3, rate=4.0, **kw):
    return shared_prefix(n, rate=rate, scenario="system_prompt",
                         share_ratio=ratio, seed=seed, **kw)


def test_sim_prefix_cache_improves_ttft_on_shared_workload():
    """Acceptance bar: >=50%-shared workload, prefix arm beats the PR 1
    layerkv+chunked arm on mean TTFT, with a real hit rate."""
    off = ServingSimulator(LLAMA2_7B, L20, SimConfig(
        policy="layerkv", chunked=True)).run(_shared_reqs())
    on = ServingSimulator(LLAMA2_7B, L20, SimConfig(
        policy="layerkv", chunked=True, prefix_cache=True)).run(
        _shared_reqs())
    assert on.prefix_hit_rate > 0.3
    assert off.prefix_hit_rate == 0.0
    assert on.mean_ttft < off.mean_ttft


def test_sim_prefix_cache_lossless_accounting_all_modes():
    for chunked in (False, True):
        for policy in ("vllm", "layerkv"):
            sim = ServingSimulator(LLAMA2_7B, L20, SimConfig(
                policy=policy, chunked=chunked, prefix_cache=True))
            m = sim.run(_shared_reqs(n=50))
            sim.bm.check()
            assert m.n_requests == 50
            assert m.prefix_hit_tokens > 0
            # all requests done: every block free or cache-retained
            assert not sim.bm.live_requests()
            assert sim.bm.num_free(DEVICE) \
                == sim.bm.pools[DEVICE].num_blocks


def test_sim_multi_turn_and_rag_scenarios_hit():
    for scenario in ("multi_turn", "rag_template"):
        reqs = shared_prefix(40, rate=4.0, scenario=scenario,
                             share_ratio=0.5, seed=5)
        m = ServingSimulator(LLAMA2_7B, L20, SimConfig(
            policy="layerkv", chunked=True, prefix_cache=True)).run(reqs)
        assert m.prefix_hit_rate > 0.1, scenario


def test_sim_promote_charges_each_byte_once():
    """The _promote double-accounting fix: total ledger 'reload' bytes
    equal the bytes actually migrated host->device (tracked independently
    through move_layer), and post-promotion host streaming excludes the
    promoted layers."""
    sim = ServingSimulator(LLAMA2_7B, L20, SimConfig(policy="layerkv"))
    migrated = []
    orig_move = sim.bm.move_layer

    def counting_move(req, layer, to_pool, detach=False):
        a = sim.bm.allocation(req, layer)
        if a.pool == HOST and to_pool == DEVICE:
            migrated.append(sim.cost.kv_bytes(a.num_tokens, 1))
        return orig_move(req, layer, to_pool, detach)

    sim.bm.move_layer = counting_move
    # long prompts at high rate force layer offload during prefill, so
    # decode must promote layers back
    from repro.serving.workload import fixed_length
    sim.run(fixed_length(60, 2048, 128, rate=4.0, seed=2))
    reloads = sum(t.nbytes for t in sim.off.ledger.log
                  if t.kind == "reload")
    assert migrated, "workload must actually promote layers"
    assert reloads == sum(migrated) == sim.reload_bytes_migrated


def test_sim_promote_updates_host_layers_on_early_stop():
    """Regression for the stale-host_layers bug: _promote always records
    post-promotion residency, even when it stops early for lack of device
    blocks, so the decode step never double-streams promoted layers."""
    sim = ServingSimulator(LLAMA2_7B, L20, SimConfig(
        policy="layerkv", num_device_blocks=4096))
    from repro.serving.workload import fixed_length
    sim.run(fixed_length(40, 1024, 64, rate=8.0, seed=4))
    # invariant at the end of any run: host_layers mirrors the block table
    for rid, n in sim.host_layers.items():
        if rid in sim.bm.tables:
            assert n == len(sim.bm.layers_on(rid, HOST))


def test_sim_short_prefix_hit_never_deadlocks():
    """Regression: the hit-path device-need estimate (uncached suffix x
    ALL layers) can exceed the layer-wise plan for SHORT shared prefixes;
    the admission gate must take the min or a request the plain path fits
    raises a spurious deadlock."""
    # 640-block pool fits r0 (1024 tokens) via the layerkv plan but NOT
    # the hit estimate of r1 ((64-16)*32 = 1536 blocks)
    reqs = shared_prefix(2, rate=0.01, scenario="system_prompt",
                         share_ratio=0.25, prompt_len=1024,
                         output_len=32, seed=9)
    sim = ServingSimulator(LLAMA2_7B, L20, SimConfig(
        policy="layerkv", prefix_cache=True, num_device_blocks=640))
    m = sim.run(reqs)  # must not raise "deadlock"
    assert m.n_requests == 2


def test_hit_rate_counts_once_per_admission():
    """Regression: head-of-line retries must not inflate the hit rate —
    stats are recorded once per admitted request."""
    reqs = _shared_reqs(n=40, ratio=0.5, rate=50.0)  # heavy congestion
    sim = ServingSimulator(LLAMA2_7B, L20, SimConfig(
        policy="layerkv", chunked=True, prefix_cache=True))
    m = sim.run(reqs)
    # every request is looked up exactly once per ADMISSION: n admissions
    # plus one re-admission per preemption, regardless of head-of-line
    # retry count
    assert sim.bm.cache.n_lookups == m.n_requests + m.preemptions
    if m.preemptions == 0:
        assert m.prefix_lookup_tokens == sum(r.prompt_len for r in reqs)


def test_match_prefix_rejects_hash_collision():
    """A forged chain-hash collision degrades to a miss: stored token ids
    are verified on match, never trusted."""
    bm = _bm()
    prompt = list(range(8))
    for l in range(2):
        bm.alloc_layer("A", l, 8, DEVICE)
    bm.register_prefix("A", prompt)
    # forge: rewrite the stored tokens of the layer-0 entry so the hash
    # "matches" a different content
    from repro.core import block_hashes
    h0 = block_hashes(prompt, 4)[0]
    bm.cache.entries[(0, h0)].tokens = (99, 99, 99, 99)
    assert bm.match_prefix(prompt) == 0  # verification rejects it


# ------------------------------------------------------------- satellites --

def test_p99_uses_ceil_rank():
    m = SimMetrics(ttft=[float(i) for i in range(1, 101)], queuing=[],
                   prefill_lat=[], tpot=[], finish_times=[], tokens_out=0,
                   makespan=0.0, slo_violations=0, n_requests=100,
                   preemptions=0)
    # nearest-rank p99 of 1..100 is the 99th value, not the max
    assert m.p99_ttft == 99.0
    m2 = dataclasses.replace(m, ttft=[5.0])
    assert m2.p99_ttft == 5.0


def test_derive_device_blocks_raises_named_error():
    sim = SimConfig(max_model_len=1 << 22)  # absurd activation reservation
    with pytest.raises(DeviceMemoryError) as ei:
        derive_device_blocks(LLAMA2_7B, L20, sim)
    msg = str(ei.value)
    assert "max_model_len" in msg and "GB" in msg
    # the old behaviour: SimConfig(num_device_blocks=0) built a zero-block
    # pool and died later with a confusing deadlock; now it names the issue
    with pytest.raises(DeviceMemoryError):
        ServingSimulator(LLAMA2_7B, L20, sim)


def test_transfer_start_reflects_link_queueing():
    led = LinkLedger(bandwidth=1e9)
    led.submit(0.0, int(1e9), "offload")      # occupies [0, 1)
    led.submit(0.5, int(1e9), "offload")      # queued behind: starts at 1
    t0, t1 = led.log
    assert t0.start == 0.0 and t0.submitted == 0.0
    assert t1.submitted == 0.5
    assert t1.start == pytest.approx(1.0)     # actual start, not submit
    assert t1.end == pytest.approx(2.0)


def test_reserve_defers_chunked_transfers():
    """§3.1.3: a collective reservation makes sub-unit transfers defer —
    completion lands after the reservation, and the logged start shows
    the deferral."""
    led = LinkLedger(bandwidth=1e9, chunk_bytes=int(0.25e9))
    led.reserve(0.0, 1.0)
    end = led.submit(0.0, int(1e9), "offload")
    assert end > 2.0 - 1e-9          # 1s reserved + 1s of transfer
    assert led.log[0].start >= 1.0   # first byte moved after reservation
    # without the reservation the same transfer takes 1s flat
    led2 = LinkLedger(bandwidth=1e9, chunk_bytes=int(0.25e9))
    assert led2.submit(0.0, int(1e9), "offload") == pytest.approx(1.0)


def test_reserve_wired_into_tp_sim():
    """The TP benchmark path: collective reservations cause observable
    transfer deferrals in a layerkv sim."""
    from repro.serving.workload import fixed_length
    # tight pool: layer-wise admission must offload, so prefill d2h
    # traffic lands inside the collective's reservation window
    sim = ServingSimulator(LLAMA2_7B, L20.scaled(2), SimConfig(
        policy="layerkv", collective_reserve_frac=0.5,
        num_device_blocks=8192))
    sim.run(fixed_length(40, 2048, 128, rate=4.0, seed=4))
    deferred = [t for t in sim.off.ledger.log
                if t.start > t.submitted + 1e-12]
    assert deferred, "reservations must defer at least one transfer"


# ------------------------------------------------------------ real engine --

def _mk_workload(cfg, n, shared_len, sfx_range, out_range, gap, seed=0):
    r0 = np.random.RandomState(seed)
    pre = [int(x) for x in r0.randint(0, cfg.vocab_size, shared_len)]
    reqs = []
    for i in range(n):
        sfx = [int(x)
               for x in r0.randint(0, cfg.vocab_size,
                                   int(r0.randint(*sfx_range)))]
        p = pre + sfx
        reqs.append(Request(rid=f"r{i}", prompt_len=len(p),
                            output_len=int(r0.randint(*out_range)),
                            arrival=i * gap, prompt=p))
    return reqs


def _run_engine(cfg, reqs, ndb, chunked, cache, nhb=512):
    eng = LayerKVEngine(
        cfg, None,
        EngineConfig(policy="layerkv", slo_aware=False,
                     num_device_blocks=ndb, num_host_blocks=nhb,
                     block_size=8, chunked=chunked, chunk_size=24,
                     prefix_cache=cache),
        rng=jax.random.PRNGKey(42))
    done = eng.run(reqs)
    return {r.rid: r.generated for r in done}, eng


@pytest.mark.slow
def test_engine_prefix_cache_lossless():
    """THE tentpole guarantee: with prefix caching on, generated tokens
    are identical to the cache-disabled engine, in exclusive AND chunked
    mode, with real sharing happening."""
    cfg = dataclasses.replace(get_smoke_config("granite-3-2b"),
                              dtype="float32")
    # stagger arrivals so early prefills register before later admissions
    gap = 1e-4
    mk = lambda: _mk_workload(cfg, 5, 24, (6, 20), (6, 12), gap, seed=1)
    base, _ = _run_engine(cfg, mk(), 64, False, False)
    hit_u, e1 = _run_engine(cfg, mk(), 64, False, True)
    base_c, _ = _run_engine(cfg, mk(), 64, True, False)
    hit_c, e2 = _run_engine(cfg, mk(), 64, True, True)
    assert e1.bm.cache.n_hits > 0 and e2.bm.cache.n_hits > 0
    e1.bm.check()
    e2.bm.check()
    assert base == base_c == hit_u == hit_c


@pytest.mark.slow
def test_engine_prefix_cache_lossless_tight_pool():
    """Losslessness when shared blocks sit under tight pools that force
    offload/eviction traffic around them (detach-on-evict, demotion,
    promotion)."""
    cfg = dataclasses.replace(get_smoke_config("granite-3-2b"),
                              dtype="float32")
    gap = 1e-4
    mk = lambda: _mk_workload(cfg, 6, 24, (10, 26), (10, 18), gap, seed=2)
    base, _ = _run_engine(cfg, mk(), 1024, True, False)
    tight_off, e0 = _run_engine(cfg, mk(), 26, True, False)
    tight_on, e1 = _run_engine(cfg, mk(), 26, True, True)
    n_off = len([t for t in e1.off.ledger.log if t.kind == "offload"])
    assert n_off > 0, "pool must be tight enough to force offload traffic"
    assert e1.bm.cache.n_hits > 0, "workload must actually share"
    e1.bm.check()
    assert base == tight_off == tight_on


@pytest.mark.slow
def test_engine_prefix_cache_skips_compute():
    """A cache hit runs strictly fewer prefill chunks/iterations: the
    engine's virtual clock advances less for the hit request's prefill."""
    cfg = dataclasses.replace(get_smoke_config("granite-3-2b"),
                              dtype="float32")
    r0 = np.random.RandomState(7)
    pre = [int(x) for x in r0.randint(0, cfg.vocab_size, 40)]
    mk = lambda: [
        Request(rid="a", prompt_len=48, output_len=4, arrival=0.0,
                prompt=pre + [int(x) for x in r0.randint(0, 100, 8)][:8]),
        Request(rid="b", prompt_len=48, output_len=4, arrival=1.0,
                prompt=pre + [int(x) for x in r0.randint(100, 200, 8)][:8]),
    ]
    _, e_off = _run_engine(cfg, mk(), 128, False, False)
    _, e_on = _run_engine(cfg, mk(), 128, False, True)
    b_off = [r for r in e_off.done if r.rid == "b"][0]
    b_on = [r for r in e_on.done if r.rid == "b"][0]
    assert e_on.bm.cache.n_hits >= 1
    assert b_on.cached_prompt_len == 40 and b_off.cached_prompt_len == 0
    # prefill latency of the hit request shrinks (40 of 48 tokens cached)
    assert b_on.prefill_latency < b_off.prefill_latency * 0.5
