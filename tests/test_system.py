"""End-to-end behaviour tests for the LayerKV system.

These exercise the full stack: config -> model -> engine/simulator ->
metrics, at smoke scale.
"""
import pytest

from repro.configs import ARCH_IDS, SHAPES, get_config, get_smoke_config
from repro.configs.llama2_7b import CONFIG as LLAMA2_7B
from repro.serving.costmodel import L20, TPU_V5E, CostModel
from repro.serving.sim import ServingSimulator, SimConfig
from repro.serving.workload import fixed_length


def test_all_archs_have_configs():
    for a in ARCH_IDS:
        cfg = get_config(a)
        smoke = get_smoke_config(a)
        assert cfg.arch_id == a
        assert smoke.n_layers <= 4 and smoke.d_model <= 512
        if smoke.moe.n_experts:
            assert smoke.moe.n_experts <= 4
        assert cfg.source, "every config cites its source"


def test_assigned_configs_exact():
    """The 10 assigned architectures match the published specs exactly."""
    expect = {
        "whisper-base": (6, 512, 8, 8, 2048, 51865),
        "chatglm3-6b": (28, 4096, 32, 2, 13696, 65024),
        "qwen2.5-3b": (36, 2048, 16, 2, 11008, 151936),
        "qwen2-vl-7b": (28, 3584, 28, 4, 18944, 152064),
        "deepseek-moe-16b": (28, 2048, 16, 16, 1408, 102400),
        "codeqwen1.5-7b": (32, 4096, 32, 32, 13440, 92416),
        "llama4-scout-17b-a16e": (48, 5120, 40, 8, 8192, 202048),
        "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
        "granite-3-2b": (40, 2048, 32, 8, 8192, 49155),
        "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
    }
    for a, (L, d, H, KV, ff, V) in expect.items():
        c = get_config(a)
        assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
                c.vocab_size) == (L, d, H, KV, ff, V), a
    # MoE extras
    dm = get_config("deepseek-moe-16b").moe
    assert (dm.n_experts, dm.top_k, dm.n_shared) == (64, 6, 2)
    l4 = get_config("llama4-scout-17b-a16e").moe
    assert (l4.n_experts, l4.top_k) == (16, 1)
    assert get_config("zamba2-2.7b").ssm.state_dim == 64


def test_input_shapes_match_spec():
    assert SHAPES["train_4k"].seq_len == 4096
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].seq_len == 32768
    assert SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524288
    assert SHAPES["long_500k"].global_batch == 1


def test_e2e_paper_pipeline_small():
    """Full pipeline: workload -> simulator (both policies) -> the paper's
    headline ordering holds (LayerKV TTFT <= vLLM TTFT)."""
    r1 = fixed_length(40, 1024, 256, rate=1.0, seed=2)
    r2 = fixed_length(40, 1024, 256, rate=1.0, seed=2)
    mv = ServingSimulator(LLAMA2_7B, L20, SimConfig(policy="vllm")).run(r1)
    ml = ServingSimulator(LLAMA2_7B, L20,
                          SimConfig(policy="layerkv")).run(r2)
    # light load: parity within tolerance (the big wins are at congestion,
    # asserted in test_serving); here we check the pipeline end-to-end
    assert ml.mean_ttft <= mv.mean_ttft * 1.15
    assert ml.n_requests == mv.n_requests == 40


def test_tpu_profile_no_contention_pathway():
    """On TPU the offload fabric is disjoint from ICI: the ledger never
    defers when no reservations exist."""
    from repro.core import LinkLedger
    led = LinkLedger(TPU_V5E.offload_bw)
    t_done = led.submit(0.0, 100 << 20, "offload")
    assert t_done == pytest.approx((100 << 20) / TPU_V5E.offload_bw)


def test_pcie_contention_defers_transfers():
    """Paper §3.1.3: transfers yield to an ongoing all-reduce."""
    from repro.core import LinkLedger
    led = LinkLedger(16e9, chunk_bytes=1 << 20)
    led.reserve(0.0, 0.010)  # all-reduce occupying the link for 10 ms
    t_done = led.submit(0.0, 16 << 20, "offload")
    uncontended = (16 << 20) / 16e9
    assert t_done > 0.010  # waited out the reservation
    assert t_done == pytest.approx(0.010 + uncontended, rel=0.5)


def test_eq4_long_prompt_offloads_everything():
    """Paper: 'When the prompt is long, x can be zero'."""
    cm = CostModel(LLAMA2_7B, L20)
    assert cm.min_retained_layers(16384) == 0


def test_kv_bytes_formula():
    """Eq.4 numerator: 2 * L * kv_heads * head_dim * f * seqlen."""
    cfg = get_config("chatglm3-6b")
    cm = CostModel(cfg, L20)
    assert cm.kv_bytes(1000) == 2 * 28 * 2 * 128 * 2 * 1000
