"""Serving-layer tests: simulator behaviour (paper claims at small scale)
and real-engine losslessness under forced layer-wise offloading."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.llama2_7b import CONFIG as LLAMA2_7B
from repro.serving.costmodel import L20, CostModel
from repro.serving.engine import EngineConfig, LayerKVEngine
from repro.serving.request import Request
from repro.serving.sim import ServingSimulator, SimConfig
from repro.serving.workload import fixed_length, sharegpt_like


# ------------------------------------------------------------- simulator ---

def test_sim_queuing_dominates_at_long_context():
    """Paper Fig.1: beyond ~1k context, queuing >> prefill in TTFT."""
    reqs = fixed_length(80, 2048, 512, rate=1.0, seed=3)
    m = ServingSimulator(LLAMA2_7B, L20, SimConfig(policy="vllm")).run(reqs)
    assert m.mean_queuing > 5 * m.mean_prefill


def test_sim_layerkv_beats_vllm_ttft():
    """Paper Fig.4/6: LayerKV reduces mean TTFT by >=5x in the congested
    regime while keeping mean TPOT under the SLO."""
    r1 = fixed_length(80, 1024, 512, rate=1.0, seed=1)
    r2 = fixed_length(80, 1024, 512, rate=1.0, seed=1)
    mv = ServingSimulator(LLAMA2_7B, L20, SimConfig(policy="vllm")).run(r1)
    ml = ServingSimulator(LLAMA2_7B, L20,
                          SimConfig(policy="layerkv")).run(r2)
    assert ml.mean_ttft * 5 < mv.mean_ttft
    assert ml.mean_tpot < 0.25  # ~TPOT SLO (0.2s) with small tolerance


def test_sim_layerkv_lower_violation_rate():
    r1 = sharegpt_like(150, rate=4.0, seed=7)
    r2 = sharegpt_like(150, rate=4.0, seed=7)
    mv = ServingSimulator(LLAMA2_7B, L20, SimConfig(policy="vllm")).run(r1)
    ml = ServingSimulator(LLAMA2_7B, L20,
                          SimConfig(policy="layerkv")).run(r2)
    assert ml.violation_rate <= mv.violation_rate


def test_sim_slo_scheduler_protects_tpot():
    """Paper Fig.8 ablation: without the SLO-aware scheduler LayerKV's
    TPOT degrades vs. with it."""
    r1 = fixed_length(60, 2048, 384, rate=1.5, seed=5)
    r2 = fixed_length(60, 2048, 384, rate=1.5, seed=5)
    on = ServingSimulator(LLAMA2_7B, L20,
                          SimConfig(policy="layerkv", slo_aware=True)).run(r1)
    off = ServingSimulator(LLAMA2_7B, L20,
                           SimConfig(policy="layerkv",
                                     slo_aware=False)).run(r2)
    assert on.mean_tpot <= off.mean_tpot + 1e-6


def test_sim_block_accounting_clean():
    sim = ServingSimulator(LLAMA2_7B, L20, SimConfig(policy="layerkv"))
    sim.run(sharegpt_like(60, rate=3.0, seed=11))
    sim.bm.check()
    assert sim.bm.num_free("device") == sim.bm.pools["device"].num_blocks
    assert not sim.bm.live_requests()


# ------------------------------------------------------------ real engine --

def _workload(cfg, n, plen_range, out_range, seed=0):
    # simultaneous arrivals: queue pressure from step one (tiny smoke
    # models decode in virtual microseconds, so staggered arrivals would
    # serialize the requests and never stress the pool)
    r0 = np.random.RandomState(seed)
    reqs = []
    for i in range(n):
        plen = int(r0.randint(*plen_range))
        reqs.append(Request(
            rid=f"r{i}", prompt_len=plen,
            output_len=int(r0.randint(*out_range)), arrival=0.0,
            prompt=[int(x) for x in r0.randint(0, cfg.vocab_size, plen)]))
    return reqs


def _run_engine(cfg, policy, ndb, reqs):
    # slo_aware off: admit as aggressively as blocks allow, so a tight pool
    # deterministically exercises the offload/reload machinery
    eng = LayerKVEngine(
        cfg, None,
        EngineConfig(policy=policy, slo_aware=False,
                     num_device_blocks=ndb,
                     num_host_blocks=512, block_size=8),
        rng=jax.random.PRNGKey(42))
    done = eng.run(reqs)
    return {r.rid: r.generated for r in done}, eng


@pytest.mark.slow
def test_engine_lossless_under_offload():
    """THE paper guarantee: layer-wise offloading never changes outputs.
    Tight device pool forces real offload+reload traffic."""
    cfg = dataclasses.replace(get_smoke_config("granite-3-2b"),
                              dtype="float32")
    reqs_v = _workload(cfg, 8, (30, 60), (16, 30))
    reqs_l = _workload(cfg, 8, (30, 60), (16, 30))
    out_v, _ = _run_engine(cfg, "vllm", 1024, reqs_v)
    out_l, eng = _run_engine(cfg, "layerkv", 30, reqs_l)
    n_off = len([t for t in eng.off.ledger.log if t.kind == "offload"])
    n_rel = len([t for t in eng.off.ledger.log if t.kind == "reload"])
    assert n_off > 0 and n_rel > 0, "pool must be tight enough to offload"
    assert out_v == out_l


@pytest.mark.slow
def test_engine_lossless_moe():
    cfg = dataclasses.replace(get_smoke_config("deepseek-moe-16b"),
                              dtype="float32")
    reqs_v = _workload(cfg, 4, (24, 40), (8, 14), seed=3)
    reqs_l = _workload(cfg, 4, (24, 40), (8, 14), seed=3)
    out_v, _ = _run_engine(cfg, "vllm", 512, reqs_v)
    out_l, eng = _run_engine(cfg, "layerkv", 16, reqs_l)
    assert out_v == out_l


@pytest.mark.slow
def test_engine_layerkv_admits_earlier():
    """With a tight pool, layer-wise admission lets more requests begin
    prefill before any finishes (the paper's core mechanism)."""
    cfg = dataclasses.replace(get_smoke_config("granite-3-2b"),
                              dtype="float32")
    cm = CostModel(cfg, L20)
    reqs_l = _workload(cfg, 6, (40, 41), (24, 25), seed=9)
    reqs_v = _workload(cfg, 6, (40, 41), (24, 25), seed=9)
    _, eng_l = _run_engine(cfg, "layerkv", 20, reqs_l)
    _, eng_v = _run_engine(cfg, "vllm", 20, reqs_v)
    ttft_l = np.mean([r.ttft for r in eng_l.done])
    ttft_v = np.mean([r.ttft for r in eng_v.done])
    assert ttft_l <= ttft_v + 1e-9
