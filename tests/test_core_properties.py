"""Hypothesis property tests for the LayerKV core invariants.

Degrades to a skip on minimal installs: `hypothesis` is an optional test
dependency (declared in pyproject's `test` extra), and the suite must still
collect without it.
"""
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need the optional 'hypothesis' test dependency")

import hypothesis.strategies as st  # noqa: E402
from hypothesis import given, settings  # noqa: E402

from repro.configs import get_config
from repro.core import (
    DEVICE, HOST, LayerwiseBlockManager, PoolExhausted,
    interleave_offload_layers,
)
from repro.core.predictor import OraclePredictor
from repro.core.slo_scheduler import SLOScheduler
from repro.serving.costmodel import CostModel, L20, TPU_V5E
from repro.serving.request import Request


# ------------------------------------------------------------ allocator ----

@st.composite
def alloc_script(draw):
    """A random sequence of allocator operations."""
    n_ops = draw(st.integers(5, 60))
    ops = []
    for i in range(n_ops):
        ops.append((
            draw(st.sampled_from(["alloc", "extend", "move", "free"])),
            draw(st.integers(0, 7)),          # request index
            draw(st.integers(0, 3)),          # layer
            draw(st.integers(1, 70)),         # tokens
            draw(st.sampled_from([DEVICE, HOST])),
        ))
    return ops


@given(alloc_script())
@settings(max_examples=200, deadline=None)
def test_block_manager_invariants(script):
    bm = LayerwiseBlockManager(num_device_blocks=32, num_host_blocks=32,
                               block_size=8, n_layers=4)
    for op, ri, layer, tokens, pool in script:
        req = f"r{ri}"
        try:
            if op == "alloc":
                if req in bm.tables and layer in bm.tables[req]:
                    continue
                bm.alloc_layer(req, layer, tokens, pool)
            elif op == "extend":
                if req in bm.tables and layer in bm.tables[req]:
                    bm.extend_layer(req, layer, 1)
            elif op == "move":
                if req in bm.tables and layer in bm.tables[req]:
                    bm.move_layer(req, layer, pool)
            elif op == "free":
                bm.free_request(req)
        except PoolExhausted:
            pass
        # core invariants hold after EVERY operation
        bm.check()
    # free everything -> pools return to full
    for req in list(bm.tables):
        bm.free_request(req)
    assert bm.num_free(DEVICE) == 32
    assert bm.num_free(HOST) == 32


def test_block_manager_no_double_alloc():
    bm = LayerwiseBlockManager(8, 8, 4, 2)
    bm.alloc_layer("a", 0, 10)
    with pytest.raises(AssertionError):
        bm.alloc_layer("a", 0, 10)


def test_block_manager_exhaustion():
    bm = LayerwiseBlockManager(2, 2, 4, 1)
    bm.alloc_layer("a", 0, 8)  # 2 blocks
    with pytest.raises(PoolExhausted):
        bm.alloc_layer("b", 0, 4)
    assert bm.free_request("a") == 2
    bm.alloc_layer("b", 0, 4)


def test_move_layer_roundtrip():
    bm = LayerwiseBlockManager(8, 8, 4, 2)
    a = bm.alloc_layer("a", 1, 12, DEVICE)
    orig = list(a.blocks)
    src, dst = bm.move_layer("a", 1, HOST)
    assert src == orig and len(dst) == len(orig)
    assert bm.layers_on("a", HOST) == [1]
    assert bm.num_free(DEVICE) == 8
    bm.move_layer("a", 1, DEVICE)
    assert bm.layers_on("a", DEVICE) == [1]
    bm.check()


# ------------------------------------------- ref-counted prefix sharing ----

def _prompt_pool():
    """A few overlapping token sequences: equal prefixes collide in the
    content-addressed cache, so random scripts genuinely share blocks."""
    base = list(range(64))
    return [base[:24], base[:24], base[:17], base[:33],
            base[:8] + [99] * 16, list(range(100, 140))]


@st.composite
def share_script(draw):
    n_ops = draw(st.integers(5, 50))
    ops = []
    for _ in range(n_ops):
        ops.append((
            draw(st.sampled_from(
                ["admit", "extend", "evict", "promote", "free", "drop"])),
            draw(st.integers(0, 5)),          # request index
            draw(st.integers(0, 5)),          # prompt index
        ))
    return ops


@given(share_script())
@settings(max_examples=150, deadline=None)
def test_prefix_cache_invariants(script):
    """Random admit/extend/evict/free scripts over a shared-prefix prompt
    pool. After EVERY operation: free + allocated == pool size, a shared
    block is never freed while its refcount > 0, COW never mutates the
    shared source, and check() validates refcount == table multiplicity."""
    L = 2
    bm = LayerwiseBlockManager(num_device_blocks=48, num_host_blocks=48,
                               block_size=8, n_layers=L, prefix_cache=True)
    prompts = _prompt_pool()
    live = {}  # req -> prompt

    def pool_conserved():
        for p in bm.pools.values():
            p.check()

    for op, ri, pi in script:
        req = f"r{ri}"
        prompt = prompts[pi]
        try:
            if op == "admit" and req not in bm.tables:
                acq = bm.acquire_prefix(req, prompt)
                if acq is not None:
                    # COW sources must stay registered and pool-allocated
                    for l, src, dst in acq.cow_copies:
                        assert src != dst
                        assert bm.cache.lookup(DEVICE, src) is not None
                        assert src in bm.pools[DEVICE]._owner
                    suffix = len(prompt) - acq.cached_len
                    for l in range(L):
                        bm.extend_layer(req, l, suffix)
                else:
                    for l in range(L):
                        bm.alloc_layer(req, l, len(prompt), DEVICE)
                bm.register_prefix(req, prompt)
                live[req] = prompt
            elif op == "extend" and req in bm.tables:
                for l in list(bm.tables[req]):
                    bm.extend_layer(req, l, 1)
            elif op == "evict" and req in bm.tables:
                for l in bm.layers_on(req, DEVICE):
                    bm.move_layer(req, l, HOST, detach=True)
            elif op == "promote" and req in bm.tables:
                for l in bm.layers_on(req, HOST):
                    if bm.layer_shared(req, l):
                        continue
                    bm.move_layer(req, l, DEVICE)
            elif op == "free":
                bm.free_request(req)
                live.pop(req, None)
            elif op == "drop":
                bm.drop_cache()
        except PoolExhausted:
            bm.free_request(req)
            live.pop(req, None)
        pool_conserved()
        bm.check()  # refcount == multiplicity, LRU consistent, no leaks
        # a block mapped by any live request is never on a free list
        for r2 in bm.tables:
            for l, a in bm.tables[r2].items():
                for b in a.blocks:
                    assert b in bm.pools[a.pool]._owner, \
                        f"live block {b} of {r2} was freed"
    for req in list(bm.tables):
        bm.free_request(req)
    bm.drop_cache()
    bm.check()
    assert bm.num_free(DEVICE) == 48 and bm.pools[DEVICE].num_free == 48
    assert bm.num_free(HOST) == 48 and bm.pools[HOST].num_free == 48


@given(st.integers(2, 6), st.integers(9, 40))
@settings(max_examples=60, deadline=None)
def test_prefix_sharing_refcount_matches_sharers(n_sharers, plen):
    """N requests with an identical prompt: full blocks are mapped by all
    of them, refcounts track the sharer count exactly, and frees release
    in any order without breaking survivors."""
    bm = LayerwiseBlockManager(256, 64, 8, 2, prefix_cache=True)
    prompt = list(range(plen))
    for l in range(2):
        bm.alloc_layer("r0", l, plen, DEVICE)
    bm.register_prefix("r0", prompt)
    for i in range(1, n_sharers):
        acq = bm.acquire_prefix(f"r{i}", prompt)
        assert acq is not None
        for l in range(2):
            bm.extend_layer(f"r{i}", l, plen - acq.cached_len)
    bm.check()
    n_full = (plen - 1) // 8  # shared full blocks (cap leaves the tail)
    if n_full:
        b0 = bm.allocation("r0", 0).blocks[0]
        e = bm.cache.lookup(DEVICE, b0)
        assert e is not None and e.ref == n_sharers
    # free in arbitrary-ish order; survivors keep working
    for i in list(range(0, n_sharers, 2)) + list(range(1, n_sharers, 2)):
        bm.free_request(f"r{i}")
        bm.check()
    assert bm.num_free(DEVICE) == 256


# ------------------------------------------------------ interleaving -------

@given(st.integers(1, 80), st.integers(0, 80))
@settings(max_examples=200, deadline=None)
def test_interleave_counts(L, retain):
    off = interleave_offload_layers(L, retain)
    assert len(off) == L - min(retain, L)
    assert len(set(off)) == len(off)
    assert all(0 <= l < L for l in off)


def test_interleave_even_paper_example():
    # paper §3.1.2: 8 layers, keep 4 -> offload 0,2,4,6
    assert interleave_offload_layers(8, 4) == [0, 2, 4, 6]


# ------------------------------------------------------ scheduler ----------

def _mk_decoding(now, tpot, n_past, output_len, tpot_slo=0.2):
    r = Request(rid="d", prompt_len=512, output_len=output_len,
                tpot_slo=tpot_slo)
    r.first_token_time = now - tpot * n_past
    assert r.first_token_time >= 0, "test setup: keep times physical"
    r.tokens_out = n_past + 1
    return r


def test_scheduler_blocks_when_slack_exhausted():
    cfg = get_config("chatglm3-6b")
    cost = CostModel(cfg, L20)
    pred = OraclePredictor([64, 128, 256, 512], accuracy=1.0)
    sched = SLOScheduler(cost, pred)
    now = 300.0
    # decoding request far behind its TPOT SLO -> no admissions
    slow = _mk_decoding(now, tpot=2.0, n_past=100, output_len=128)
    queue = [Request(rid=f"q{i}", prompt_len=4096, output_len=128)
             for i in range(4)]
    assert sched.max_prefills(queue, [slow], now) == 0


def test_scheduler_admits_with_headroom():
    cfg = get_config("chatglm3-6b")
    cost = CostModel(cfg, L20)
    pred = OraclePredictor([64, 128, 256, 512], accuracy=1.0)
    sched = SLOScheduler(cost, pred)
    now = 10.0
    fast = _mk_decoding(now, tpot=0.02, n_past=10, output_len=256)
    queue = [Request(rid=f"q{i}", prompt_len=512, output_len=128)
             for i in range(8)]
    n = sched.max_prefills(queue, [fast], now)
    assert n >= 1


def test_scheduler_admits_all_when_no_decoding():
    cfg = get_config("chatglm3-6b")
    sched = SLOScheduler(CostModel(cfg, L20),
                         OraclePredictor([64], accuracy=1.0))
    queue = [Request(rid="q", prompt_len=128, output_len=64)]
    assert sched.max_prefills(queue, [], 0.0) == 1


@given(st.floats(0.01, 1.0), st.integers(1, 300), st.integers(8, 4096))
@settings(max_examples=100, deadline=None)
def test_scheduler_budget_monotone_in_slack(tpot, n_past, prompt_len):
    """Admissions never increase when the decoding request is slower."""
    cfg = get_config("chatglm3-6b")
    cost = CostModel(cfg, L20)
    pred = OraclePredictor([64, 128, 256, 512], accuracy=1.0)
    sched = SLOScheduler(cost, pred)
    now = 2 * tpot * n_past + 10.0
    queue = [Request(rid=f"q{i}", prompt_len=prompt_len, output_len=128)
             for i in range(6)]
    fast = _mk_decoding(now, tpot=tpot, n_past=n_past, output_len=512)
    slow = _mk_decoding(now, tpot=tpot * 2, n_past=n_past, output_len=512)
    assert sched.max_prefills(queue, [slow], now) \
        <= sched.max_prefills(queue, [fast], now)


# ------------------------------------------------------ cost model ---------

def test_eq4_retention_monotone():
    """More layers retained as the offload link slows (Eq. 4)."""
    import dataclasses as dc
    cfg = get_config("codeqwen1.5-7b")  # MHA: heavy KV
    xs = []
    for bw in [64e9, 8e9, 1e9, 1e8]:
        hw = dc.replace(L20, offload_bw=bw)
        xs.append(CostModel(cfg, hw).min_retained_layers(1024))
    assert xs == sorted(xs)
    assert xs[-1] > 0  # pathological link -> must retain some layers


def test_prefill_time_superlinear():
    cm = CostModel(get_config("chatglm3-6b"), TPU_V5E)
    t1, t2 = cm.prefill_time(4096), cm.prefill_time(8192)
    assert t2 > 2 * t1  # superlinear in seqlen (attention term)


# ------------------------------------------------------ forecast -----------

def test_forecast_eq5_conservation():
    from repro.core import AvailabilityForecast
    pred = OraclePredictor([16, 64], accuracy=1.0)
    fc = AvailabilityForecast(pred, block_size=8)
    reqs = []
    for i, out_len in enumerate([4, 12, 40]):
        r = Request(rid=f"r{i}", prompt_len=32, output_len=out_len)
        r.tokens_out = 2
        reqs.append(r)
    base = fc.forecast(100, reqs, horizon=8)
    # releasing requests can only help availability vs a world where
    # nothing ever finishes
    never = fc.forecast(100, [], horizon=8)
    assert all(b >= 100 - (i + 1) * (len(reqs) + 0)
               for i, b in enumerate(base))
    assert len(base) == 8 and len(never) == 8


# ------------------------------------------- session cancel invariants -----

@st.composite
def cancel_schedule(draw):
    """(victim index, step count before the cancel) pairs + an axes arm."""
    n = draw(st.integers(6, 10))
    cancels = draw(st.lists(
        st.tuples(st.integers(0, n - 1), st.integers(0, 12)),
        min_size=1, max_size=4, unique_by=lambda c: c[0]))
    arm = draw(st.sampled_from(
        ["excl", "chunked", "chunked_prefix", "chunked_prefix_fused"]))
    return n, sorted(cancels, key=lambda c: c[1]), arm


@given(cancel_schedule())
@settings(max_examples=20, deadline=None)
def test_session_cancel_accounting_property(schedule):
    """ANY cancellation schedule, on any axes arm, leaves the pools at
    baseline after drain: every surviving request finishes, no sharer's
    prefix blocks are freed with a cancelled sharer, and every
    block-manager invariant holds at each cancel point."""
    from repro.configs.llama2_7b import CONFIG as LLAMA2_7B
    from repro.serving.session import ServingSession
    from repro.serving.sim import ServingSimulator, SimConfig
    from repro.serving.workload import shared_prefix

    n, cancels, arm = schedule
    kw = {"excl": {},
          "chunked": dict(chunked=True),
          "chunked_prefix": dict(chunked=True, prefix_cache=True),
          "chunked_prefix_fused": dict(chunked=True, prefix_cache=True,
                                       fused=True)}[arm]
    sim = ServingSimulator(LLAMA2_7B, L20, SimConfig(
        policy="layerkv", num_device_blocks=2048,
        num_host_blocks=1 << 14, **kw))
    sess = ServingSession(sim)
    reqs = shared_prefix(n, rate=50.0, scenario="rag_template",
                         share_ratio=0.5, prompt_len=320, output_len=48,
                         n_templates=2, seed=9)
    hs = [sess.submit(r, arrival=r.arrival) for r in reqs]
    steps = 0
    for victim, at_step in cancels:
        while steps < at_step and sess.step():
            steps += 1
        hs[victim].cancel()
        sim.bm.check()       # invariants hold at EVERY cancel point
    sess.drain()
    n_cancelled = len(sim.core.cancelled)
    assert n_cancelled >= 1
    assert len(sim.done) == n - n_cancelled
    assert all(h.finished or h.cancelled for h in hs)
    sim.bm.drop_cache()      # release cache-retained blocks, then baseline
    sim.bm.check()
    assert sim.bm.num_free(DEVICE) == sim.bm.pools[DEVICE].num_blocks
    assert sim.bm.num_free(HOST) == sim.bm.pools[HOST].num_blocks
    assert not sim.bm.live_requests()
    # sanitizer-enabled re-run: conftest forces sanitize=True for sim
    # tests, so the shadow model checked S1-S8 at every step above;
    # re-assert the deep tier at the post-unwind baseline (S8)
    san = sim.core.sanitizer
    assert san is not None and san.n_checks > 0
    san.check(sim.core, full=True)


# ------------------------------------------- preemption invariants ---------

@st.composite
def preempt_schedule(draw):
    """(victim index, step count before the forced pause) pairs + an
    axes arm — the preemption analogue of `cancel_schedule`."""
    n = draw(st.integers(5, 9))
    pauses = draw(st.lists(
        st.tuples(st.integers(0, n - 1), st.integers(1, 14)),
        min_size=1, max_size=4))
    arm = draw(st.sampled_from(
        ["excl", "chunked", "chunked_prefix", "chunked_prefix_fused"]))
    return n, sorted(pauses, key=lambda c: c[1]), arm


@given(preempt_schedule())
@settings(max_examples=20, deadline=None)
def test_preemption_lossless_property(schedule):
    """ANY forced-pause schedule, on any axes arm: no request is lost,
    duplicated, or starved — every one finishes its FULL output (pause/
    resume is lossless, zero recompute), every pause is matched by a
    resume, block-manager invariants hold at each pause point, and the
    pools return to baseline after drain."""
    from repro.configs.llama2_7b import CONFIG as LLAMA2_7B
    from repro.serving.session import ServingSession
    from repro.serving.sim import ServingSimulator, SimConfig
    from repro.serving.workload import shared_prefix

    n, pauses, arm = schedule
    kw = {"excl": {},
          "chunked": dict(chunked=True),
          "chunked_prefix": dict(chunked=True, prefix_cache=True),
          "chunked_prefix_fused": dict(chunked=True, prefix_cache=True,
                                       fused=True)}[arm]
    sim = ServingSimulator(LLAMA2_7B, L20, SimConfig(
        policy="layerkv", preemption=True, admission="deadline",
        num_device_blocks=2048, num_host_blocks=1 << 14, **kw))
    sess = ServingSession(sim)
    reqs = shared_prefix(n, rate=50.0, scenario="rag_template",
                         share_ratio=0.5, prompt_len=320, output_len=48,
                         n_templates=2, seed=9)
    for r in reqs:
        sess.submit(r, arrival=r.arrival)
    steps = forced = 0
    for victim, at_step in pauses:
        while steps < at_step and sess.step():
            steps += 1
        # pause whatever the victim index lands on among RUNNING work;
        # preempt_request refuses non-running requests, that's fine
        running = sim.core.prefilling + sim.core.decoding
        if running and sim.core.preempt_request(
                running[victim % len(running)], sim.core.now):
            forced += 1
        sim.bm.check()        # invariants hold at EVERY pause point
    sess.drain()
    assert sim.core.n_preempted >= forced
    assert sim.core.n_resumed == sim.core.n_preempted
    assert sim.preemptions == 0                     # zero recompute
    assert len(sim.done) == n                       # nobody lost
    assert sorted(r.rid for r in sim.done) \
        == sorted(r.rid for r in reqs)              # nobody duplicated
    assert all(r.tokens_out == r.output_len for r in sim.done)
    assert not sim.core.paused
    sim.bm.drop_cache()
    sim.bm.check()
    assert sim.bm.num_free(DEVICE) == sim.bm.pools[DEVICE].num_blocks
    assert sim.bm.num_free(HOST) == sim.bm.pools[HOST].num_blocks
    assert not sim.bm.live_requests()
    # sanitizer-enabled re-run (see cancel property above): every pause/
    # resume step was shadow-checked; deep-check the final baseline too
    san = sim.core.sanitizer
    assert san is not None and san.n_checks > 0
    san.check(sim.core, full=True)


# ------------------------------------------- cluster routing invariants ----

@st.composite
def routing_schedule(draw):
    """Replica count, routing policy, and a random cancel schedule."""
    n = draw(st.integers(8, 14))
    n_rep = draw(st.integers(1, 4))
    router = draw(st.sampled_from(
        ["round_robin", "least_loaded", "prefix_affinity", "slo_aware"]))
    cancels = draw(st.lists(
        st.tuples(st.integers(0, n - 1), st.integers(0, 20)),
        min_size=0, max_size=4, unique_by=lambda c: c[0]))
    return n, n_rep, router, sorted(cancels, key=lambda c: c[1])


@given(routing_schedule())
@settings(max_examples=20, deadline=None)
def test_cluster_no_request_lost_or_duplicated_property(schedule):
    """ANY routing policy x replica count x cancel schedule: every
    submitted request lands on exactly ONE replica (or the cluster's
    pre-dispatch cancel list), none is lost or served twice, and every
    replica's pools return to baseline after drain."""
    from repro.configs.llama2_7b import CONFIG as LLAMA2_7B
    from repro.serving.cluster import ClusterSession
    from repro.serving.sim import ServingSimulator, SimConfig
    from repro.serving.workload import multi_tenant

    n, n_rep, router, cancels = schedule
    cl = ClusterSession(
        [ServingSimulator(LLAMA2_7B, L20, SimConfig(
            policy="layerkv", chunked=True, prefix_cache=True,
            num_device_blocks=2048, num_host_blocks=1 << 14))
         for _ in range(n_rep)],
        router=router)
    reqs = multi_tenant(n, rate=40.0, n_tenants=3, prompt_len=320,
                        output_len=32, seed=17)
    hs = [cl.submit(r, arrival=r.arrival) for r in reqs]
    steps = 0
    for victim, at_step in cancels:
        while steps < at_step and cl.step():
            steps += 1
        hs[victim].cancel()
        for s in cl.sessions:
            s.backend.bm.check()   # invariants hold at every cancel point
    cl.drain()
    done = [r for s in cl.sessions for r in s.core.done]
    cncl = [r for s in cl.sessions for r in s.core.cancelled] \
        + cl.cancelled
    seen = sorted(r.rid for r in done + cncl)
    assert seen == sorted(r.rid for r in reqs)
    assert len(done) == len(hs) - len(cncl)
    assert all(h.finished or h.cancelled for h in hs)
    for s in cl.sessions:
        bm = s.backend.bm
        bm.drop_cache()
        bm.check()
        assert bm.num_free(DEVICE) == bm.pools[DEVICE].num_blocks
        assert bm.num_free(HOST) == bm.pools[HOST].num_blocks
        assert not bm.live_requests()


# ------------------------------------------- fault recovery invariants -----

@st.composite
def fault_schedule(draw):
    """Replica count, routing policy, and a seeded random fault plan."""
    n = draw(st.integers(8, 14))
    n_rep = draw(st.integers(2, 4))
    router = draw(st.sampled_from(
        ["round_robin", "least_loaded", "prefix_affinity", "slo_aware"]))
    seed = draw(st.integers(0, 10_000))
    n_events = draw(st.integers(1, 4))
    return n, n_rep, router, seed, n_events


@given(fault_schedule())
@settings(max_examples=15, deadline=None)
def test_cluster_fault_recovery_lossless_property(schedule):
    """ANY seeded fault plan x routing policy x replica count: every
    submitted request either finishes with its FULL token stream
    (salvaged + restarted remainder == the requested output, exactly
    once) or is shed with a typed reason — none is lost, duplicated,
    or left in limbo — and every replica's pools return to baseline
    with the sanitizer's deep tier holding at drain."""
    from repro.configs.llama2_7b import CONFIG as LLAMA2_7B
    from repro.serving.cluster import ClusterSession
    from repro.serving.faults import FaultPlan
    from repro.serving.sim import ServingSimulator, SimConfig
    from repro.serving.workload import multi_tenant

    n, n_rep, router, seed, n_events = schedule
    plan = FaultPlan.random(seed, n_rep, horizon=2.0, n_events=n_events)
    cl = ClusterSession(
        [ServingSimulator(LLAMA2_7B, L20, SimConfig(
            policy="layerkv", chunked=True, prefix_cache=True,
            num_device_blocks=2048, num_host_blocks=1 << 14))
         for _ in range(n_rep)],
        router=router, fault_plan=plan, liveness_timeout=1.0)
    reqs = multi_tenant(n, rate=40.0, n_tenants=3, prompt_len=320,
                        output_len=32, seed=17)
    hs = [cl.submit(r, arrival=r.arrival) for r in reqs]
    done = cl.drain()
    shed = cl.shed + [r for s in cl.sessions for r in s.core.shed]
    seen = sorted(r.rid for r in done) + sorted(r.rid for r in shed)
    assert sorted(seen) == sorted(r.rid for r in reqs)
    assert all(h.finished or h.shed for h in hs)
    for r in done:
        # token conservation across any number of kills: delivered ==
        # requested, with the restarted remainder never recomputing
        # what was already streamed
        assert r.tokens_out + r.tokens_salvaged == 32
    for s in cl.sessions:
        bm = s.backend.bm
        bm.drop_cache()
        bm.check()
        assert bm.num_free(DEVICE) == bm.pools[DEVICE].num_blocks
        assert bm.num_free(HOST) == bm.pools[HOST].num_blocks
        assert not bm.live_requests()
        san = s.core.sanitizer
        assert san is not None
        san.check(s.core, full=True)
