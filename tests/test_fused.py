"""Fused paged mixed-step coverage (ISSUE 3).

Three layers of guarantees:
  * kernel parity — `paged_prefill_pallas` (interpret mode) matches
    `ref.paged_prefill_reference` across q_offset/kv_len edge cases
    (chunk straddling a block boundary, single-token final chunk,
    decode-style one-token segments, dummy zero-length segments, the
    two-pool host-tier variant), and the reference itself matches the
    dense gather+flash oracle and the paged decode oracle bit-for-bit;
  * engine losslessness — `EngineConfig.fused` (one forward per
    iteration, chunks attending straight against the pools) generates
    tokens identical to the two-call chunked engine: dense + MoE, tight
    pools forcing mid-prefill offload (host-tier segments in the fused
    step), and prefix-cache hits starting at prefill_done = cached_len;
  * bucketed-shape contract — power-of-two padded jit signatures
    (prefill pad_to, decode batch width, mixed T/S/MAXB) and the
    retrace counter; `gather_layer(kv_valid=...)` slicing.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.llama2_7b import CONFIG as LLAMA2_7B
from repro.kernels import ops, ref
from repro.kernels.paged_prefill import paged_prefill_pallas
from repro.serving.costmodel import L20, CostModel
from repro.serving.engine import EngineConfig, LayerKVEngine
from repro.serving.executor import PagedExecutor, _bucket
from repro.serving.request import Request

TQ = 8


# ------------------------------------------------------------ kernel parity

def _pool(nb, bs, kv, d, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), (nb, bs, 2, kv, d),
                             jnp.float32)


def _segments(specs, h, d, bs, maxb, nb, seed=1):
    """Build a flat TQ-padded batch from (q_offset, n_q_tokens) specs.
    Returns (q, tab, seg_ids, q_pos, kv_len)."""
    rng = np.random.RandomState(seed)
    pads = [-(-max(n, 1) // TQ) * TQ for _, n in specs]
    T = sum(pads)
    seg_ids = np.zeros(T, np.int32)
    q_pos = np.zeros(T, np.int32)
    kv_len = np.zeros(len(specs), np.int32)
    t = 0
    for i, ((off, n), pad) in enumerate(zip(specs, pads)):
        seg_ids[t:t + pad] = i
        q_pos[t:t + pad] = off + np.arange(pad)
        kv_len[i] = off + n
        t += pad
    tab = rng.permutation(nb)[:len(specs) * maxb].reshape(len(specs), maxb)
    q = jax.random.normal(jax.random.PRNGKey(seed + 7), (T, h, d),
                          jnp.float32)
    return (q, jnp.asarray(tab, jnp.int32), jnp.asarray(seg_ids),
            jnp.asarray(q_pos), jnp.asarray(kv_len))


@pytest.mark.parametrize("spec", [
    (13, 11),   # chunk straddling a block boundary (BS=8)
    (0, 16),    # first chunk of a fresh prompt, block-aligned
    (23, 1),    # single-token final chunk
    (5, 3),     # mid-block start AND end
])
def test_paged_prefill_pallas_matches_ref_edges(spec):
    H, KV, D, BS, NB, MAXB = 6, 2, 64, 8, 32, 4
    pool = _pool(NB, BS, KV, D)
    q, tab, seg, pos, klen = _segments([spec], H, D, BS, MAXB, NB)
    out = paged_prefill_pallas(q, pool, tab, seg, pos, klen)
    expect = ref.paged_prefill_reference(q, pool, tab, seg, pos, klen)
    np.testing.assert_allclose(out, expect, atol=2e-5, rtol=2e-5)


def test_paged_prefill_multi_segment_chunk_decode_dummy():
    """One kernel call serving a chunk, two decode tokens, and a padded
    dummy segment (kv_len 0) — the fused step's steady-state layout."""
    H, KV, D, BS, NB, MAXB = 8, 2, 32, 8, 48, 5
    pool = _pool(NB, BS, KV, D)
    specs = [(9, 12), (30, 1), (17, 1), (0, 0)]
    q, tab, seg, pos, klen = _segments(specs, H, D, BS, MAXB, NB)
    out = paged_prefill_pallas(q, pool, tab, seg, pos, klen)
    expect = ref.paged_prefill_reference(q, pool, tab, seg, pos, klen)
    # a fully-masked row (the kv_len=0 dummy segment) is garbage by
    # contract — callers discard it; compare live segments only and just
    # require the dummy rows to be finite
    live = np.asarray(klen)[np.asarray(seg)] > 0
    np.testing.assert_allclose(np.asarray(out)[live],
                               np.asarray(expect)[live],
                               atol=2e-5, rtol=2e-5)
    assert np.all(np.isfinite(np.asarray(out)))


def test_paged_prefill_host_tier_variant():
    """Two-pool variant: host-resident segments read the HOST pool, with
    ids valid only there (the device-side fetch clamps and is discarded)."""
    H, KV, D, BS, MAXB = 4, 1, 32, 8, 3
    dpool = _pool(8, BS, KV, D, seed=3)       # small device pool
    hpool = _pool(64, BS, KV, D, seed=4)      # bigger host pool
    specs = [(4, 9), (11, 5)]
    q, _, seg, pos, klen = _segments(specs, H, D, BS, MAXB, 8)
    tab = jnp.asarray([[60, 33, 51], [2, 5, 1]], jnp.int32)  # host ids > NBd
    tier = jnp.asarray([True, False])
    out = paged_prefill_pallas(q, dpool, tab, seg, pos, klen,
                               host_pool=hpool, tier=tier)
    expect = ref.paged_prefill_reference(q, dpool, tab, seg, pos, klen,
                                         host_pool=hpool, tier=tier)
    np.testing.assert_allclose(out, expect, atol=2e-5, rtol=2e-5)


def test_paged_prefill_ref_matches_dense_flash_oracle():
    """The reference kernel == gather-to-dense + masked attention oracle
    (the two-call path's math) for a chunk at a q_offset."""
    H, KV, D, BS, NB, MAXB = 6, 3, 32, 8, 24, 3
    pool = _pool(NB, BS, KV, D)
    off, C = 10, 9
    q, tab, seg, pos, klen = _segments([(off, C)], H, D, BS, MAXB, NB)
    out = ref.paged_prefill_reference(q, pool, tab, seg, pos, klen)
    dense = pool[tab[0]]
    k = dense[:, :, 0].reshape(MAXB * BS, KV, D)[None]
    v = dense[:, :, 1].reshape(MAXB * BS, KV, D)[None]
    expect = ref.mha_reference(q[None, :C], k, v, causal=True,
                               kv_len=jnp.array([off + C]), q_offset=off)
    np.testing.assert_array_equal(np.asarray(out[:C]),
                                  np.asarray(expect[0]))


def test_paged_prefill_ref_decode_row_matches_paged_attention():
    """A one-token segment (decode riding the fused step) == the decode
    oracle `paged_attention_reference` bit-for-bit."""
    H, KV, D, BS, NB, MAXB = 8, 2, 64, 16, 32, 4
    pool = _pool(NB, BS, KV, D)
    ctx = 41
    q, tab, seg, pos, klen = _segments([(ctx, 1)], H, D, BS, MAXB, NB)
    out = ref.paged_prefill_reference(q, pool, tab, seg, pos, klen)
    expect = ref.paged_attention_reference(
        q[:1], pool, tab, jnp.asarray([ctx + 1], jnp.int32))
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(expect[0]))


def test_ops_paged_prefill_backend_dispatch():
    H, KV, D, BS, NB, MAXB = 4, 2, 32, 8, 16, 2
    pool = _pool(NB, BS, KV, D)
    q, tab, seg, pos, klen = _segments([(3, 5)], H, D, BS, MAXB, NB)
    a = ops.paged_prefill(q, pool, tab, seg, pos, klen, backend="ref")
    b = ops.paged_prefill(q, pool, tab, seg, pos, klen, backend="pallas")
    np.testing.assert_allclose(a, b, atol=2e-5, rtol=2e-5)


# --------------------------------------------------- bucketing / satellites

def test_bucket_power_of_two():
    assert [_bucket(n) for n in (1, 2, 3, 5, 8, 9)] == [1, 2, 4, 8, 8, 16]
    assert _bucket(3, lo=8) == 8


def _tiny_executor(ndb=16, nhb=32, bs=8):
    cfg = dataclasses.replace(get_smoke_config("granite-3-2b"),
                              dtype="float32")
    return PagedExecutor(cfg, None, ndb, nhb, bs,
                         rng=jax.random.PRNGKey(0)), cfg


def test_trash_block_is_extra_physical_block():
    ex, _ = _tiny_executor(ndb=16, nhb=32)
    assert ex.device_pool.shape[0] == 17
    assert ex.host_pool.shape[0] == 33


def test_decode_bucketing_counts_retraces_once_per_bucket():
    ex, cfg = _tiny_executor()
    L = cfg.n_layers
    # seed two sequences' KV via real prefills so decode reads valid blocks
    k = np.zeros((L, 3, 2), np.int32)  # 3 rows x 2 blocks of table space
    for r, blocks in enumerate(([0, 1], [2, 3], [4, 5])):
        _, kk, vv = ex.prefill([7, 3, 5, 2, 9][: 5], 16)
        for l in range(L):
            ex.write_layer("device", blocks, kk[l], vv[l])
        k[:, r, :] = blocks
    # R=2 and R=3 share the R-bucket 2->2? no: bucket(2)=2, bucket(3)=4
    out2 = ex.decode([1, 2], k[:, :2], [5, 5])
    out3 = ex.decode([1, 2, 3], k, [5, 5, 5])
    out3b = ex.decode([3, 2, 1], k, [5, 5, 5])
    assert len(out2) == 2 and len(out3) == 3 and len(out3b) == 3
    assert ex.jit_retraces["decode"] == 2  # buckets (2, ...) and (4, ...)
    # padded rows must not corrupt real rows: R=3 twice, same inputs
    assert ex.decode([1, 2, 3], k, [5, 5, 5]) == out3
    assert ex.jit_retraces["decode"] == 2  # still no new signature


def test_prefill_pad_bucketing_shares_signatures():
    ex, _ = _tiny_executor()
    ex.prefill([1, 2, 3], 8)      # bucket 16
    ex.prefill([4, 5], 16)        # bucket 16 — same signature
    ex.prefill([1] * 20, 24)      # bucket 32
    assert ex.jit_retraces["prefill"] == 2


def test_gather_layer_kv_valid_slices_to_live_blocks():
    ex, _ = _tiny_executor()
    BS = ex.block_size
    _, k, v = ex.prefill(list(range(1, 21)), 24)
    ex.write_layer("device", [3, 6, 9], k[0], v[0])
    full_k, full_v = ex.gather_layer("device", [3, 6, 9])
    part_k, part_v = ex.gather_layer("device", [3, 6, 9], kv_valid=10)
    # live prefix identical, dead tail zeroed
    live = -(-10 // BS) * BS
    np.testing.assert_array_equal(np.asarray(part_k[:live]),
                                  np.asarray(full_k[:live]))
    assert np.all(np.asarray(part_k[live:]) == 0)
    assert np.all(np.asarray(part_v[live:]) == 0)
    zk, zv = ex.gather_layer("device", [3, 6, 9], kv_valid=0)
    assert zk.shape == full_k.shape and np.all(np.asarray(zk) == 0)
    assert np.all(np.asarray(zv) == 0)


def test_mixed_step_time_fused_arm():
    """The fused arm charges one weight stream: never slower than the
    two-call arm, strictly faster when the decode side was param-bound."""
    cm = CostModel(LLAMA2_7B, L20)
    t_chunk = cm.chunk_prefill_time(64, 512)
    for B, ctx in [(1, 128), (8, 512), (32, 2048)]:
        two = cm.mixed_step_time(t_chunk, B, ctx)
        fused = cm.mixed_step_time(t_chunk, B, ctx, fused=True)
        assert fused <= two + 1e-12
    # decode-bound iteration (tiny chunk): dropping the duplicated param
    # stream must strictly help
    t_small = cm.chunk_prefill_time(1, 0)
    assert cm.mixed_step_time(t_small, 8, 256, fused=True) \
        < cm.mixed_step_time(t_small, 8, 256)
    # no decode batch / no chunk: arms agree
    assert cm.mixed_step_time(t_chunk, 0, 0, fused=True) \
        == cm.mixed_step_time(t_chunk, 0, 0)
    assert cm.mixed_step_time(0.0, 4, 128, fused=True) \
        == cm.mixed_step_time(0.0, 4, 128)


def test_engine_fused_requires_chunked():
    cfg = dataclasses.replace(get_smoke_config("granite-3-2b"),
                              dtype="float32")
    with pytest.raises(ValueError):
        LayerKVEngine(cfg, None, EngineConfig(fused=True, chunked=False))


# ------------------------------------------------------------- real engine

def _workload(cfg, n, plen_range, out_range, seed=0, arrivals=False):
    r0 = np.random.RandomState(seed)
    reqs = []
    for i in range(n):
        plen = int(r0.randint(*plen_range))
        reqs.append(Request(
            rid=f"r{i}", prompt_len=plen,
            output_len=int(r0.randint(*out_range)),
            arrival=float(i) * 1e-6 if arrivals else 0.0,
            prompt=[int(x) for x in r0.randint(0, cfg.vocab_size, plen)]))
    return reqs


def _run_engine(cfg, reqs, ndb=40, fused=False, chunk_size=24,
                prefix_cache=False):
    eng = LayerKVEngine(
        cfg, None,
        EngineConfig(policy="layerkv", slo_aware=False,
                     num_device_blocks=ndb, num_host_blocks=512,
                     block_size=8, chunked=True, chunk_size=chunk_size,
                     fused=fused, prefix_cache=prefix_cache),
        rng=jax.random.PRNGKey(42))
    done = eng.run(reqs)
    return {r.rid: r.generated for r in done}, eng


@pytest.mark.slow
def test_engine_fused_lossless_dense():
    """THE fused guarantee: one forward per iteration (chunks attending
    straight against the pools) never changes generated tokens."""
    cfg = dataclasses.replace(get_smoke_config("granite-3-2b"),
                              dtype="float32")
    mk = lambda: _workload(cfg, 4, (28, 52), (8, 14))
    out_two, _ = _run_engine(cfg, mk(), fused=False)
    out_f, eng = _run_engine(cfg, mk(), fused=True)
    assert max(r.n_chunks for r in eng.done) > 1, "workload must chunk"
    assert out_two == out_f
    # steady state reuses bucketed signatures: far fewer mixed traces
    # than iterations
    iters = sum(r.n_chunks + r.tokens_out for r in eng.done)
    assert 0 < eng.ex.jit_retraces["mixed"] < iters


@pytest.mark.slow
def test_engine_fused_lossless_moe():
    cfg = dataclasses.replace(get_smoke_config("deepseek-moe-16b"),
                              dtype="float32")
    mk = lambda: _workload(cfg, 3, (28, 48), (6, 12), seed=3)
    out_two, _ = _run_engine(cfg, mk(), fused=False)
    out_f, _ = _run_engine(cfg, mk(), fused=True)
    assert out_two == out_f


@pytest.mark.slow
def test_engine_fused_lossless_tight_pool_offload():
    """Tight pool forces layer-wise offload DURING chunked prefill: the
    fused step must read host-tier segments (two-pool kernel variant) and
    still match the two-call engine."""
    cfg = dataclasses.replace(get_smoke_config("granite-3-2b"),
                              dtype="float32")
    mk = lambda: _workload(cfg, 5, (28, 52), (8, 16), seed=2)
    out_two, _ = _run_engine(cfg, mk(), ndb=30)
    out_f, eng = _run_engine(cfg, mk(), ndb=30, fused=True)
    n_off = len([t for t in eng.off.ledger.log if t.kind == "offload"])
    n_rel = len([t for t in eng.off.ledger.log if t.kind == "reload"])
    assert n_off > 0 and n_rel > 0, "pool must be tight enough to offload"
    assert any(sig[1][-1] for sig in eng.ex._jit_sigs
               if sig[0] == "mixed"), "host-tier fused step must run"
    assert out_two == out_f


@pytest.mark.slow
def test_engine_fused_lossless_prefix_cache_hits():
    """Prefix-cache hits start the fused chunk at prefill_done =
    cached_len: q_offset > 0 against shared blocks, tokens unchanged."""
    cfg = dataclasses.replace(get_smoke_config("granite-3-2b"),
                              dtype="float32")
    r0 = np.random.RandomState(5)
    shared = [int(x) for x in r0.randint(0, cfg.vocab_size, 24)]

    def mk():
        reqs = []
        for i in range(4):
            tail = [int(x) for x in np.random.RandomState(100 + i)
                    .randint(0, cfg.vocab_size, 14)]
            p = shared + tail
            reqs.append(Request(rid=f"r{i}", prompt_len=len(p),
                                output_len=8, arrival=float(i) * 1e-6,
                                prompt=p))
        return reqs

    out_two, _ = _run_engine(cfg, mk(), ndb=64, chunk_size=16,
                             prefix_cache=True)
    out_f, eng = _run_engine(cfg, mk(), ndb=64, chunk_size=16,
                             prefix_cache=True, fused=True)
    assert any(r.cached_prompt_len > 0 for r in eng.done), \
        "workload must actually hit the cache"
    assert out_two == out_f
