"""Observability layer (src/repro/obs): registry, tracer, exporters.

What is pinned here:

  * registry semantics — labelled counters/gauges, Counter-shaped views,
    Prometheus-rendered snapshots, cross-replica snapshot merging;
  * EXACT TTFT attribution — for every finished request, on both
    backends and across the scheduling axes, the cause-labelled
    intervals of `Tracer.ttft_breakdown` sum to the measured TTFT
    bit-for-bit (the telescoping-partition contract trace.py documents),
    including through a vLLM recompute-preemption reopen;
  * event coverage — every member of EVENT_TYPES is emitted by some
    reachable scenario (lifecycle, preemption, shed, cancel, cluster
    faults), so the documented vocabulary never rots;
  * zero overhead when off — a `trace=False` run never imports
    `repro.obs.trace` (subprocess-checked) and is BIT-IDENTICAL to an
    untraced run on every scheduling arm;
  * export validity — the Chrome-trace JSON loads, timestamps are
    monotone per track, durations non-negative, and the Prometheus text
    round-trips the snapshot.
"""
import json
import random
import subprocess
import sys

import pytest

from repro.configs.llama2_7b import CONFIG as LLAMA2_7B
from repro.core import DEVICE, HOST
from repro.obs import ATTRIBUTION_CAUSES, EVENT_TYPES, MetricsRegistry
from repro.obs.export import perfetto_trace, prometheus_text
from repro.obs.trace import Tracer
from repro.serving.cluster import ClusterSession
from repro.serving.costmodel import L20
from repro.serving.faults import FaultPlan
from repro.serving.request import Request
from repro.serving.scheduler import ServeConfig
from repro.serving.session import ServingSession
from repro.serving.sim import ServingSimulator
from repro.serving.workload import multi_tenant

EPS = 1e-9


def _sim(**kw):
    base = dict(policy="layerkv", num_device_blocks=2048,
                num_host_blocks=1 << 14, trace=True)
    base.update(kw)
    return ServingSimulator(LLAMA2_7B, L20, ServeConfig.for_sim(**base))


def _reqs(n=10, prompt=256, output=32, rate=8.0, seed=0):
    rng = random.Random(seed)
    t, out = 0.0, []
    for i in range(n):
        t += rng.expovariate(rate)
        out.append(Request(rid=f"r{i}", prompt_len=prompt,
                           output_len=output, arrival=t))
    return out


def _assert_exact(done, tracer):
    bks = tracer.breakdowns()
    for r in done:
        assert r.rid in bks, f"{r.rid} has no finalized breakdown"
        total = sum(bks[r.rid].values())
        assert abs(total - r.ttft) < EPS, \
            f"{r.rid}: sum {total} != ttft {r.ttft} ({bks[r.rid]})"
        assert set(bks[r.rid]) <= set(ATTRIBUTION_CAUSES)


# ------------------------------------------------------------- registry ---

def test_registry_counters_gauges_and_views():
    reg = MetricsRegistry()
    reg.inc("a")
    reg.inc("a", 2.0)
    reg.inc("b", kind="x")
    reg.inc("b", 3.0, kind="y")
    reg.set_gauge("g", 7.0, tier="device")
    reg.set_gauge("g", 5.0, tier="device")      # last write wins
    assert reg.get("a") == 3.0
    assert reg.get("b", kind="y") == 3.0
    assert reg.get("never") == 0.0              # reads never create
    assert reg.total("b") == 4.0
    assert reg.counter_view("b", "kind") == {"x": 1, "y": 3}
    snap = reg.snapshot()
    assert snap["a"] == 3.0
    assert snap['b{kind="y"}'] == 3.0
    assert snap['g{tier="device"}'] == 5.0
    stamped = reg.snapshot(replica="2")
    assert stamped['b{kind="y",replica="2"}'] == 3.0
    merged = MetricsRegistry.merge_snapshots(snap, snap)
    assert merged["a"] == 6.0


def test_prometheus_text_renders_sorted_lines():
    txt = prometheus_text({"b": 2.0, 'a{k="v"}': 1.5})
    assert txt == 'a{k="v"} 1.5\nb 2\n'
    assert prometheus_text({}) == ""


# ------------------------------------------------------ exact attribution ---

@pytest.mark.parametrize("policy", ["vllm", "layerkv"])
@pytest.mark.parametrize("chunked", [False, True],
                         ids=["exclusive", "chunked"])
def test_sim_ttft_decomposition_exact(policy, chunked):
    """The acceptance contract: sum of attributed intervals == measured
    TTFT, exactly, for every request, on both policies and both step
    semantics."""
    sim = _sim(policy=policy, chunked=chunked)
    sim.run(_reqs())
    assert len(sim.done) == 10
    _assert_exact(sim.done, sim.core.tracer)


def test_sim_decomposition_exact_under_device_pressure():
    """A pool small enough to block admission: waits get attributed to
    gate causes (not arrival_sync) and the sum stays exact."""
    sim = _sim(policy="vllm")
    sim.run(_reqs(n=16, prompt=384, output=48, rate=16.0))
    tr = sim.core.tracer
    _assert_exact(sim.done, tr)
    causes = {c for b in tr.breakdowns().values() for c in b}
    assert "gate:device_blocks" in causes
    gates = [e for e in tr.events if e["type"] == "sched_pass"
             and e["args"]["stop_gate"] == "gate:device_blocks"]
    assert gates, "no pass recorded the device gate as its stop reason"


@pytest.mark.parametrize("chunked", [False, True],
                         ids=["exclusive", "chunked"])
def test_engine_ttft_decomposition_exact(chunked):
    """Same contract on the real engine (including the exclusive
    prefill-inside-admission path), plus wall-clock stamps on every
    event."""
    import dataclasses
    import jax
    from repro.configs import get_smoke_config
    from repro.serving.engine import LayerKVEngine
    cfg = dataclasses.replace(get_smoke_config("granite-3-2b"),
                              dtype="float32")
    ec = ServeConfig.for_engine(policy="layerkv", chunked=chunked,
                                num_device_blocks=64, trace=True)
    eng = LayerKVEngine(cfg, None, ec, rng=jax.random.PRNGKey(0))
    rng = random.Random(0)
    reqs, t = [], 0.0
    for i in range(6):
        t += rng.expovariate(20.0)
        reqs.append(Request(
            rid=f"r{i}", prompt_len=24, output_len=8, arrival=t,
            prompt=[rng.randrange(cfg.vocab_size) for _ in range(24)]))
    done = eng.run(reqs)
    tr = eng.core.tracer
    assert len(tr.breakdowns()) == 6
    _assert_exact(done, tr)
    assert all("wall" in ev for ev in tr.events)
    # executor counters live on the core's registry (one namespace)
    assert eng.ex.registry is eng.core.registry
    assert sum(eng.ex.jit_retraces.values()) \
        == eng.core.registry.total("jit_retraces") > 0


def test_recompute_preemption_reopens_partition_exactly():
    """A vLLM recompute preemption resets first_token_time; the tracer
    reopens the partition (discarded decode time -> recompute_lost, the
    requeue wait -> recompute_requeue) and the invariant holds for the
    NEW first token."""
    class _Pool:
        num_blocks = 8

    class _Ledger:
        busy_until = 0.0
        log = ()

    class _Off:
        ledger = _Ledger()

    class _BM:
        tables = {}
        pools = {DEVICE: _Pool(), HOST: _Pool()}

        def num_free(self, pool):
            return 8

    class _Core:
        L = 2
        waiting = ()
        paused = ()
        bm = _BM()
        off = _Off()

        def in_flight(self):
            return 0

    tr = Tracer()
    r = Request(rid="x", prompt_len=16, output_len=8, arrival=0.0)
    r.prefill_start = 1.0
    tr.sched_pass(_Core(), 1.0, [r], None)           # queued 0..1
    r.first_token_time = 2.0
    tr.first_token(r, 2.0)                           # prefill 1..2
    assert sum(tr.ttft_breakdown("x").values()) == pytest.approx(2.0)
    r.first_token_time = -1.0                        # recompute reset
    r.n_preempted += 1
    tr.preempt(r, 5.0, mode="recompute")             # lost 2..5
    r.prefill_start = 7.0
    tr.sched_pass(_Core(), 7.0, [r], None)           # requeue 5..7
    r.first_token_time = 9.0
    tr.first_token(r, 9.0)                           # prefill 7..9
    b = tr.ttft_breakdown("x")
    assert b["recompute_lost"] == pytest.approx(3.0)
    assert b["recompute_requeue"] == pytest.approx(2.0)
    assert b["prefill"] == pytest.approx(3.0)
    assert sum(b.values()) == pytest.approx(9.0)     # == new ttft
    assert tr.breakdowns()["x"] == b                 # finalized again
    # two queued spans: the original wait and the requeue wait
    spans = [e for e in tr.events if e["type"] == "queued"]
    assert [(e["t0"], e["t1"]) for e in spans] == [(0.0, 1.0), (5.0, 7.0)]


# ----------------------------------------------------------- event battery ---

def test_every_event_type_is_emitted():
    """Union of events over reachable scenarios == EVENT_TYPES exactly:
    the documented vocabulary neither rots nor grows silently."""
    seen = set()

    def collect(*tracers):
        for tr in tracers:
            seen.update(ev["type"] for ev in tr.events)

    # lifecycle + chunked spans + mid-flight cancel
    sim = _sim(chunked=True)
    sess = ServingSession(sim)
    hs = [sess.submit(r) for r in _reqs(n=4)]   # all queued at t=0
    sess.step()
    sess.cancel(hs[-1])
    sess.drain()
    collect(sim.core.tracer)

    # lossless preemption: preempt / resume / paused
    simp = _sim(chunked=True, admission="deadline", preemption=True,
                num_device_blocks=160, block_size=16)
    reqs = [Request(rid=f"b{i}", prompt_len=400, output_len=300,
                    arrival=0.01 * i, priority=0,
                    ttft_slo=60.0, tpot_slo=10.0) for i in range(6)]
    reqs += [Request(rid=f"i{j}", prompt_len=400, output_len=40,
                     arrival=3.0 + 2 * j, priority=1,
                     ttft_slo=1.0, tpot_slo=0.5) for j in range(3)]
    simp.run(reqs)
    assert simp.core.n_preempted > 0
    collect(simp.core.tracer)

    # graceful degradation: an infeasible request is shed, not wedged
    sims = _sim(num_device_blocks=64, block_size=16, shed_overload=True)
    shed_sess = ServingSession(sims)
    shed_sess.submit(Request(rid="big", prompt_len=65536, output_len=4,
                             arrival=0.0), arrival=0.0)
    shed_sess.drain()
    assert sims.core.shed
    collect(sims.core.tracer)

    # cluster faults over a 1-replica fleet: the crash mid-burst kills
    # in-flight work, re-dispatch finds no live replica -> backoff
    # retries until the revive; manual drain_replica covers "drain"
    plan = FaultPlan.parse("crash@0.4:r0:recover=2.0", n_replicas=1)
    cl = ClusterSession([_sim(chunked=True)], fault_plan=plan)
    for r in multi_tenant(16, rate=16.0, n_tenants=2, prompt_len=256,
                          output_len=24, seed=7):
        cl.submit(r, arrival=r.arrival)
    cl.drain()
    assert cl.n_kills == 1 and cl.n_recoveries == 1
    assert cl.n_retries >= 1
    cl.drain_replica(0)
    collect(cl.tracer, *[s.core.tracer for s in cl.sessions])

    assert seen == set(EVENT_TYPES), \
        (sorted(set(EVENT_TYPES) - seen), sorted(seen - set(EVENT_TYPES)))


def test_sched_pass_decision_record_contents():
    """The per-pass decision record carries who/why plus pool occupancy
    per layer/tier and ledger activity."""
    sim = _sim(chunked=True)
    sim.run(_reqs(n=6))
    passes = [e for e in sim.core.tracer.events
              if e["type"] == "sched_pass"]
    assert passes
    gates = set(ATTRIBUTION_CAUSES) | {None}
    for p in passes:
        a = p["args"]
        assert set(a["blocked"].values()) <= set(ATTRIBUTION_CAUSES)
        assert a["stop_gate"] in gates
        for tier in (DEVICE, HOST):
            assert 0 <= a["pool"][tier]["free"] \
                <= a["pool"][tier]["total"]
        assert len(a["layer_device_blocks"]) == sim.core.L
        assert len(a["layer_host_blocks"]) == sim.core.L
        assert a["ledger"]["n_transfers"] >= 0
    admitted = {rid for p in passes for rid in p["args"]["admitted"]}
    assert admitted == {r.rid for r in sim.done}


# ------------------------------------------------------- off == identical ---

_ARMS = {
    "vllm-exclusive": dict(policy="vllm"),
    "layerkv-exclusive": dict(policy="layerkv"),
    "layerkv-chunked": dict(policy="layerkv", chunked=True),
    "layerkv-fused": dict(policy="layerkv", chunked=True, fused=True),
    "layerkv-prefix": dict(policy="layerkv", chunked=True,
                           prefix_cache=True),
    "layerkv-preempt": dict(policy="layerkv", chunked=True,
                            admission="deadline", preemption=True),
}


@pytest.mark.parametrize("arm", _ARMS, ids=list(_ARMS))
def test_trace_off_is_bit_identical(arm):
    """trace=True must OBSERVE, never steer: metrics (raw series
    included) are bit-identical with tracing on and off, on every
    scheduling arm."""
    def run(trace):
        sim = _sim(trace=trace, **_ARMS[arm])
        return sim.run(multi_tenant(14, rate=16.0, n_tenants=3,
                                    prompt_len=256, output_len=24,
                                    seed=3))
    assert run(True) == run(False)


def test_trace_off_never_imports_tracer():
    """Zero-overhead contract, checked in a pristine interpreter: a
    trace=False run never loads repro.obs.trace and installs no
    tracer."""
    code = """
import sys
from repro.configs.llama2_7b import CONFIG
from repro.serving.costmodel import L20
from repro.serving.sim import ServingSimulator
from repro.serving.scheduler import ServeConfig
from repro.serving.request import Request
sim = ServingSimulator(CONFIG, L20, ServeConfig.for_sim())
sim.run([Request(rid="r0", prompt_len=64, output_len=8, arrival=0.0)])
assert sim.core.tracer is None
assert "repro.obs.trace" not in sys.modules, "tracer imported when off"
assert "repro.obs.export" not in sys.modules, "exporter imported when off"
assert "repro.obs.registry" in sys.modules   # the always-on half
print("OK")
"""
    out = subprocess.run([sys.executable, "-c", code], cwd="src",
                         capture_output=True, text=True)
    assert out.returncode == 0, out.stderr
    assert out.stdout.strip() == "OK"


# --------------------------------------------------------------- exporters ---

def _check_chrome_trace(doc, want_pids=None):
    assert set(doc) == {"traceEvents", "displayTimeUnit"}
    evs = doc["traceEvents"]
    names = {e["name"] for e in evs if e["ph"] != "M"}
    assert names <= set(EVENT_TYPES)
    last_ts = {}
    for e in evs:
        if e["ph"] == "M":
            continue
        key = (e["pid"], e["tid"])
        assert e["ts"] >= last_ts.get(key, float("-inf")), \
            f"timestamps regressed on track {key}"
        last_ts[key] = e["ts"]
        if e["ph"] == "X":
            assert e["dur"] >= 0.0
        else:
            assert e["ph"] == "i" and e["s"] in ("t", "p")
    if want_pids is not None:
        assert {e["pid"] for e in evs} == want_pids


def test_session_write_trace_valid_chrome_json(tmp_path):
    sim = _sim(chunked=True)
    sess = ServingSession(sim)
    for r in _reqs(n=5):
        sess.submit(r, arrival=r.arrival)
    sess.drain()
    path = tmp_path / "trace.json"
    sess.write_trace(str(path))
    doc = json.loads(path.read_text())
    _check_chrome_trace(doc, want_pids={0})
    # one span track per request + the scheduler track
    tids = {e["tid"] for e in doc["traceEvents"]}
    assert len(tids) == 1 + 5


def test_write_trace_requires_tracing_on():
    sim = _sim(trace=False)
    with pytest.raises(ValueError, match="trac"):
        ServingSession(sim).write_trace("/dev/null")


def test_cluster_perfetto_merges_replicas_and_fleet_track(tmp_path):
    plan = FaultPlan.parse("crash@0.4:r0:recover=2.0", n_replicas=2)
    cl = ClusterSession([_sim(chunked=True) for _ in range(2)],
                        fault_plan=plan)
    for r in multi_tenant(16, rate=16.0, n_tenants=2, prompt_len=256,
                          output_len=24, seed=7):
        cl.submit(r, arrival=r.arrival)
    cl.drain()
    assert cl.n_kills == 1
    doc = cl.perfetto()
    _check_chrome_trace(doc, want_pids={0, 1, 2})  # 2 replicas + fleet
    labels = {e["args"]["name"] for e in doc["traceEvents"]
              if e["ph"] == "M" and e["name"] == "process_name"}
    assert labels == {"replica 0", "replica 1", "cluster"}
    kills = [e for e in doc["traceEvents"] if e["name"] == "kill"]
    assert kills and kills[0]["pid"] == 2       # on the fleet track
    path = tmp_path / "cluster.json"
    cl.write_trace(str(path))
    assert json.loads(path.read_text()) == doc
    # the fleet snapshot pools per-replica registries under a label
    snap = cl.snapshot()
    assert snap["replica_kills"] == 1.0
    assert any("replica=" in k for k in snap)
    assert "replica_kills 1\n" in prometheus_text(snap)


def test_perfetto_skips_missing_tracers():
    doc = perfetto_trace([None, Tracer()], labels=["a", "b"])
    assert all(e["pid"] == 1 for e in doc["traceEvents"])


# -------------------------------------------------- per-tenant reporting ---

def test_class_report_by_tenant():
    """`SimMetrics.class_report(by="tenant")` re-keys the pooled raw
    series on the tenant id encoded in `t{k}r{i}` rids."""
    sim = _sim(chunked=True)
    m = sim.run(multi_tenant(18, rate=16.0, n_tenants=3, prompt_len=256,
                             output_len=24, seed=5))
    rep = m.class_report(by="tenant")
    assert set(rep) <= {0, 1, 2} and len(rep) >= 2
    assert sum(e["n"] for e in rep.values()) == m.n_requests
    for e in rep.values():
        assert e["n"] > 0 and e["mean_ttft"] > 0.0
        assert e["p99_ttft"] >= e["mean_ttft"] * 0.5
        assert e["goodput"] >= 0.0 and e["n_shed"] == 0
        assert "n_retries" not in e        # tracked per priority only
    # default axis unchanged (back-compat): priority classes
    by_prio = m.class_report()
    assert set(by_prio) == {0}
    assert "n_retries" in by_prio[0]
    with pytest.raises(ValueError, match="tenant"):
        m.class_report(by="bogus")


def test_class_report_tenant_pools_foreign_rids_under_minus_one():
    from repro.serving.sim import SimMetrics
    m = SimMetrics(ttft=[1.0, 2.0], queuing=[0.0, 0.0],
                   prefill_lat=[0.0, 0.0], tpot=[0.0, 0.0],
                   finish_times=[1.0, 2.0], tokens_out=4, makespan=2.0,
                   slo_violations=0, n_requests=2, preemptions=0,
                   priorities=[0, 0], tbt=[0.0, 0.0],
                   deadline_slack=[1.0, 1.0], req_tokens=[2, 2],
                   rids=["t1r0", "plain"])
    rep = m.class_report(by="tenant")
    assert set(rep) == {-1, 1}
    assert rep[1]["mean_ttft"] == pytest.approx(1.0)
    assert rep[-1]["mean_ttft"] == pytest.approx(2.0)
