"""PHASE001 corpus (known-good twin): the registry is total over the
enum and the cancel dispatch covers every live queue."""
import enum


class Phase(enum.Enum):
    QUEUED = 0
    PREFILL = 1
    DECODE = 2
    PAUSED = 3


PHASE_QUEUES = {
    Phase.QUEUED: "waiting",
    Phase.PREFILL: "prefilling",
    Phase.DECODE: "decoding",
    Phase.PAUSED: "paused",
}
LIVE_QUEUES = ("waiting", "prefilling", "decoding", "paused")


class Core:
    def cancel(self, r):
        if r in self.waiting:
            self.waiting.remove(r)
        elif r in self.prefilling:
            self.prefilling.remove(r)
        elif r in self.decoding:
            self.decoding.remove(r)
        elif r in self.paused:
            self.paused.remove(r)
