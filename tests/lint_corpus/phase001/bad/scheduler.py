"""PHASE001 corpus (known-bad): a PHASE_QUEUES registry missing an enum
member, and a cancel path that forgets the paused queue. Never
executed — parsed only."""
import enum


class Phase(enum.Enum):
    QUEUED = 0
    PREFILL = 1
    DECODE = 2
    PAUSED = 3


PHASE_QUEUES = {
    Phase.QUEUED: "waiting",
    Phase.PREFILL: "prefilling",
    Phase.DECODE: "decoding",
}  # BAD: no entry for Phase.PAUSED
LIVE_QUEUES = ("waiting", "prefilling", "decoding", "paused")


class Core:
    def cancel(self, r):
        if r in self.waiting:        # BAD: dispatch never tests 'paused'
            self.waiting.remove(r)
        elif r in self.prefilling:
            self.prefilling.remove(r)
        elif r in self.decoding:
            self.decoding.remove(r)
