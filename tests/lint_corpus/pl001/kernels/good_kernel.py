"""PL001 corpus (known-good twin): program ids hoisted to kernel top
level and closed over — the pattern the real kernels use."""
from jax.experimental import pallas as pl


def kernel(o_ref):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        o_ref[0] = j  # closes over the hoisted id

    def _finalize():
        o_ref[1] = i

    pl.when(i == 1)(_finalize)
    pl.when(i == 2)(lambda: o_ref[j])
