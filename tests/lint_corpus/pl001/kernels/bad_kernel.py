"""PL001 corpus (known-bad): pl.program_id read inside pl.when bodies,
one per form the rule understands. Never executed — parsed only."""
from jax.experimental import pallas as pl


def kernel(o_ref):
    i = pl.program_id(0)  # fine: top level

    @pl.when(i == 0)
    def _init():
        o_ref[0] = pl.program_id(1)  # BAD: decorator form

    def _finalize():
        o_ref[1] = pl.program_id(0)  # BAD: call form

    pl.when(i == 1)(_finalize)
    pl.when(i == 2)(lambda: o_ref[pl.program_id(0)])  # BAD: lambda form
