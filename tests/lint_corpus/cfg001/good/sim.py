"""CFG001 corpus: the sim backend's read sites."""


def run(sc):
    return (sc.policy, sc.live_knob, sc.sim_knob)
