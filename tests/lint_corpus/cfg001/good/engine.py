"""CFG001 corpus: the engine backend's read sites."""


def run(sc):
    return (sc.policy, sc.live_knob, sc.engine_knob)
