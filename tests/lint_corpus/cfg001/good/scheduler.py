"""CFG001 corpus (known-good twin): every field is read by the backend
set its section claims."""
import dataclasses


@dataclasses.dataclass
class ServeConfig:
    # ---- scheduling axes (shared) -------------------------------------
    policy: str = "layerkv"
    live_knob: int = 0        # read by both backends
    # ---- engine-only ---------------------------------------------------
    engine_knob: int = 1      # engine.py reads it, sim.py does not
    # ---- sim-only --------------------------------------------------------
    sim_knob: int = 2         # sim.py reads it, engine.py does not
