"""CFG001 corpus: the sim backend's read sites."""


def run(sc):
    return (sc.policy, sc.sim_knob, sc.engine_knob)
