"""CFG001 corpus (known-bad): dead and misplaced ServeConfig fields.
Never executed — parsed only; the sibling sim.py/engine.py files are
the backend read sites the rule cross-references."""
import dataclasses


@dataclasses.dataclass
class ServeConfig:
    # ---- scheduling axes (shared) -------------------------------------
    policy: str = "layerkv"
    dead_knob: int = 0        # BAD: read by nobody
    # ---- engine-only ---------------------------------------------------
    engine_knob: int = 1      # BAD: the engine never reads it
    # ---- sim-only --------------------------------------------------------
    sim_knob: int = 2         # ok: sim.py reads it
