"""CFG001 corpus: the engine backend's read sites."""


def run(sc):
    return sc.policy
