"""OBS001 corpus (known-bad): tracer emissions reachable with tracing
off — a bare `self.tracer` call, a call through a chained
`core.tracer`, and a local alias called without testing it. Never
executed — parsed only."""


class Core:
    def __init__(self, sc):
        self.tracer = None

    def finish(self, r, now):
        self.tracer.finish(r, now)  # BAD: crashes every trace=False run
        return r

    def admit(self, core, admitted, now):
        core.tracer.sched_pass(core, now, admitted, None)  # BAD
        return admitted

    def pump(self, r, now):
        tracer = self.tracer
        tracer.cancel(r, now)  # BAD: alias never tested
