"""OBS001 corpus (known-good): the same emission shapes with the
zero-overhead contract honoured — an `is not None` branch guard, a
guarded chained access, an alias tested before the call, and an
`and`-chain guard. Value reads without a call are exempt. Never
executed — parsed only."""


class Core:
    def __init__(self, sc):
        self.tracer = None

    def finish(self, r, now):
        if self.tracer is not None:
            self.tracer.finish(r, now)
        return r

    def admit(self, core, admitted, now):
        if core.tracer is not None:
            core.tracer.sched_pass(core, now, admitted, None)
        return admitted

    def pump(self, r, now):
        tracer = self.tracer
        if tracer is not None:
            tracer.cancel(r, now)

    def emitted(self, r, now):
        return self.tracer is not None and self.tracer.events

    def export(self, tracers):
        # a tracer handed to an exporter is a value read, not an
        # emission — the exporter skips None entries itself
        return [t for t in [self.tracer] + tracers if t is not None]
