"""SEAM001 corpus (known-good twin): the same ranking expressed through
the read-only observer API and policy-local state."""


class AdmissionPolicy:
    def order(self, waiting, now, core):
        raise NotImplementedError


class GreedyAdmission(AdmissionPolicy):
    name = "greedy"

    def __init__(self):
        self._calls = 0  # policy-local state is fine

    def order(self, waiting, now, core):
        self._calls += 1
        keyed = []
        for i, r in enumerate(waiting):
            eta = core.admit_eta(r, now)       # observer API
            hit = core.cached_hint(r)          # observer API
            keyed.append((eta - hit, r.arrival, i, r))
        keyed.sort(key=lambda k: k[:3])
        return [r for _, _, _, r in keyed]
