"""SEAM001 corpus (known-bad): a policy that mutates core and request
state instead of observing it. Never executed — parsed only."""


class AdmissionPolicy:
    def order(self, waiting, now, core):
        raise NotImplementedError


class GreedyAdmission(AdmissionPolicy):
    name = "greedy"

    def order(self, waiting, now, core):
        best = sorted(waiting, key=lambda r: r.arrival)
        core.preempt_request(best[0])  # BAD: mutating call on core
        for r in waiting:
            r.priority = 99            # BAD: writes through argument
        core.waiting.clear()           # BAD: non-read call on core state
        return best
