"""MC001 corpus (known-bad): a scheduler whose shed path pools the
paused queue into an overload-shed sweep (so a PAUSED request takes the
QUEUED-only SHED edge), and a force-finish shortcut that jumps QUEUED
straight to FINISHED. Never executed — parsed only; the model checker
must reach both bugs and pin their phase-write lines with traces."""


PHASE_QUEUES = {
    Phase.QUEUED: "waiting",
    Phase.PREFILL: "prefilling",
    Phase.DECODE: "decoding",
    Phase.PAUSED: "paused",
    Phase.FINISHED: "done",
    Phase.CANCELLED: "cancelled",
    Phase.SHED: "shed",
}
LIVE_QUEUES = ("waiting", "prefilling", "decoding", "paused")


class SchedulerCore:
    def admit_waiting(self, now):
        r = next((q for q in self.waiting if q is not None), None)
        if r is None:
            return
        self.waiting.remove(r)
        r.phase = Phase.PREFILL
        self.prefilling.append(r)

    def preempt_request(self, r, now):
        if r in self.waiting or r in self.paused:
            return False
        if r in self.prefilling:
            self.prefilling.remove(r)
        elif r in self.decoding:
            self.decoding.remove(r)
        else:
            return False
        r.phase = Phase.PAUSED
        self.paused.append(r)
        return True

    def cancel(self, r, now):
        if r in self.waiting:
            self.waiting.remove(r)
        elif r in self.prefilling:
            self.prefilling.remove(r)
        elif r in self.decoding:
            self.decoding.remove(r)
        elif r in self.paused:
            self.paused.remove(r)
        else:
            return False
        r.phase = Phase.CANCELLED
        self.cancelled.append(r)
        return True

    def shed_request(self, r, reason, now):
        if r in self.waiting:
            self.waiting.remove(r)
        r.phase = Phase.SHED
        self.shed.append(r)

    def shed_blocked(self, now):
        # BAD: the shed sweep pools paused work in with the waiting
        # queue, so a PAUSED request reaches shed_request (whose
        # contract is waiting-only) and takes an illegal SHED edge
        # while still sitting in the paused queue.
        pool = list(self.waiting) + list(self.paused)
        r = next((q for q in pool if q is not None), None)
        if r is None:
            return False
        self.shed_request(r, "overload", now)
        return True

    def force_finish(self, r, now):
        if r not in self.waiting:
            return False
        self.waiting.remove(r)
        r.phase = Phase.FINISHED  # BAD: QUEUED -> FINISHED skips work
        self.done.append(r)
        return True
