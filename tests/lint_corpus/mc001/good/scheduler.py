"""MC001 corpus (known-good twin): the shed sweep only ever binds
waiting requests and finishing goes through the decode queue, so every
reachable transition stays inside the declared edge set."""


PHASE_QUEUES = {
    Phase.QUEUED: "waiting",
    Phase.PREFILL: "prefilling",
    Phase.DECODE: "decoding",
    Phase.PAUSED: "paused",
    Phase.FINISHED: "done",
    Phase.CANCELLED: "cancelled",
    Phase.SHED: "shed",
}
LIVE_QUEUES = ("waiting", "prefilling", "decoding", "paused")


class SchedulerCore:
    def admit_waiting(self, now):
        r = next((q for q in self.waiting if q is not None), None)
        if r is None:
            return
        self.waiting.remove(r)
        r.phase = Phase.PREFILL
        self.prefilling.append(r)

    def preempt_request(self, r, now):
        if r in self.waiting or r in self.paused:
            return False
        if r in self.prefilling:
            self.prefilling.remove(r)
        elif r in self.decoding:
            self.decoding.remove(r)
        else:
            return False
        r.phase = Phase.PAUSED
        self.paused.append(r)
        return True

    def cancel(self, r, now):
        if r in self.waiting:
            self.waiting.remove(r)
        elif r in self.prefilling:
            self.prefilling.remove(r)
        elif r in self.decoding:
            self.decoding.remove(r)
        elif r in self.paused:
            self.paused.remove(r)
        else:
            return False
        r.phase = Phase.CANCELLED
        self.cancelled.append(r)
        return True

    def shed_request(self, r, reason, now):
        if r in self.waiting:
            self.waiting.remove(r)
        r.phase = Phase.SHED
        self.shed.append(r)

    def shed_blocked(self, now):
        # the sweep draws from the waiting queue only: every request it
        # binds is QUEUED, so the SHED edge it takes is legal
        r = next((q for q in self.waiting if q is not None), None)
        if r is None:
            return False
        self.shed_request(r, "overload", now)
        return True

    def force_finish(self, r, now):
        if r in self.decoding:
            self.decoding.remove(r)
            r.phase = Phase.FINISHED
            self.done.append(r)
            return True
        return False
