"""UNIT001 corpus (known-good twin): the same accounting routed
through the sanctioned converter, so every dimension lines up."""
from typing import TypeAlias

Tokens: TypeAlias = int
Blocks: TypeAlias = int


def tokens_to_blocks(n_tokens: Tokens, block_size: int) -> Blocks:
    return -(-n_tokens // block_size) if n_tokens > 0 else 0


def can_admit(free_blocks: Blocks, prompt_len: Tokens,
              block_size: int) -> bool:
    return free_blocks >= tokens_to_blocks(prompt_len, block_size)


def remaining_budget(budget: Tokens, used: Tokens) -> Tokens:
    return budget - used


def reserve(prompt_len: Tokens, block_size: int) -> Blocks:
    return tokens_to_blocks(prompt_len, block_size)
