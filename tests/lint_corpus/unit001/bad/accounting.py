"""UNIT001 corpus (known-bad): KV accounting that mixes block counts
and token counts without converting. Never executed — parsed only."""
from typing import TypeAlias

Tokens: TypeAlias = int
Blocks: TypeAlias = int


def tokens_to_blocks(n_tokens: Tokens, block_size: int) -> Blocks:
    return -(-n_tokens // block_size) if n_tokens > 0 else 0


def can_admit(free_blocks: Blocks, prompt_len: Tokens) -> bool:
    return free_blocks >= prompt_len  # BAD: blocks compared to tokens


def remaining_budget(budget: Tokens, held: Blocks) -> Tokens:
    return budget - held  # BAD: tokens minus blocks


def reserve(held: Blocks, block_size: int) -> Blocks:
    return tokens_to_blocks(held, block_size)  # BAD: blocks as tokens
