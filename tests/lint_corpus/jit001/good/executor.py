"""JIT001 corpus (known-good twin): every width is bucketed, wrapped in
an array, or declared static before it crosses jax.jit."""
import functools

import jax
import jax.numpy as jnp


def _bucket(n, q=64):
    return max(q, (n + q - 1) // q * q)


class Executor:
    def __init__(self):
        self._decode_fn = jax.jit(self._decode,
                                  static_argnames=("cap",))

    @functools.partial(jax.jit, static_argnums=(0, 2))
    def _forward(self, x, width):
        return x[:width]

    def _decode(self, x, width, cap):
        return x[:width], cap

    def step(self, x, toks):
        n = len(toks)
        nb = _bucket(n)
        self._forward(x, nb)                     # ok: width is static
        self._forward(x, _bucket(128))           # ok: bucketed
        self._decode_fn(x, jnp.asarray(n), cap=4)  # ok: array + static
        self._decode_fn(x, nb, cap=4)            # ok: bucketed name
