"""JIT001 corpus (known-bad): raw Python ints crossing jax.jit as
traced arguments. Never executed — parsed only."""
import functools

import jax


def _bucket(n, q=64):
    return max(q, (n + q - 1) // q * q)


class Executor:
    def __init__(self):
        self._decode_fn = jax.jit(self._decode,
                                  static_argnames=("cap",))

    @functools.partial(jax.jit, static_argnums=0)
    def _forward(self, x, width):
        return x[:width]

    def _decode(self, x, width, cap):
        return x[:width], cap

    def step(self, x, toks):
        n = len(toks)
        self._forward(x, n)                    # BAD: len() traced
        self._forward(x, 128)                  # BAD: int literal traced
        self._decode_fn(x, _bucket(n), cap=4)  # ok: bucketed + static
        self._decode_fn(x, n + 1, cap=4)       # BAD: tainted arithmetic
