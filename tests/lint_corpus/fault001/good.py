"""FAULT001 corpus (known-good): the same shapes with the opt-in
contract honoured — None defaults, an `is not None` branch guard, and
an `and`-chain guard. Never executed — parsed only."""


class Cluster:
    def __init__(self, backends, fault_plan=None):
        self.faults = fault_plan

    def step(self, now):
        if self.faults is not None:
            self.faults.poll(self, now)
        return True

    def dispatchable(self, i, now):
        return self.faults is None or not (
            self.faults is not None and self.faults.dispatch_fails(i, now))

    def next_wedge(self, wedged):
        if self.faults is not None:
            return min(wedged, key=lambda k: self.faults.wedge_end(k))
        return None


def attach(cluster, *, faults=None):
    cluster.faults = faults
