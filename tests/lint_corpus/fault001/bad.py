"""FAULT001 corpus (known-bad): fault hooks live by default — a
constructed `fault_plan` default, an unguarded call through `.faults`,
and a kw-only `faults` defaulting to an instance. Never executed —
parsed only."""


class FaultPlan:
    pass


class Cluster:
    def __init__(self, backends,
                 fault_plan=FaultPlan()):  # BAD: ambient fault plan
        self.faults = fault_plan

    def step(self, now):
        self.faults.poll(self, now)  # BAD: no `is not None` guard
        return True


def attach(cluster, *, faults=FaultPlan()):  # BAD: kw-only non-None
    cluster.faults = faults
