"""Lossless priority preemption + deadline-aware admission.

Pins the PR's three contracts:

  1. LOSSLESS — a preempted-then-resumed request produces exactly the
     tokens an uninterrupted run produces (engine, real forward passes),
     and in the simulator finishes its full output with ZERO recompute
     (the vLLM-recompute counter stays 0; pause/resume moves KV, it
     never discards it).
  2. OFF == TODAY — with `preemption=False` (the default), and even with
     `preemption=True` under a homogeneous priority class, the paused
     queue stays empty and scheduling is bit-identical to the
     pre-preemption scheduler.
  3. DEADLINE ORDERING — the `deadline` admission policy serves by
     virtual deadline (EDF with a bounded priority boost), so a tight
     interactive arrival overtakes queued batch work, but only within
     its aging window (no starvation).
"""
import dataclasses

import pytest

from repro.configs import get_config
from repro.core import DEVICE, HOST
from repro.serving.costmodel import L20
from repro.serving.request import Phase, Request
from repro.serving.scheduler import DeadlineAdmission, ServeConfig
from repro.serving.sim import ServingSimulator, SimMetrics, pooled_percentile

LLAMA2_7B = get_config("llama2-7b")


def _mix(n_batch=6, n_int=3):
    """Long batch requests that fill a small pool, then tight-deadline
    interactive arrivals that must preempt to meet their SLO."""
    reqs = [Request(rid=f"b{i}", prompt_len=400, output_len=300,
                    arrival=0.01 * i, priority=0,
                    ttft_slo=60.0, tpot_slo=10.0) for i in range(n_batch)]
    reqs += [Request(rid=f"i{j}", prompt_len=400, output_len=40,
                     arrival=3.0 + 2 * j, priority=1,
                     ttft_slo=1.0, tpot_slo=0.5) for j in range(n_int)]
    return reqs


# ------------------------------------------------------ admission order ----

def test_deadline_admission_interactive_overtakes():
    """A later-arriving priority-1 request with a tight deadline orders
    ahead of earlier batch work (EDF + priority boost)."""
    batch = Request(rid="b", prompt_len=100, output_len=10,
                    arrival=0.0, priority=0, ttft_slo=3.0)
    inter = Request(rid="i", prompt_len=100, output_len=10,
                    arrival=1.0, priority=1, ttft_slo=0.75)
    pol = DeadlineAdmission(age_frac=0.5)
    assert [r.rid for r in pol.order([batch, inter], 1.0, None)] \
        == ["i", "b"]


def test_deadline_admission_aging_bound():
    """The priority boost is BOUNDED: an interactive request arriving
    far enough after a batch request orders behind it — the batch
    request's real deadline has aged past the boost window, so it is
    never starved by an endless interactive stream."""
    batch = Request(rid="b", prompt_len=100, output_len=10,
                    arrival=0.0, priority=0, ttft_slo=3.0)
    # boost window = age_frac * ttft_slo = 0.375s; vdl_i = arrival + 0.375
    late = Request(rid="i", prompt_len=100, output_len=10,
                   arrival=10.0, priority=1, ttft_slo=0.75)
    pol = DeadlineAdmission(age_frac=0.5)
    assert [r.rid for r in pol.order([batch, late], 10.0, None)] \
        == ["b", "i"]


def test_deadline_admission_paused_keys_by_next_token():
    """A paused mid-decode request is keyed by its NEXT-token due time
    (last token + TPOT SLO), not its long-gone first-token deadline."""
    paused = Request(rid="p", prompt_len=100, output_len=10,
                     arrival=0.0, priority=0, tpot_slo=0.2, ttft_slo=3.0)
    paused.phase = Phase.PAUSED
    paused.last_token_time = 9.9          # next token due 10.1
    fresh = Request(rid="f", prompt_len=100, output_len=10,
                    arrival=8.0, priority=0, ttft_slo=3.0)  # dl 11.0
    pol = DeadlineAdmission()
    assert [r.rid for r in pol.order([fresh, paused], 10.0, None)] \
        == ["p", "f"]


# -------------------------------------------------- victim affordability ---

def test_victim_affordable_scales_with_resume_bytes():
    """A victim with ample deadline slack affords a small resume charge
    but not one whose h2d promotion would eat its whole budget."""
    from repro.core.predictor import OraclePredictor
    from repro.core.slo_scheduler import SLOScheduler
    from repro.serving.costmodel import CostModel
    slo = SLOScheduler(CostModel(LLAMA2_7B, L20),
                       OraclePredictor([64], accuracy=1.0))
    r = Request(rid="v", prompt_len=128, output_len=64,
                arrival=0.0, ttft_slo=5.0)
    assert slo.preempt_slack(r, now=1.0) == pytest.approx(4.0)
    assert slo.victim_affordable(r, 1.0, resume_bytes=L20.offload_bw * 1.0,
                                 offload_bw=L20.offload_bw)
    assert not slo.victim_affordable(r, 1.0,
                                     resume_bytes=L20.offload_bw * 8.0,
                                     offload_bw=L20.offload_bw)


# ------------------------------------------------------ sim losslessness ---

@pytest.mark.parametrize("chunked", [True, False],
                         ids=["chunked", "exclusive"])
def test_sim_preemption_lossless_under_overload(chunked):
    """Tight pool + deadline admission + preemption: interactive
    arrivals pause batch KV to HOST, every request still finishes its
    FULL output, nothing is recomputed, and the pools drain to
    baseline."""
    sc = ServeConfig.for_sim(policy="layerkv", chunked=chunked,
                             admission="deadline", preemption=True,
                             num_device_blocks=160, block_size=16)
    sim = ServingSimulator(LLAMA2_7B, L20, sc)
    m = sim.run(_mix())
    assert m.n_requests == 9
    assert sim.core.n_preempted > 0            # preemption actually fired
    assert sim.core.n_resumed == sim.core.n_preempted
    assert sim.preemptions == 0                # zero recompute-preemptions
    assert all(r.tokens_out == r.output_len for r in sim.done)
    # the interactive class got its first token well inside its 1s SLO
    int_ttft = [r.ttft for r in sim.done if r.priority == 1]
    assert int_ttft and max(int_ttft) < 1.0
    sim.finish()                               # pools back to baseline


def test_sim_preemption_vllm_policy_resumes_whole_kv():
    """Under the vLLM-style baseline policy (no layer-wise streaming) a
    paused request resumes only when its ENTIRE KV fits again — every
    pause is matched by a resume and every request still finishes its
    full output. (The policy's OWN recompute-eviction path may also fire
    under this load; that legacy mechanism is orthogonal and unchanged —
    only the layerkv arm pins it to zero.)"""
    sc = ServeConfig.for_sim(policy="vllm", chunked=True,
                             admission="deadline", preemption=True,
                             num_device_blocks=2048, block_size=16)
    sim = ServingSimulator(LLAMA2_7B, L20, sc)
    reqs = [Request(rid=f"b{i}", prompt_len=200, output_len=100,
                    arrival=0.01 * i, priority=0,
                    ttft_slo=60.0, tpot_slo=10.0) for i in range(6)]
    reqs += [Request(rid=f"i{j}", prompt_len=200, output_len=20,
                     arrival=1.0 + j, priority=1,
                     ttft_slo=0.5, tpot_slo=0.2) for j in range(3)]
    sim.run(reqs)
    assert sim.core.n_preempted > 0
    assert sim.core.n_resumed == sim.core.n_preempted
    assert all(r.tokens_out == r.output_len for r in sim.done)
    sim.finish()


def test_sim_forced_preempt_pause_visible_and_resumes():
    """Forcing a pause mid-decode via the public API parks the request
    (phase PAUSED, KV on HOST, counted in LoadStats.n_paused) and the
    admission pass resumes it to completion with no recompute."""
    from repro.serving.session import ServingSession
    sc = ServeConfig.for_sim(policy="layerkv", chunked=True,
                             admission="deadline", preemption=True,
                             num_device_blocks=512, block_size=16)
    sim = ServingSimulator(LLAMA2_7B, L20, sc)
    sess = ServingSession(sim)
    reqs = [Request(rid=f"r{i}", prompt_len=200, output_len=60,
                    arrival=0.0) for i in range(3)]
    hs = [sess.submit(r, arrival=0.0) for r in reqs]
    forced = False
    while sess.step():
        if not forced and reqs[0] in sim.core.decoding \
                and reqs[0].tokens_out >= 3:
            assert sim.core.preempt_request(reqs[0], sim.core.now)
            assert reqs[0].phase is Phase.PAUSED
            assert hs[0].paused and not hs[0].done
            assert not sim.bm.layers_on("r0", DEVICE)
            assert sim.bm.layers_on("r0", HOST)
            assert sim.core.load_stats().n_paused == 1
            sim.bm.check()
            forced = True
    assert forced
    sess.drain()
    assert sim.core.n_preempted == 1 and sim.core.n_resumed == 1
    assert sim.preemptions == 0
    assert all(r.tokens_out == r.output_len for r in reqs)
    assert reqs[0].n_preempted == 1
    sim.finish()


# ------------------------------------------------- off == today (inert) ----

def test_preemption_off_and_homogeneous_priority_identical():
    """Three arms on one workload: (a) preemption off, (b) preemption on
    but every request in the same priority class, (c) default config.
    (a) and (b) must be BIT-IDENTICAL (no strictly-lower victim ever
    exists, so the controller never fires) and (c) must equal (a)
    (the feature defaults off)."""
    def run(**kw):
        sc = ServeConfig.for_sim(policy="layerkv", chunked=True,
                                 num_device_blocks=256, block_size=16, **kw)
        sim = ServingSimulator(LLAMA2_7B, L20, sc)
        reqs = [Request(rid=f"r{i}", prompt_len=300, output_len=80,
                        arrival=0.05 * i) for i in range(8)]
        sim.run(reqs)
        assert sim.core.n_preempted == 0 and not sim.core.paused
        return [(r.rid, r.ttft, r.finish_time) for r in sim.done]

    off = run(preemption=False)
    on_flat = run(preemption=True)
    default = run()
    assert off == on_flat == default


# ----------------------------------------------------- engine identity -----

def test_engine_preempt_resume_token_identity():
    """REAL forward passes: pause r0 mid-decode (KV demoted to HOST,
    physically copied), resume it, and the generated token ids are
    EXACTLY those of an uninterrupted run — the KV bytes survived the
    round trip through the host pool."""
    jax = pytest.importorskip("jax")
    from repro.configs import get_smoke_config
    from repro.serving.engine import LayerKVEngine
    from repro.serving.session import ServingSession
    import numpy as np

    cfg = dataclasses.replace(get_smoke_config("granite-3-2b"),
                              dtype="float32")

    def mkreqs(seed=1):
        rng = np.random.RandomState(seed)
        return [Request(rid=f"r{i}", prompt_len=24, output_len=8,
                        arrival=0.0,
                        prompt=[int(x) for x in
                                rng.randint(0, cfg.vocab_size, 24)])
                for i in range(3)]

    sc = ServeConfig.for_engine(policy="layerkv", preemption=True,
                                admission="deadline",
                                num_device_blocks=96, block_size=8)
    e1 = LayerKVEngine(cfg, None, sc, rng=jax.random.PRNGKey(0))
    ref = {r.rid: list(r.generated) for r in e1.run(mkreqs())}

    e2 = LayerKVEngine(cfg, None, sc, rng=jax.random.PRNGKey(0))
    sess = ServingSession(e2)
    reqs = mkreqs()
    for r in reqs:
        sess.submit(r, arrival=0.0)
    preempted = False
    while True:
        if not preempted:
            v = [r for r in e2.decoding
                 if r.rid == "r0" and r.tokens_out >= 3]
            if v:
                assert e2.core.preempt_request(v[0], e2.now)
                assert v[0].phase is Phase.PAUSED
                preempted = True
        if not sess.step():
            break
    got = {r.rid: list(r.generated) for r in sess.drain()}
    assert preempted
    assert e2.core.n_preempted == 1 and e2.core.n_resumed == 1
    assert got == ref
    e2.finish()


# -------------------------------------------------- pooled percentiles -----

def test_pooled_percentile_nearest_rank():
    s = [0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9, 1.0]
    assert pooled_percentile(s, 0.50) == 0.5     # ceil(0.5*10)=5th
    assert pooled_percentile(s, 0.99) == 1.0
    assert pooled_percentile([3.0], 0.99) == 3.0
    assert pooled_percentile(list(reversed(s)), 0.50) == 0.5  # order-free


def test_class_report_pools_raw_series_across_merge():
    """Per-class percentiles come from the POOLED raw series, not from
    averaging per-part percentiles — merging parts then slicing by class
    must equal a hand computation over the concatenated values."""
    def mk(ttft, makespan, priorities, tbt, slack, toks):
        return SimMetrics(
            ttft=ttft, queuing=[0.0] * len(ttft),
            prefill_lat=[0.0] * len(ttft), tpot=[0.01] * len(ttft),
            finish_times=[makespan] * len(ttft), tokens_out=sum(toks),
            makespan=makespan, slo_violations=0, n_requests=len(ttft),
            preemptions=0, priorities=priorities, tbt=tbt,
            deadline_slack=slack, req_tokens=toks)

    a = mk([0.1, 0.9], 10.0, [1, 0], [0.02, 0.03], [0.5, -0.1], [10, 20])
    b = mk([0.3, 0.7], 12.0, [1, 1], [0.04, 0.05], [0.2, 0.4], [30, 40])
    m = SimMetrics.merge([a, b])
    rep = m.class_report()
    assert set(rep) == {0, 1}
    assert rep[1]["n"] == 3 and rep[0]["n"] == 1
    # pooled class-1 TTFT series is [0.1, 0.3, 0.7]
    assert rep[1]["p99_ttft"] == pooled_percentile([0.1, 0.3, 0.7], 0.99)
    assert rep[1]["mean_ttft"] == pytest.approx((0.1 + 0.3 + 0.7) / 3)
    assert rep[0]["deadline_violation_rate"] == 1.0   # slack -0.1
    assert rep[1]["deadline_violation_rate"] == 0.0
    # goodput gates tokens on deadline-met: class 0's 20 tokens violated
    assert m.deadline_violations == 1
    assert m.goodput == pytest.approx((10 + 30 + 40) / 12.0)
