"""Training substrate: loss goes down, checkpoint round-trips, optimizer
math properties.

Degrades to a skip on minimal installs (same as test_core_properties):
`hypothesis` is an optional test dependency and the suite must still
collect without it.
"""
import dataclasses
import tempfile

import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need the optional 'hypothesis' test dependency")

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from hypothesis import given, settings  # noqa: E402
import hypothesis.strategies as st  # noqa: E402

from repro.configs import get_smoke_config  # noqa: E402
from repro.training import checkpoint  # noqa: E402
from repro.training.data import DataConfig, SyntheticLM  # noqa: E402
from repro.training.optimizer import (  # noqa: E402
    AdamWConfig, adamw_update, init_opt_state, lr_at,
)
from repro.training.train_loop import train  # noqa: E402


@pytest.mark.slow
def test_loss_decreases_dense():
    cfg = dataclasses.replace(get_smoke_config("qwen2.5-3b"),
                              dtype="float32")
    res = train(cfg, steps=80, dc=DataConfig(batch_size=8, seq_len=64),
                verbose=False)
    assert res.final_loss < res.losses[0] - 0.8


@pytest.mark.slow
def test_loss_decreases_ssm():
    cfg = dataclasses.replace(get_smoke_config("xlstm-1.3b"),
                              dtype="float32")
    res = train(cfg, steps=80, dc=DataConfig(batch_size=8, seq_len=64),
                verbose=False)
    assert res.final_loss < res.losses[0] - 0.5


def test_lr_schedule_shape():
    oc = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    assert float(lr_at(oc, 0)) == 0.0
    assert abs(float(lr_at(oc, 10)) - 1e-3) < 1e-9
    assert float(lr_at(oc, 100)) == pytest.approx(1e-4, rel=1e-3)
    # monotone decay after warmup
    vals = [float(lr_at(oc, s)) for s in range(10, 101, 10)]
    assert vals == sorted(vals, reverse=True)


@given(st.floats(0.1, 10.0))
@settings(max_examples=20, deadline=None)
def test_grad_clip_bounds_update(scale):
    oc = AdamWConfig(lr=1e-3, clip_norm=1.0, weight_decay=0.0)
    params = {"w": jnp.ones((4, 4))}
    grads = {"w": jnp.full((4, 4), scale)}
    st_ = init_opt_state(params)
    _, _, m = adamw_update(oc, grads, st_, params)
    assert float(m["grad_norm"]) == pytest.approx(scale * 4.0, rel=1e-4)


def test_checkpoint_roundtrip():
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    with tempfile.TemporaryDirectory() as d:
        checkpoint.save(d, tree, meta={"step": 7})
        restored, meta = checkpoint.load(d, tree)
    assert meta["step"] == 7
    np.testing.assert_array_equal(np.asarray(tree["a"]),
                                  restored["a"])
    np.testing.assert_array_equal(
        np.asarray(tree["b"]["c"], dtype=np.float32),
        np.asarray(restored["b"]["c"], dtype=np.float32))


def test_synthetic_data_learnable_structure():
    cfg = get_smoke_config("granite-3-2b")
    gen = SyntheticLM(cfg, DataConfig(batch_size=4, seq_len=32, noise=0.0,
                                      seed=1))
    b = next(gen.batches())
    # deterministic chain: same context token -> same successor
    toks = np.asarray(b["tokens"])
    labels = np.asarray(b["labels"])
    mapping = {}
    clashes = 0
    for row_t, row_l in zip(toks, labels):
        for t, l in zip(row_t, row_l):
            if t in mapping and mapping[t] != l:
                clashes += 1
            mapping[t] = l
    assert clashes == 0
