"""Three-term roofline analysis from the dry-run's compiled artifacts.

Per (arch x shape) on the single-pod 16x16 mesh:

    compute term    = FLOPs / (chips * 197e12)
    memory term     = HBM bytes / (chips * 819e9)
    collective term = collective bytes / (chips * links * 50e9)

Methodology notes (CPU-only container — structural analysis, no wall time):
  * XLA `cost_analysis()` counts a `lax.scan` body ONCE; every model here
    scans over layers (and the train step scans over microbatches), so raw
    HLO numbers describe one layer. We report BOTH the raw value and a
    scan-corrected estimate:
        X_total ~= X_top + iters * X_body,
    with X_body ~= X_raw - X_top_analytic, where the non-loop share (lm
    head + loss + optimizer) is estimated analytically. Collectives are
    split body/top by the HLO parser directly.
  * MODEL_FLOPS is the analytic useful-work count (6*N_active*D for train
    incl. backward, 2*N_active*T + attention terms for inference), giving
    the MODEL_FLOPS / HLO_FLOPS utilization ratio the spec asks for.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Optional

from repro.configs import SHAPES, get_config
from repro.configs.base import InputShape, ModelConfig

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip
ICI_BW = 50e9            # bytes/s / link
ICI_LINKS = 4            # v5e: 4 links per chip (2D torus)


# ---------------------------------------------------------------------------
# analytic model FLOPs (global, whole step)
# ---------------------------------------------------------------------------

def attention_flops(cfg: ModelConfig, tokens: int, ctx: int) -> float:
    """2 * 2 * L_attn * H * hd * tokens * ctx (QK^T and PV), causal halves
    the prefill case."""
    hd = cfg.resolved_head_dim
    return (4.0 * cfg.n_attention_layers() * cfg.n_q_heads * hd
            * tokens * ctx)


def model_flops(cfg: ModelConfig, shp: InputShape) -> float:
    """Useful FLOPs of one step (global, forward [+backward for train])."""
    N = cfg.active_param_count()
    B, S = shp.global_batch, shp.seq_len
    if shp.kind == "train":
        T = B * S
        base = 6.0 * N * T                      # fwd 2ND + bwd 4ND
        attn = 3.0 * attention_flops(cfg, T, S) * 0.5   # causal avg ctx S/2
        return base + attn
    if shp.kind == "prefill":
        T = B * S
        return 2.0 * N * T + attention_flops(cfg, T, S) * 0.5
    # decode: one token per sequence against ctx of S (or the SW window)
    ctx = S
    if S > 32768 and cfg.sliding_window:
        ctx = cfg.sliding_window
    if cfg.family == "ssm":
        ctx = 0  # recurrent state, no KV attention
    return 2.0 * N * B + attention_flops(cfg, B, ctx)


def hbm_bytes_analytic(cfg: ModelConfig, shp: InputShape) -> float:
    """Minimum HBM traffic of one step (global): weights once (+opt state
    r/w for train), KV/state cache r/w, activation stream."""
    f = 2  # bf16
    B, S = shp.global_batch, shp.seq_len
    N = cfg.active_param_count()
    Ntot = cfg.param_count()
    act_stream = 4.0 * B * S * cfg.d_model * f * cfg.n_layers
    if shp.kind == "train":
        # params + grads + adam m/v (f32) read+write, remat re-read
        return Ntot * (2 + 4 * 3 * 2) + act_stream * 2
    if shp.kind == "prefill":
        kv = cfg.kv_bytes_per_token() * B * S
        return N * f + act_stream + kv
    ctx = S if not (S > 32768 and cfg.sliding_window) else cfg.sliding_window
    if cfg.family == "ssm":
        kv = 0.0
    else:
        kv = cfg.kv_bytes_per_token() * B * ctx
    return N * f + kv + 4.0 * B * cfg.d_model * f * cfg.n_layers


# ---------------------------------------------------------------------------
# scan-iteration counts (for body-once corrections)
# ---------------------------------------------------------------------------

def layer_iters(cfg: ModelConfig) -> int:
    """Effective body multiplier. Hybrid/xlstm nest an inner per-superblock
    scan whose body is counted once, so the HLO 'body' ~ one inner layer
    (+ the superblock's shared part); n_layers is the consistent
    multiplier across every family (slight overcount of the shared
    attention / sLSTM share, noted in EXPERIMENTS.md)."""
    return cfg.n_layers


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    hlo_flops_corrected: float
    useful_ratio: float
    bytes_per_device_gib: float
    fits_hbm: bool
    notes: str = ""

    def row(self):
        return (f"{self.arch:24s} {self.shape:12s} {self.mesh:8s} "
                f"C={self.compute_s*1e3:9.3f}ms M={self.memory_s*1e3:9.3f}ms "
                f"X={self.collective_s*1e3:9.3f}ms -> {self.dominant:10s} "
                f"useful={self.useful_ratio:5.2f} "
                f"mem={self.bytes_per_device_gib:6.2f}GiB"
                f"{' OVER-HBM' if not self.fits_hbm else ''}")


def analyze_record(rec: dict) -> Optional[Roofline]:
    if not rec.get("ok"):
        return None
    cfg = get_config(rec["arch"])
    shp = SHAPES[rec["shape"]]
    chips = rec["n_devices"]
    iters = layer_iters(cfg)
    mb = rec.get("microbatches", 1)
    total_iters = iters * (mb if shp.kind == "train" else 1)

    # --- compute ------------------------------------------------------------
    mf = model_flops(cfg, shp)
    # scan correction on reported (per-device) flops: treat the whole
    # reported value as one body pass plus shared top-level work; the
    # analytic non-loop share for these models is <2% of a body, so
    # X_total ~= X_raw * total_iters is the working estimate.
    hlo_flops_dev = rec.get("flops", 0.0)
    hlo_flops_total = hlo_flops_dev * total_iters * chips
    # prefill attention runs inside nested q/kv chunk scans whose bodies are
    # also counted once — take max with the analytic count
    compute_s = max(hlo_flops_total, mf) / chips / PEAK_FLOPS

    # --- memory ---------------------------------------------------------
    # scan-corrected HLO bytes double-count the (non-loop) optimizer and
    # logits traffic iters times; the analytic minimum-traffic model is the
    # honest memory term on this container (see module docstring)
    hbm_total = hbm_bytes_analytic(cfg, shp)
    memory_s = hbm_total / chips / HBM_BW

    # --- collectives ------------------------------------------------------
    coll = rec.get("collectives", {})
    body = coll.get("body_bytes", 0)
    top = coll.get("top_bytes", coll.get("total_bytes", 0))
    coll_total = body * total_iters + top  # per-device bytes
    collective_s = coll_total / (ICI_LINKS * ICI_BW)

    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    gib = rec.get("bytes_per_device", 0) / 2**30
    return Roofline(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant, model_flops=mf,
        hlo_flops_corrected=hlo_flops_total,
        useful_ratio=mf / hlo_flops_total if hlo_flops_total else 0.0,
        bytes_per_device_gib=gib, fits_hbm=gib <= 16.0)


def analyze_file(path: str, mesh: str = "16x16"):
    recs = json.load(open(path))
    out = []
    for r in recs:
        if r.get("mesh") != mesh:
            continue
        a = analyze_record(r)
        if a:
            out.append(a)
    return out


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default="results/dryrun_results.json")
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args()
    rows = analyze_file(args.results, args.mesh)
    print(f"{'arch':24s} {'shape':12s} {'mesh':8s} roofline terms")
    for r in rows:
        print(r.row())
    # dominant-term histogram
    from collections import Counter
    print("\ndominant terms:", dict(Counter(r.dominant for r in rows)))


if __name__ == "__main__":
    main()
