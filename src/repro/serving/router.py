"""Routing policies for the cluster serving subsystem.

At fleet scale the *router* decides which replica's queue a request
joins — and, because the PR 2 prefix cache is per-replica, whether it
lands on the replica that already holds its prefix. Dispatch is
therefore the single biggest lever on both queueing delay (the paper's
dominant TTFT term) and the effective cache hit rate.

A `RoutingPolicy` sees the shared `SchedulerCore` of every replica
(load introspection only — policies never mutate a core) and picks a
replica index per request at its ARRIVAL time. Four built-ins:

  round_robin      static striping; the load-oblivious baseline;
  least_loaded     join-shortest-queue by outstanding KV-block demand
                   (`SchedulerCore.load_stats().kv_demand`): blocks held
                   by in-flight requests plus the minimum blocks the
                   waiting queue still needs;
  prefix_affinity  route by the prompt's block-hash chain so repeat
                   prefixes rendezvous on the replica whose cache holds
                   them (probed via `match_prefix`, with a
                   highest-random-weight hash of the first full block
                   breaking ties before any replica has registered it),
                   plus a load-based spillover threshold priced in the
                   request's own prefill economics so a hot template
                   cannot hotspot one replica into unbounded queueing;
  slo_aware        route to the replica whose Alg.1 slack admits the
                   request soonest (`SchedulerCore.admit_eta`: queued
                   Eq.3 prefill work plus the part of the request's own
                   prefill the Eq.1 decode slack cannot absorb). With
                   deadline admission the ETA is preemption-adjusted:
                   only same-or-higher-priority queued work counts,
                   since lower-priority work orders behind the request
                   (and with preemption on can even be paused for it).

Every policy breaks ties toward the lowest replica index, so routing is
deterministic — the cluster benchmarks and the cluster-of-1 identity
tests rely on reproducible dispatch.
"""
from __future__ import annotations

from typing import List, Sequence

from repro.core.block_manager import block_hashes
from repro.serving.request import Request
from repro.serving.scheduler import SchedulerCore


class RoutingPolicy:
    """Picks the replica a request is dispatched to. `choose` runs once
    per request, at the request's arrival on the cluster's shared
    virtual clock; `cores` are the replicas' scheduler cores in replica
    order. Implementations must be read-only observers of the cores."""

    name = "?"

    def choose(self, request: Request, cores: Sequence[SchedulerCore],
               now: float) -> int:
        raise NotImplementedError


class RoundRobinRouting(RoutingPolicy):
    """Static striping, load- and content-oblivious (the baseline every
    load-aware policy must beat)."""

    name = "round_robin"

    def __init__(self):
        self._next = 0

    def choose(self, request, cores, now):
        i = self._next % len(cores)
        self._next += 1
        return i


def _least(loads: List, by_tokens: bool = False) -> int:
    if by_tokens:
        return min(range(len(loads)),
                   key=lambda i: (loads[i].token_demand, i))
    return min(range(len(loads)), key=lambda i: (loads[i].kv_demand, i))


class LeastLoadedRouting(RoutingPolicy):
    """Join-shortest-queue on outstanding KV-block demand. Queue length
    in *blocks* (not requests) is the right unit here: the paper's core
    finding is that TTFT is dominated by queueing for KV blocks, so a
    replica with few-but-huge prompts queued is more loaded than one
    with many tiny ones.

    With `ServeConfig.route_by_tokens` the key switches to outstanding
    TOKEN demand (`LoadStats.token_demand`): queued uncached prefill
    suffixes plus live context. Blocks weigh a replica by pool
    pressure, tokens by the compute it still owes — under heavy prefix
    sharing the two rankings differ (a replica whose queue is all cache
    hits owes little compute but still needs the blocks). Default off:
    block-demand routing is the paper's join-shortest-queue."""

    name = "least_loaded"

    def choose(self, request, cores, now):
        by_tokens = bool(cores) and cores[0].sc.route_by_tokens
        return _least([c.load_stats() for c in cores], by_tokens)


class PrefixAffinityRouting(RoutingPolicy):
    """Rendezvous dispatch on the prompt's block-hash chain, with a
    load-based spillover threshold priced in prefill economics.

    Preference order: replicas holding a longer cached prefix of the
    prompt come first (probed with the same `match_prefix` admission
    uses); ties — including the all-cold case before any replica has
    registered the template — break by highest-random-weight rendezvous
    on the hash of the prompt's FIRST full block (the head of the
    content-addressing chain every cached block commits to), so all
    requests of a template agree on a home replica, and on the same
    deterministic spill SEQUENCE, even before the first one finishes
    prefilling.

    Spillover (consistent-hashing-with-bounded-loads shaped): walk the
    preference order and take the first replica whose estimated
    admission delay (`SchedulerCore.admit_eta` — queued Eq.3 prefill
    work against Eq.1 decode slack) is within the spill budget of the
    cluster-wide minimum. The budget is priced in the request's OWN
    prefill economics: `spill_frac * (saved + cold)`, where `saved` is
    the Eq.3 compute the candidate's cached prefix would skip and
    `cold` the full-prompt prefill cost. Waiting a little for a big hit
    is worth it; waiting longer than the recompute it avoids is not —
    so a hot template spills to its (deterministic) next-preferred
    replica exactly when affinity stops paying for itself, and a fresh
    template tolerates only a small backlog before placing by load. A
    spilled request re-prefills and registers the prefix on the spill
    target, so hot templates organically replicate instead of
    hotspotting one replica."""

    name = "prefix_affinity"

    def __init__(self, spill_frac: float = 0.5):
        self.spill_frac = spill_frac

    def choose(self, request, cores, now):
        toks = request.prompt
        if not toks:
            # nothing to rendezvous on: place by load
            return _least([c.load_stats() for c in cores])
        bs = cores[0].bm.block_size
        anchor = block_hashes(toks, bs)[0] if len(toks) >= bs \
            else hash(tuple(toks))
        matches = [c.bm.match_prefix(toks) for c in cores]
        etas = [c.admit_eta(request, now) for c in cores]
        eta_min = min(etas)
        pref = sorted(range(len(cores)),
                      key=lambda i: (-matches[i], hash((anchor, i))))
        for i in pref:
            cold = cores[i].cost.chunk_prefill_time(request.prompt_len, 0)
            saved = cold - cores[i].cost.chunk_prefill_time(
                request.prompt_len - matches[i], matches[i])
            if etas[i] <= eta_min + self.spill_frac * (saved + cold):
                return i
        return min(range(len(cores)), key=lambda i: (etas[i], i))


class SLOAwareRouting(RoutingPolicy):
    """Route to the replica whose Alg.1 slack admits the request
    soonest. `admit_eta` prices the Eq.3 prefill work queued ahead of
    the request plus whatever part of its own prefill the decode batch's
    Eq.1 slack cannot absorb — under deadline admission only
    same-or-higher-priority queued work counts (lower-priority work
    orders behind the request, and with preemption on can be paused for
    it); KV-block demand breaks ETA ties (two empty replicas -> the
    emptier pool)."""

    name = "slo_aware"

    def choose(self, request, cores, now):
        keyed = [(c.admit_eta(request, now),
                  c.load_stats().kv_demand, i)
                 for i, c in enumerate(cores)]
        return min(keyed)[2]


ROUTING_POLICIES = {
    RoundRobinRouting.name: RoundRobinRouting,
    LeastLoadedRouting.name: LeastLoadedRouting,
    PrefixAffinityRouting.name: PrefixAffinityRouting,
    SLOAwareRouting.name: SLOAwareRouting,
}


def make_routing_policy(spec) -> RoutingPolicy:
    """str name -> fresh policy instance; a RoutingPolicy passes through
    (policies are stateful — round_robin's cursor — so instances are
    never shared between clusters)."""
    if isinstance(spec, RoutingPolicy):
        return spec
    if spec not in ROUTING_POLICIES:
        raise ValueError(f"unknown routing policy {spec!r}; choose from "
                         f"{sorted(ROUTING_POLICIES)}")
    return ROUTING_POLICIES[spec]()
