"""Discrete-event serving simulator.

Drives the *production* LayerKV decision logic (block manager, offload
engine, SLO scheduler, forecast) with a simulated clock and the Eq.3/4 cost
model, reproducing the paper's 7B-70B figures on a CPU-only box. The only
thing swapped vs. the real engine is the executor: step latencies come from
`CostModel` instead of measured JAX step times.

Everything decision-shaped — admission (policy-ordered, Alg.1 budgeted),
the device-need gate, the Eq.4 layer-split allocation, chunk assembly,
cache-copy ledger routing, cancellation — lives in the shared
`SchedulerCore` (serving/scheduler.py); the real engine drives the SAME
core, so the two frontends cannot drift. The simulator keeps only what is
simulation-specific: pricing iterations with the cost model, Eq.5
proactive eviction, preemption-by-recompute, and the §3.1.3 collective
reservation.

Engine-step semantics (ServeConfig.chunked selects the second mode):

  exclusive  vLLM 0.5.5 (the paper's baseline): iteration-level batching;
             prefills run exclusively, stalling the decode batch; decode
             batches every running sequence; preemption-by-recompute when a
             decode step cannot get a block.
  chunked    chunked prefill with mixed batching: each prompt is split into
             scheduler-controlled chunks under a per-iteration token budget
             (max_prefill_tokens, tightened by Eq.1 slack when slo_aware);
             chunk tokens batch WITH the decode tokens, so an iteration
             costs max(chunk compute, decode compute) instead of their sum.
             `ServeConfig.fused` additionally prices the iteration as the
             fused single-forward executor (one weight stream), mirroring
             the real engine's fused axis.

Policies (orthogonal to the step semantics):
  'vllm'     request-wise allocation: a prefill is admitted only when KV
             blocks for ALL layers of the whole prompt are free on device.
  'layerkv'  layer-wise allocation (paper): device blocks for the x retained
             layers (+1 transient send-buffer layer), the remaining L-x
             layers stream to host hidden under prefill compute; optional
             SLO-aware admission (Alg. 1) and Eq.5 proactive eviction.

The simulator is driven through a `ServingSession` (serving/session.py):
submit/stream/cancel online, or the batch `run(requests)` wrapper.
"""
from __future__ import annotations

import dataclasses
import math
import re
import statistics
from typing import List, Optional

from repro.configs.base import ModelConfig
from repro.core import (
    AvailabilityForecast, DEVICE, HOST, LayerwiseBlockManager, OffloadEngine,
    PoolExhausted, SLOScheduler,
)
from repro.core.predictor import LengthPredictor, OraclePredictor
from repro.core.units import Blocks, Bytes, Seconds
from repro.serving.costmodel import CostModel, HWProfile
from repro.serving.request import Phase, Request
from repro.serving.scheduler import CoreDelegateMixin, SchedulerCore, \
    ServeConfig
from repro.serving.session import ServingSession


def SimConfig(**kw) -> ServeConfig:
    """Deprecated shim: builds a `ServeConfig` with the historical
    simulator defaults (derived device blocks, 2^20 host blocks, batch
    256, chunk floor 16)."""
    return ServeConfig.for_sim(**kw)


def pooled_percentile(series: List[float], q: float = 0.99) -> float:
    """Nearest-rank percentile over a RAW pooled series: the
    ceil(q*n)-th smallest. Every percentile in SimMetrics — cluster-wide
    or per-class — goes through this one helper over concatenated raw
    series; averaging per-replica (or slicing per-class from truncated)
    percentiles understates the tail exactly when load is imbalanced."""
    if not series:
        return 0.0
    s = sorted(series)
    return s[min(len(s), math.ceil(q * len(s))) - 1]


@dataclasses.dataclass
class SimMetrics:
    """Per-run serving metrics. Carries RAW per-request series (not
    just aggregates) so `merge` can pool seeds without averaging
    averages — percentiles over merged runs are pooled nearest-rank,
    and `class_report()` re-slices everything by priority class."""
    ttft: List[float]
    queuing: List[float]
    prefill_lat: List[float]
    tpot: List[float]
    finish_times: List[float]
    tokens_out: int
    makespan: float
    slo_violations: int
    n_requests: int
    preemptions: int
    # chunked-mode accounting (zero in exclusive mode)
    chunk_iters: int = 0                 # iterations that carried a chunk
    max_iter_prefill_tokens: int = 0     # largest per-iteration chunk total
    # prefix-cache accounting (zero with the cache off)
    prefix_hit_tokens: int = 0           # prompt tokens served from cache
    prefix_lookup_tokens: int = 0        # prompt tokens looked up
    n_cancelled: int = 0                 # session cancellations (excluded
    #                                      from every latency series above)
    # per-request series ALIGNED with ttft/tpot/... (same index = same
    # request), so per-class slices stay raw series and percentiles pool
    # correctly across replicas
    priorities: List[int] = dataclasses.field(default_factory=list)
    tbt: List[float] = dataclasses.field(default_factory=list)
    #   ^ per-request MAX inter-token gap (s) — the stall preemption causes
    deadline_slack: List[float] = dataclasses.field(default_factory=list)
    #   ^ effective_deadline - first_token_time (s); negative = violated
    req_tokens: List[int] = dataclasses.field(default_factory=list)
    #   ^ tokens generated per request (goodput numerator, deadline-gated)
    # fault-tolerance accounting (all zero/empty without a FaultPlan or
    # shed_overload — the counters exist so degraded runs stay auditable)
    n_shed: int = 0                      # requests rejected under overload
    shed_priorities: List[int] = dataclasses.field(default_factory=list)
    shed_reasons: List[str] = dataclasses.field(default_factory=list)
    #   ^ AdmissionImpossible subclass names, aligned with shed_priorities
    n_retries: int = 0                   # dispatch retries (backoff spins)
    retry_priorities: List[int] = dataclasses.field(default_factory=list)
    n_redispatched: int = 0              # restarts after a replica kill
    redispatch_priorities: List[int] = dataclasses.field(
        default_factory=list)
    n_replica_kills: int = 0
    n_replica_recoveries: int = 0
    # request ids ALIGNED with ttft/priorities/... — carry the tenant
    # encoding (rid "t{k}r{i}") so class_report(by="tenant") can re-key
    # the same raw series without a second bookkeeping path
    rids: List[str] = dataclasses.field(default_factory=list)
    shed_rids: List[str] = dataclasses.field(default_factory=list)

    @classmethod
    def merge(cls, parts: List["SimMetrics"]) -> "SimMetrics":
        """Pool per-replica metrics into cluster-level metrics. Raw
        latency SERIES are concatenated and the derived statistics
        (means, p99, throughput) recomputed over the pooled data —
        averaging per-replica percentiles is statistically wrong and
        understates the tail exactly when replicas are imbalanced,
        which is what routing policies differ on. Counters sum;
        makespan / max_iter_prefill_tokens take the max."""
        return cls(
            ttft=[t for m in parts for t in m.ttft],
            queuing=[t for m in parts for t in m.queuing],
            prefill_lat=[t for m in parts for t in m.prefill_lat],
            tpot=[t for m in parts for t in m.tpot],
            finish_times=[t for m in parts for t in m.finish_times],
            tokens_out=sum(m.tokens_out for m in parts),
            makespan=max((m.makespan for m in parts), default=0.0),
            slo_violations=sum(m.slo_violations for m in parts),
            n_requests=sum(m.n_requests for m in parts),
            preemptions=sum(m.preemptions for m in parts),
            chunk_iters=sum(m.chunk_iters for m in parts),
            max_iter_prefill_tokens=max(
                (m.max_iter_prefill_tokens for m in parts), default=0),
            prefix_hit_tokens=sum(m.prefix_hit_tokens for m in parts),
            prefix_lookup_tokens=sum(
                m.prefix_lookup_tokens for m in parts),
            n_cancelled=sum(m.n_cancelled for m in parts),
            priorities=[p for m in parts for p in m.priorities],
            tbt=[t for m in parts for t in m.tbt],
            deadline_slack=[s for m in parts for s in m.deadline_slack],
            req_tokens=[n for m in parts for n in m.req_tokens],
            n_shed=sum(m.n_shed for m in parts),
            shed_priorities=[p for m in parts for p in m.shed_priorities],
            shed_reasons=[s for m in parts for s in m.shed_reasons],
            n_retries=sum(m.n_retries for m in parts),
            retry_priorities=[p for m in parts
                              for p in m.retry_priorities],
            n_redispatched=sum(m.n_redispatched for m in parts),
            redispatch_priorities=[p for m in parts
                                   for p in m.redispatch_priorities],
            n_replica_kills=sum(m.n_replica_kills for m in parts),
            n_replica_recoveries=sum(
                m.n_replica_recoveries for m in parts),
            rids=[r for m in parts for r in m.rids],
            shed_rids=[r for m in parts for r in m.shed_rids],
        )

    @property
    def mean_ttft(self):
        return statistics.mean(self.ttft) if self.ttft else 0.0

    @property
    def p99_ttft(self):
        """Nearest-rank p99 over the pooled raw series (int(0.99*n) was
        an off-by-one that indexed the MAX at n=100)."""
        return pooled_percentile(self.ttft, 0.99)

    @property
    def p99_tbt(self):
        return pooled_percentile(self.tbt, 0.99)

    @property
    def mean_tbt(self):
        vals = [t for t in self.tbt if t > 0]
        return statistics.mean(vals) if vals else 0.0

    @property
    def deadline_violations(self) -> int:
        return sum(1 for s in self.deadline_slack if s < 0)

    @property
    def deadline_violation_rate(self) -> float:
        return self.deadline_violations / max(len(self.deadline_slack), 1)

    @property
    def goodput(self) -> float:
        """Tokens/s from requests that met their first-token deadline
        (tokens that arrive too late to matter don't count — the
        SLO-attainment throughput the deadline scheduler optimizes)."""
        if self.makespan <= 0:
            return 0.0
        good = sum(n for n, s in zip(self.req_tokens, self.deadline_slack,
                                     strict=True) if s >= 0)
        return good / self.makespan

    @staticmethod
    def _tenant_of(rid: str) -> int:
        """Tenant id encoded in a multi-tenant rid (``t{k}r{i}``);
        -1 for rids outside that convention (single-tenant runs)."""
        m = re.match(r"^t(\d+)r\d+$", rid)
        return int(m.group(1)) if m else -1

    def class_report(self, by: str = "priority") -> dict:
        """Per-class metrics, computed by slicing the ALIGNED raw series
        and running the same pooled nearest-rank path as the
        cluster-wide percentiles (never recomputed from pre-truncated
        per-replica statistics). `by="priority"` (default) keys on the
        priority class; `by="tenant"` keys on the tenant id parsed from
        rids shaped ``t{k}r{i}`` (everything else pools under -1) —
        per-tenant tail latency and goodput from ONE run's series. Each
        entry reports n / mean+p99 TTFT / p99 TBT / deadline-violation
        rate / goodput share (tokens per second from deadline-met
        requests) / requests shed under overload; priority entries add
        the remaining fault-tolerance counters (dispatch retries,
        kill-restart re-dispatches — which classes degradation actually
        lands on), which are tracked per priority only."""
        if by == "tenant":
            keys = [self._tenant_of(r) for r in self.rids]
            shed_keys = [self._tenant_of(r) for r in self.shed_rids]
            retry_keys: List[int] = []
            redispatch_keys: List[int] = []
        elif by == "priority":
            keys = self.priorities
            shed_keys = self.shed_priorities
            retry_keys = self.retry_priorities
            redispatch_keys = self.redispatch_priorities
        else:
            raise ValueError(
                f"class_report: unknown axis {by!r} "
                "(expected 'priority' or 'tenant')")
        out: dict = {}
        classes = set(keys) | set(shed_keys) | set(retry_keys) \
            | set(redispatch_keys)
        for cls_id in sorted(classes):
            idx = [i for i, p in enumerate(keys) if p == cls_id]
            ttft = [self.ttft[i] for i in idx]
            slack = [self.deadline_slack[i] for i in idx]
            toks = [self.req_tokens[i] for i in idx]
            entry = {
                "n": len(idx),
                "mean_ttft": statistics.mean(ttft) if ttft else 0.0,
                "p99_ttft": pooled_percentile(ttft, 0.99),
                "p99_tbt": pooled_percentile(
                    [self.tbt[i] for i in idx], 0.99),
                "deadline_violation_rate":
                    sum(1 for s in slack if s < 0) / max(len(slack), 1),
                "goodput": (sum(n for n, s in zip(toks, slack, strict=True)
                                if s >= 0) / self.makespan)
                    if self.makespan > 0 else 0.0,
                "n_shed": sum(1 for p in shed_keys if p == cls_id),
            }
            if by == "priority":
                entry["n_retries"] = sum(
                    1 for p in retry_keys if p == cls_id)
                entry["n_redispatched"] = sum(
                    1 for p in redispatch_keys if p == cls_id)
            out[cls_id] = entry
        return out

    @property
    def prefix_hit_rate(self):
        return self.prefix_hit_tokens / self.prefix_lookup_tokens \
            if self.prefix_lookup_tokens else 0.0

    @property
    def mean_tpot(self):
        vals = [t for t in self.tpot if t > 0]
        return statistics.mean(vals) if vals else 0.0

    @property
    def mean_queuing(self):
        return statistics.mean(self.queuing) if self.queuing else 0.0

    @property
    def mean_prefill(self):
        return statistics.mean(self.prefill_lat) if self.prefill_lat else 0.0

    @property
    def throughput(self):
        return self.tokens_out / self.makespan if self.makespan > 0 else 0.0

    @property
    def violation_rate(self):
        return self.slo_violations / max(self.n_requests, 1)


class DeviceMemoryError(ValueError):
    """Params + activation reservation exceed the device memory budget."""


def derive_device_blocks(cfg: ModelConfig, hw: HWProfile,
                         sim: ServeConfig) -> Blocks:
    """vLLM-style profiling: KV pool = gpu_mem_util * (mem - params -
    activations(max_model_len)); longer max context -> more activation
    reservation -> fewer KV blocks (paper §2.2). Raises DeviceMemoryError
    (naming the shortfall) instead of silently returning a zero-block pool
    that would later die with a confusing scheduling deadlock."""
    L = max(cfg.n_attention_layers(), 1)
    param_bytes = cfg.param_count() * hw.f_precision
    act_bytes = 2 * sim.max_model_len * cfg.d_model * 24 * hw.f_precision
    budget = hw.mem_bytes * sim.gpu_mem_util
    free = budget - param_bytes - act_bytes
    kv_per_block = 2 * cfg.n_kv_heads * cfg.resolved_head_dim \
        * hw.f_precision * sim.block_size  # one layer's block
    blocks = int(free // kv_per_block) // L * L if free > 0 else 0
    if blocks < L:
        raise DeviceMemoryError(
            f"no room for a KV pool on {hw.name}: memory budget "
            f"{budget / 1e9:.2f} GB (mem {hw.mem_bytes / 1e9:.1f} GB x "
            f"gpu_mem_util {sim.gpu_mem_util}) - params "
            f"{param_bytes / 1e9:.2f} GB - activation reservation "
            f"{act_bytes / 1e9:.2f} GB (max_model_len={sim.max_model_len}) "
            f"leaves {free / 1e9:.2f} GB, but one block per layer needs "
            f"{L * kv_per_block / 1e9:.2f} GB ({L} layers x {kv_per_block} "
            f"B). Lower max_model_len, raise gpu_mem_util, shard over more "
            f"chips, or set num_device_blocks explicitly.")
    return blocks


class ServingSimulator(CoreDelegateMixin):
    """The discrete-event serving backend: drives the shared
    `SchedulerCore` with step latencies priced by `CostModel` instead
    of real forwards — same decisions as `LayerKVEngine`, no JAX
    dependency. This is what the benchmarks and policy studies run."""

    produces_token_ids = False   # step latencies are modeled; the token
    #                              stream carries ordinals, not real ids

    def __init__(self, cfg: ModelConfig, hw: HWProfile, sim: ServeConfig,
                 predictor: Optional[LengthPredictor] = None,
                 alpha: float = 1.15, beta: float = 1.1):
        self.cfg = cfg
        self.hw = hw
        self.sim = sim.validate()
        self.cost = CostModel(cfg, hw, alpha=alpha, beta=beta)
        self.L = max(cfg.n_attention_layers(), 1)
        ndb = sim.num_device_blocks or derive_device_blocks(cfg, hw, sim)
        self.bm = LayerwiseBlockManager(ndb, sim.num_host_blocks,
                                        sim.block_size, self.L,
                                        prefix_cache=sim.prefix_cache)
        self.off = OffloadEngine(self.cost, self.L)
        self.predictor = predictor or OraclePredictor(
            [64, 128, 256, 512, 1024])
        self.sched = SLOScheduler(self.cost, self.predictor)
        self.fc = AvailabilityForecast(self.predictor, sim.block_size)
        # cache-driven physical copies (COW / promote / demote) charge the
        # link ledger in the core; d2d copies never touch the offload link
        self.core = SchedulerCore(
            self.sim, self.cost, self.bm, self.off, self.sched, self.L,
            reserve_blocks=int(sim.forecast_threshold_frac * ndb))
        self._chunk_iters = 0
        self._max_iter_prefill_tokens = 0

    @property
    def preemptions(self) -> int:
        """vLLM recompute-preemptions (core registry-backed)."""
        return int(self.core.registry.get("preemptions", kind="recompute"))

    # --------------------------------------------- shared-core delegation
    # queues/host_layers/clock()/advance_to() come from CoreDelegateMixin
    @property
    def t(self) -> Seconds:
        return self.core.now

    @t.setter
    def t(self, v: Seconds) -> None:
        self.core.now = v

    @property
    def plans(self):
        return self.core.plans

    @property
    def reload_bytes_migrated(self) -> Bytes:
        return self.core.reload_bytes_migrated

    def finish(self) -> None:
        self.bm.check()

    def cancel(self, r: Request) -> bool:
        return self.core.cancel(r, self.t)

    # ------------------------------------------------------------ helpers
    def _prefill_cost(self, r: Request) -> Seconds:
        """Eq.3 prefill compute for the UNCACHED part of r's prompt (the
        cached prefix, r.prefill_done at admission, skips compute)."""
        c = r.prefill_done
        return self.cost.chunk_prefill_time(r.prompt_len - c, c)

    def _finish_prefill(self, r: Request) -> None:
        """Prefill-complete bookkeeping shared by every admission path:
        publish the prompt's full blocks into the prefix cache."""
        if self.sim.prefix_cache and r.prompt:
            self.bm.register_prefix(r.rid, r.prompt)

    def _promote(self, now: Seconds, dt: Seconds,
                 decoding: List[Request]) -> None:
        """Swap host-resident layers back to device while blocks and link
        bandwidth allow (paper: 'maximizing the number of layers retained
        on the GPU'). Budget: what the link can move within one step.

        Accounting: each promoted byte is charged to the link ledger
        exactly once, here. Callers must recompute the decode step's
        host_kv_bytes AFTER promotion (from the post-promotion host_layers)
        so promoted bytes are not ALSO charged as per-step host streaming —
        double-charging inflated busy_until and delayed later prefill
        offload completions."""
        reserve = int(2 * self.sim.forecast_threshold_frac
                      * self.bm.pools[DEVICE].num_blocks)
        budget = self.cost.hw.offload_bw * max(dt, 1e-6)
        room = True
        for r in sorted(decoding, key=lambda q: q.prefill_start):
            if budget <= 0 or not room:
                break
            host = self.bm.layers_on(r.rid, HOST)
            if not host:
                continue
            for l in host:
                if budget <= 0:
                    break
                a = self.bm.allocation(r.rid, l)
                # charge the bytes actually resident in the allocation
                # (ctx-1 during a step: this step's token isn't written yet)
                per_layer_bytes = self.cost.kv_bytes(a.num_tokens, 1)
                if self.bm.num_free(DEVICE) < len(a.blocks) + reserve:
                    room = False
                    break
                self.bm.move_layer(r.rid, l, DEVICE)
                self.off.ledger.submit(now, per_layer_bytes, "reload")
                self.core.reload_bytes_migrated += per_layer_bytes
                budget -= per_layer_bytes
            self.host_layers[r.rid] = len(self.bm.layers_on(r.rid, HOST))

    def _extend_for_token(self, r: Request) -> bool:
        """Grow allocations by one token across all layers; False if the
        device pool is exhausted (caller preempts)."""
        try:
            for l in list(self.bm.tables[r.rid]):
                self.bm.extend_layer(r.rid, l, 1)
            return True
        except PoolExhausted:
            return False

    def _preempt(self, r: Request, t: Seconds):
        """vLLM recompute-preemption: drop all KV, requeue at the FRONT."""
        self.bm.free_request(r.rid)
        self.host_layers.pop(r.rid, None)
        r.phase = Phase.QUEUED
        r.tokens_out = 0
        r.first_token_time = -1.0
        r.prefill_done = 0
        r.n_chunks = 0
        r.cached_prompt_len = 0
        r.n_preempted += 1
        self.waiting.appendleft(r)
        self.core.registry.inc("preemptions", kind="recompute")
        if self.core.tracer is not None:
            self.core.tracer.preempt(r, t, mode="recompute")

    def _select_decode_batch(self, now: Seconds,
                             decoding: List[Request]) -> tuple:
        """Pick this iteration's running batch. Device-resident requests
        always run; host-resident ones join only while their layer-wise
        h2d streaming stays hideable under the step's HBM-bound compute
        (paper §4 overlap), most-behind-on-TPOT first. The rest pause this
        iteration — their TPOT *average* is protected by Eq.1 admission.
        vLLM policy: everything is device-resident, so sel == decoding."""
        if self.sim.policy == "vllm":
            return list(decoding), 0.0

        def urgency(r):
            return r.tpot_slo - r.current_tpot(now)  # ascending: worst first

        cand = sorted(decoding, key=urgency)
        avg_ctx = sum(r.prompt_len + r.tokens_out for r in cand) / len(cand)
        t_est = self.cost.decode_step_time(len(cand), int(avg_ctx), 0.0)
        budget = self.cost.hw.offload_bw * t_est * 0.9
        sel, used = [], 0.0
        for r in cand:
            hb = self.cost.kv_bytes(r.prompt_len + r.tokens_out,
                                    self.host_layers.get(r.rid, 0))
            if hb == 0.0:
                sel.append(r)
            elif hb <= budget:
                sel.append(r)
                budget -= hb
                used += hb
        if not sel:  # progress guarantee: run the most urgent one anyway
            r = cand[0]
            used = self.cost.kv_bytes(r.prompt_len + r.tokens_out,
                                      self.host_layers.get(r.rid, 0))
            sel = [r]
        return sel, used

    def _evict_for_space(self, now: Seconds, decoding: List[Request],
                         min_free_blocks: Blocks = 64):
        """Emergency eviction: move device layers of the most recently
        admitted requests to host until some headroom exists."""
        for r in sorted(decoding, key=lambda q: -q.prefill_start):
            if self.bm.num_free(DEVICE) >= min_free_blocks:
                return
            dev_layers = self.bm.layers_on(r.rid, DEVICE)
            ctx = self.bm.allocation(r.rid, dev_layers[0]).num_tokens \
                if dev_layers else 0
            for l in dev_layers:
                a = self.bm.allocation(r.rid, l)
                if self.core.host_free() < len(a.blocks):
                    return  # host tier full: nothing more to evict into
                # detach: shared prefix blocks are copied out, never pulled
                # from under the requests still mapping them
                self.bm.move_layer(r.rid, l, HOST, detach=True)
                if self.bm.num_free(DEVICE) >= min_free_blocks:
                    break
            moved = len(dev_layers) - len(self.bm.layers_on(r.rid, DEVICE))
            if moved:
                self.off.proactive_offload(now, ctx, moved)
                self.host_layers[r.rid] = len(self.bm.layers_on(r.rid, HOST))

    def _proactive_evict(self, now: Seconds,
                         decoding: List[Request]):
        """Eq.5: if the forecast dips below threshold, offload retained
        layers of the most recent requests (x/2 first, then all)."""
        thresh = int(self.sim.forecast_threshold_frac
                     * self.bm.pools[DEVICE].num_blocks)
        if not self.fc.needs_proactive_offload(
                self.bm.num_free(DEVICE), decoding,
                self.sim.forecast_horizon, thresh):
            return
        for r in sorted(decoding, key=lambda q: -q.prefill_start):
            dev_layers = self.bm.layers_on(r.rid, DEVICE)
            if not dev_layers:
                continue
            n_evict = max(len(dev_layers) // 2, 1)
            ctx = self.bm.allocation(r.rid, dev_layers[0]).num_tokens
            moved = 0
            for l in dev_layers[:n_evict]:
                a = self.bm.allocation(r.rid, l)
                if self.core.host_free() < len(a.blocks):
                    break  # host tier full: stop evicting
                self.bm.move_layer(r.rid, l, HOST, detach=True)
                moved += 1
            if not moved:
                return
            self.host_layers[r.rid] = len(self.bm.layers_on(r.rid, HOST))
            self.off.proactive_offload(now, ctx, moved)
            if self.bm.num_free(DEVICE) >= thresh:
                break

    # ------------------------------------------------------ shared pieces
    def _decode_bookkeep(self, t: Seconds, sel: List[Request]) -> None:
        """Post-step accounting for one decode batch: grow allocations,
        evict-or-preempt on exhaustion, retire finished requests."""
        finished: List[Request] = []
        for r in sel:
            ok = self._extend_for_token(r)
            if not ok and self.sim.policy == "layerkv":
                # evict device layers (newest requests first) to host
                # instead of preempting (paper §3.1.1)
                self._evict_for_space(t, self.decoding)
                ok = self._extend_for_token(r)
            if not ok:
                self._preempt(r, t)
                self.decoding.remove(r)
                continue
            r.tokens_out += 1
            r.note_token(t)
            if r.tokens_out >= r.output_len:
                r.finish_time = t
                r.phase = Phase.FINISHED
                self.bm.free_request(r.rid)
                self.core.release(r)
                self.predictor.observe(r.output_len)
                self.done.append(r)
                finished.append(r)
                if self.core.tracer is not None:
                    self.core.tracer.finish(r, t)
        for r in finished:
            self.decoding.remove(r)

    def _metrics(self, done: List[Request]) -> SimMetrics:
        mk = max((r.finish_time for r in done), default=0.0)
        return SimMetrics(
            ttft=[r.ttft for r in done],
            queuing=[r.queuing_delay for r in done],
            prefill_lat=[r.prefill_latency for r in done],
            tpot=[r.tpot for r in done],
            finish_times=[r.finish_time for r in done],
            # tokens_salvaged: delivered by incarnations a replica kill
            # destroyed — still real output of this request
            tokens_out=sum(r.tokens_out + r.tokens_salvaged for r in done),
            makespan=mk,
            slo_violations=sum(1 for r in done if r.slo_violated()),
            n_requests=len(done),
            # recompute-preemptions (vLLM path) + lossless pause/resume
            preemptions=self.preemptions + self.core.n_preempted,
            priorities=[r.priority for r in done],
            tbt=[r.max_tbt for r in done],
            deadline_slack=[r.effective_deadline - r.first_token_time
                            for r in done],
            req_tokens=[r.tokens_out + r.tokens_salvaged for r in done],
            chunk_iters=self._chunk_iters,
            max_iter_prefill_tokens=self._max_iter_prefill_tokens,
            prefix_hit_tokens=self.bm.cache.hit_tokens
            if self.bm.cache else 0,
            prefix_lookup_tokens=self.bm.cache.lookup_tokens
            if self.bm.cache else 0,
            n_cancelled=len(self.core.cancelled),
            n_shed=len(self.core.shed),
            shed_priorities=[r.priority for r in self.core.shed],
            shed_reasons=[r.shed_reason or "" for r in self.core.shed],
            rids=[r.rid for r in done],
            shed_rids=[r.rid for r in self.core.shed],
        )

    def metrics(self) -> SimMetrics:
        """Metrics over everything finished so far (session use)."""
        return self._metrics(self.done)

    # ---------------------------------------------------------------- step
    def step(self) -> bool:
        """One engine-step iteration at the current clock. Returns False
        when fully idle (nothing admissible, nothing in flight)."""
        out = self._step_chunked() if self.sim.chunked \
            else self._step_exclusive()
        if self.core.sanitizer is not None:
            self.core.sanitizer.check(self.core)
        return out

    def _step_exclusive(self) -> bool:
        """vLLM 0.5.5 engine-step: prefills stall the decode batch."""
        t = self.t
        admitted = self.core.admit_waiting(
            t, token_budget=self.sim.max_prefill_tokens)

        if admitted:
            # prefills run exclusively (vLLM 0.5.5 semantics); cached
            # prefixes skip their share of the Eq.3 compute. The TP
            # all-reduce reserves the link FIRST (§3.1.3) so this
            # batch's d2h offload traffic defers around it.
            for r in admitted:
                r.phase = Phase.PREFILL
                r.prefill_start = t
            dt = sum(self._prefill_cost(r) for r in admitted)
            if self.sim.collective_reserve_frac > 0.0:
                self.off.ledger.reserve(
                    t, self.sim.collective_reserve_frac * dt)
            if self.sim.policy == "layerkv":
                for r in admitted:
                    n_off = self.host_layers.get(r.rid, 0)
                    if n_off:
                        self.off.ledger.submit(
                            t, self.cost.kv_bytes(r.prompt_len, n_off),
                            "offload")
            t += dt
            self.t = t
            for r in admitted:
                # preserved across a replica-kill restart: the user saw
                # their first token from the dead incarnation already
                if r.first_token_time < 0:
                    r.first_token_time = t
                    if self.core.tracer is not None:
                        self.core.tracer.first_token(r, t)
                r.tokens_out = 1
                r.note_token(t)
                r.prefill_done = r.prompt_len
                r.n_chunks += 1
                r.phase = Phase.DECODE
                self._finish_prefill(r)
                self.decoding.append(r)
            return True

        if self.decoding:
            if self.sim.policy == "layerkv" and self.sim.proactive:
                self._proactive_evict(t, self.decoding)
            sel, host_bytes = self._select_decode_batch(t, self.decoding)
            B = len(sel)
            avg_ctx = sum(r.prompt_len + r.tokens_out for r in sel) / B
            if self.sim.policy == "layerkv":
                # promote against an ESTIMATED step time, then price
                # the step from what is STILL host-resident: promoted
                # bytes are charged once (to the ledger, in _promote),
                # never again as per-step host streaming
                dt_est = self.cost.decode_step_time(
                    B, int(avg_ctx), host_bytes)
                self._promote(t, dt_est, self.decoding)
                host_bytes = sum(
                    self.cost.kv_bytes(r.prompt_len + r.tokens_out,
                                       self.host_layers.get(r.rid, 0))
                    for r in sel)
            dt = self.cost.decode_step_time(B, int(avg_ctx), host_bytes)
            t += dt
            self.t = t
            self._decode_bookkeep(t, sel)
            return True

        return False

    def _step_chunked(self) -> bool:
        """One chunked-prefill iteration: admission into the chunk queue,
        then up to `max_prefill_tokens` prompt-chunk tokens (FCFS across
        in-flight prefills, Eq.1-tightened when slo_aware) batched WITH
        the decode tokens; costs max(chunk compute, decode compute)."""
        t = self.t
        self.core.admit_waiting(t)
        if not (self.prefilling or self.decoding):
            return False

        if self.sim.policy == "layerkv" and self.sim.proactive:
            self._proactive_evict(t, self.decoding)
        sel: List[Request] = []
        host_bytes = 0.0
        avg_ctx = 0
        if self.decoding:
            sel, host_bytes = self._select_decode_batch(t, self.decoding)
            avg_ctx = int(sum(r.prompt_len + r.tokens_out for r in sel)
                          / len(sel))

        chunks = self.core.assemble_chunks(t, len(sel))
        t_chunk = sum(self.cost.chunk_prefill_time(c, r.prefill_done)
                      for r, c in chunks)
        # §3.1.3: the TP all-reduce of the chunk compute reserves the
        # link BEFORE this iteration's d2h traffic is submitted
        if t_chunk > 0.0 and self.sim.collective_reserve_frac > 0.0:
            self.off.ledger.reserve(
                t, self.sim.collective_reserve_frac * t_chunk)

        # chunk-granular d2h: each chunk's offloaded-layer KV enters
        # the link ledger as it is produced, overlapping chunk compute
        if self.sim.policy == "layerkv":
            for r, c in chunks:
                n_off = self.host_layers.get(r.rid, 0)
                if n_off:
                    self.off.ledger.submit(
                        t, self.cost.kv_bytes(c, n_off), "offload")

        if self.sim.policy == "layerkv" and self.decoding:
            # promote against an estimate, then re-price host streaming
            # from post-promotion residency (each byte charged once)
            dt_est = self.cost.mixed_step_time(t_chunk, len(sel),
                                               avg_ctx, host_bytes,
                                               fused=self.sim.fused)
            self._promote(t, dt_est, self.decoding)
            host_bytes = sum(
                self.cost.kv_bytes(r.prompt_len + r.tokens_out,
                                   self.host_layers.get(r.rid, 0))
                for r in sel)
        dt = self.cost.mixed_step_time(t_chunk, len(sel), avg_ctx,
                                       host_bytes, fused=self.sim.fused)
        t0 = t
        t += dt
        self.t = t
        if self.core.tracer is not None:
            # before the bookkeeping below mutates prefill_done
            self.core.tracer.chunk_iteration(self.core, t0, t, chunks)

        if chunks:
            self._chunk_iters += 1
            self._max_iter_prefill_tokens = max(
                self._max_iter_prefill_tokens,
                sum(c for _, c in chunks))
        for r, c in chunks:
            r.prefill_done += c
            r.n_chunks += 1
            if self.sim.prefix_cache and r.prompt:
                # incremental publication, mirroring the engine: full
                # blocks written so far become hittable immediately
                self.bm.register_prefix(r.rid, r.prompt,
                                        upto=r.prefill_done)
            if r.prefill_complete:
                if r.first_token_time < 0:  # survives replica-kill restart
                    r.first_token_time = t
                    if self.core.tracer is not None:
                        self.core.tracer.first_token(r, t)
                r.tokens_out = 1
                r.note_token(t)
                r.phase = Phase.DECODE
                self.prefilling.remove(r)
                self.decoding.append(r)

        self._decode_bookkeep(t, sel)
        return True

    # ---------------------------------------------------------------- run
    def run(self, requests: List[Request]) -> SimMetrics:
        """Batch convenience wrapper: one session, every request submitted
        up front at its own arrival, drained to completion."""
        self._chunk_iters = 0
        self._max_iter_prefill_tokens = 0
        session = ServingSession(self)
        for r in sorted(requests, key=lambda q: q.arrival):
            session.submit(r, arrival=r.arrival)
        session.drain()
        return self._metrics(self.done)
