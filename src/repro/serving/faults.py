"""Deterministic fault injection for the cluster (serving/faults.py).

A `FaultPlan` is a list of `FaultEvent`s stamped on the shared virtual
clock; a `FaultEngine` replays them against a `ClusterSession` as its
clock passes each stamp. Everything is seeded and time-stamped, so a
failure scenario is REPLAYABLE: the same plan over the same workload
produces a bit-identical recovery trace and bit-identical metrics
(pinned by tests/test_faults.py).

Fault taxonomy (docs/ARCHITECTURE.md "Failure model & recovery"):

  crash          replica dies at t: its in-flight and queued work is
                 unwound via the cancel machinery and re-dispatched
                 through the routing policy; `recover_after` revives it
                 cold (KV and prefix cache gone) that much later
  wedge          replica freezes for `duration`: it serves nothing and
                 its clock does not advance (liveness detection, when
                 armed, may declare it dead first)
  slowdown       every step of the replica is stretched by `factor`
                 for `duration` (a straggler, not a corpse)
  dispatch_fail  dispatches to the replica fail transiently for
                 `duration`; the cluster retries with exponential
                 backoff, bounded by `max_dispatch_retries`
  host_exhaust   `blocks` host-pool blocks become unusable for
                 `duration` (models host memory pressure); admission
                 backpressures or sheds instead of wedging
  link_stall     the replica's d2h/h2d offload link is reserved (busy)
                 for `duration` — transfers queue behind it (§3.1.3
                 reservation machinery)

Default-off discipline (lint rule FAULT001): nothing in the serving
stack constructs or consults a `FaultEngine` unless a plan was
explicitly installed (`ClusterSession(fault_plan=...)`), mirroring the
sanitizer's opt-in contract, and every fault-free code path is
bit-identical to the pre-fault scheduler.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import random
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core import HOST

FAULT_KINDS = ("crash", "wedge", "slowdown", "dispatch_fail",
               "host_exhaust", "link_stall")
# synthesized follow-up events (never appear in a user plan)
_INTERNAL_KINDS = ("_revive", "_host_clear")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault on the shared virtual clock."""
    t: float                      # clock stamp the fault fires at
    kind: str                     # one of FAULT_KINDS
    replica: int
    duration: float = 0.0         # window length (wedge/slowdown/...)
    factor: float = 2.0           # slowdown stretch multiplier
    blocks: int = 0               # host_exhaust reserve; 0 = whole pool
    recover_after: float = -1.0   # crash: revive delay; < 0 = permanent

    def describe(self) -> str:
        extra = ""
        if self.kind == "crash":
            extra = (f" recover_after={self.recover_after:g}"
                     if self.recover_after >= 0 else " permanent")
        elif self.kind == "slowdown":
            extra = f" dur={self.duration:g} factor={self.factor:g}"
        elif self.kind == "host_exhaust":
            extra = f" dur={self.duration:g} blocks={self.blocks}"
        elif self.duration:
            extra = f" dur={self.duration:g}"
        return f"t={self.t:g} {self.kind} r{self.replica}{extra}"


class FaultPlan:
    """An immutable, time-ordered fault schedule.

    Build one explicitly, from a seed (`FaultPlan.random`), or from the
    CLI grammar (`FaultPlan.parse`):

        crash@0.5:r0:recover=1.0;wedge@0.2:r1:dur=0.3
        random:7            (seeded; replica count filled in by caller)
    """

    def __init__(self, events: Sequence[FaultEvent]):
        for e in events:
            if e.kind not in FAULT_KINDS:
                raise ValueError(f"unknown fault kind {e.kind!r} "
                                 f"(expected one of {FAULT_KINDS})")
            if e.t < 0:
                raise ValueError(f"fault stamped before t=0: {e}")
        self.events: Tuple[FaultEvent, ...] = tuple(
            sorted(events, key=lambda e: (e.t, e.replica, e.kind)))

    def __len__(self) -> int:
        return len(self.events)

    def describe(self) -> List[str]:
        return [e.describe() for e in self.events]

    @classmethod
    def random(cls, seed: int, n_replicas: int, horizon: float = 10.0,
               n_events: int = 3,
               kinds: Optional[Sequence[str]] = None) -> "FaultPlan":
        """Seeded plan: same (seed, n_replicas, horizon, n_events,
        kinds) -> identical plan, forever. Random crashes always carry
        a recovery so a random plan cannot permanently sink the whole
        cluster."""
        rng = random.Random(seed)
        pool = tuple(kinds) if kinds else FAULT_KINDS
        events: List[FaultEvent] = []
        for _ in range(n_events):
            kind = rng.choice(pool)
            t = round(rng.uniform(0.05, horizon), 4)
            i = rng.randrange(n_replicas)
            dur = round(rng.uniform(0.1, max(horizon / 2, 0.2)), 4)
            if kind == "crash":
                events.append(FaultEvent(
                    t, kind, i,
                    recover_after=round(rng.uniform(0.2, horizon / 2), 4)))
            elif kind == "slowdown":
                events.append(FaultEvent(
                    t, kind, i, duration=dur,
                    factor=round(rng.uniform(1.5, 4.0), 2)))
            elif kind == "host_exhaust":
                events.append(FaultEvent(
                    t, kind, i, duration=dur,
                    blocks=rng.randrange(64, 1024)))
            else:
                events.append(FaultEvent(t, kind, i, duration=dur))
        return cls(events)

    @classmethod
    def parse(cls, spec: str, n_replicas: int = 1,
              horizon: float = 10.0) -> "FaultPlan":
        """Parse the `--fault-plan` CLI grammar (see class docstring)."""
        spec = spec.strip()
        if spec.startswith("random:"):
            parts = spec.split(":")
            seed = int(parts[1])
            n_events = 3
            for p in parts[2:]:
                key, _, val = p.partition("=")
                if key == "n":
                    n_events = int(val)
                else:
                    raise ValueError(f"unknown random-plan option {p!r}")
            return cls.random(seed, n_replicas, horizon=horizon,
                              n_events=n_events)
        events = []
        for item in filter(None, (s.strip() for s in spec.split(";"))):
            head, *opts = item.split(":")
            kind, _, stamp = head.partition("@")
            if not stamp:
                raise ValueError(f"fault {item!r} missing '@time'")
            fields: Dict[str, object] = {"t": float(stamp), "kind": kind}
            for opt in opts:
                if opt.startswith("r") and opt[1:].isdigit():
                    fields["replica"] = int(opt[1:])
                    continue
                key, _, val = opt.partition("=")
                if key == "dur":
                    fields["duration"] = float(val)
                elif key == "recover":
                    fields["recover_after"] = float(val)
                elif key in ("factor", "blocks"):
                    fields[key] = type(FaultEvent.__dataclass_fields__
                                       [key].default)(float(val))
                else:
                    raise ValueError(f"unknown fault option {opt!r} "
                                     f"in {item!r}")
            if "replica" not in fields:
                raise ValueError(f"fault {item!r} missing ':rN' replica")
            events.append(FaultEvent(**fields))  # type: ignore[arg-type]
        return cls(events)


class FaultEngine:
    """Replays a `FaultPlan` against a cluster as virtual time passes.

    The cluster polls (`poll(cluster, upto)`) at each step; events
    stamped at or before `upto` fire in stamp order. Crash recoveries
    and host-pool releases are synthesized as internal follow-up events
    so the whole schedule stays a single deterministic queue. Window
    predicates (`is_wedged` / `slow_factor` / `dispatch_fails`) are pure
    reads keyed on the query time."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._seq = itertools.count()
        self._queue: List[Tuple[float, int, FaultEvent]] = [
            (e.t, next(self._seq), e) for e in plan.events]
        heapq.heapify(self._queue)
        self.trace: List[str] = []       # applied events, in fire order
        self._wedge: Dict[int, Tuple[float, float]] = {}
        self._slow: Dict[int, Tuple[float, float, float]] = {}
        self._dfail: Dict[int, List[Tuple[float, float]]] = {}

    # ------------------------------------------------------------- apply
    def poll(self, cluster, upto: float) -> None:
        """Fire every event stamped at or before `upto`, in order."""
        while self._queue and self._queue[0][0] <= upto:
            _, _, ev = heapq.heappop(self._queue)
            self._apply(cluster, ev)

    def _push(self, ev: FaultEvent) -> None:
        heapq.heappush(self._queue, (ev.t, next(self._seq), ev))

    def _apply(self, cluster, ev: FaultEvent) -> None:
        i = ev.replica
        if i >= cluster.n_replicas:
            return  # plan written for a bigger cluster; ignore
        self.trace.append(ev.describe())
        if cluster.tracer is not None:
            cluster.tracer.instant("fault", ev.t, kind=ev.kind,
                                   replica=i)
        if ev.kind == "crash":
            if cluster.alive[i]:
                cluster.kill(i, reason="fault", at=ev.t)
                if ev.recover_after >= 0:
                    self._push(FaultEvent(ev.t + ev.recover_after,
                                          "_revive", i))
        elif ev.kind == "_revive":
            cluster.revive(i, at=ev.t)
        elif ev.kind == "wedge":
            start, end = self._wedge.get(i, (ev.t, ev.t))
            self._wedge[i] = (min(start, ev.t),
                              max(end, ev.t + ev.duration))
        elif ev.kind == "slowdown":
            self._slow[i] = (ev.t, ev.t + ev.duration, ev.factor)
        elif ev.kind == "dispatch_fail":
            self._dfail.setdefault(i, []).append(
                (ev.t, ev.t + ev.duration))
        elif ev.kind == "host_exhaust":
            core = cluster.cores[i]
            amount = ev.blocks if ev.blocks > 0 \
                else core.bm.pools[HOST].num_blocks
            core.fault_host_reserve += amount
            self._push(FaultEvent(ev.t + ev.duration, "_host_clear", i,
                                  blocks=amount))
        elif ev.kind == "_host_clear":
            core = cluster.cores[i]
            core.fault_host_reserve = max(
                0, core.fault_host_reserve - ev.blocks)
        elif ev.kind == "link_stall":
            cluster.cores[i].off.ledger.reserve(ev.t, ev.duration)

    # ------------------------------------------------------- pure reads
    def next_event_time(self) -> Optional[float]:
        return self._queue[0][0] if self._queue else None

    def has_pending(self) -> bool:
        return bool(self._queue)

    def is_wedged(self, i: int, now: float) -> bool:
        w = self._wedge.get(i)
        return w is not None and w[0] <= now < w[1]

    def wedge_end(self, i: int) -> float:
        return self._wedge[i][1]

    def slow_factor(self, i: int, now: float) -> float:
        s = self._slow.get(i)
        return s[2] if s is not None and s[0] <= now < s[1] else 1.0

    def dispatch_fails(self, i: int, when: float) -> bool:
        return any(s <= when < e for s, e in self._dfail.get(i, ()))
