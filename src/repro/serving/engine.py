"""The LayerKV serving engine: continuous batching over real JAX execution.

Wires the paper's decision components (block manager, offload plans, SLO
scheduler, Eq.5 forecast) to the `PagedExecutor`. Two policies:

  'vllm'     request-wise: admit a prefill only when device blocks for the
             whole prompt x all layers are free (baseline).
  'layerkv'  layer-wise: admit with Eq.4's x retained layers (+1 send
             buffer); offloaded layers live in the HOST pool and are
             streamed/promoted back for decode.

Orthogonally, `EngineConfig.chunked` selects the engine-step semantics,
completing a 3-axis scheduling matrix (policy x slo_aware x chunked):

  exclusive  (default) a prefill runs its whole prompt in one call,
             stalling the decode batch — vLLM 0.5.5 semantics.
  chunked    prompts prefill in scheduler-controlled chunks under a
             per-iteration token budget (`chunk_size`, tightened by Eq.1
             slack when slo_aware); chunk compute batches with the decode
             step, the clock advancing by max(chunk, decode) per
             iteration. Chunk KV appends into the paged pools at arbitrary
             token offsets (`PagedExecutor.write_layer_slice`), with
             causal masking against already-cached blocks, and each
             chunk's offloaded-layer d2h traffic hits the link ledger as
             it is produced.

`EngineConfig.fused` (chunked mode only) collapses the iteration's two
executor calls (chunk forward + decode forward) into ONE
`PagedExecutor.mixed_step`: chunk and decode tokens share a single
weight stream per layer, and chunks attend directly against the paged
pools through the paged-prefill kernel instead of a gathered dense
prefix buffer. Tokens are identical to the two-call path
(tests/test_fused.py); the iteration is charged
`CostModel.mixed_step_time(..., fused=True)` (one weight stream).

The engine clock is virtual (driven by the cost model) so runs are exactly
reproducible and policy behaviour — not CPU speed — determines metrics;
generated TOKENS are real model outputs, which is what the losslessness
tests assert — in chunked mode the tokens must match the exclusive-mode
engine exactly (see tests/test_chunked.py).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import (
    DEVICE, HOST, LayerwiseBlockManager, OffloadEngine, PoolExhausted,
    SLOScheduler, interleave_offload_layers,
)
from repro.core.predictor import HistogramPredictor, LengthPredictor
from repro.serving.costmodel import CostModel, HWProfile, TPU_V5E
from repro.serving.executor import MixedChunk, MixedDecode, PagedExecutor
from repro.serving.request import Phase, Request


@dataclasses.dataclass
class EngineConfig:
    policy: str = "layerkv"
    slo_aware: bool = True
    num_device_blocks: int = 128
    num_host_blocks: int = 1024
    block_size: int = 16
    max_batch_size: int = 64
    max_tokens_per_request: int = 4096
    chunked: bool = False           # chunked prefill + mixed batching
    chunk_size: int = 32            # per-iteration prefill token budget
    chunk_floor: int = 8            # min chunk tokens/iter (progress)
    prefix_cache: bool = False      # ref-counted cross-request sharing
    fused: bool = False             # ONE forward per iteration: chunks +
    #                                 decode batch share a weight stream and
    #                                 chunks attend straight against the
    #                                 paged pools (requires chunked=True)


class LayerKVEngine:
    def __init__(self, cfg: ModelConfig, params=None,
                 ec: Optional[EngineConfig] = None,
                 hw: HWProfile = TPU_V5E,
                 predictor: Optional[LengthPredictor] = None, rng=None):
        self.cfg = cfg
        self.ec = ec or EngineConfig()
        if self.ec.fused and not self.ec.chunked:
            raise ValueError("EngineConfig.fused requires chunked=True")
        self.ex = PagedExecutor(cfg, params, self.ec.num_device_blocks,
                                self.ec.num_host_blocks, self.ec.block_size,
                                rng=rng)
        self.L = cfg.n_layers
        self.bm = LayerwiseBlockManager(self.ec.num_device_blocks,
                                        self.ec.num_host_blocks,
                                        self.ec.block_size, self.L,
                                        prefix_cache=self.ec.prefix_cache)
        if self.ec.prefix_cache:
            # cache-driven copies (COW, promote, demote) move REAL bytes
            # through the executor and charge the transfer ledger
            self.bm.on_copy = self._cache_copy
        self.cost = CostModel(cfg, hw)
        self.off = OffloadEngine(self.cost, self.L)
        self.predictor = predictor or HistogramPredictor(
            [16, 32, 64, 128, 256])
        self.sched = SLOScheduler(self.cost, self.predictor)
        self.now = 0.0
        self.waiting: deque[Request] = deque()
        self.prefilling: List[Request] = []   # chunked mode: in-flight chunks
        self.decoding: List[Request] = []
        self.done: List[Request] = []
        self.host_layers: Dict[str, int] = {}
        self._chunk_bufs: Dict[str, tuple] = {}  # rid -> cached (kbuf, vbuf)

    # ------------------------------------------------------------- helpers
    def _blocks(self, tokens: int) -> int:
        return self.bm.blocks_for_tokens(tokens)

    def _cache_copy(self, src_pool: str, src: int, dst_pool: str,
                    dst: int) -> None:
        src_tier = "device" if src_pool == DEVICE else "host"
        dst_tier = "device" if dst_pool == DEVICE else "host"
        self.ex.copy_blocks(src_tier, dst_tier, [src], [dst])
        nbytes = self.cost.kv_bytes(self.ec.block_size, 1)
        if src_pool == HOST and dst_pool == DEVICE:
            self.off.ledger.submit(self.now, nbytes, "reload")
        elif src_pool == DEVICE and dst_pool == HOST:
            self.off.ledger.submit(self.now, nbytes, "offload")

    def _cached_hint(self, r: Request) -> int:
        """Cached-prefix length for Eq.3 admission estimates (price the
        uncached suffix only, or admission over-throttles)."""
        if self.ec.prefix_cache and r.prompt:
            return self.bm.match_prefix(r.prompt)
        return 0

    def _device_need(self, r: Request) -> int:
        """Admission gate: min of the plain-policy need and the hit-path
        need — a hit estimate larger than the plain path (short prefix,
        all layers device-resident) must never wedge a request the
        layer-wise fallback fits."""
        if self.ec.policy == "vllm":
            need = self._blocks(r.prompt_len) * self.L
        else:
            plan = self.off.plan_for_prompt(r.prompt_len)
            send_buf = 1 if plan.offload_layers else 0
            need = self._blocks(r.prompt_len) * (plan.x + send_buf)
        if self.ec.prefix_cache and r.prompt:
            c = self.bm.match_prefix(r.prompt)
            if c > 0:
                hit_need = (self._blocks(r.prompt_len)
                            - c // self.ec.block_size) * self.L
                need = min(need, hit_need)
        return need

    # -------------------------------------------------------------- prefill
    def _alloc_prefill(self, r: Request):
        """Allocate r's prompt KV per the policy; returns (retain, off)
        layer lists or None when the pools cannot fit it.

        With the prefix cache on, a content hit maps the shared prefix
        blocks (refcount +1 per layer, COW copy of the partial tail) and
        extends each layer with the uncached suffix — all device-resident;
        prefill compute then starts at prefill_done = cached_len. A hit
        that cannot fit falls through to the plain policy path."""
        if self.ec.prefix_cache and r.prompt:
            acq = self.bm.acquire_prefix(r.rid, r.prompt)
            if acq is not None:
                try:
                    suffix = r.prompt_len - acq.cached_len
                    for l in range(self.L):
                        self.bm.extend_layer(r.rid, l, suffix)
                except PoolExhausted:
                    self.bm.free_request(r.rid)
                    r.prefill_done = 0
                else:
                    r.prefill_done = acq.cached_len
                    r.cached_prompt_len = acq.cached_len
                    self.bm.cache.count(r.prompt_len, acq.cached_len)
                    return list(range(self.L)), []
        per_layer = self._blocks(r.prompt_len)
        if self.ec.policy == "vllm":
            retain = list(range(self.L))
            off = []
        else:
            plan = self.off.plan_for_prompt(r.prompt_len)
            fit = max(self.bm.num_free(DEVICE) // max(per_layer, 1) - 1, 0)
            retain_n = min(self.L, max(plan.x, fit))
            off = interleave_offload_layers(self.L, retain_n)
            retain = [l for l in range(self.L) if l not in set(off)]
        try:
            for l in retain:
                self.bm.alloc_layer(r.rid, l, r.prompt_len, DEVICE)
            for l in off:
                self.bm.alloc_layer(r.rid, l, r.prompt_len, HOST)
        except PoolExhausted:
            self.bm.free_request(r.rid)
            return None
        if self.ec.prefix_cache and r.prompt:
            self.bm.cache.count(r.prompt_len, 0)  # admitted as a miss
        return retain, off

    def _do_prefill(self, r: Request) -> bool:
        alloc = self._alloc_prefill(r)
        if alloc is None:
            return False
        retain, off = alloc

        if r.prefill_done > 0:
            # prefix-cache hit: run the uncached suffix as ONE chunk
            # against the shared prefix blocks (q_offset causal masking);
            # compute for the cached tokens is skipped entirely
            c, p = r.prefill_remaining, r.prefill_done
            self._run_chunk(r, c)
            self.now += self.cost.chunk_prefill_time(c, p)
        else:
            pad = self._blocks(r.prompt_len) * self.ec.block_size
            next_tok, k, v = self.ex.prefill(r.prompt, pad)
            for l in retain:
                a = self.bm.allocation(r.rid, l)
                self.ex.write_layer("device", a.blocks, k[l], v[l])
            for l in off:
                a = self.bm.allocation(r.rid, l)
                self.ex.write_layer("host", a.blocks, k[l], v[l])
            if off:
                from repro.core import OffloadPlan
                self.off.prefill_offload_done(
                    self.now, r.prompt_len,
                    OffloadPlan(retain, off, len(retain)))
            self.now += self.cost.prefill_time(r.prompt_len)
            r.prefill_done = r.prompt_len
            r.n_chunks += 1
            r.generated.append(next_tok)
            if self.ec.prefix_cache and r.prompt:
                self.bm.register_prefix(r.rid, r.prompt)
        self.host_layers[r.rid] = len(off)
        r.prefill_start = r.prefill_start if r.prefill_start >= 0 else self.now
        r.first_token_time = self.now
        r.tokens_out = 1
        r.phase = Phase.DECODE
        self.decoding.append(r)
        return True

    # ------------------------------------------------------- chunked prefill
    def _gather_buffers(self, r: Request):
        """Dense (L, S_buf, KV, hd) K/V prefix buffers for r — the LEGACY
        (two-call) chunk path only; fused mode attends straight against
        the pools and never materializes these. Gathered from the pools on
        the request's FIRST chunk, then cached and kept fresh with the
        chunk appends: a prefilling request's block contents only change
        through its own chunks (evictions touch decoding requests), so
        re-gathering every chunk would be pure waste. Only the blocks
        holding the `prefill_done` live tokens are physically gathered
        (zero for a fresh prompt, the cached prefix for a hit)."""
        if r.rid in self._chunk_bufs:
            return self._chunk_bufs[r.rid]
        ks, vs = [], []
        for l in range(self.L):
            a = self.bm.allocation(r.rid, l)
            tier = "device" if a.pool == DEVICE else "host"
            k, v = self.ex.gather_layer(tier, a.blocks,
                                        kv_valid=r.prefill_done)
            ks.append(k)
            vs.append(v)
        bufs = (jnp.stack(ks), jnp.stack(vs))
        self._chunk_bufs[r.rid] = bufs
        return bufs

    def _run_chunk(self, r: Request, c: int) -> None:
        """Prefill tokens [prefill_done, prefill_done + c) of r: run the
        chunk against the cached prefix, append its KV into the paged pools
        at the token offset, and account the chunk's d2h traffic."""
        p = r.prefill_done
        kbuf, vbuf = self._gather_buffers(r)
        logits, kc, vc = self.ex.prefill_chunk(r.prompt[p:p + c], p,
                                               kbuf, vbuf)
        for l in range(self.L):
            a = self.bm.allocation(r.rid, l)
            tier = "device" if a.pool == DEVICE else "host"
            self.ex.write_layer_slice(tier, a.blocks, p, kc[l], vc[l])
        n_off = len(self.bm.layers_on(r.rid, HOST))
        if n_off:
            self.off.ledger.submit(
                self.now, self.cost.kv_bytes(c, n_off), "offload")
        r.prefill_done += c
        r.n_chunks += 1
        if self.ec.prefix_cache and r.prompt:
            # incremental publication: full blocks whose KV is now written
            # become hittable while the rest of this prompt still prefills
            self.bm.register_prefix(r.rid, r.prompt, upto=r.prefill_done)
        if r.prefill_complete:
            self._chunk_bufs.pop(r.rid, None)
            r.generated.append(int(jnp.argmax(logits)))
        else:
            self._chunk_bufs[r.rid] = (
                kbuf.at[:, p:p + c].set(kc.astype(kbuf.dtype)),
                vbuf.at[:, p:p + c].set(vc.astype(vbuf.dtype)))

    # ---------------------------------------------------------- fused step
    def _run_mixed(self, chunk_work: List[tuple],
                   sel: List[Request]) -> None:
        """One fused iteration: every prefill chunk AND the decode batch in
        a single `PagedExecutor.mixed_step` forward — one weight stream per
        layer per iteration. Chunk tokens attend straight against the paged
        pools (block tables sliced to the live prefix + chunk), so the
        O(S) dense prefix gather of the two-call path is gone entirely;
        new KV scatters into the pools inside the step. Bookkeeping
        (ledger d2h, prefill progress, prefix registration, token appends)
        mirrors `_run_chunk` + `_run_decode` exactly."""
        for r in sel:
            for l in list(self.bm.tables[r.rid]):
                self.bm.extend_layer(r.rid, l, 1)
        chunks: List[MixedChunk] = []
        for r, c in chunk_work:
            p = r.prefill_done
            nb_live = -(-(p + c) // self.ec.block_size)
            tabs, tiers = [], []
            for l in range(self.L):
                a = self.bm.allocation(r.rid, l)
                tabs.append(a.blocks[:nb_live])
                tiers.append(a.pool == HOST)
            chunks.append(MixedChunk(tokens=r.prompt[p:p + c], offset=p,
                                     tables=tabs, tiers=tiers))
        decodes: List[MixedDecode] = []
        for r in sel:
            ctx = r.prompt_len + r.tokens_out - 1
            tabs = []
            for l in range(self.L):
                a = self.bm.allocation(r.rid, l)
                assert a.pool == DEVICE
                tabs.append(a.blocks)
            decodes.append(MixedDecode(token=r.generated[-1], ctx=ctx,
                                       tables=tabs))
        out = self.ex.mixed_step(chunks, decodes)
        for i, (r, c) in enumerate(chunk_work):
            n_off = len(self.bm.layers_on(r.rid, HOST))
            if n_off:
                self.off.ledger.submit(
                    self.now, self.cost.kv_bytes(c, n_off), "offload")
            r.prefill_done += c
            r.n_chunks += 1
            if self.ec.prefix_cache and r.prompt:
                self.bm.register_prefix(r.rid, r.prompt,
                                        upto=r.prefill_done)
            if r.prefill_complete:
                r.generated.append(int(out[i]))
        for j, r in enumerate(sel):
            r.generated.append(int(out[len(chunk_work) + j]))
            r.tokens_out += 1

    # ------------------------------------------------------ residency mgmt
    def _ensure_device(self, r: Request) -> bool:
        """Promote every host-resident layer of r to device (h2d). Returns
        False when blocks run out (request pauses this iteration)."""
        for l in self.bm.layers_on(r.rid, HOST):
            a = self.bm.allocation(r.rid, l)
            need = len(a.blocks)
            if self.bm.num_free(DEVICE) < need:
                return False
            src, dst = self.bm.move_layer(r.rid, l, DEVICE)
            self.ex.copy_blocks("host", "device", src, dst)
            self.off.ledger.submit(
                self.now, self.cost.kv_bytes(a.num_tokens, 1), "reload")
        self.host_layers[r.rid] = 0
        return True

    def _evict_newest(self, exclude=()) -> bool:
        """Push the newest request's device layers to host to make room.
        Shared prefix blocks are copied out (detach), never pulled from
        under the requests still mapping them."""
        excl = set(exclude)
        for r in sorted(self.decoding, key=lambda q: -q.prefill_start):
            if r.rid in excl:
                continue
            dev = self.bm.layers_on(r.rid, DEVICE)
            if not dev:
                continue
            for l in dev:
                a = self.bm.allocation(r.rid, l)
                if self.bm.num_free(HOST) < len(a.blocks):
                    return False
                src, dst = self.bm.move_layer(r.rid, l, HOST, detach=True)
                self.ex.copy_blocks("device", "host", src, dst)
                self.off.proactive_offload(self.now, a.num_tokens, 1)
            self.host_layers[r.rid] = len(self.bm.layers_on(r.rid, HOST))
            return True
        return False

    # ------------------------------------------------------ decode iteration
    def _select_runnable(self, allow_empty: bool = False) -> List[Request]:
        """Pick this iteration's decode batch: device-resident or promotable
        requests with room to grow, most-behind-on-TPOT first."""
        sel: List[Request] = []
        reserved = 0  # growth blocks earmarked for already-selected requests
        for r in sorted(self.decoding,
                        key=lambda q: q.tpot_slo - q.current_tpot(self.now)):
            sel_ids = [q.rid for q in sel] + [r.rid]

            def _need():
                """Promotion blocks + growth blocks for r this iteration."""
                need = 0
                for l in self.bm.layers_on(r.rid, HOST):
                    a = self.bm.allocation(r.rid, l)
                    need += len(a.blocks)
                    if a.num_tokens % self.ec.block_size == 0:
                        need += 1
                for l in self.bm.layers_on(r.rid, DEVICE):
                    a = self.bm.allocation(r.rid, l)
                    if a.num_tokens % self.ec.block_size == 0:
                        need += 1
                return need
            while self.bm.num_free(DEVICE) - reserved < _need():
                if not self._evict_newest(exclude=sel_ids):
                    break
            if self.bm.num_free(DEVICE) - reserved < _need():
                continue  # pause this iteration
            growth = _need()
            if self.host_layers.get(r.rid, 0):
                if not self._ensure_device(r):
                    continue
                # promotion blocks were consumed; growth remains earmarked
                growth = sum(
                    1 for l in self.bm.layers_on(r.rid, DEVICE)
                    if self.bm.allocation(r.rid, l).num_tokens
                    % self.ec.block_size == 0)
            reserved += growth
            sel.append(r)
        if not sel and not allow_empty:
            raise RuntimeError("engine wedged: no runnable request")
        return sel

    def _run_decode(self, sel: List[Request]) -> float:
        """Grow allocations, run one real decode step over `sel`, append the
        new tokens. Returns the modeled step time; the caller advances the
        clock and retires finished requests."""
        for r in sel:
            for l in list(self.bm.tables[r.rid]):
                self.bm.extend_layer(r.rid, l, 1)
        maxb = max(len(self.bm.allocation(r.rid, 0).blocks) for r in sel)
        R = len(sel)
        tables = np.zeros((self.L, R, maxb), np.int32)
        for i, r in enumerate(sel):
            for l in range(self.L):
                a = self.bm.allocation(r.rid, l)
                assert a.pool == DEVICE
                tables[l, i, :len(a.blocks)] = a.blocks
        kv_lens = [r.prompt_len + r.tokens_out - 1 for r in sel]
        toks = [r.generated[-1] for r in sel]
        new_toks = self.ex.decode(toks, tables, kv_lens)
        for r, tok in zip(sel, new_toks):
            r.generated.append(tok)
            r.tokens_out += 1
        avg_ctx = int(sum(kv_lens) / R) + 1
        return self.cost.decode_step_time(R, avg_ctx, 0.0)

    def _retire_finished(self) -> None:
        for r in list(self.decoding):
            if r.tokens_out >= r.output_len:
                r.finish_time = self.now
                r.phase = Phase.FINISHED
                self.bm.free_request(r.rid)
                self.host_layers.pop(r.rid, None)
                self.predictor.observe(r.output_len)
                self.decoding.remove(r)
                self.done.append(r)

    # ---------------------------------------------------------------- step
    def _admit_waiting(self) -> int:
        """Shared admission loop. Exclusive mode runs each admitted prefill
        immediately (`_do_prefill`); chunked mode only allocates and queues
        the request for chunk-by-chunk prefill."""
        if not self.waiting:
            return 0
        if self.ec.policy == "layerkv" and self.ec.slo_aware:
            budget_n = self.sched.max_prefills(
                list(self.waiting), self.decoding, self.now,
                cached_len=self._cached_hint)
        else:
            budget_n = len(self.waiting)
        admitted = 0
        while self.waiting and budget_n > 0 and \
                len(self.decoding) + len(self.prefilling) \
                < self.ec.max_batch_size:
            r = self.waiting[0]
            if self.bm.num_free(DEVICE) < self._device_need(r):
                break
            if self.ec.chunked:
                alloc = self._alloc_prefill(r)
                if alloc is None:
                    break
                self.waiting.popleft()
                self.host_layers[r.rid] = len(alloc[1])
                r.phase = Phase.PREFILL
                r.prefill_start = self.now
                self.prefilling.append(r)
            else:
                self.waiting.popleft()
                r.prefill_start = self.now
                if not self._do_prefill(r):
                    self.waiting.appendleft(r)
                    break
            admitted += 1
            budget_n -= 1
        return admitted

    def step(self) -> bool:
        """One scheduler iteration. Returns False when fully idle."""
        if self.ec.chunked:
            return self._step_chunked()
        if self._admit_waiting():
            return True
        if not self.decoding:
            return False
        sel = self._select_runnable()
        self.now += self._run_decode(sel)
        self._retire_finished()
        return True

    def _step_chunked(self) -> bool:
        """One chunked-mode iteration: admit into the chunk queue, run up to
        `chunk_size` prompt-chunk tokens (FCFS, Eq.1-tightened when
        slo_aware) plus one decode step, and advance the clock by
        max(chunk compute, decode compute) — mixed batching."""
        self._admit_waiting()
        if not (self.prefilling or self.decoding):
            return False

        # decode batch first: its tokens count against the iteration's
        # token budget (same semantics as the simulator)
        sel: List[Request] = []
        if self.decoding:
            sel = self._select_runnable(allow_empty=bool(self.prefilling))

        # chunk assembly: FCFS under the per-iteration token budget
        if self.ec.policy == "layerkv" and self.ec.slo_aware:
            cap = self.sched.max_chunk_tokens(
                self.decoding, self.now, self.ec.chunk_size,
                floor=self.ec.chunk_floor)
        else:
            cap = self.ec.chunk_size
        budget = cap - len(sel)
        if self.prefilling and not sel:
            budget = max(budget, self.ec.chunk_floor)
        chunk_work: List[tuple] = []
        for r in list(self.prefilling):
            if budget <= 0:
                break
            c = min(budget, r.prefill_remaining)
            chunk_work.append((r, c))
            budget -= c

        chunk_time = 0.0
        for r, c in chunk_work:
            chunk_time += self.cost.chunk_prefill_time(c, r.prefill_done)

        if self.ec.fused:
            # ONE forward: chunks + decode batch share the weight stream
            R = len(sel)
            avg_ctx = (int(sum(r.prompt_len + r.tokens_out - 1
                               for r in sel) / R) + 1) if sel else 0
            self._run_mixed(chunk_work, sel)
            self.now += self.cost.mixed_step_time(chunk_time, R, avg_ctx,
                                                  fused=True)
        else:
            for r, c in chunk_work:
                self._run_chunk(r, c)
            dec_time = self._run_decode(sel) if sel else 0.0
            self.now += max(chunk_time, dec_time)

        # requests whose final chunk just ran get their first token now
        for r, _ in chunk_work:
            if r.prefill_complete and r.phase is Phase.PREFILL:
                r.first_token_time = self.now
                r.tokens_out = 1
                r.phase = Phase.DECODE
                self.prefilling.remove(r)
                self.decoding.append(r)
        self._retire_finished()
        return True

    # ----------------------------------------------------------------- run
    def run(self, requests: List[Request]) -> List[Request]:
        pending = deque(sorted(requests, key=lambda r: r.arrival))
        while pending or self.waiting or self.prefilling or self.decoding:
            while pending and pending[0].arrival <= self.now:
                self.waiting.append(pending.popleft())
            if not self.step():
                if pending:
                    self.now = max(self.now, pending[0].arrival)
                elif self.waiting:
                    raise RuntimeError("wedged with waiting requests")
        self.bm.check()
        return self.done
