"""The LayerKV serving engine: continuous batching over real JAX execution.

Wires the paper's decision components (block manager, offload plans, SLO
scheduler, Eq.5 forecast) to the `PagedExecutor`. Two policies:

  'vllm'     request-wise: admit a prefill only when device blocks for the
             whole prompt x all layers are free (baseline).
  'layerkv'  layer-wise: admit with Eq.4's x retained layers (+1 send
             buffer); offloaded layers live in the HOST pool and are
             streamed/promoted back for decode.

The engine clock is virtual (driven by the cost model) so runs are exactly
reproducible and policy behaviour — not CPU speed — determines metrics;
generated TOKENS are real model outputs, which is what the losslessness
tests assert.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Optional

import numpy as np

from repro.configs.base import ModelConfig
from repro.core import (
    DEVICE, HOST, LayerwiseBlockManager, OffloadEngine, PoolExhausted,
    SLOScheduler, interleave_offload_layers,
)
from repro.core.predictor import HistogramPredictor, LengthPredictor
from repro.serving.costmodel import CostModel, HWProfile, TPU_V5E
from repro.serving.executor import PagedExecutor
from repro.serving.request import Phase, Request


@dataclasses.dataclass
class EngineConfig:
    policy: str = "layerkv"
    slo_aware: bool = True
    num_device_blocks: int = 128
    num_host_blocks: int = 1024
    block_size: int = 16
    max_batch_size: int = 64
    max_tokens_per_request: int = 4096


class LayerKVEngine:
    def __init__(self, cfg: ModelConfig, params=None,
                 ec: Optional[EngineConfig] = None,
                 hw: HWProfile = TPU_V5E,
                 predictor: Optional[LengthPredictor] = None, rng=None):
        self.cfg = cfg
        self.ec = ec or EngineConfig()
        self.ex = PagedExecutor(cfg, params, self.ec.num_device_blocks,
                                self.ec.num_host_blocks, self.ec.block_size,
                                rng=rng)
        self.L = cfg.n_layers
        self.bm = LayerwiseBlockManager(self.ec.num_device_blocks,
                                        self.ec.num_host_blocks,
                                        self.ec.block_size, self.L)
        self.cost = CostModel(cfg, hw)
        self.off = OffloadEngine(self.cost, self.L)
        self.predictor = predictor or HistogramPredictor(
            [16, 32, 64, 128, 256])
        self.sched = SLOScheduler(self.cost, self.predictor)
        self.now = 0.0
        self.waiting: deque[Request] = deque()
        self.decoding: List[Request] = []
        self.done: List[Request] = []
        self.host_layers: Dict[str, int] = {}

    # ------------------------------------------------------------- helpers
    def _blocks(self, tokens: int) -> int:
        return self.bm.blocks_for_tokens(tokens)

    def _device_need(self, r: Request) -> int:
        if self.ec.policy == "vllm":
            return self._blocks(r.prompt_len) * self.L
        plan = self.off.plan_for_prompt(r.prompt_len)
        send_buf = 1 if plan.offload_layers else 0
        return self._blocks(r.prompt_len) * (plan.x + send_buf)

    # -------------------------------------------------------------- prefill
    def _do_prefill(self, r: Request) -> bool:
        per_layer = self._blocks(r.prompt_len)
        if self.ec.policy == "vllm":
            retain = list(range(self.L))
            off = []
        else:
            plan = self.off.plan_for_prompt(r.prompt_len)
            fit = max(self.bm.num_free(DEVICE) // max(per_layer, 1) - 1, 0)
            retain_n = min(self.L, max(plan.x, fit))
            off = interleave_offload_layers(self.L, retain_n)
            retain = [l for l in range(self.L) if l not in set(off)]
        try:
            for l in retain:
                self.bm.alloc_layer(r.rid, l, r.prompt_len, DEVICE)
            for l in off:
                self.bm.alloc_layer(r.rid, l, r.prompt_len, HOST)
        except PoolExhausted:
            self.bm.free_request(r.rid)
            return False

        pad = self._blocks(r.prompt_len) * self.ec.block_size
        next_tok, k, v = self.ex.prefill(r.prompt, pad)
        for l in retain:
            a = self.bm.allocation(r.rid, l)
            self.ex.write_layer("device", a.blocks, k[l], v[l])
        for l in off:
            a = self.bm.allocation(r.rid, l)
            self.ex.write_layer("host", a.blocks, k[l], v[l])
        if off:
            from repro.core import OffloadPlan
            self.off.prefill_offload_done(
                self.now, r.prompt_len, OffloadPlan(retain, off, len(retain)))
        self.host_layers[r.rid] = len(off)
        self.now += self.cost.prefill_time(r.prompt_len)
        r.prefill_start = r.prefill_start if r.prefill_start >= 0 else self.now
        r.first_token_time = self.now
        r.tokens_out = 1
        r.generated.append(next_tok)
        r.phase = Phase.DECODE
        self.decoding.append(r)
        return True

    # ------------------------------------------------------ residency mgmt
    def _ensure_device(self, r: Request) -> bool:
        """Promote every host-resident layer of r to device (h2d). Returns
        False when blocks run out (request pauses this iteration)."""
        for l in self.bm.layers_on(r.rid, HOST):
            a = self.bm.allocation(r.rid, l)
            need = len(a.blocks)
            if self.bm.num_free(DEVICE) < need:
                return False
            src, dst = self.bm.move_layer(r.rid, l, DEVICE)
            self.ex.copy_blocks("host", "device", src, dst)
            self.off.ledger.submit(
                self.now, self.cost.kv_bytes(a.num_tokens, 1), "reload")
        self.host_layers[r.rid] = 0
        return True

    def _evict_newest(self, exclude=()) -> bool:
        """Push the newest request's device layers to host to make room."""
        excl = set(exclude)
        for r in sorted(self.decoding, key=lambda q: -q.prefill_start):
            if r.rid in excl:
                continue
            dev = self.bm.layers_on(r.rid, DEVICE)
            if not dev:
                continue
            for l in dev:
                a = self.bm.allocation(r.rid, l)
                if self.bm.num_free(HOST) < len(a.blocks):
                    return False
                src, dst = self.bm.move_layer(r.rid, l, HOST)
                self.ex.copy_blocks("device", "host", src, dst)
                self.off.proactive_offload(self.now, a.num_tokens, 1)
            self.host_layers[r.rid] = len(self.bm.layers_on(r.rid, HOST))
            return True
        return False

    # ---------------------------------------------------------------- step
    def step(self) -> bool:
        """One scheduler iteration. Returns False when fully idle."""
        # admission
        admitted = 0
        if self.waiting:
            if self.ec.policy == "layerkv" and self.ec.slo_aware:
                budget_n = self.sched.max_prefills(
                    list(self.waiting), self.decoding, self.now)
            else:
                budget_n = len(self.waiting)
            while self.waiting and budget_n > 0 and \
                    len(self.decoding) < self.ec.max_batch_size:
                r = self.waiting[0]
                if self.bm.num_free(DEVICE) < self._device_need(r):
                    break
                self.waiting.popleft()
                r.prefill_start = self.now
                if not self._do_prefill(r):
                    self.waiting.appendleft(r)
                    break
                admitted += 1
                budget_n -= 1
        if admitted:
            return True

        if not self.decoding:
            return False

        # decode iteration: select runnable requests (device-resident or
        # promotable + room to grow), most-behind-on-TPOT first
        sel: List[Request] = []
        reserved = 0  # growth blocks earmarked for already-selected requests
        for r in sorted(self.decoding,
                        key=lambda q: q.tpot_slo - q.current_tpot(self.now)):
            sel_ids = [q.rid for q in sel] + [r.rid]

            def _need():
                """Promotion blocks + growth blocks for r this iteration."""
                need = 0
                for l in self.bm.layers_on(r.rid, HOST):
                    a = self.bm.allocation(r.rid, l)
                    need += len(a.blocks)
                    if a.num_tokens % self.ec.block_size == 0:
                        need += 1
                for l in self.bm.layers_on(r.rid, DEVICE):
                    a = self.bm.allocation(r.rid, l)
                    if a.num_tokens % self.ec.block_size == 0:
                        need += 1
                return need
            while self.bm.num_free(DEVICE) - reserved < _need():
                if not self._evict_newest(exclude=sel_ids):
                    break
            if self.bm.num_free(DEVICE) - reserved < _need():
                continue  # pause this iteration
            growth = _need()
            if self.host_layers.get(r.rid, 0):
                if not self._ensure_device(r):
                    continue
                # promotion blocks were consumed; growth remains earmarked
                growth = sum(
                    1 for l in self.bm.layers_on(r.rid, DEVICE)
                    if self.bm.allocation(r.rid, l).num_tokens
                    % self.ec.block_size == 0)
            reserved += growth
            sel.append(r)
        if not sel:
            raise RuntimeError("engine wedged: no runnable request")

        # grow allocations for the incoming token, then build tables
        for r in sel:
            for l in list(self.bm.tables[r.rid]):
                self.bm.extend_layer(r.rid, l, 1)
        maxb = max(len(self.bm.allocation(r.rid, 0).blocks) for r in sel)
        R = len(sel)
        tables = np.zeros((self.L, R, maxb), np.int32)
        for i, r in enumerate(sel):
            for l in range(self.L):
                a = self.bm.allocation(r.rid, l)
                assert a.pool == DEVICE
                tables[l, i, :len(a.blocks)] = a.blocks
        kv_lens = [r.prompt_len + r.tokens_out - 1 for r in sel]
        toks = [r.generated[-1] for r in sel]
        new_toks = self.ex.decode(toks, tables, kv_lens)

        avg_ctx = int(sum(kv_lens) / R) + 1
        self.now += self.cost.decode_step_time(R, avg_ctx, 0.0)
        for r, tok in zip(sel, new_toks):
            r.generated.append(tok)
            r.tokens_out += 1
            if r.tokens_out >= r.output_len:
                r.finish_time = self.now
                r.phase = Phase.FINISHED
                self.bm.free_request(r.rid)
                self.host_layers.pop(r.rid, None)
                self.predictor.observe(r.output_len)
                self.decoding.remove(r)
                self.done.append(r)
        return True

    # ----------------------------------------------------------------- run
    def run(self, requests: List[Request]) -> List[Request]:
        pending = deque(sorted(requests, key=lambda r: r.arrival))
        while pending or self.waiting or self.decoding:
            while pending and pending[0].arrival <= self.now:
                self.waiting.append(pending.popleft())
            if not self.step():
                if pending:
                    self.now = max(self.now, pending[0].arrival)
                elif self.waiting:
                    raise RuntimeError("wedged with waiting requests")
        self.bm.check()
        return self.done
