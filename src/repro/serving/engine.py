"""The LayerKV serving engine: continuous batching over real JAX execution.

Wires the paper's decision components (block manager, offload plans, SLO
scheduler, Eq.5 forecast) to the `PagedExecutor`. Two policies:

  'vllm'     request-wise: admit a prefill only when device blocks for the
             whole prompt x all layers are free (baseline).
  'layerkv'  layer-wise: admit with Eq.4's x retained layers (+1 send
             buffer); offloaded layers live in the HOST pool and are
             streamed/promoted back for decode.

Orthogonally, `ServeConfig.chunked` selects the engine-step semantics
(exclusive vLLM-0.5.5 prefill vs chunked prefill + mixed batching) and
`ServeConfig.fused` (chunked only) collapses the iteration's two executor
calls into ONE `PagedExecutor.mixed_step` — see ROADMAP "Scheduling
matrix" for the full five-axis picture.

Everything decision-shaped — admission (policy-ordered, Alg.1 budgeted),
the device-need gate, the Eq.4 layer-split allocation, chunk assembly,
cache-copy ledger routing, cancellation — lives in the shared
`SchedulerCore` (serving/scheduler.py), which the discrete-event
simulator drives identically; this module keeps only the real execution:
moving bytes through the paged pools and the JAX forwards.

The engine is driven through a `ServingSession` (serving/session.py):
`submit()` requests while it runs, `stream()` tokens per iteration,
`cancel()` any live request. `run(requests)` remains as a thin batch
wrapper over a session. The engine clock is virtual (driven by the cost
model) so runs are exactly reproducible and policy behaviour — not CPU
speed — determines metrics; generated TOKENS are real model outputs,
which is what the losslessness tests assert (tests/test_chunked.py,
tests/test_fused.py, tests/test_session.py).
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import DEVICE, HOST, LayerwiseBlockManager, OffloadEngine, \
    SLOScheduler
from repro.core.predictor import HistogramPredictor, LengthPredictor
from repro.serving.costmodel import CostModel, HWProfile, TPU_V5E
from repro.serving.executor import MixedChunk, MixedDecode, PagedExecutor
from repro.serving.request import Phase, Request
from repro.serving.scheduler import CoreDelegateMixin, SchedulerCore, \
    ServeConfig
from repro.serving.session import ServingSession


def EngineConfig(*, chunk_size: Optional[int] = None, **kw) -> ServeConfig:
    """Deprecated shim: builds a `ServeConfig` with the historical engine
    defaults (128 device blocks, 32-token chunk budget). `chunk_size` is
    the old name of `max_prefill_tokens`."""
    if chunk_size is not None:
        kw["max_prefill_tokens"] = chunk_size
    return ServeConfig.for_engine(**kw)


class LayerKVEngine(CoreDelegateMixin):
    """The real serving backend: drives the shared `SchedulerCore`
    against actual JAX forwards (`PagedExecutor`) and physical
    device<->host block movement. Accepts the same `ServeConfig` as the
    simulator; wall-clock is measured, not modeled. Token streams are
    deterministic for a fixed (params, prompts, config)."""

    produces_token_ids = True    # Request.generated carries real tokens

    def __init__(self, cfg: ModelConfig, params=None,
                 ec: Optional[ServeConfig] = None,
                 hw: HWProfile = TPU_V5E,
                 predictor: Optional[LengthPredictor] = None, rng=None):
        self.cfg = cfg
        self.ec = (ec or ServeConfig.for_engine()).validate()
        ndb = self.ec.num_device_blocks or 128  # 0 = backend default
        self.ex = PagedExecutor(cfg, params, ndb,
                                self.ec.num_host_blocks, self.ec.block_size,
                                rng=rng)
        self.L = cfg.n_layers
        self.bm = LayerwiseBlockManager(ndb, self.ec.num_host_blocks,
                                        self.ec.block_size, self.L,
                                        prefix_cache=self.ec.prefix_cache)
        self.cost = CostModel(cfg, hw)
        self.off = OffloadEngine(self.cost, self.L)
        self.predictor = predictor or HistogramPredictor(
            [16, 32, 64, 128, 256])
        self.sched = SLOScheduler(self.cost, self.predictor)
        # cache-driven copies (COW, promote, demote) move REAL bytes
        # through the executor; the core charges the transfer ledger
        self.core = SchedulerCore(self.ec, self.cost, self.bm, self.off,
                                  self.sched, self.L,
                                  physical_copy=self._physical_copy)
        # one registry per engine: the executor's jit-retrace counters
        # share the core's namespace so a single snapshot() has both
        self.ex.registry = self.core.registry
        if self.core.tracer is not None:
            # real-execution traces carry wall time next to the virtual
            # clock (the virtual clock stays primary so streams merge)
            self.core.tracer.wall_clock = time.perf_counter
        self._chunk_bufs: Dict[str, tuple] = {}  # rid -> cached (k, v)

    # --------------------------------------------- shared-core delegation
    # queues/host_layers/clock()/advance_to() come from CoreDelegateMixin
    @property
    def now(self) -> float:
        return self.core.now

    @now.setter
    def now(self, t: float) -> None:
        self.core.now = t

    def finish(self) -> None:
        self.bm.check()
        assert not self._chunk_bufs, \
            "leaked chunk prefix buffers: " + ", ".join(self._chunk_bufs)

    def _physical_copy(self, src_pool: str, src: int, dst_pool: str,
                       dst: int) -> None:
        src_tier = "device" if src_pool == DEVICE else "host"
        dst_tier = "device" if dst_pool == DEVICE else "host"
        self.ex.copy_blocks(src_tier, dst_tier, [src], [dst])

    def cancel(self, r: Request) -> bool:
        """Unwind a live request (see SchedulerCore.cancel); the engine
        additionally drops its cached chunk prefix buffers."""
        if not self.core.cancel(r, self.now):
            return False
        self._chunk_bufs.pop(r.rid, None)
        return True

    # -------------------------------------------------------------- prefill
    def _do_prefill(self, r: Request) -> bool:
        alloc = self.core.alloc_prefill(r)
        if alloc is None:
            return False
        retain, off = alloc

        if r.prefill_done > 0:
            # prefix-cache hit: run the uncached suffix as ONE chunk
            # against the shared prefix blocks (q_offset causal masking);
            # compute for the cached tokens is skipped entirely
            c, p = r.prefill_remaining, r.prefill_done
            self._run_chunk(r, c)
            self.now += self.cost.chunk_prefill_time(c, p)
        else:
            pad = self.bm.blocks_for_tokens(r.prompt_len) \
                * self.ec.block_size
            next_tok, k, v = self.ex.prefill(r.prompt, pad)
            for l in retain:
                a = self.bm.allocation(r.rid, l)
                self.ex.write_layer("device", a.blocks, k[l], v[l])
            for l in off:
                a = self.bm.allocation(r.rid, l)
                self.ex.write_layer("host", a.blocks, k[l], v[l])
            if off:
                from repro.core import OffloadPlan
                self.off.prefill_offload_done(
                    self.now, r.prompt_len,
                    OffloadPlan(retain, off, len(retain)))
            self.now += self.cost.prefill_time(r.prompt_len)
            r.prefill_done = r.prompt_len
            r.n_chunks += 1
            r.generated.append(next_tok)
            if self.ec.prefix_cache and r.prompt:
                self.bm.register_prefix(r.rid, r.prompt)
        r.prefill_start = r.prefill_start if r.prefill_start >= 0 else self.now
        if r.first_token_time < 0:  # survives replica-kill restart
            r.first_token_time = self.now
        r.tokens_out = 1
        r.note_token(self.now)
        r.phase = Phase.DECODE
        self.decoding.append(r)
        return True

    # ------------------------------------------------------- chunked prefill
    def _gather_buffers(self, r: Request):
        """Dense (L, S_buf, KV, hd) K/V prefix buffers for r — the LEGACY
        (two-call) chunk path only; fused mode attends straight against
        the pools and never materializes these. Gathered from the pools on
        the request's FIRST chunk, then cached and kept fresh with the
        chunk appends: a prefilling request's block contents only change
        through its own chunks (evictions touch decoding requests), so
        re-gathering every chunk would be pure waste. Only the blocks
        holding the `prefill_done` live tokens are physically gathered
        (zero for a fresh prompt, the cached prefix for a hit). Entries
        are dropped on the final chunk AND on cancel (`cancel()`), so the
        dict is empty whenever no request is mid-prefill."""
        if r.rid in self._chunk_bufs:
            return self._chunk_bufs[r.rid]
        ks, vs = [], []
        for l in range(self.L):
            a = self.bm.allocation(r.rid, l)
            tier = "device" if a.pool == DEVICE else "host"
            k, v = self.ex.gather_layer(tier, a.blocks,
                                        kv_valid=r.prefill_done)
            ks.append(k)
            vs.append(v)
        bufs = (jnp.stack(ks), jnp.stack(vs))
        self._chunk_bufs[r.rid] = bufs
        return bufs

    def _run_chunk(self, r: Request, c: int) -> None:
        """Prefill tokens [prefill_done, prefill_done + c) of r: run the
        chunk against the cached prefix, append its KV into the paged pools
        at the token offset, and account the chunk's d2h traffic."""
        p = r.prefill_done
        kbuf, vbuf = self._gather_buffers(r)
        logits, kc, vc = self.ex.prefill_chunk(r.prompt[p:p + c], p,
                                               kbuf, vbuf)
        for l in range(self.L):
            a = self.bm.allocation(r.rid, l)
            tier = "device" if a.pool == DEVICE else "host"
            self.ex.write_layer_slice(tier, a.blocks, p, kc[l], vc[l])
        n_off = len(self.bm.layers_on(r.rid, HOST))
        if n_off:
            self.off.ledger.submit(
                self.now, self.cost.kv_bytes(c, n_off), "offload")
        r.prefill_done += c
        r.n_chunks += 1
        if self.ec.prefix_cache and r.prompt:
            # incremental publication: full blocks whose KV is now written
            # become hittable while the rest of this prompt still prefills
            self.bm.register_prefix(r.rid, r.prompt, upto=r.prefill_done)
        if r.prefill_complete:
            self._chunk_bufs.pop(r.rid, None)
            r.generated.append(int(jnp.argmax(logits)))
        else:
            self._chunk_bufs[r.rid] = (
                kbuf.at[:, p:p + c].set(kc.astype(kbuf.dtype)),
                vbuf.at[:, p:p + c].set(vc.astype(vbuf.dtype)))

    # ---------------------------------------------------------- fused step
    def _run_mixed(self, chunk_work: List[tuple],
                   sel: List[Request]) -> None:
        """One fused iteration: every prefill chunk AND the decode batch in
        a single `PagedExecutor.mixed_step` forward — one weight stream per
        layer per iteration. Chunk tokens attend straight against the paged
        pools (block tables sliced to the live prefix + chunk), so the
        O(S) dense prefix gather of the two-call path is gone entirely;
        new KV scatters into the pools inside the step. Bookkeeping
        (ledger d2h, prefill progress, prefix registration, token appends)
        mirrors `_run_chunk` + `_run_decode` exactly."""
        for r in sel:
            for l in list(self.bm.tables[r.rid]):
                self.bm.extend_layer(r.rid, l, 1)
        chunks: List[MixedChunk] = []
        for r, c in chunk_work:
            p = r.prefill_done
            nb_live = -(-(p + c) // self.ec.block_size)
            tabs, tiers = [], []
            for l in range(self.L):
                a = self.bm.allocation(r.rid, l)
                tabs.append(a.blocks[:nb_live])
                tiers.append(a.pool == HOST)
            chunks.append(MixedChunk(tokens=r.prompt[p:p + c], offset=p,
                                     tables=tabs, tiers=tiers))
        decodes: List[MixedDecode] = []
        for r in sel:
            ctx = r.prompt_len + r.tokens_out - 1
            tabs = []
            for l in range(self.L):
                a = self.bm.allocation(r.rid, l)
                assert a.pool == DEVICE
                tabs.append(a.blocks)
            decodes.append(MixedDecode(token=r.generated[-1], ctx=ctx,
                                       tables=tabs))
        out = self.ex.mixed_step(chunks, decodes)
        for i, (r, c) in enumerate(chunk_work):
            n_off = len(self.bm.layers_on(r.rid, HOST))
            if n_off:
                self.off.ledger.submit(
                    self.now, self.cost.kv_bytes(c, n_off), "offload")
            r.prefill_done += c
            r.n_chunks += 1
            if self.ec.prefix_cache and r.prompt:
                self.bm.register_prefix(r.rid, r.prompt,
                                        upto=r.prefill_done)
            if r.prefill_complete:
                r.generated.append(int(out[i]))
        for j, r in enumerate(sel):
            r.generated.append(int(out[len(chunk_work) + j]))
            r.tokens_out += 1

    # ------------------------------------------------------ residency mgmt
    def _ensure_device(self, r: Request) -> bool:
        """Promote every host-resident layer of r to device (h2d). Returns
        False when blocks run out (request pauses this iteration)."""
        for l in self.bm.layers_on(r.rid, HOST):
            a = self.bm.allocation(r.rid, l)
            need = len(a.blocks)
            if self.bm.num_free(DEVICE) < need:
                return False
            src, dst = self.bm.move_layer(r.rid, l, DEVICE)
            self.ex.copy_blocks("host", "device", src, dst)
            self.off.ledger.submit(
                self.now, self.cost.kv_bytes(a.num_tokens, 1), "reload")
        self.host_layers[r.rid] = 0
        return True

    def _evict_newest(self, exclude=()) -> bool:
        """Push the newest request's device layers to host to make room.
        Shared prefix blocks are copied out (detach), never pulled from
        under the requests still mapping them."""
        excl = set(exclude)
        for r in sorted(self.decoding, key=lambda q: -q.prefill_start):
            if r.rid in excl:
                continue
            dev = self.bm.layers_on(r.rid, DEVICE)
            if not dev:
                continue
            for l in dev:
                a = self.bm.allocation(r.rid, l)
                if self.core.host_free() < len(a.blocks):
                    return False
                src, dst = self.bm.move_layer(r.rid, l, HOST, detach=True)
                self.ex.copy_blocks("device", "host", src, dst)
                self.off.proactive_offload(self.now, a.num_tokens, 1)
            self.host_layers[r.rid] = len(self.bm.layers_on(r.rid, HOST))
            return True
        return False

    # ------------------------------------------------------ decode iteration
    def _select_runnable(self, allow_empty: bool = False) -> List[Request]:
        """Pick this iteration's decode batch: device-resident or promotable
        requests with room to grow, most-behind-on-TPOT first."""
        sel: List[Request] = []
        reserved = 0  # growth blocks earmarked for already-selected requests
        for r in sorted(self.decoding,
                        key=lambda q: q.tpot_slo - q.current_tpot(self.now)):
            sel_ids = [q.rid for q in sel] + [r.rid]

            def _need(r: Request = r) -> int:
                """Promotion blocks + growth blocks for r this iteration."""
                need = 0
                for l in self.bm.layers_on(r.rid, HOST):
                    a = self.bm.allocation(r.rid, l)
                    need += len(a.blocks)
                    if a.num_tokens % self.ec.block_size == 0:
                        need += 1
                for l in self.bm.layers_on(r.rid, DEVICE):
                    a = self.bm.allocation(r.rid, l)
                    if a.num_tokens % self.ec.block_size == 0:
                        need += 1
                return need
            while self.bm.num_free(DEVICE) - reserved < _need():
                if not self._evict_newest(exclude=sel_ids):
                    break
            if self.bm.num_free(DEVICE) - reserved < _need():
                continue  # pause this iteration
            growth = _need()
            if self.host_layers.get(r.rid, 0):
                if not self._ensure_device(r):
                    continue
                # promotion blocks were consumed; growth remains earmarked
                growth = sum(
                    1 for l in self.bm.layers_on(r.rid, DEVICE)
                    if self.bm.allocation(r.rid, l).num_tokens
                    % self.ec.block_size == 0)
            reserved += growth
            sel.append(r)
        if not sel and not allow_empty:
            raise RuntimeError("engine wedged: no runnable request")
        return sel

    def _run_decode(self, sel: List[Request]) -> float:
        """Grow allocations, run one real decode step over `sel`, append the
        new tokens. Returns the modeled step time; the caller advances the
        clock and retires finished requests."""
        for r in sel:
            for l in list(self.bm.tables[r.rid]):
                self.bm.extend_layer(r.rid, l, 1)
        maxb = max(len(self.bm.allocation(r.rid, 0).blocks) for r in sel)
        R = len(sel)
        tables = np.zeros((self.L, R, maxb), np.int32)
        for i, r in enumerate(sel):
            for l in range(self.L):
                a = self.bm.allocation(r.rid, l)
                assert a.pool == DEVICE
                tables[l, i, :len(a.blocks)] = a.blocks
        kv_lens = [r.prompt_len + r.tokens_out - 1 for r in sel]
        toks = [r.generated[-1] for r in sel]
        new_toks = self.ex.decode(toks, tables, kv_lens)
        for r, tok in zip(sel, new_toks, strict=True):
            r.generated.append(tok)
            r.tokens_out += 1
        avg_ctx = int(sum(kv_lens) / R) + 1
        return self.cost.decode_step_time(R, avg_ctx, 0.0)

    def _retire_finished(self) -> None:
        # the generation cap backstops runaway requests whose target EOS
        # position exceeds the engine's per-request budget
        cap = self.ec.max_tokens_per_request
        for r in list(self.decoding):
            if r.tokens_out >= min(r.output_len, cap):
                r.finish_time = self.now
                r.phase = Phase.FINISHED
                self.bm.free_request(r.rid)
                self.core.release(r)
                self.predictor.observe(r.output_len)
                self.decoding.remove(r)
                self.done.append(r)
                if self.core.tracer is not None:
                    self.core.tracer.finish(r, self.now)

    # ---------------------------------------------------------------- step
    def step(self) -> bool:
        """One scheduler iteration. Returns False when fully idle."""
        out = self._step_chunked() if self.ec.chunked \
            else self._step_exclusive()
        if self.core.sanitizer is not None:
            self.core.sanitizer.check(self.core)
        return out

    def _step_exclusive(self) -> bool:
        """Exclusive-prefill iteration (vLLM 0.5.5 semantics)."""
        if self.core.admit_waiting(self.now, immediate=self._do_prefill):
            return True
        if not self.decoding:
            return False
        sel = self._select_runnable()
        self.now += self._run_decode(sel)
        for r in sel:
            r.note_token(self.now)
        self._retire_finished()
        return True

    def _step_chunked(self) -> bool:
        """One chunked-mode iteration: admit into the chunk queue, run up
        to `max_prefill_tokens` prompt-chunk tokens (policy-ordered
        admission, FCFS chunk assembly, Eq.1-tightened when slo_aware)
        plus one decode step, and advance the clock by
        max(chunk compute, decode compute) — mixed batching."""
        self.core.admit_waiting(self.now)
        if not (self.prefilling or self.decoding):
            return False
        t0 = self.now

        # decode batch first: its tokens count against the iteration's
        # token budget (same semantics as the simulator)
        sel: List[Request] = []
        if self.decoding:
            sel = self._select_runnable(allow_empty=bool(self.prefilling))
        chunk_work = self.core.assemble_chunks(self.now, len(sel))

        chunk_time = 0.0
        for r, c in chunk_work:
            chunk_time += self.cost.chunk_prefill_time(c, r.prefill_done)

        if self.ec.fused:
            # ONE forward: chunks + decode batch share the weight stream
            R = len(sel)
            avg_ctx = (int(sum(r.prompt_len + r.tokens_out - 1
                               for r in sel) / R) + 1) if sel else 0
            self._run_mixed(chunk_work, sel)
            self.now += self.cost.mixed_step_time(chunk_time, R, avg_ctx,
                                                  fused=True)
        else:
            for r, c in chunk_work:
                self._run_chunk(r, c)
            dec_time = self._run_decode(sel) if sel else 0.0
            self.now += max(chunk_time, dec_time)

        for r in sel:
            r.note_token(self.now)
        if self.core.tracer is not None:
            # chunks already ran: prefill_done holds the post-chunk count
            self.core.tracer.chunk_iteration(
                self.core, t0, self.now, chunk_work,
                done={r.rid: r.prefill_done for r, _ in chunk_work})
        # requests whose final chunk just ran get their first token now
        for r, _ in chunk_work:
            if r.prefill_complete and r.phase is Phase.PREFILL:
                if r.first_token_time < 0:  # survives replica-kill restart
                    r.first_token_time = self.now
                    if self.core.tracer is not None:
                        self.core.tracer.first_token(r, self.now)
                r.tokens_out = 1
                r.note_token(self.now)
                r.phase = Phase.DECODE
                self.prefilling.remove(r)
                self.decoding.append(r)
        self._retire_finished()
        return True

    # ----------------------------------------------------------------- run
    def run(self, requests: List[Request]) -> List[Request]:
        """Batch convenience wrapper: one session, every request submitted
        up front at its own arrival, drained to completion."""
        session = ServingSession(self)
        for r in sorted(requests, key=lambda q: q.arrival):
            session.submit(r, arrival=r.arrival)
        return session.drain()
