"""Online serving sessions: submit / stream / cancel / drain over any
backend that drives the shared `SchedulerCore` (the real `LayerKVEngine`
or the discrete-event `ServingSimulator`).

The old entry point was a closed-loop batch call — `run(requests)`
consumed a pre-sorted list once and raised when it wedged. A
`ServingSession` is the open-loop replacement: requests are submitted
while the system runs, every `step()` interleaves newly-arrived requests
with in-flight iterations, tokens stream out per iteration, and any live
request can be cancelled with its KV (shared prefix blocks, mid-prefill
chunk state, host-resident offloaded layers) unwound. `run()` on both
backends is now a thin wrapper over a session, so every losslessness
test in the repo doubles as an online-vs-offline equivalence test.

Backpressure: a request that cannot be admitted yet simply waits in the
queue — admission retries every step as in-flight work frees blocks.
Only a request that can NEVER fit (pools smaller than its minimum need,
nothing in flight) raises `AdmissionImpossible`, and only from the
blocking entry points (`drain`, `stream`); `step()` just reports idle.

The session clock is the backend's virtual clock. `submit()` without an
explicit arrival stamps the request at the current clock (true online
arrival); an explicit future arrival parks it in a pending heap and the
idle path jumps the clock forward exactly like the old batch loops did.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Iterator, List, Optional, Protocol

from repro.serving.request import Phase, Request
from repro.serving.scheduler import SchedulerCore


class ServingBackend(Protocol):
    """What a session needs from an engine or simulator."""

    core: SchedulerCore
    #: True when steps produce real token ids in Request.generated (the
    #: engine); the simulator only advances `tokens_out` counters.
    produces_token_ids: bool

    def clock(self) -> float: ...
    def advance_to(self, t: float) -> None: ...
    def step(self) -> bool: ...          # one iteration; False when idle
    def cancel(self, r: Request) -> bool: ...
    def finish(self) -> None: ...        # end-of-drain invariant checks


@dataclasses.dataclass
class RequestHandle:
    """A submitted request, as seen by the caller. Carries a stream
    cursor so `take_new()` / `stream()` deliver each token exactly once."""

    request: Request
    session: "ServingSession"
    _cursor: int = 0

    @property
    def rid(self) -> str:
        return self.request.rid

    @property
    def phase(self) -> Phase:
        return self.request.phase

    @property
    def finished(self) -> bool:
        return self.request.phase is Phase.FINISHED

    @property
    def paused(self) -> bool:
        """True while the request is preempted (KV parked on HOST). A
        paused request is still live: it resumes losslessly and keeps
        streaming, so `done` stays False."""
        return self.request.phase is Phase.PAUSED

    @property
    def cancelled(self) -> bool:
        return self.request.phase is Phase.CANCELLED

    @property
    def shed(self) -> bool:
        """True when the scheduler rejected the request under overload
        (graceful degradation, `shed_overload`); the typed reason is on
        `request.shed_reason`. Terminal, like cancelled."""
        return self.request.phase is Phase.SHED

    @property
    def done(self) -> bool:
        return self.finished or self.cancelled or self.shed

    def take_new(self) -> List[int]:
        """Tokens produced since the last call (non-blocking). Real token
        ids on the engine; on the simulator (no real model) the stream
        carries token ordinals instead."""
        r = self.request
        n = r.tokens_out
        if self.session.backend.produces_token_ids:
            n = min(n, len(r.generated))
            new = [int(t) for t in r.generated[self._cursor:n]]
        else:
            new = list(range(self._cursor, n))
        self._cursor = max(self._cursor, n)
        return new

    def cancel(self) -> bool:
        return self.session.cancel(self)


def cancel_parked(pending: list, r: Request, now: float,
                  cancelled: List[Request]) -> bool:
    """Cancel a not-yet-arrived request parked in an (arrival, seq,
    Request) heap: nothing is in flight to unwind, only the lifecycle
    stamps the core's cancel path would set. Shared by `ServingSession`
    (replica-level heap) and `ClusterSession` (pre-dispatch heap) so the
    two parked-cancel semantics cannot drift. Returns False when `r` is
    not in the heap."""
    for i, (_, _, q) in enumerate(pending):
        if q is r:
            pending.pop(i)
            heapq.heapify(pending)
            r.phase = Phase.CANCELLED
            r.finish_time = now
            cancelled.append(r)
            return True
    return False


class ServingSession:
    """Open-loop serving frontend over one backend."""

    def __init__(self, backend: ServingBackend):
        self.backend = backend
        self.core = backend.core
        self._pending: list = []          # (arrival, seq, Request) heap
        self._seq = itertools.count()
        self.handles: dict = {}           # rid -> RequestHandle

    # ------------------------------------------------------------ submit
    def submit(self, request: Request,
               arrival: Optional[float] = None) -> RequestHandle:
        """Enqueue a request. `arrival=None` stamps it at the current
        clock (online submission); an explicit future arrival is parked
        and fed to the scheduler when the clock reaches it; an explicit
        past arrival enters the queue now but keeps its stamp (its
        queuing delay is measured from the stamped arrival, exactly as
        the old batch loops did)."""
        if request.rid in self.handles:
            raise ValueError(f"duplicate rid {request.rid!r}")
        now = self.backend.clock()
        t = now if arrival is None else arrival
        request.arrival = t
        h = RequestHandle(request, self)
        self.handles[request.rid] = h
        if t <= now:
            self.core.waiting.append(request)
        else:
            heapq.heappush(self._pending, (t, next(self._seq), request))
        return h

    def _feed_arrivals(self) -> None:
        now = self.backend.clock()
        while self._pending and self._pending[0][0] <= now:
            self.core.waiting.append(heapq.heappop(self._pending)[2])

    # -------------------------------------------------------------- step
    def step(self) -> bool:
        """One scheduler iteration, feeding any arrivals the clock has
        reached first. When the backend is idle but future arrivals are
        parked, jumps the clock to the next arrival (the old batch-loop
        semantics). Returns False only when nothing can progress — the
        system is empty, or every waiting request is blocked and nothing
        is in flight (backpressure: a later submit() can unblock it)."""
        self._feed_arrivals()
        if self.backend.step():
            return True
        if self._pending:
            self.backend.advance_to(self._pending[0][0])
            self._feed_arrivals()
            return self.backend.step()
        return False

    @property
    def backlog(self) -> int:
        """Requests accepted but not yet prefilling (queue pressure)."""
        return len(self.core.waiting) + len(self._pending)

    def next_event_time(self) -> Optional[float]:
        """Virtual time of this session's next event, or None when fully
        idle: the backend clock while any work is queued or in flight,
        else the earliest parked arrival. A cluster uses this to advance
        its replicas in lockstep — always stepping the session whose next
        event is earliest on the shared virtual clock."""
        if self.core.waiting or not self.core.idle():
            return self.backend.clock()
        if self._pending:
            return self._pending[0][0]
        return None

    # ------------------------------------------------------------ stream
    def stream(self, handle: RequestHandle) -> Iterator[int]:
        """Per-token iterator for one request: pumps the scheduler until
        the request finishes (or is cancelled), yielding its tokens as
        each iteration produces them. Other in-flight requests advance
        normally while streaming."""
        while True:
            yield from handle.take_new()
            if handle.done:
                return
            if not self.step():
                # graceful degradation first: with shed_overload on, the
                # blocking head is rejected (typed reason) and the pump
                # continues; only a hard-wedged scheduler still raises
                if self.core.shed_blocked(self.backend.clock()):
                    continue
                # names the request that actually blocks admission
                # (under prefix_aware ordering it may not be `handle`)
                raise self.core.wedged_error()

    # ------------------------------------------------------------ cancel
    def cancel(self, handle: RequestHandle) -> bool:
        """Cancel a live request, unwinding everything it has in flight
        (see SchedulerCore.cancel). Pending (not-yet-arrived) requests
        are cancelled from the heap. Idempotent; False when the request
        already finished."""
        r = handle.request
        if cancel_parked(self._pending, r, self.backend.clock(),
                         self.core.cancelled):
            return True
        return self.backend.cancel(r)

    # -------------------------------------------------------------- reap
    def reap(self, handle: RequestHandle) -> Optional[Request]:
        """Release a done (finished or cancelled) request's retained
        state — its handle, and its entry in the backend's done/cancelled
        lists — and return the request, or None if it is not done yet.

        Retention is the session default so `drain()` can return results
        and the simulator can compute metrics over everything it served;
        a LONG-LIVED session must reap handles as it consumes their
        results or per-request state (prompt + generated tokens)
        accumulates for the life of the session."""
        r = handle.request
        if not handle.done:
            return None
        self.handles.pop(r.rid, None)
        if handle.finished:
            if r in self.core.done:
                self.core.done.remove(r)
        elif handle.shed:
            if r in self.core.shed:
                self.core.shed.remove(r)
        elif r in self.core.cancelled:
            self.core.cancelled.remove(r)
        return r

    # ------------------------------------------------------------- drain
    def drain(self) -> List[Request]:
        """Run the system empty and return the finished requests. Raises
        AdmissionImpossible when a waiting request can never be served."""
        while self._pending or self.core.waiting \
                or not self.core.idle():
            if not self.step():
                if self.core.shed_blocked(self.backend.clock()):
                    continue
                raise self.core.wedged_error()
        self.backend.finish()
        return list(self.core.done)

    # ------------------------------------------------------------- export
    def write_trace(self, path: str) -> None:
        """Export this session's event stream as Chrome-trace JSON
        (load at ui.perfetto.dev). Requires `ServeConfig.trace`."""
        if self.core.tracer is None:
            raise ValueError(
                "tracing is off: construct the backend with "
                "ServeConfig(trace=True) to record events")
        from repro.obs.export import write_trace
        write_trace([self.core.tracer], path)
