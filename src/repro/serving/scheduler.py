"""Shared scheduler core: the admission/queueing/residency logic that the
real engine (`engine.py`) and the discrete-event simulator (`sim.py`) both
drive.

Before this module existed, the two serving frontends each carried private
copies of the same decisions — cached-prefix probing, the device-block
admission gate, the Eq.4 layer-split allocation, the Alg.1 admission loop,
chunk assembly under the per-iteration token budget, and the ledger
routing of cache-driven block copies — which is exactly how they drift.
Everything decision-shaped now lives here, once; the backends keep only
what genuinely differs (the engine moves real bytes through the
`PagedExecutor`, the simulator prices steps with the cost model).

Three public pieces:

  ServeConfig      ONE config for both backends (EngineConfig/SimConfig
                   are thin deprecation shims over it);
  AdmissionPolicy  pluggable ordering of the waiting queue — `fcfs`
                   (paper semantics), `prefix_aware` (cache-hitting
                   requests admit first under congestion, with an aging
                   bound so misses never starve), and `deadline`
                   (earliest-virtual-deadline-first across priority
                   classes, the order the preemption controller serves);
  SchedulerCore    the shared state machine: waiting/prefilling/decoding/
                   paused queues, admission, allocation, chunk assembly,
                   lossless preemption (pause = demote KV layer-wise to
                   HOST, resume = promote back, zero recompute), and the
                   cancellation path that unwinds everything a request
                   can leave in flight.
"""
from __future__ import annotations

import dataclasses
import os
from collections import deque
from typing import TYPE_CHECKING, Any, Callable, Deque, Dict, List, \
    Optional, Tuple

from repro.core import (
    DEVICE, HOST, LayerwiseBlockManager, OffloadEngine, PoolExhausted,
    SLOScheduler, interleave_offload_layers,
)
from repro.core.units import Blocks, Seconds, Tokens
from repro.obs.registry import MetricsRegistry
from repro.serving.costmodel import CostModel
from repro.serving.request import Phase, Request

if TYPE_CHECKING:  # pragma: no cover — import cycle (sanitizer -> here)
    from repro.core.sanitizer import KVSanitizer
    from repro.obs.trace import Tracer


# Which SchedulerCore queue a request in each Phase sits in. This registry
# is load-bearing twice: the runtime sanitizer walks it to assert
# phase/queue consistency after every step, and the PHASE001 lint rule
# asserts it stays TOTAL over the Phase enum — adding a lifecycle state
# without deciding where such requests live is a hard lint error, not a
# silent fall-through in some free/cancel path.
PHASE_QUEUES: Dict[Phase, str] = {
    Phase.QUEUED: "waiting",
    Phase.PREFILL: "prefilling",
    Phase.DECODE: "decoding",
    Phase.PAUSED: "paused",
    Phase.FINISHED: "done",
    Phase.CANCELLED: "cancelled",
    Phase.SHED: "shed",
}

# The queues holding LIVE requests — the ones cancel() must test and
# unwind paths must cover. PHASE001 also checks that any scheduler
# function dispatching over several of these covers all of them (or
# carries an explicit suppression naming why not).
LIVE_QUEUES: Tuple[str, ...] = ("waiting", "prefilling", "decoding",
                                "paused")


# --------------------------------------------------------------------------
# Unified configuration
# --------------------------------------------------------------------------

@dataclasses.dataclass
class ServeConfig:
    """One config for the whole serving stack — accepted verbatim by BOTH
    `LayerKVEngine` and `ServingSimulator` (a drift-guard test asserts
    this stays true). Fields are grouped: the shared scheduling axes and
    pool geometry first, then knobs only one backend reads (clearly
    marked). `EngineConfig` / `SimConfig` remain as deprecation shims
    that fill in each backend's historical defaults.
    """
    # ---- scheduling axes (shared) ----------------------------------------
    policy: str = "layerkv"         # 'layerkv' | 'vllm'
    slo_aware: bool = True          # Alg.1 admission (layerkv only)
    chunked: bool = False           # chunked prefill + mixed batching
    prefix_cache: bool = False      # ref-counted cross-request sharing
    fused: bool = False             # ONE forward/iteration (chunked only)
    preemption: bool = False        # lossless priority preemption: when a
    #                                 higher-priority request cannot pass
    #                                 the device-block gate, demote victim
    #                                 KV layer-wise to HOST and resume it
    #                                 later with NO recompute. Off (the
    #                                 default) is bit-identical to the
    #                                 pre-preemption scheduler. Pairs
    #                                 naturally with admission='deadline'.
    admission: str = "fcfs"         # waiting-queue order: 'fcfs' |
    #                                 'prefix_aware' | 'deadline'
    #                                 (see AdmissionPolicy)
    route_by_tokens: bool = False   # least_loaded routing keys on
    #                                 outstanding TOKEN demand
    #                                 (LoadStats.token_demand) instead
    #                                 of KV-block demand. Off (the
    #                                 default) keeps the paper's
    #                                 block-demand join-shortest-queue
    #                                 bit-identically.
    sanitize: bool = False          # opt-in runtime KV-accounting
    #                                 sanitizer: shadow-track every pool/
    #                                 cache/ledger mutation and assert the
    #                                 S1-S8 invariants after each step on
    #                                 either backend (docs/ARCHITECTURE.md
    #                                 "Invariants & analysis"). Also forced
    #                                 on by the REPRO_SANITIZE=1 env var.
    shed_overload: bool = False     # graceful degradation: when a gate-
    #                                 blocked request's deadline is
    #                                 hopeless (or the scheduler would
    #                                 wedge outright), SHED it with a
    #                                 typed reason (AdmissionImpossible
    #                                 subclass name on r.shed_reason)
    #                                 instead of stalling the queue. Off
    #                                 (the default) is bit-identical to
    #                                 the pre-fault scheduler.
    shed_grace_frac: float = 1.0    # how far past its effective deadline
    #                                 (unit: fraction of the request's own
    #                                 TTFT SLO) a blocked request may age
    #                                 before shed_overload rejects it
    trace: bool = False             # end-to-end tracing: per-request
    #                                 lifecycle spans, per-pass scheduler
    #                                 decision records, and exact TTFT
    #                                 attribution (repro.obs). Off (the
    #                                 default) is bit-identical and never
    #                                 even imports the tracer module —
    #                                 same identity discipline as
    #                                 `sanitize`/`preemption`. Export via
    #                                 repro.obs.export / `launch/serve.py
    #                                 --trace=PATH`.
    admission_age_frac: float = 0.5  # aging bound, unit: fraction of the
    #                                 request's own TTFT SLO.
    #                                 prefix_aware: a HIT is ordered by a
    #                                 virtual arrival this fraction of its
    #                                 TTFT SLO early, so a miss is only
    #                                 ever overtaken by hits arriving
    #                                 within that window after it (bounded
    #                                 reordering, no starvation).
    #                                 deadline: each priority level above
    #                                 0 moves the virtual deadline this
    #                                 fraction of the request's TTFT SLO
    #                                 earlier (same bounded-overtaking
    #                                 argument, per class)
    # ---- pool geometry / batching (shared) -------------------------------
    num_device_blocks: Blocks = 0   # 0 = backend default (engine: 128,
    #                                 sim: derive from HW memory)
    num_host_blocks: Blocks = 1024  # host (offload) KV pool size
    block_size: int = 16            # tokens per paged-KV block
    max_batch_size: int = 64        # in-flight (prefill+decode) requests
    max_prefill_tokens: Tokens = 8192  # per-iteration prefill budget
    #                                 (chunked mode chunk cap; exclusive
    #                                 sim batched-prefill cap)
    chunk_floor: Tokens = 8         # min chunk tokens/iter (progress)
    # ---- engine-only -----------------------------------------------------
    max_tokens_per_request: Tokens = 4096  # generation cap per request
    # ---- sim-only --------------------------------------------------------
    proactive: bool = True          # Eq.5 forecast eviction
    collective_reserve_frac: float = 0.0  # §3.1.3 all-reduce reservation
    forecast_horizon: int = 32
    forecast_threshold_frac: float = 0.05
    gpu_mem_util: float = 0.9       # vLLM gpu_memory_utilization
    max_model_len: Tokens = 16384   # drives activation reservation

    def validate(self) -> "ServeConfig":
        if self.fused and not self.chunked:
            raise ValueError("ServeConfig.fused requires chunked=True")
        if self.admission not in ADMISSION_POLICIES:
            raise ValueError(
                f"unknown admission policy {self.admission!r}; "
                f"choose from {sorted(ADMISSION_POLICIES)}")
        return self

    # Historical per-backend defaults, preserved so the EngineConfig /
    # SimConfig shims (and anything still importing them) behave exactly
    # as before the unification.
    @classmethod
    def for_engine(cls, **kw: Any) -> "ServeConfig":
        kw.setdefault("num_device_blocks", 128)
        kw.setdefault("max_prefill_tokens", 32)
        return cls(**kw).validate()

    @classmethod
    def for_sim(cls, **kw: Any) -> "ServeConfig":
        kw.setdefault("num_host_blocks", 1 << 20)
        kw.setdefault("max_batch_size", 256)
        kw.setdefault("chunk_floor", 16)
        return cls(**kw).validate()


@dataclasses.dataclass(frozen=True)
class LoadStats:
    """One replica's load, as a cluster router sees it (read-only
    snapshot of `SchedulerCore` state — computing it never changes a
    scheduling decision). `kv_demand` is the join-shortest-queue key:
    device blocks already held by in-flight requests plus the minimum
    blocks every waiting request still needs, i.e. the outstanding
    KV-block demand this replica's device pool has committed to."""

    n_waiting: int           # requests queued, not yet prefilling
    n_inflight: int          # prefilling + decoding
    queued_blocks: Blocks    # min device blocks the waiting queue
    #                          still needs, plus the device blocks
    #                          paused (preempted) requests need to
    #                          resume
    active_blocks: Blocks    # device blocks held by live allocations
    free_blocks: Blocks      # allocatable now (incl. reclaimable
    #                          cache)
    total_blocks: Blocks     # device pool size
    n_paused: int = 0        # preempted requests parked on HOST
    queued_tokens: Tokens = 0   # prefill tokens still owed by the
    #                             waiting queue (uncached suffixes)
    #                             and paused requests
    active_tokens: Tokens = 0   # context tokens (prompt + generated)
    #                             held by in-flight requests

    @property
    def kv_demand(self) -> Blocks:
        return self.queued_blocks + self.active_blocks

    @property
    def token_demand(self) -> Tokens:
        """Outstanding token demand: the `route_by_tokens` routing
        key. Token demand weighs a replica by the COMPUTE it still
        owes (queued prefill suffixes + live context), where
        `kv_demand` weighs it by pool pressure — under heavy prefix
        sharing the two rankings genuinely differ."""
        return self.queued_tokens + self.active_tokens

    @property
    def occupancy(self) -> float:
        return 1.0 - self.free_blocks / self.total_blocks \
            if self.total_blocks else 0.0


class AdmissionImpossible(RuntimeError):
    """The head waiting request can never be admitted: nothing is in
    flight to free blocks and the pools cannot fit it. Raised instead of
    the old opaque "wedged with waiting requests" — a temporarily
    unadmittable request simply waits (backpressure), only a permanently
    unservable one raises."""


# Typed rejection reasons: with `shed_overload` on, the scheduler sheds a
# doomed request (Phase.SHED, `r.shed_reason` = the subclass NAME) instead
# of raising/wedging; the classes double as raisable errors for callers
# that want hard failure. Per-class shed counts surface in
# `SimMetrics.class_report()`.
class PoolInfeasible(AdmissionImpossible):
    """The request's minimum device need exceeds the pool outright — no
    amount of waiting can ever admit it."""


class HostPoolExhausted(AdmissionImpossible):
    """The HOST (offload) pool cannot take the request's layers — under
    a host_exhaust fault or genuine host-memory pressure."""


class DeadlineUnmeetable(AdmissionImpossible):
    """The request aged past its effective deadline plus grace while
    blocked; serving it now could only burn pool on a lost cause."""


class DispatchFailed(AdmissionImpossible):
    """Cluster-level: every dispatch attempt failed (transient dispatch
    faults or no live replica) and the bounded retry budget ran out."""


# --------------------------------------------------------------------------
# Admission ordering policies
# --------------------------------------------------------------------------

class AdmissionPolicy:
    """Orders the waiting queue before each admission pass. Admission
    itself stays head-of-line within the returned order (the first
    request that does not fit blocks the rest), so a policy controls
    priority, never fairness-by-accident."""

    name = "?"

    def order(self, waiting: List[Request], now: float,
              core: "SchedulerCore") -> List[Request]:
        raise NotImplementedError


class FCFSAdmission(AdmissionPolicy):
    """Paper semantics: first come, first served — no reordering, hence
    no starvation (§1)."""

    name = "fcfs"

    def order(self, waiting: List[Request], now: float,
              core: "SchedulerCore") -> List[Request]:
        return list(waiting)


class PrefixAwareAdmission(AdmissionPolicy):
    """Cache-hitting requests admit ahead of cold misses under
    congestion. Two mechanisms compound:

      * shortest-job-first on the Eq.3 prefill cost — a hit's prefill
        prices only the uncached suffix, so serving hits first shrinks
        the mean queueing everyone sees behind exclusive prefills and
        the Alg.1 slack each admission consumes;
      * head-of-line unblocking — a hit's device-block need is only its
        suffix (the shared prefix is already resident), so a small hit
        admits into a block gap that would stall a large miss at the
        head, raising pool utilization and the effective hit rate (the
        prefix is reused while it is still hot, before LRU churn).

    Anti-starvation (aging bound): ordering is FCFS on a *virtual*
    arrival in which a hit gets a head start of `age_frac` of its own
    TTFT SLO. A miss can therefore only be overtaken by hits that
    arrived within that bounded window after it — never by the whole
    future hit stream — so the miss delay added over strict FCFS is
    bounded (~ arrival_rate x window overtakes) and no request starves,
    no matter how deep the queue grows. Under light load the order
    degenerates to plain FCFS."""

    name = "prefix_aware"

    def __init__(self, age_frac: float = 0.5) -> None:
        self.age_frac = age_frac

    def order(self, waiting: List[Request], now: float,
              core: "SchedulerCore") -> List[Request]:
        keyed: List[Tuple[float, int, Request]] = []
        for i, r in enumerate(waiting):
            head_start = self.age_frac * r.ttft_slo \
                if core.cached_hint(r) > 0 else 0.0
            keyed.append((r.arrival - head_start, i, r))
        keyed.sort()
        return [r for _, _, r in keyed]


class DeadlineAdmission(AdmissionPolicy):
    """Earliest-virtual-deadline-first across priority classes (the
    SLO-attainment ordering of "Mitigating KV Cache Competition",
    arXiv 2503.13773). Each request is keyed by

        vdl = deadline_for_ordering - priority * age_frac * ttft_slo

    so a higher class's deadline is treated as `age_frac` of its own
    TTFT SLO earlier per priority level. The deadline used for ordering
    is the request's effective first-token deadline, except for PAUSED
    requests that already emitted tokens — their first-token deadline is
    history, so their *next-token* due time (last token + TPOT SLO)
    keys the resume instead.

    Anti-starvation (bounded aging): a batch request (priority 0) is
    only ever overtaken by higher-class requests whose boosted virtual
    deadline still precedes its own — i.e. requests arriving within a
    bounded window after it. Past that window every new arrival orders
    BEHIND the batch request, whose real deadline keeps aging, so under
    any finite load it reaches the head and (admission being
    head-of-line for waiting requests) admits as soon as in-flight work
    frees blocks — no request starves forever."""

    name = "deadline"

    def __init__(self, age_frac: float = 0.5) -> None:
        self.age_frac = age_frac

    def order(self, waiting: List[Request], now: float,
              core: "SchedulerCore") -> List[Request]:
        keyed: List[Tuple[float, float, int, Request]] = []
        for i, r in enumerate(waiting):
            if r.phase is Phase.PAUSED and r.last_token_time >= 0.0:
                dl = r.last_token_time + r.tpot_slo
            else:
                dl = r.effective_deadline
            vdl = dl - r.priority * self.age_frac * r.ttft_slo
            keyed.append((vdl, r.arrival, i, r))
        keyed.sort(key=lambda k: k[:3])
        return [r for _, _, _, r in keyed]


ADMISSION_POLICIES = {
    FCFSAdmission.name: FCFSAdmission,
    PrefixAwareAdmission.name: PrefixAwareAdmission,
    DeadlineAdmission.name: DeadlineAdmission,
}


def make_admission_policy(sc: ServeConfig) -> AdmissionPolicy:
    if sc.admission == PrefixAwareAdmission.name:
        return PrefixAwareAdmission(sc.admission_age_frac)
    if sc.admission == DeadlineAdmission.name:
        return DeadlineAdmission(sc.admission_age_frac)
    return ADMISSION_POLICIES[sc.admission]()


# --------------------------------------------------------------------------
# The shared core
# --------------------------------------------------------------------------

# backend hook: (src_pool, src_block, dst_pool, dst_block) -> None, moves
# the REAL bytes (engine) — the core itself only charges the ledger
PhysicalCopy = Callable[[str, int, str, int], None]


class SchedulerCore:
    """Queues + decisions shared by the engine and the simulator.

    Owns the request lifecycle state (waiting/prefilling/decoding/done/
    cancelled), per-request residency bookkeeping (`host_layers`, Eq.4
    plan memo), admission (policy ordering, Alg.1 budget, the device-need
    gate, the layer-split allocation), chunk assembly, the ledger routing
    of cache-driven copies, and cancellation. The clock is the backend's:
    backends assign `core.now` as their step progresses so ledger stamps
    land at the right virtual time."""

    def __init__(self, sc: ServeConfig, cost: CostModel,
                 bm: LayerwiseBlockManager, off: OffloadEngine,
                 slo: SLOScheduler, n_layers: int,
                 physical_copy: Optional[PhysicalCopy] = None,
                 reserve_blocks: Blocks = 0) -> None:
        self.sc = sc
        self.cost = cost
        self.bm = bm
        self.off = off
        self.slo = slo
        self.L = n_layers
        self.policy = make_admission_policy(sc)
        self.physical_copy = physical_copy
        # layerkv allocation headroom (sim: Eq.5 forecast reserve)
        self.reserve_blocks: Blocks = reserve_blocks
        self.now: Seconds = 0.0
        # ---- request lifecycle --------------------------------------------
        self.waiting: deque[Request] = deque()
        self.prefilling: List[Request] = []   # chunked: in-flight chunks
        self.decoding: List[Request] = []
        self.paused: List[Request] = []       # preempted, KV parked on HOST
        self.done: List[Request] = []
        self.cancelled: List[Request] = []
        self.shed: List[Request] = []         # rejected under overload
        #                                       (graceful degradation)
        # unified counter/gauge registry (repro.obs): preemption/resume/
        # shed/cancel counts live here (back-compat properties below);
        # the owning backend and cluster fold in their own counters so
        # one snapshot() returns everything
        self.registry = MetricsRegistry()
        # host-pool blocks made unusable by an active host_exhaust fault
        # (serving/faults.py). 0 unless a FaultPlan is installed on the
        # owning cluster, and every read is inert at 0 — fault-free runs
        # are bit-identical.
        self.fault_host_reserve = 0
        # ---- per-request bookkeeping --------------------------------------
        self.host_layers: Dict[str, int] = {}  # layers resident on host
        self.plans: Dict[str, object] = {}     # rid -> Eq.4 OffloadPlan
        self.reload_bytes_migrated = 0
        if sc.prefix_cache:
            # cache-driven copies (COW, promote, demote) charge the
            # transfer ledger here; the engine also moves the real bytes
            bm.on_copy = self.cache_copy
        # opt-in KV-accounting sanitizer: installed AFTER on_copy so its
        # event wrappers see the fully-wired manager; backends call
        # sanitizer.check(core) after every step
        self.sanitizer: Optional["KVSanitizer"] = None
        if sc.sanitize or os.environ.get("REPRO_SANITIZE"):
            from repro.core.sanitizer import KVSanitizer
            self.sanitizer = KVSanitizer(bm, off, cost)
        # opt-in tracer, installed exactly like the sanitizer: the
        # module is imported ONLY here, so trace=False runs never load
        # it and every hot-path emission is one `is not None` test
        self.tracer: Optional["Tracer"] = None
        if sc.trace:
            from repro.obs.trace import Tracer
            self.tracer = Tracer()

    # ---------------------------------------------- counter back-compat
    @property
    def n_preempted(self) -> int:
        """Lossless preemption events (registry-backed)."""
        return int(self.registry.get("preemptions", kind="pause"))

    @property
    def n_resumed(self) -> int:
        return int(self.registry.get("resumes"))

    # ------------------------------------------------------------- queries
    def in_flight(self) -> int:
        return len(self.prefilling) + len(self.decoding)

    def idle(self) -> bool:
        return not (self.prefilling or self.decoding or self.paused)

    def _blocks(self, tokens: Tokens) -> Blocks:
        return self.bm.blocks_for_tokens(tokens)

    def host_free(self) -> Blocks:
        """Usable HOST-pool blocks: the manager's free count minus any
        fault-injected reserve. Every HOST-side gate (admission offload
        layers, preemption demotion, sim eviction) reads this instead of
        `bm.num_free(HOST)` so host_exhaust faults degrade those paths
        without ever touching real pool accounting."""
        return self.bm.num_free(HOST) - self.fault_host_reserve

    def cached_hint(self, r: Request) -> Tokens:
        """Cached-prefix length for Eq.3 admission estimates (price the
        uncached suffix only, or admission over-throttles)."""
        if self.sc.prefix_cache and r.prompt:
            return self.bm.match_prefix(r.prompt)
        return 0

    def device_need(self, r: Request, memoize: bool = True) -> Blocks:
        """MINIMUM device blocks to start r's prefill. With the prefix
        cache on, a hit needs only the uncached suffix (+ COW tail) but
        all layers device-resident — which for short prefixes can EXCEED
        the layer-wise plan; the gate takes the min of the two estimates
        (a larger hit estimate must never wedge a request the plain path
        fits). `memoize=False` keeps the Eq.4 plan out of the per-request
        memo — for probes about requests this core may never own (the
        cluster feasibility backstop), whose memo entry `release()` would
        otherwise never drop."""
        if self.sc.policy == "vllm":
            need = self._blocks(r.prompt_len) * self.L
        else:
            plan = self.plans.get(r.rid)
            if plan is None:
                plan = self.off.plan_for_prompt(r.prompt_len)
                if memoize:
                    self.plans[r.rid] = plan
            send_buf = 1 if plan.offload_layers else 0
            need = self._blocks(r.prompt_len) * (plan.x + send_buf)
        if self.sc.prefix_cache and r.prompt:
            c = self.bm.match_prefix(r.prompt)
            if c > 0:
                hit_need = (self._blocks(r.prompt_len)
                            - c // self.sc.block_size) * self.L
                need = min(need, hit_need)
        return need

    # --------------------------------------------------- load introspection
    def occupancy(self) -> float:
        """Fraction of the device pool held by live allocations (cheap —
        suitable for per-step sampling)."""
        total = self.bm.pools[DEVICE].num_blocks
        return 1.0 - self.bm.num_free(DEVICE) / total if total else 0.0

    def load_stats(self) -> LoadStats:
        """Snapshot this replica's outstanding KV-block demand for a
        cluster router. Pure read: `device_need` only fills the same
        Eq.4 plan memo admission would, so probing never perturbs the
        schedule (the cluster-of-1 identity tests pin this)."""
        total = self.bm.pools[DEVICE].num_blocks
        free = self.bm.num_free(DEVICE)
        queued = sum(self.device_need(r) for r in self.waiting) \
            + sum(self.resume_need(r) for r in self.paused)
        # token-level demand (the route_by_tokens routing key):
        # prefill tokens still owed — a hit's cached prefix costs
        # nothing, exactly as admission prices it — plus the live
        # context every in-flight request already holds
        queued_toks = sum(r.prompt_len - self.cached_hint(r)
                          for r in self.waiting) \
            + sum(r.prefill_remaining for r in self.paused)
        active_toks = sum(r.prompt_len + r.tokens_out
                          for r in self.prefilling + self.decoding)
        return LoadStats(n_waiting=len(self.waiting),
                         n_inflight=self.in_flight(),
                         queued_blocks=queued,
                         active_blocks=total - free,
                         free_blocks=free, total_blocks=total,
                         n_paused=len(self.paused),
                         queued_tokens=queued_toks,
                         active_tokens=active_toks)

    def admit_eta(self, r: Request, now: Seconds) -> Seconds:
        """Estimated delay before this replica's Alg.1 slack admits `r`
        behind its current waiting queue: the Eq.3 prefill work already
        queued ahead of it, plus however much of r's own prefill does not
        fit in the decode batch's remaining Eq.1 slack. Prefix-cache hits
        price only their uncached suffix, exactly as admission does. With
        slo_aware off (or the vllm policy) the queue term alone orders
        replicas.

        Preemption-adjusted: under the `deadline` admission ordering,
        waiting work of a strictly LOWER priority class never sits ahead
        of `r` (it orders behind, and with preemption on its running
        siblings can even be paused for r) — so only same-or-higher
        class queued work counts toward r's ETA. This is what `slo_aware`
        routing sees: an overloaded-with-batch replica still advertises
        a near-zero ETA to an interactive request."""
        t = max(now, self.now)

        def _cost(q: Request) -> Seconds:
            c = self.cached_hint(q)
            return self.cost.chunk_prefill_time(q.prompt_len - c, c)

        ahead = [q for q in self.waiting if q.priority >= r.priority] \
            if self.sc.admission == "deadline" else self.waiting
        queued = sum(_cost(q) for q in ahead)
        if not (self.sc.policy == "layerkv" and self.sc.slo_aware):
            return queued
        budget = self.slo.allow_prefill_budget(self.decoding, t)
        if budget == float("inf"):
            return queued
        return queued + max(_cost(r) - max(budget - queued, 0.0), 0.0)

    # --------------------------------------------------------- cache copies
    def cache_copy(self, src_pool: str, src: int, dst_pool: str,
                   dst: int) -> None:
        """Route one cache-driven block copy: the backend's hook moves
        the real bytes (engine), the ledger charges the offload link for
        cross-tier moves (d2d COW copies never touch the link)."""
        if self.physical_copy is not None:
            self.physical_copy(src_pool, src, dst_pool, dst)
        nbytes = self.cost.kv_bytes(self.sc.block_size, 1)
        if src_pool == HOST and dst_pool == DEVICE:
            self.off.ledger.submit(self.now, nbytes, "reload")
            self.reload_bytes_migrated += nbytes
        elif src_pool == DEVICE and dst_pool == HOST:
            self.off.ledger.submit(self.now, nbytes, "offload")

    # ----------------------------------------------------------- allocation
    def alloc_prefill(self, r: Request) -> Optional[Tuple[list, list]]:
        """Allocate r's prompt KV per the policy; returns (retain, off)
        layer lists or None when the pools cannot fit it. Sets
        `host_layers[r.rid]` and, on a prefix hit, r.prefill_done /
        r.cached_prompt_len (all layers device-resident; prefill compute
        then starts at the cached length). A hit that cannot fit falls
        through to the plain policy path. Never touches the transfer
        ledger — callers account d2h traffic at the granularity their
        step semantics require (whole-prompt vs per-chunk)."""
        if self.sc.prefix_cache and r.prompt:
            acq = self.bm.acquire_prefix(r.rid, r.prompt)
            if acq is not None:
                try:
                    suffix = r.prompt_len - acq.cached_len
                    for l in range(self.L):
                        self.bm.extend_layer(r.rid, l, suffix)
                except PoolExhausted:
                    self.bm.free_request(r.rid)
                    r.prefill_done = 0
                else:
                    r.prefill_done = acq.cached_len
                    r.cached_prompt_len = acq.cached_len
                    self.host_layers[r.rid] = 0
                    self.bm.cache.count(r.prompt_len, acq.cached_len)
                    return list(range(self.L)), []
        per_layer = self._blocks(r.prompt_len)
        try:
            if self.sc.policy == "vllm":
                retain, off = list(range(self.L)), []
            else:
                plan = self.plans.get(r.rid)
                if plan is None:
                    plan = self.off.plan_for_prompt(r.prompt_len)
                    self.plans[r.rid] = plan
                # retain as many layers as currently fit (free
                # prefetching, §3.1.1), never fewer than Eq.4's x
                fit = max((self.bm.num_free(DEVICE) - self.reserve_blocks)
                          // max(per_layer, 1) - 1, 0)
                retain_n = min(self.L, max(plan.x, fit))
                off = interleave_offload_layers(self.L, retain_n)
                retain = [l for l in range(self.L) if l not in set(off)]
                # host-side gate for the offload layers: inert unless a
                # host_exhaust fault holds a reserve (without one, the
                # HOST allocation below raises PoolExhausted on exactly
                # the same shortfall)
                if off and self.fault_host_reserve > 0 \
                        and self.host_free() < per_layer * len(off):
                    return None
            for l in retain:
                self.bm.alloc_layer(r.rid, l, r.prompt_len, DEVICE)
            for l in off:
                self.bm.alloc_layer(r.rid, l, r.prompt_len, HOST)
        except PoolExhausted:
            self.bm.free_request(r.rid)
            return None
        self.host_layers[r.rid] = len(off)
        if self.sc.prefix_cache and r.prompt:
            self.bm.cache.count(r.prompt_len, 0)  # admitted as a miss
        return retain, off

    # ----------------------------------------------------------- preemption
    def _migrate_layer(self, rid: str, layer: int, to_pool: str,
                       kind: str, now: float) -> None:
        """Move one layer's KV across tiers for pause/resume: the block
        manager remaps (detach: blocks shared through the prefix cache
        are copied out, never pulled from under another sharer), the
        backend hook moves the real bytes, and the transfer ledger is
        charged once per layer."""
        a = self.bm.allocation(rid, layer)
        nbytes = self.cost.kv_bytes(a.num_tokens, 1)
        from_pool = a.pool
        src, dst = self.bm.move_layer(rid, layer, to_pool, detach=True)
        if self.physical_copy is not None:
            for s, d in zip(src, dst, strict=True):
                self.physical_copy(from_pool, s, to_pool, d)
        self.off.ledger.submit(now, nbytes, kind)
        if kind == "reload":
            self.reload_bytes_migrated += nbytes

    def reclaimable_blocks(self, r: Request) -> Blocks:
        """Device blocks that preempting `r` would actually free: blocks
        shared through the prefix cache are detached (copied out, the
        device original stays with its other sharers) and free nothing."""
        n = 0
        for l in self.bm.layers_on(r.rid, DEVICE):
            for b in self.bm.allocation(r.rid, l).blocks:
                e = self.bm.cache.lookup(DEVICE, b) if self.bm.cache \
                    else None
                if e is None or e.ref <= 1:
                    n += 1
        return n

    def total_host_blocks(self, r: Request) -> Blocks:
        """Blocks a request currently holds on the HOST tier."""
        return sum(len(self.bm.allocation(r.rid, l).blocks)
                   for l in self.bm.layers_on(r.rid, HOST))

    def resume_need(self, r: Request) -> Blocks:
        """MINIMUM device blocks to resume a paused request. Under the
        request-wise `vllm` policy that is its whole KV (decode needs
        every layer device-resident); under `layerkv` it is one layer's
        footprint — the rest stays host-resident and streams/promotes
        through the same §3.1.1 machinery every offloaded request uses."""
        if self.sc.policy == "vllm":
            return self.total_host_blocks(r)
        return self._blocks(r.prompt_len + r.tokens_out)

    def preempt_request(self, r: Request, now: Seconds) -> bool:
        """Pause one running request losslessly: demote its
        device-resident KV layer-wise to HOST through the PR 2 demotion
        path and park it in `paused`. Nothing is recomputed on resume —
        prefill progress, chunk state, and generated tokens all survive
        (the engine's cached chunk buffers stay valid; chunk assembly
        re-seats a resumed prefill by its original `prefill_start`).
        Returns False when `r` is not running or the HOST pool cannot
        hold its KV (the victim is then simply left running)."""
        # repro-lint: disable=PHASE001 -- pause targets RUNNING work only:
        # a QUEUED request holds no KV to demote and a PAUSED one is
        # already parked, so only prefilling/decoding membership is tested
        if r in self.prefilling:
            src_q = self.prefilling
        elif r in self.decoding:
            src_q = self.decoding
        else:
            return False
        dev = self.bm.layers_on(r.rid, DEVICE)
        host_need = sum(len(self.bm.allocation(r.rid, l).blocks)
                        for l in dev)
        if self.host_free() < host_need:
            return False
        for l in dev:
            self._migrate_layer(r.rid, l, HOST, "offload", now)
        self.host_layers[r.rid] = len(self.bm.layers_on(r.rid, HOST))
        src_q.remove(r)
        r.phase = Phase.PAUSED
        r.n_preempted += 1
        self.paused.append(r)
        self.registry.inc("preemptions", kind="pause")
        if self.tracer is not None:
            self.tracer.preempt(r, now, mode="pause")
        return True

    def _try_resume(self, r: Request, now: Seconds) -> bool:
        """Re-enter a paused request where it left off (decoding once its
        prefill completed, else the chunk queue) — no recompute ever.
        Promotion is greedy: as many host layers move back to DEVICE as
        fit (allocation headroom respected); whatever stays host-resident
        re-enters through the SAME layer-wise machinery every offloaded
        request already uses (the sim streams/promotes it per §3.1.1, the
        engine's decode selection promotes on demand). Under the
        request-wise `vllm` policy everything must promote. False when
        even `resume_need` does not fit yet — the request stays paused,
        and unlike a blocked fresh admission it does NOT stall the pass
        (its KV is safe on host and its aging continues)."""
        if self.bm.num_free(DEVICE) < self.resume_need(r):
            return False
        for l in self.bm.layers_on(r.rid, HOST):
            a = self.bm.allocation(r.rid, l)
            if self.bm.num_free(DEVICE) - self.reserve_blocks \
                    < len(a.blocks):
                if self.sc.policy == "vllm":
                    return False   # unreachable past the gate, but safe
                break
            self._migrate_layer(r.rid, l, DEVICE, "reload", now)
        self.host_layers[r.rid] = len(self.bm.layers_on(r.rid, HOST))
        self.paused.remove(r)
        if r.prefill_complete:
            r.phase = Phase.DECODE
            self.decoding.append(r)
        else:
            r.phase = Phase.PREFILL
            self.prefilling.append(r)
        self.registry.inc("resumes")
        if self.tracer is not None:
            self.tracer.resume(r, now)
        return True

    def _preempt_to_fit(self, r: Request, now: Seconds) -> bool:
        """Victim selection (arXiv 2503.13773-shaped): when `r` fails the
        device-block gate, free its shortfall by pausing strictly
        lower-priority running requests. Victims are taken lowest
        priority class first, then largest reclaimable KV, then latest
        deadline; SLO pricing (SLOScheduler.victim_affordable) charges
        each victim the h2d promotion it must later pay against its own
        deadline slack — unaffordable victims are touched only when `r`
        is itself already past its effective deadline. All-or-nothing:
        if the chosen set cannot cover the shortfall, nobody is paused
        (a pointless preemption costs two PCIe crossings and buys no
        admission)."""
        shortfall = self.device_need(r) - self.bm.num_free(DEVICE)
        if shortfall <= 0:
            return True
        cands = [v for v in self.prefilling + self.decoding
                 if v.priority < r.priority]
        if not cands:
            return False
        reclaim = {v.rid: self.reclaimable_blocks(v) for v in cands}
        bw = self.cost.hw.offload_bw
        afford = {
            v.rid: self.slo.victim_affordable(
                v, now, self.cost.kv_bytes(
                    v.prompt_len + v.tokens_out, self.L), bw)
            for v in cands}
        critical = now > r.effective_deadline
        pool = [v for v in cands if afford[v.rid]]
        if critical:
            pool += [v for v in cands if not afford[v.rid]]
        pool.sort(key=lambda v: (v.priority, -reclaim[v.rid],
                                 -v.effective_deadline))
        chosen: List[Request] = []
        freed = 0
        for v in pool:
            if freed >= shortfall:
                break
            chosen.append(v)
            freed += reclaim[v.rid]
        if freed < shortfall:
            return False
        for v in chosen:
            self.preempt_request(v, now)
        return self.bm.num_free(DEVICE) >= self.device_need(r)

    # ------------------------------------------------------------ admission
    def admission_budget(self, order: List[Request],
                         now: Seconds) -> int:
        """Alg.1: how many of the ordered waiting prefills fit in the
        decode batch's minimum TPOT slack."""
        if self.sc.policy == "layerkv" and self.sc.slo_aware:
            return self.slo.max_prefills(order, self.decoding, now,
                                         cached_len=self.cached_hint)
        return len(order)

    def admit_waiting(self, now: Seconds,
                      immediate: Optional[Callable[[Request], bool]] = None,
                      token_budget: Optional[Tokens] = None
                      ) -> List[Request]:
        """One admission pass over the policy-ordered waiting queue.
        Head-of-line within the order: the first request that fails a
        gate stops the pass. Three caller modes:

          chunked (sc.chunked)   allocate KV and queue the request into
                                 `prefilling` for chunk-by-chunk prefill;
          immediate=<fn>         exclusive engine: run each admitted
                                 prefill NOW (fn appends to `decoding`);
          neither                exclusive sim: allocate only; the caller
                                 runs the returned batch exclusively
                                 (`token_budget` caps its prompt tokens).

        With preemption on, PAUSED requests join the same policy order
        (under `deadline` ordering a resume competes by its next-token
        due time) and re-enter by promoting their parked KV — they never
        consume the Alg.1 prefill budget (nothing is prefilled) and a
        blocked resume is skipped rather than stalling the pass (its KV
        is safe on host; only fresh admissions are head-of-line). When a
        fresh request fails the device-block gate, the preemption
        controller may pause lower-priority running requests to fit it
        (`_preempt_to_fit`) before the gate gives up.

        Returns the (fresh) requests admitted this pass."""
        pool = list(self.waiting) + list(self.paused)
        if not pool:
            return []
        order = self.policy.order(pool, now, self)
        waiting_set = set(map(id, self.waiting))
        budget_n = self.admission_budget(
            [r for r in order if id(r) in waiting_set], now)
        admitted: List[Request] = []
        deferred = immediate is None and not self.sc.chunked
        # TTFT attribution: which gate stopped this pass (head-of-line:
        # every request still waiting afterwards waited on it)
        stop_gate: Optional[str] = None
        for r in order:
            in_flight = self.in_flight() + (len(admitted) if deferred
                                            else 0)
            if in_flight >= self.sc.max_batch_size:
                stop_gate = "gate:max_batch_size"
                break
            if id(r) not in waiting_set:
                self._try_resume(r, now)
                continue
            if budget_n <= 0:
                stop_gate = "gate:alg1_budget"
                break
            if token_budget is not None and admitted \
                    and r.prompt_len > token_budget:
                stop_gate = "gate:token_budget"
                break
            if self.bm.num_free(DEVICE) < self.device_need(r):
                if not (self.sc.preemption
                        and self._preempt_to_fit(r, now)):
                    if self._maybe_shed(r, now):
                        continue
                    stop_gate = "gate:device_blocks"
                    break
            if self.sc.chunked:
                if self.alloc_prefill(r) is None:
                    if self._maybe_shed(r, now):
                        continue
                    stop_gate = "gate:host_reserve"
                    break
                self.waiting.remove(r)
                r.phase = Phase.PREFILL
                r.prefill_start = now
                self.prefilling.append(r)
            elif immediate is not None:
                self.waiting.remove(r)
                # read the clock FRESH: an earlier immediate() in this
                # pass ran a whole prefill and advanced it — stamping the
                # pass-start `now` would under-report queueing and tie
                # every prefill_start in the pass (breaking newest-first
                # eviction ordering)
                r.prefill_start = self.now
                if not immediate(r):
                    self.waiting.appendleft(r)
                    if self._maybe_shed(r, now):
                        continue
                    stop_gate = "gate:host_reserve"
                    break
            else:
                if self.alloc_prefill(r) is None:
                    if self._maybe_shed(r, now):
                        continue
                    stop_gate = "gate:host_reserve"
                    break
                self.waiting.remove(r)
            admitted.append(r)
            budget_n -= 1
            if token_budget is not None:
                token_budget -= r.prompt_len
        tracer = self.tracer
        if tracer is not None:
            tracer.sched_pass(self, now, admitted, stop_gate,
                              immediate_mode=immediate is not None)
        return admitted

    # ------------------------------------------------------- chunk assembly
    def chunk_token_cap(self, now: Seconds) -> Tokens:
        """Per-iteration prefill token budget: Eq.1 slack converted to
        tokens when slo_aware, else the static cap."""
        if self.sc.policy == "layerkv" and self.sc.slo_aware:
            return self.slo.max_chunk_tokens(
                self.decoding, now, self.sc.max_prefill_tokens,
                floor=self.sc.chunk_floor)
        return self.sc.max_prefill_tokens

    def assemble_chunks(self, now: Seconds, decode_tokens: Tokens
                        ) -> List[Tuple[Request, int]]:
        """FCFS chunk assembly under the token budget; this iteration's
        decode tokens count against it. A floor guarantees prefill
        progress when no decode batch runs."""
        budget = self.chunk_token_cap(now) - decode_tokens
        if self.prefilling and decode_tokens == 0:
            budget = max(budget, self.sc.chunk_floor)
        work: List[Tuple[Request, int]] = []
        for r in sorted(self.prefilling, key=lambda q: q.prefill_start):
            if budget <= 0:
                break
            c = min(budget, r.prefill_remaining)
            work.append((r, c))
            budget -= c
        return work

    # ------------------------------------------------------------- release
    def release(self, r: Request) -> None:
        """Drop the per-request bookkeeping (retire and cancel paths)."""
        self.host_layers.pop(r.rid, None)
        self.plans.pop(r.rid, None)

    def cancel(self, r: Request, now: Seconds) -> bool:
        """Unwind everything `r` has in flight, whatever its phase:

          * waiting      — just leaves the queue;
          * prefilling   — mid-chunk KV (device AND host-resident
                           offloaded layers) is freed; blocks it shares
                           through the prefix cache are decref'd, never
                           pulled from under another sharer, and FULL
                           blocks it already registered stay behind as
                           reclaimable cache (a cancelled request's
                           computed prefix remains hittable);
          * decoding     — same, plus it leaves the decode batch;
          * paused       — same unwind over its host-parked KV (a
                           preempted request never resumes after cancel).

        Transfers already submitted to the link ledger are sunk cost: the
        bytes were queued on the link, the ledger is occupancy accounting
        and stays monotone. Returns False when `r` is not live (already
        finished or cancelled) — cancellation is idempotent."""
        self.now = now
        was_live = False
        if r in self.waiting:
            self.waiting.remove(r)
            was_live = True
        if r in self.prefilling:
            self.prefilling.remove(r)
            was_live = True
        if r in self.decoding:
            self.decoding.remove(r)
            was_live = True
        if r in self.paused:
            self.paused.remove(r)
            was_live = True
        if not was_live:
            return False
        if r.rid in self.bm.tables:
            self.bm.free_request(r.rid)
        self.release(r)
        r.phase = Phase.CANCELLED
        r.finish_time = now
        self.cancelled.append(r)
        self.registry.inc("cancelled_total")
        if self.tracer is not None:
            self.tracer.cancel(r, now)
        return True

    # ---------------------------------------------- graceful degradation
    def _shed_class(self, r: Request) -> type:
        """Typed rejection reason for a blocked request, most-specific
        first (permanent infeasibility beats fault pressure beats aging
        out)."""
        if self.device_need(r, memoize=False) \
                > self.bm.pools[DEVICE].num_blocks:
            return PoolInfeasible
        if self.fault_host_reserve > 0:
            return HostPoolExhausted
        return DeadlineUnmeetable

    def shed_request(self, r: Request, reason: str,
                     now: Seconds) -> None:
        """Reject a WAITING request with a typed reason: it leaves the
        queue terminally (Phase.SHED), keeps nothing allocated, and is
        reported per deadline class by `SimMetrics.class_report()`."""
        if r in self.waiting:
            self.waiting.remove(r)
        self.release(r)
        r.phase = Phase.SHED
        r.shed_reason = reason
        r.prefill_start = -1.0
        r.finish_time = now
        self.shed.append(r)
        self.registry.inc("shed_total", reason=reason)
        if self.tracer is not None:
            self.tracer.shed(r, now, reason)

    def _maybe_shed(self, r: Request, now: Seconds) -> bool:
        """Shed-by-deadline-class at the admission gate: with
        `shed_overload` on, a fresh request that failed a gate AND has
        aged `shed_grace_frac` of its own TTFT SLO past its effective
        deadline is rejected (typed reason) instead of blocking the
        head of the line. Off by default — returning False preserves
        the head-of-line `break` bit-identically."""
        if not self.sc.shed_overload:
            return False
        if now <= r.effective_deadline \
                + self.sc.shed_grace_frac * r.ttft_slo:
            return False
        self.shed_request(r, self._shed_class(r).__name__, now)
        return True

    def shed_blocked(self, now: Seconds) -> bool:
        """Last-resort degradation for a WEDGED scheduler: nothing is in
        flight, nothing can be admitted, and the queue would otherwise
        raise `wedged_error`. With `shed_overload` on, shed the blocking
        head of the policy order (typed reason) so the queue behind it
        drains; returns True when something was shed (progress)."""
        if not self.sc.shed_overload or not self.waiting:
            return False
        order = self.policy.order(list(self.waiting), now, self)
        r = next((q for q in order if q in self.waiting), None)
        if r is None:
            return False
        self.shed_request(r, self._shed_class(r).__name__, now)
        return True

    def wedged_error(self) -> AdmissionImpossible:
        """Names the request that actually blocked the admission pass:
        the head of the POLICY order (admission is head-of-line within
        it), which under prefix_aware need not be waiting[0]."""
        pool = list(self.waiting) or list(self.paused)
        order = self.policy.order(pool, self.now, self)
        r = order[0] if order else pool[0]
        if r in self.paused:
            return AdmissionImpossible(
                f"paused request {r.rid} can never resume: needs "
                f"{self.resume_need(r)} device blocks, the pool has "
                f"{self.bm.pools[DEVICE].num_blocks} and nothing is in "
                f"flight to free any")
        return AdmissionImpossible(
            f"request {r.rid} (prompt {r.prompt_len}) can never be "
            f"admitted: needs {self.device_need(r)} device blocks, the "
            f"pool has {self.bm.pools[DEVICE].num_blocks} and nothing is "
            f"in flight to free any")


class CoreDelegateMixin:
    """Queue/clock delegation shared by every backend that drives a
    `SchedulerCore` — the engine and the simulator inherit this instead
    of each hand-mirroring the core's lifecycle state (which is exactly
    the duplication the core exists to prevent). Subclasses set
    `self.core` in __init__ and keep their own named clock property
    (`engine.now`, `sim.t`) on top of `clock()`/`advance_to()`."""

    core: SchedulerCore

    @property
    def waiting(self) -> Deque[Request]:
        return self.core.waiting

    @property
    def prefilling(self) -> List[Request]:
        return self.core.prefilling

    @property
    def decoding(self) -> List[Request]:
        return self.core.decoding

    @property
    def paused(self) -> List[Request]:
        return self.core.paused

    @property
    def done(self) -> List[Request]:
        return self.core.done

    @property
    def cancelled(self) -> List[Request]:
        return self.core.cancelled

    @property
    def shed(self) -> List[Request]:
        return self.core.shed

    @property
    def host_layers(self) -> Dict[str, int]:
        return self.core.host_layers

    def clock(self) -> float:
        return self.core.now

    def advance_to(self, t: float) -> None:
        self.core.now = max(self.core.now, t)
