"""Shared scheduler core: the admission/queueing/residency logic that the
real engine (`engine.py`) and the discrete-event simulator (`sim.py`) both
drive.

Before this module existed, the two serving frontends each carried private
copies of the same decisions — cached-prefix probing, the device-block
admission gate, the Eq.4 layer-split allocation, the Alg.1 admission loop,
chunk assembly under the per-iteration token budget, and the ledger
routing of cache-driven block copies — which is exactly how they drift.
Everything decision-shaped now lives here, once; the backends keep only
what genuinely differs (the engine moves real bytes through the
`PagedExecutor`, the simulator prices steps with the cost model).

Three public pieces:

  ServeConfig      ONE config for both backends (EngineConfig/SimConfig
                   are thin deprecation shims over it);
  AdmissionPolicy  pluggable ordering of the waiting queue — `fcfs`
                   (paper semantics) and `prefix_aware` (cache-hitting
                   requests admit first under congestion, with an aging
                   bound so misses never starve);
  SchedulerCore    the shared state machine: waiting/prefilling/decoding
                   queues, admission, allocation, chunk assembly, and the
                   cancellation path that unwinds everything a request
                   can leave in flight.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Dict, List, Optional, Tuple

from repro.core import (
    DEVICE, HOST, LayerwiseBlockManager, OffloadEngine, PoolExhausted,
    SLOScheduler, interleave_offload_layers,
)
from repro.serving.costmodel import CostModel
from repro.serving.request import Phase, Request


# --------------------------------------------------------------------------
# Unified configuration
# --------------------------------------------------------------------------

@dataclasses.dataclass
class ServeConfig:
    """One config for the whole serving stack — accepted verbatim by BOTH
    `LayerKVEngine` and `ServingSimulator` (a drift-guard test asserts
    this stays true). Fields are grouped: the shared scheduling axes and
    pool geometry first, then knobs only one backend reads (clearly
    marked). `EngineConfig` / `SimConfig` remain as deprecation shims
    that fill in each backend's historical defaults.
    """
    # ---- scheduling axes (shared) ----------------------------------------
    policy: str = "layerkv"         # 'layerkv' | 'vllm'
    slo_aware: bool = True          # Alg.1 admission (layerkv only)
    chunked: bool = False           # chunked prefill + mixed batching
    prefix_cache: bool = False      # ref-counted cross-request sharing
    fused: bool = False             # ONE forward/iteration (chunked only)
    admission: str = "fcfs"         # waiting-queue order: 'fcfs' |
    #                                 'prefix_aware' (see AdmissionPolicy)
    admission_age_frac: float = 0.5  # prefix_aware aging bound: a HIT is
    #                                 ordered by a virtual arrival this
    #                                 fraction of its TTFT SLO early, so
    #                                 a miss is only ever overtaken by
    #                                 hits arriving within that window
    #                                 after it (bounded reordering, no
    #                                 starvation)
    # ---- pool geometry / batching (shared) -------------------------------
    num_device_blocks: int = 0      # 0 = backend default (engine: 128,
    #                                 sim: derive from HW memory)
    num_host_blocks: int = 1024
    block_size: int = 16
    max_batch_size: int = 64
    max_prefill_tokens: int = 8192  # per-iteration prefill token budget
    #                                 (chunked mode chunk cap; exclusive
    #                                 sim batched-prefill cap)
    chunk_floor: int = 8            # min chunk tokens/iter (progress)
    # ---- engine-only -----------------------------------------------------
    max_tokens_per_request: int = 4096
    # ---- sim-only --------------------------------------------------------
    proactive: bool = True          # Eq.5 forecast eviction
    collective_reserve_frac: float = 0.0  # §3.1.3 all-reduce reservation
    forecast_horizon: int = 32
    forecast_threshold_frac: float = 0.05
    gpu_mem_util: float = 0.9       # vLLM gpu_memory_utilization
    max_model_len: int = 16384      # drives activation reservation

    def validate(self) -> "ServeConfig":
        if self.fused and not self.chunked:
            raise ValueError("ServeConfig.fused requires chunked=True")
        if self.admission not in ADMISSION_POLICIES:
            raise ValueError(
                f"unknown admission policy {self.admission!r}; "
                f"choose from {sorted(ADMISSION_POLICIES)}")
        return self

    # Historical per-backend defaults, preserved so the EngineConfig /
    # SimConfig shims (and anything still importing them) behave exactly
    # as before the unification.
    @classmethod
    def for_engine(cls, **kw) -> "ServeConfig":
        kw.setdefault("num_device_blocks", 128)
        kw.setdefault("max_prefill_tokens", 32)
        return cls(**kw).validate()

    @classmethod
    def for_sim(cls, **kw) -> "ServeConfig":
        kw.setdefault("num_host_blocks", 1 << 20)
        kw.setdefault("max_batch_size", 256)
        kw.setdefault("chunk_floor", 16)
        return cls(**kw).validate()


@dataclasses.dataclass(frozen=True)
class LoadStats:
    """One replica's load, as a cluster router sees it (read-only
    snapshot of `SchedulerCore` state — computing it never changes a
    scheduling decision). `kv_demand` is the join-shortest-queue key:
    device blocks already held by in-flight requests plus the minimum
    blocks every waiting request still needs, i.e. the outstanding
    KV-block demand this replica's device pool has committed to."""

    n_waiting: int        # requests queued, not yet prefilling
    n_inflight: int       # prefilling + decoding
    queued_blocks: int    # min device blocks the waiting queue still needs
    active_blocks: int    # device blocks held by live allocations
    free_blocks: int      # allocatable now (incl. reclaimable cache)
    total_blocks: int     # device pool size

    @property
    def kv_demand(self) -> int:
        return self.queued_blocks + self.active_blocks

    @property
    def occupancy(self) -> float:
        return 1.0 - self.free_blocks / self.total_blocks \
            if self.total_blocks else 0.0


class AdmissionImpossible(RuntimeError):
    """The head waiting request can never be admitted: nothing is in
    flight to free blocks and the pools cannot fit it. Raised instead of
    the old opaque "wedged with waiting requests" — a temporarily
    unadmittable request simply waits (backpressure), only a permanently
    unservable one raises."""


# --------------------------------------------------------------------------
# Admission ordering policies
# --------------------------------------------------------------------------

class AdmissionPolicy:
    """Orders the waiting queue before each admission pass. Admission
    itself stays head-of-line within the returned order (the first
    request that does not fit blocks the rest), so a policy controls
    priority, never fairness-by-accident."""

    name = "?"

    def order(self, waiting: List[Request], now: float,
              core: "SchedulerCore") -> List[Request]:
        raise NotImplementedError


class FCFSAdmission(AdmissionPolicy):
    """Paper semantics: first come, first served — no reordering, hence
    no starvation (§1)."""

    name = "fcfs"

    def order(self, waiting, now, core):
        return list(waiting)


class PrefixAwareAdmission(AdmissionPolicy):
    """Cache-hitting requests admit ahead of cold misses under
    congestion. Two mechanisms compound:

      * shortest-job-first on the Eq.3 prefill cost — a hit's prefill
        prices only the uncached suffix, so serving hits first shrinks
        the mean queueing everyone sees behind exclusive prefills and
        the Alg.1 slack each admission consumes;
      * head-of-line unblocking — a hit's device-block need is only its
        suffix (the shared prefix is already resident), so a small hit
        admits into a block gap that would stall a large miss at the
        head, raising pool utilization and the effective hit rate (the
        prefix is reused while it is still hot, before LRU churn).

    Anti-starvation (aging bound): ordering is FCFS on a *virtual*
    arrival in which a hit gets a head start of `age_frac` of its own
    TTFT SLO. A miss can therefore only be overtaken by hits that
    arrived within that bounded window after it — never by the whole
    future hit stream — so the miss delay added over strict FCFS is
    bounded (~ arrival_rate x window overtakes) and no request starves,
    no matter how deep the queue grows. Under light load the order
    degenerates to plain FCFS."""

    name = "prefix_aware"

    def __init__(self, age_frac: float = 0.5):
        self.age_frac = age_frac

    def order(self, waiting, now, core):
        keyed: List[Tuple[float, int, Request]] = []
        for i, r in enumerate(waiting):
            head_start = self.age_frac * r.ttft_slo \
                if core.cached_hint(r) > 0 else 0.0
            keyed.append((r.arrival - head_start, i, r))
        keyed.sort()
        return [r for _, _, r in keyed]


ADMISSION_POLICIES = {
    FCFSAdmission.name: FCFSAdmission,
    PrefixAwareAdmission.name: PrefixAwareAdmission,
}


def make_admission_policy(sc: ServeConfig) -> AdmissionPolicy:
    if sc.admission == PrefixAwareAdmission.name:
        return PrefixAwareAdmission(sc.admission_age_frac)
    return ADMISSION_POLICIES[sc.admission]()


# --------------------------------------------------------------------------
# The shared core
# --------------------------------------------------------------------------

# backend hook: (src_pool, src_block, dst_pool, dst_block) -> None, moves
# the REAL bytes (engine) — the core itself only charges the ledger
PhysicalCopy = Callable[[str, int, str, int], None]


class SchedulerCore:
    """Queues + decisions shared by the engine and the simulator.

    Owns the request lifecycle state (waiting/prefilling/decoding/done/
    cancelled), per-request residency bookkeeping (`host_layers`, Eq.4
    plan memo), admission (policy ordering, Alg.1 budget, the device-need
    gate, the layer-split allocation), chunk assembly, the ledger routing
    of cache-driven copies, and cancellation. The clock is the backend's:
    backends assign `core.now` as their step progresses so ledger stamps
    land at the right virtual time."""

    def __init__(self, sc: ServeConfig, cost: CostModel,
                 bm: LayerwiseBlockManager, off: OffloadEngine,
                 slo: SLOScheduler, n_layers: int,
                 physical_copy: Optional[PhysicalCopy] = None,
                 reserve_blocks: int = 0):
        self.sc = sc
        self.cost = cost
        self.bm = bm
        self.off = off
        self.slo = slo
        self.L = n_layers
        self.policy = make_admission_policy(sc)
        self.physical_copy = physical_copy
        # layerkv allocation headroom (sim: Eq.5 forecast reserve)
        self.reserve_blocks = reserve_blocks
        self.now = 0.0
        # ---- request lifecycle --------------------------------------------
        self.waiting: deque[Request] = deque()
        self.prefilling: List[Request] = []   # chunked: in-flight chunks
        self.decoding: List[Request] = []
        self.done: List[Request] = []
        self.cancelled: List[Request] = []
        # ---- per-request bookkeeping --------------------------------------
        self.host_layers: Dict[str, int] = {}  # layers resident on host
        self.plans: Dict[str, object] = {}     # rid -> Eq.4 OffloadPlan
        self.reload_bytes_migrated = 0
        if sc.prefix_cache:
            # cache-driven copies (COW, promote, demote) charge the
            # transfer ledger here; the engine also moves the real bytes
            bm.on_copy = self.cache_copy

    # ------------------------------------------------------------- queries
    def in_flight(self) -> int:
        return len(self.prefilling) + len(self.decoding)

    def idle(self) -> bool:
        return not (self.prefilling or self.decoding)

    def _blocks(self, tokens: int) -> int:
        return self.bm.blocks_for_tokens(tokens)

    def cached_hint(self, r: Request) -> int:
        """Cached-prefix length for Eq.3 admission estimates (price the
        uncached suffix only, or admission over-throttles)."""
        if self.sc.prefix_cache and r.prompt:
            return self.bm.match_prefix(r.prompt)
        return 0

    def device_need(self, r: Request, memoize: bool = True) -> int:
        """MINIMUM device blocks to start r's prefill. With the prefix
        cache on, a hit needs only the uncached suffix (+ COW tail) but
        all layers device-resident — which for short prefixes can EXCEED
        the layer-wise plan; the gate takes the min of the two estimates
        (a larger hit estimate must never wedge a request the plain path
        fits). `memoize=False` keeps the Eq.4 plan out of the per-request
        memo — for probes about requests this core may never own (the
        cluster feasibility backstop), whose memo entry `release()` would
        otherwise never drop."""
        if self.sc.policy == "vllm":
            need = self._blocks(r.prompt_len) * self.L
        else:
            plan = self.plans.get(r.rid)
            if plan is None:
                plan = self.off.plan_for_prompt(r.prompt_len)
                if memoize:
                    self.plans[r.rid] = plan
            send_buf = 1 if plan.offload_layers else 0
            need = self._blocks(r.prompt_len) * (plan.x + send_buf)
        if self.sc.prefix_cache and r.prompt:
            c = self.bm.match_prefix(r.prompt)
            if c > 0:
                hit_need = (self._blocks(r.prompt_len)
                            - c // self.sc.block_size) * self.L
                need = min(need, hit_need)
        return need

    # --------------------------------------------------- load introspection
    def occupancy(self) -> float:
        """Fraction of the device pool held by live allocations (cheap —
        suitable for per-step sampling)."""
        total = self.bm.pools[DEVICE].num_blocks
        return 1.0 - self.bm.num_free(DEVICE) / total if total else 0.0

    def load_stats(self) -> LoadStats:
        """Snapshot this replica's outstanding KV-block demand for a
        cluster router. Pure read: `device_need` only fills the same
        Eq.4 plan memo admission would, so probing never perturbs the
        schedule (the cluster-of-1 identity tests pin this)."""
        total = self.bm.pools[DEVICE].num_blocks
        free = self.bm.num_free(DEVICE)
        queued = sum(self.device_need(r) for r in self.waiting)
        return LoadStats(n_waiting=len(self.waiting),
                         n_inflight=self.in_flight(),
                         queued_blocks=queued,
                         active_blocks=total - free,
                         free_blocks=free, total_blocks=total)

    def admit_eta(self, r: Request, now: float) -> float:
        """Estimated delay before this replica's Alg.1 slack admits `r`
        behind its current waiting queue: the Eq.3 prefill work already
        queued ahead of it, plus however much of r's own prefill does not
        fit in the decode batch's remaining Eq.1 slack. Prefix-cache hits
        price only their uncached suffix, exactly as admission does. With
        slo_aware off (or the vllm policy) the queue term alone orders
        replicas."""
        t = max(now, self.now)

        def _cost(q: Request) -> float:
            c = self.cached_hint(q)
            return self.cost.chunk_prefill_time(q.prompt_len - c, c)

        queued = sum(_cost(q) for q in self.waiting)
        if not (self.sc.policy == "layerkv" and self.sc.slo_aware):
            return queued
        budget = self.slo.allow_prefill_budget(self.decoding, t)
        if budget == float("inf"):
            return queued
        return queued + max(_cost(r) - max(budget - queued, 0.0), 0.0)

    # --------------------------------------------------------- cache copies
    def cache_copy(self, src_pool: str, src: int, dst_pool: str,
                   dst: int) -> None:
        """Route one cache-driven block copy: the backend's hook moves
        the real bytes (engine), the ledger charges the offload link for
        cross-tier moves (d2d COW copies never touch the link)."""
        if self.physical_copy is not None:
            self.physical_copy(src_pool, src, dst_pool, dst)
        nbytes = self.cost.kv_bytes(self.sc.block_size, 1)
        if src_pool == HOST and dst_pool == DEVICE:
            self.off.ledger.submit(self.now, nbytes, "reload")
            self.reload_bytes_migrated += nbytes
        elif src_pool == DEVICE and dst_pool == HOST:
            self.off.ledger.submit(self.now, nbytes, "offload")

    # ----------------------------------------------------------- allocation
    def alloc_prefill(self, r: Request) -> Optional[Tuple[list, list]]:
        """Allocate r's prompt KV per the policy; returns (retain, off)
        layer lists or None when the pools cannot fit it. Sets
        `host_layers[r.rid]` and, on a prefix hit, r.prefill_done /
        r.cached_prompt_len (all layers device-resident; prefill compute
        then starts at the cached length). A hit that cannot fit falls
        through to the plain policy path. Never touches the transfer
        ledger — callers account d2h traffic at the granularity their
        step semantics require (whole-prompt vs per-chunk)."""
        if self.sc.prefix_cache and r.prompt:
            acq = self.bm.acquire_prefix(r.rid, r.prompt)
            if acq is not None:
                try:
                    suffix = r.prompt_len - acq.cached_len
                    for l in range(self.L):
                        self.bm.extend_layer(r.rid, l, suffix)
                except PoolExhausted:
                    self.bm.free_request(r.rid)
                    r.prefill_done = 0
                else:
                    r.prefill_done = acq.cached_len
                    r.cached_prompt_len = acq.cached_len
                    self.host_layers[r.rid] = 0
                    self.bm.cache.count(r.prompt_len, acq.cached_len)
                    return list(range(self.L)), []
        per_layer = self._blocks(r.prompt_len)
        try:
            if self.sc.policy == "vllm":
                retain, off = list(range(self.L)), []
            else:
                plan = self.plans.get(r.rid)
                if plan is None:
                    plan = self.off.plan_for_prompt(r.prompt_len)
                    self.plans[r.rid] = plan
                # retain as many layers as currently fit (free
                # prefetching, §3.1.1), never fewer than Eq.4's x
                fit = max((self.bm.num_free(DEVICE) - self.reserve_blocks)
                          // max(per_layer, 1) - 1, 0)
                retain_n = min(self.L, max(plan.x, fit))
                off = interleave_offload_layers(self.L, retain_n)
                retain = [l for l in range(self.L) if l not in set(off)]
            for l in retain:
                self.bm.alloc_layer(r.rid, l, r.prompt_len, DEVICE)
            for l in off:
                self.bm.alloc_layer(r.rid, l, r.prompt_len, HOST)
        except PoolExhausted:
            self.bm.free_request(r.rid)
            return None
        self.host_layers[r.rid] = len(off)
        if self.sc.prefix_cache and r.prompt:
            self.bm.cache.count(r.prompt_len, 0)  # admitted as a miss
        return retain, off

    # ------------------------------------------------------------ admission
    def admission_budget(self, order: List[Request], now: float) -> int:
        """Alg.1: how many of the ordered waiting prefills fit in the
        decode batch's minimum TPOT slack."""
        if self.sc.policy == "layerkv" and self.sc.slo_aware:
            return self.slo.max_prefills(order, self.decoding, now,
                                         cached_len=self.cached_hint)
        return len(order)

    def admit_waiting(self, now: float,
                      immediate: Optional[Callable[[Request], bool]] = None,
                      token_budget: Optional[int] = None) -> List[Request]:
        """One admission pass over the policy-ordered waiting queue.
        Head-of-line within the order: the first request that fails a
        gate stops the pass. Three caller modes:

          chunked (sc.chunked)   allocate KV and queue the request into
                                 `prefilling` for chunk-by-chunk prefill;
          immediate=<fn>         exclusive engine: run each admitted
                                 prefill NOW (fn appends to `decoding`);
          neither                exclusive sim: allocate only; the caller
                                 runs the returned batch exclusively
                                 (`token_budget` caps its prompt tokens).

        Returns the requests admitted this pass."""
        if not self.waiting:
            return []
        order = self.policy.order(list(self.waiting), now, self)
        budget_n = self.admission_budget(order, now)
        admitted: List[Request] = []
        deferred = immediate is None and not self.sc.chunked
        for r in order:
            if budget_n <= 0:
                break
            in_flight = self.in_flight() + (len(admitted) if deferred
                                            else 0)
            if in_flight >= self.sc.max_batch_size:
                break
            if token_budget is not None and admitted \
                    and r.prompt_len > token_budget:
                break
            if self.bm.num_free(DEVICE) < self.device_need(r):
                break
            if self.sc.chunked:
                if self.alloc_prefill(r) is None:
                    break
                self.waiting.remove(r)
                r.phase = Phase.PREFILL
                r.prefill_start = now
                self.prefilling.append(r)
            elif immediate is not None:
                self.waiting.remove(r)
                # read the clock FRESH: an earlier immediate() in this
                # pass ran a whole prefill and advanced it — stamping the
                # pass-start `now` would under-report queueing and tie
                # every prefill_start in the pass (breaking newest-first
                # eviction ordering)
                r.prefill_start = self.now
                if not immediate(r):
                    self.waiting.appendleft(r)
                    break
            else:
                if self.alloc_prefill(r) is None:
                    break
                self.waiting.remove(r)
            admitted.append(r)
            budget_n -= 1
            if token_budget is not None:
                token_budget -= r.prompt_len
        return admitted

    # ------------------------------------------------------- chunk assembly
    def chunk_token_cap(self, now: float) -> int:
        """Per-iteration prefill token budget: Eq.1 slack converted to
        tokens when slo_aware, else the static cap."""
        if self.sc.policy == "layerkv" and self.sc.slo_aware:
            return self.slo.max_chunk_tokens(
                self.decoding, now, self.sc.max_prefill_tokens,
                floor=self.sc.chunk_floor)
        return self.sc.max_prefill_tokens

    def assemble_chunks(self, now: float, decode_tokens: int
                        ) -> List[Tuple[Request, int]]:
        """FCFS chunk assembly under the token budget; this iteration's
        decode tokens count against it. A floor guarantees prefill
        progress when no decode batch runs."""
        budget = self.chunk_token_cap(now) - decode_tokens
        if self.prefilling and decode_tokens == 0:
            budget = max(budget, self.sc.chunk_floor)
        work: List[Tuple[Request, int]] = []
        for r in sorted(self.prefilling, key=lambda q: q.prefill_start):
            if budget <= 0:
                break
            c = min(budget, r.prefill_remaining)
            work.append((r, c))
            budget -= c
        return work

    # ------------------------------------------------------------- release
    def release(self, r: Request) -> None:
        """Drop the per-request bookkeeping (retire and cancel paths)."""
        self.host_layers.pop(r.rid, None)
        self.plans.pop(r.rid, None)

    def cancel(self, r: Request, now: float) -> bool:
        """Unwind everything `r` has in flight, whatever its phase:

          * waiting      — just leaves the queue;
          * prefilling   — mid-chunk KV (device AND host-resident
                           offloaded layers) is freed; blocks it shares
                           through the prefix cache are decref'd, never
                           pulled from under another sharer, and FULL
                           blocks it already registered stay behind as
                           reclaimable cache (a cancelled request's
                           computed prefix remains hittable);
          * decoding     — same, plus it leaves the decode batch.

        Transfers already submitted to the link ledger are sunk cost: the
        bytes were queued on the link, the ledger is occupancy accounting
        and stays monotone. Returns False when `r` is not live (already
        finished or cancelled) — cancellation is idempotent."""
        self.now = now
        was_live = False
        if r in self.waiting:
            self.waiting.remove(r)
            was_live = True
        if r in self.prefilling:
            self.prefilling.remove(r)
            was_live = True
        if r in self.decoding:
            self.decoding.remove(r)
            was_live = True
        if not was_live:
            return False
        if r.rid in self.bm.tables:
            self.bm.free_request(r.rid)
        self.release(r)
        r.phase = Phase.CANCELLED
        r.finish_time = now
        self.cancelled.append(r)
        return True

    def wedged_error(self) -> AdmissionImpossible:
        """Names the request that actually blocked the admission pass:
        the head of the POLICY order (admission is head-of-line within
        it), which under prefix_aware need not be waiting[0]."""
        order = self.policy.order(list(self.waiting), self.now, self)
        r = order[0] if order else self.waiting[0]
        return AdmissionImpossible(
            f"request {r.rid} (prompt {r.prompt_len}) can never be "
            f"admitted: needs {self.device_need(r)} device blocks, the "
            f"pool has {self.bm.pools[DEVICE].num_blocks} and nothing is "
            f"in flight to free any")


class CoreDelegateMixin:
    """Queue/clock delegation shared by every backend that drives a
    `SchedulerCore` — the engine and the simulator inherit this instead
    of each hand-mirroring the core's lifecycle state (which is exactly
    the duplication the core exists to prevent). Subclasses set
    `self.core` in __init__ and keep their own named clock property
    (`engine.now`, `sim.t`) on top of `clock()`/`advance_to()`."""

    core: SchedulerCore

    @property
    def waiting(self):
        return self.core.waiting

    @property
    def prefilling(self):
        return self.core.prefilling

    @property
    def decoding(self):
        return self.core.decoding

    @property
    def done(self):
        return self.core.done

    @property
    def cancelled(self):
        return self.core.cancelled

    @property
    def host_layers(self):
        return self.core.host_layers

    def clock(self) -> float:
        return self.core.now

    def advance_to(self, t: float) -> None:
        self.core.now = max(self.core.now, t)
