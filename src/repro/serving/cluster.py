"""Cluster serving: one router, N replica backends, one session API.

A `ClusterSession` owns N replicas — each a `ServingSession` over any
`ServingBackend` (the real `LayerKVEngine` or the discrete-event
`ServingSimulator`; heterogeneous pool geometry is allowed) — and
exposes the exact submit/stream/cancel/drain/reap surface of a single
session. The one new decision is DISPATCH: which replica's queue a
request joins, made by a pluggable `RoutingPolicy` (serving/router.py)
at the request's arrival time on the shared virtual clock.

Time. Each replica backend keeps its own virtual clock (cost-model
driven on both backends), so the cluster is a discrete-event system of
N servers plus one arrival stream. `step()` always advances the replica
whose next event is EARLIEST on the shared virtual clock, and a parked
arrival is dispatched exactly when it becomes the earliest event — so
routing observes each replica's state as of the arrival, never the
future. Replica clocks advance in lockstep order of events, exactly
like a multi-server event queue.

Identity. A cluster of 1 is bit-identical to a bare `ServingSession`
over the same backend: every arrival dispatches to replica 0 before the
same step it would have fed in a bare session, and the routing policies
only *read* scheduler state — `tests/test_cluster.py` pins tokens on
the engine and exact metrics on the simulator across all five
scheduling axes and all four policies.

Cancellation routes to the owning replica and reuses the PR 4 unwind;
a request cancelled before its arrival dispatches is unwound entirely
inside the cluster (nothing is in flight anywhere). `metrics()` merges
the replicas' `SimMetrics` by POOLING raw latency series
(`SimMetrics.merge`) — per-replica percentiles are never averaged.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Iterator, List, Optional, Sequence, Union

from repro.core import DEVICE
from repro.serving.request import Phase, Request
from repro.serving.router import RoutingPolicy, make_routing_policy
from repro.serving.scheduler import AdmissionImpossible
from repro.serving.session import RequestHandle, ServingBackend, \
    ServingSession, cancel_parked
from repro.serving.sim import SimMetrics


@dataclasses.dataclass
class ReplicaStats:
    """Per-replica dispatch accounting for the drain report."""
    dispatched: int = 0
    steps: int = 0
    peak_occupancy: float = 0.0   # max device-pool occupancy observed


@dataclasses.dataclass
class ClusterHandle:
    """A submitted request, as seen by the cluster caller. Before its
    arrival dispatches, the request lives only in the cluster's pending
    heap (no replica knows it); afterwards the handle delegates to the
    owning replica's `RequestHandle`."""

    request: Request
    cluster: "ClusterSession"
    replica: Optional[int] = None           # set at dispatch
    _inner: Optional[RequestHandle] = None  # set at dispatch

    @property
    def rid(self) -> str:
        return self.request.rid

    @property
    def phase(self) -> Phase:
        return self.request.phase

    @property
    def finished(self) -> bool:
        return self.request.phase is Phase.FINISHED

    @property
    def cancelled(self) -> bool:
        return self.request.phase is Phase.CANCELLED

    @property
    def done(self) -> bool:
        return self.finished or self.cancelled

    def take_new(self) -> List[int]:
        """Tokens produced since the last call (non-blocking); [] until
        the request has dispatched to a replica."""
        return self._inner.take_new() if self._inner is not None else []

    def cancel(self) -> bool:
        return self.cluster.cancel(self)


class ClusterSession:
    """Multi-replica serving frontend: same API as `ServingSession`,
    plus a routing policy and per-replica introspection."""

    def __init__(self, backends: Sequence[ServingBackend],
                 router: Union[str, RoutingPolicy] = "round_robin"):
        if not backends:
            raise ValueError("a cluster needs at least one backend")
        self.sessions = [ServingSession(b) for b in backends]
        self.router = make_routing_policy(router)
        self._pending: list = []           # (arrival, seq, Request) heap
        self._seq = itertools.count()
        self.handles: dict = {}            # rid -> ClusterHandle
        self.cancelled: List[Request] = []  # cancelled before dispatch
        self.stats = [ReplicaStats() for _ in backends]

    @property
    def n_replicas(self) -> int:
        return len(self.sessions)

    @property
    def cores(self):
        return [s.core for s in self.sessions]

    def clock(self) -> float:
        """The shared virtual clock: the furthest any replica has
        simulated. Arrivals stamped "now" are dispatched once every
        earlier replica event has run (virtual-time event order)."""
        return max(s.backend.clock() for s in self.sessions)

    # ------------------------------------------------------------ submit
    def submit(self, request: Request,
               arrival: Optional[float] = None) -> ClusterHandle:
        """Enqueue a request. An arrival at or before the shared clock
        routes NOW (it has already arrived — exactly the bare session's
        direct-to-waiting path, in submit order); a future arrival parks
        in the cluster heap and routes when the shared clock reaches it,
        so load-aware policies observe arrival-time load, never
        submission-time load. `arrival=None` stamps the current shared
        clock. rids are unique cluster-wide."""
        if request.rid in self.handles:
            raise ValueError(f"duplicate rid {request.rid!r}")
        now = self.clock()
        request.arrival = now if arrival is None else arrival
        h = ClusterHandle(request, self)
        self.handles[request.rid] = h
        if request.arrival <= now:
            self._route(request)
        else:
            heapq.heappush(self._pending,
                           (request.arrival, next(self._seq), request))
        return h

    def _route(self, r: Request) -> int:
        """Pick r's replica and hand it to that replica's session (which
        parks still-future arrivals in its own heap — a replica clock can
        lag the shared clock). Returns the chosen replica index.

        Feasibility backstop (heterogeneous geometry): a policy may pick
        a replica whose pool can NEVER fit the request — the same
        `device_need` test `wedged_error` reports on. When another
        replica could serve it, the request is re-routed to the feasible
        replica with the least KV-block demand instead of wedging a
        queue forever; when NO replica fits (including a cluster of 1),
        the choice stands and drain raises AdmissionImpossible exactly
        like a bare session."""
        i = self.router.choose(r, self.cores, r.arrival)
        if not 0 <= i < self.n_replicas:
            raise ValueError(
                f"router {self.router.name!r} chose replica {i} "
                f"of {self.n_replicas}")
        cores = self.cores

        def _fits(j: int) -> bool:
            # memoize=False: replicas that don't win the request must
            # not retain a plan memo nothing will ever release
            return cores[j].device_need(r, memoize=False) <= \
                cores[j].bm.pools[DEVICE].num_blocks

        if not _fits(i):
            feasible = [j for j in range(self.n_replicas) if _fits(j)]
            if feasible:
                i = min(feasible,
                        key=lambda j: (cores[j].load_stats().kv_demand, j))
        h = self.handles[r.rid]
        h.replica = i
        h._inner = self.sessions[i].submit(r, arrival=r.arrival)
        self.stats[i].dispatched += 1
        return i

    def _dispatch(self) -> int:
        return self._route(heapq.heappop(self._pending)[2])

    # -------------------------------------------------------------- step
    def step(self) -> bool:
        """One cluster event: dispatch the next arrival if it precedes
        every live replica's next event, else step the replica whose
        next event is earliest. A replica that cannot progress (wedged
        on backpressure) is dropped from the event comparison — its
        frozen clock must stall neither the other replicas NOR the
        dispatch of parked arrivals they could serve; a dispatch that
        lands on a stalled replica revives it. Returns False only when
        nothing can progress anywhere."""
        stalled: set = set()
        while True:
            nxt = [(s.next_event_time(), i)
                   for i, s in enumerate(self.sessions)]
            busy = sorted((t, i) for t, i in nxt
                          if t is not None and i not in stalled)
            if self._pending and \
                    (not busy or self._pending[0][0] <= busy[0][0]):
                stalled.discard(self._dispatch())
                continue
            if not busy:
                return False
            _, i = busy[0]
            if self.sessions[i].step():
                st = self.stats[i]
                st.steps += 1
                st.peak_occupancy = max(st.peak_occupancy,
                                        self.sessions[i].core.occupancy())
                return True
            stalled.add(i)

    @property
    def backlog(self) -> int:
        """Requests accepted but not yet prefilling, cluster-wide."""
        return len(self._pending) + sum(s.backlog for s in self.sessions)

    # ------------------------------------------------------------ stream
    def stream(self, handle: ClusterHandle) -> Iterator[int]:
        """Per-token iterator for one request; every replica advances
        normally while streaming."""
        while True:
            yield from handle.take_new()
            if handle.done:
                return
            if not self.step():
                raise self._wedged()

    # ------------------------------------------------------------ cancel
    def cancel(self, handle: ClusterHandle) -> bool:
        """Cancel a live request. Dispatched requests route to the
        owning replica session (live unwind or replica-heap removal —
        the PR 4 path); an undispatched request is unwound entirely here
        (no replica ever saw it). Idempotent; False once done."""
        if handle._inner is not None:
            return handle._inner.cancel()
        return cancel_parked(self._pending, handle.request, self.clock(),
                             self.cancelled)

    # -------------------------------------------------------------- reap
    def reap(self, handle: ClusterHandle) -> Optional[Request]:
        """Release a done request's retained state, cluster-wide: the
        cluster handle plus the owning replica session's handle and
        done/cancelled entry."""
        if not handle.done:
            return None
        r = handle.request
        self.handles.pop(r.rid, None)
        if handle._inner is not None:
            return self.sessions[handle.replica].reap(handle._inner)
        if r in self.cancelled:
            self.cancelled.remove(r)
        return r

    # ------------------------------------------------------------- drain
    def _wedged(self) -> AdmissionImpossible:
        for s in self.sessions:
            if s.core.waiting:
                return s.core.wedged_error()
        return AdmissionImpossible(
            "cluster wedged with no waiting request (bug)")

    def drain(self) -> List[Request]:
        """Run every replica empty; returns the finished requests in
        finish-time order (a cluster of 1 returns exactly the bare
        session's list — replica done-lists are already time-ordered
        and the sort is stable)."""
        while self._pending or \
                any(s.next_event_time() is not None for s in self.sessions):
            if not self.step():
                raise self._wedged()
        for s in self.sessions:
            s.backend.finish()
        out = [r for s in self.sessions for r in s.core.done]
        out.sort(key=lambda r: r.finish_time)
        return out

    # ----------------------------------------------------------- metrics
    def metrics(self) -> SimMetrics:
        """Pooled metrics across replicas (simulator backends): raw
        latency series are concatenated BEFORE means/percentiles —
        averaging per-replica p99s would understate the tail whenever
        replicas are imbalanced, which is exactly what routing policies
        differ on. Requests cancelled before dispatch are counted here
        (no replica ever saw them)."""
        m = SimMetrics.merge([s.backend.metrics() for s in self.sessions])
        m.n_cancelled += len(self.cancelled)
        return m

    # --------------------------------------------------------------- run
    def run(self, requests: List[Request]) -> List[Request]:
        """Batch convenience wrapper, mirroring the backends' run()."""
        for r in sorted(requests, key=lambda q: q.arrival):
            self.submit(r, arrival=r.arrival)
        return self.drain()
