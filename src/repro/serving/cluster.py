"""Cluster serving: one router, N replica backends, one session API.

A `ClusterSession` owns N replicas — each a `ServingSession` over any
`ServingBackend` (the real `LayerKVEngine` or the discrete-event
`ServingSimulator`; heterogeneous pool geometry is allowed) — and
exposes the exact submit/stream/cancel/drain/reap surface of a single
session. The one new decision is DISPATCH: which replica's queue a
request joins, made by a pluggable `RoutingPolicy` (serving/router.py)
at the request's arrival time on the shared virtual clock.

Time. Each replica backend keeps its own virtual clock (cost-model
driven on both backends), so the cluster is a discrete-event system of
N servers plus one arrival stream. `step()` always advances the replica
whose next event is EARLIEST on the shared virtual clock, and a parked
arrival is dispatched exactly when it becomes the earliest event — so
routing observes each replica's state as of the arrival, never the
future. Replica clocks advance in lockstep order of events, exactly
like a multi-server event queue.

Identity. A cluster of 1 is bit-identical to a bare `ServingSession`
over the same backend: every arrival dispatches to replica 0 before the
same step it would have fed in a bare session, and the routing policies
only *read* scheduler state — `tests/test_cluster.py` pins tokens on
the engine and exact metrics on the simulator across all five
scheduling axes and all four policies. The fault-tolerance machinery
below preserves a second identity: with no `FaultPlan` and no manual
`kill`/`drain_replica` call, every new code path is unreachable and the
cluster behaves bit-identically to the pre-fault implementation.

Fault tolerance. Replicas can fail and recover on the shared virtual
clock — injected deterministically by a `FaultPlan` (serving/faults.py)
or forced manually:

  * `kill(i)` hard-fails replica i NOW: its parked arrivals return to
    the cluster heap, every live request it owns is unwound through the
    PR 4 cancel machinery (all KV freed — the replica's memory is gone),
    the already-streamed tokens are salvaged onto the `ClusterHandle`
    (a consumer never sees a gap or a duplicate), and the remainder of
    each request is re-dispatched through the routing policy. Restart
    folds the delivered tokens into the prompt, so only the UNSTREAMED
    remainder is recomputed and context math stays exact.
  * `drain_replica(i)` is the graceful variant: queued-but-unstarted
    work re-routes immediately (it holds no KV), in-flight work
    finishes normally, and the replica retires once empty.
  * `revive(i)` brings a killed replica back COLD (its prefix cache is
    dropped — the memory did not survive) at a given virtual time.
  * Liveness: with `liveness_timeout` set, a replica whose next due
    event lags the shared clock by more than the timeout while it is
    frozen (fault-wedged or backpressure-stalled) is declared dead and
    killed — detection by missing heartbeat, not by oracle knowledge of
    the injected fault.
  * Dispatch-level faults retry with exponential backoff
    (`retry_backoff * 2**k`), bounded by `max_dispatch_retries`; a
    request that exhausts its retries is SHED with the typed reason
    `DispatchFailed` instead of wedging the cluster.
  * Prefix affinity survives a kill: the first re-dispatched request of
    a template records its recovery target in `_template_home`, and
    subsequent re-dispatched requests of the same template follow it
    (the template re-registers its prefix on the recovery replica).

Cancellation routes to the owning replica and reuses the PR 4 unwind;
a request cancelled before its arrival dispatches is unwound entirely
inside the cluster (nothing is in flight anywhere). `metrics()` merges
the replicas' `SimMetrics` by POOLING raw latency series
(`SimMetrics.merge`) — per-replica percentiles are never averaged —
and adds the cluster-level fault counters (kills, recoveries, retries,
re-dispatches, cluster-level sheds).
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Iterator, List, Optional, Sequence, Union

from repro.core import DEVICE
from repro.core.block_manager import block_hashes
from repro.obs.registry import MetricsRegistry
from repro.serving.faults import FaultEngine, FaultPlan
from repro.serving.request import Phase, Request
from repro.serving.router import RoutingPolicy, make_routing_policy
from repro.serving.scheduler import AdmissionImpossible, DispatchFailed
from repro.serving.session import RequestHandle, ServingBackend, \
    ServingSession, cancel_parked
from repro.serving.sim import SimMetrics


@dataclasses.dataclass
class ReplicaStats:
    """Per-replica dispatch accounting for the drain report."""
    dispatched: int = 0
    steps: int = 0
    peak_occupancy: float = 0.0   # max device-pool occupancy observed


@dataclasses.dataclass
class ClusterHandle:
    """A submitted request, as seen by the cluster caller. Before its
    arrival dispatches, the request lives only in the cluster's pending
    heap (no replica knows it); afterwards the handle delegates to the
    owning replica's `RequestHandle`.

    The handle survives replica failure: when the owning replica is
    killed, the tokens its dead incarnation already produced are
    salvaged into `_salvaged` (minus the prefix the consumer already
    took, tracked by `_salvage_cursor`) and the request is re-dispatched
    with a fresh inner handle — `take_new()` keeps delivering each token
    exactly once across any number of kills."""

    request: Request
    cluster: "ClusterSession"
    replica: Optional[int] = None           # set at dispatch
    _inner: Optional[RequestHandle] = None  # set at dispatch
    #: tokens produced by DEAD incarnations, in stream order
    _salvaged: List[int] = dataclasses.field(default_factory=list)
    #: how much of `_salvaged` the consumer has already taken
    _salvage_cursor: int = 0

    @property
    def rid(self) -> str:
        return self.request.rid

    @property
    def phase(self) -> Phase:
        return self.request.phase

    @property
    def finished(self) -> bool:
        return self.request.phase is Phase.FINISHED

    @property
    def cancelled(self) -> bool:
        return self.request.phase is Phase.CANCELLED

    @property
    def shed(self) -> bool:
        """True when the request was rejected under overload or fault
        pressure (graceful degradation); the typed reason is on
        `request.shed_reason`. Terminal, like cancelled."""
        return self.request.phase is Phase.SHED

    @property
    def done(self) -> bool:
        return self.finished or self.cancelled or self.shed

    def take_new(self) -> List[int]:
        """Tokens produced since the last call (non-blocking); [] until
        the request has dispatched to a replica. After a replica kill
        the salvaged backlog drains first, then the live incarnation's
        stream; simulator ordinals are rebased by `tokens_salvaged` so
        the combined stream counts 0,1,2,... without repeats."""
        out = list(self._salvaged[self._salvage_cursor:])
        self._salvage_cursor = len(self._salvaged)
        if self._inner is not None:
            new = self._inner.take_new()
            base = self.request.tokens_salvaged
            if base and not self._inner.session.backend.produces_token_ids:
                new = [base + v for v in new]
            out.extend(new)
        return out

    def _salvage(self) -> None:
        """Preserve the dead incarnation's stream on the handle: every
        token it produced joins `_salvaged`, and the cursor skips the
        prefix the inner handle already delivered. Detaches the inner
        handle — the replica that owned it is gone."""
        r = self.request
        inner = self._inner
        if inner is None:
            return
        if inner.session.backend.produces_token_ids:
            vals = [int(t) for t in r.generated[:r.tokens_out]]
        else:
            base = r.tokens_salvaged
            vals = list(range(base, base + r.tokens_out))
        delivered = inner._cursor
        self._salvaged.extend(vals)
        self._salvage_cursor += delivered
        self._inner = None
        self.replica = None

    def cancel(self) -> bool:
        return self.cluster.cancel(self)


class ClusterSession:
    """Multi-replica serving frontend: same API as `ServingSession`,
    plus a routing policy, per-replica introspection, and replica
    failure injection/detection/recovery (module docstring)."""

    def __init__(self, backends: Sequence[ServingBackend],
                 router: Union[str, RoutingPolicy] = "round_robin",
                 fault_plan: Optional[FaultPlan] = None,
                 liveness_timeout: Optional[float] = None,
                 max_dispatch_retries: int = 8,
                 retry_backoff: float = 0.05):
        if not backends:
            raise ValueError("a cluster needs at least one backend")
        self.sessions = [ServingSession(b) for b in backends]
        self.router = make_routing_policy(router)
        self._pending: list = []           # (arrival, seq, Request) heap
        self._seq = itertools.count()
        self.handles: dict = {}            # rid -> ClusterHandle
        self.cancelled: List[Request] = []  # cancelled before dispatch
        self.stats = [ReplicaStats() for _ in backends]
        # --- fault tolerance (all inert without a plan / manual kill) ---
        self.faults = FaultEngine(fault_plan) \
            if fault_plan is not None else None
        self.liveness_timeout = liveness_timeout
        self.max_dispatch_retries = max_dispatch_retries
        self.retry_backoff = retry_backoff
        self.alive = [True] * len(self.sessions)
        self.draining = [False] * len(self.sessions)
        self.shed: List[Request] = []      # shed at cluster level
        #                                    (dispatch retries exhausted)
        self.recovery_log: List[str] = []  # deterministic replay trace
        self._template_home: dict = {}     # prefix anchor -> recovery
        #                                    replica (kill re-homing)
        # cluster-level counters (kills/recoveries/retries/redispatch/
        # shed) live in the obs registry; back-compat properties below
        self.registry = MetricsRegistry()
        self.retry_priorities: List[int] = []
        self.redispatch_priorities: List[int] = []
        # fleet-level event stream (kill/revive/drain/retry/redispatch/
        # fault instants), present iff the replicas themselves trace —
        # one more track merged onto the shared virtual clock
        self.tracer = None
        if any(s.core.tracer is not None for s in self.sessions):
            from repro.obs.trace import Tracer
            self.tracer = Tracer()

    # ---------------------------------------------- counter back-compat
    @property
    def n_kills(self) -> int:
        return int(self.registry.get("replica_kills"))

    @property
    def n_recoveries(self) -> int:
        return int(self.registry.get("replica_recoveries"))

    @property
    def n_retries(self) -> int:
        return int(self.registry.get("dispatch_retries"))

    @property
    def n_replicas(self) -> int:
        return len(self.sessions)

    @property
    def cores(self):
        return [s.core for s in self.sessions]

    def clock(self) -> float:
        """The shared virtual clock: the furthest any replica has
        simulated. Arrivals stamped "now" are dispatched once every
        earlier replica event has run (virtual-time event order)."""
        return max(s.backend.clock() for s in self.sessions)

    # ------------------------------------------------------------ submit
    def submit(self, request: Request,
               arrival: Optional[float] = None) -> ClusterHandle:
        """Enqueue a request. An arrival at or before the shared clock
        routes NOW (it has already arrived — exactly the bare session's
        direct-to-waiting path, in submit order); a future arrival parks
        in the cluster heap and routes when the shared clock reaches it,
        so load-aware policies observe arrival-time load, never
        submission-time load. `arrival=None` stamps the current shared
        clock. rids are unique cluster-wide."""
        if request.rid in self.handles:
            raise ValueError(f"duplicate rid {request.rid!r}")
        now = self.clock()
        request.arrival = now if arrival is None else arrival
        h = ClusterHandle(request, self)
        self.handles[request.rid] = h
        if request.arrival <= now:
            self._route(request)
        else:
            heapq.heappush(self._pending,
                           (request.arrival, next(self._seq), request))
        return h

    def _anchor(self, r: Request):
        """The prompt's content-addressing anchor — the same key
        `prefix_affinity` rendezvouses on — used to re-home a template
        after its replica is killed. None when there is no prompt."""
        toks = r.prompt
        if not toks:
            return None
        bs = self.cores[0].bm.block_size
        return block_hashes(toks, bs)[0] if len(toks) >= bs \
            else hash(tuple(toks))

    def _route(self, r: Request,
               when: Optional[float] = None) -> Optional[int]:
        """Pick r's replica and hand it to that replica's session (which
        parks still-future arrivals in its own heap — a replica clock can
        lag the shared clock). Returns the chosen replica index, or None
        when dispatch failed (no live replica, or an injected transient
        failure) and the request was parked for retry / shed.

        Routing only ever considers live, non-draining replicas; with
        every replica healthy the candidate list is the full replica
        list and the path is bit-identical to the pre-fault router call.
        A re-dispatched request (`n_redispatched > 0`) prefers its
        template's recorded recovery home so prefix affinity survives
        the kill that displaced it.

        Feasibility backstop (heterogeneous geometry): a policy may pick
        a replica whose pool can NEVER fit the request — the same
        `device_need` test `wedged_error` reports on. When another live
        replica could serve it, the request is re-routed to the feasible
        replica with the least KV-block demand instead of wedging a
        queue forever; when NO replica fits (including a cluster of 1),
        the choice stands and drain raises AdmissionImpossible exactly
        like a bare session."""
        t = r.arrival if when is None else when
        live = [j for j in range(self.n_replicas)
                if self.alive[j] and not self.draining[j]]
        if not live:
            return self._dispatch_failed(r, t)
        cores = self.cores
        i: Optional[int] = None
        if r.n_redispatched:
            home = self._template_home.get(self._anchor(r))
            if home is not None and home in live:
                i = home
        if i is None:
            c = self.router.choose(r, [cores[j] for j in live], t)
            if not 0 <= c < len(live):
                raise ValueError(
                    f"router {self.router.name!r} chose replica {c} "
                    f"of {len(live)}")
            i = live[c]

        def _fits(j: int) -> bool:
            # memoize=False: replicas that don't win the request must
            # not retain a plan memo nothing will ever release
            return cores[j].device_need(r, memoize=False) <= \
                cores[j].bm.pools[DEVICE].num_blocks

        if not _fits(i):
            feasible = [j for j in live if _fits(j)]
            if feasible:
                i = min(feasible,
                        key=lambda j: (cores[j].load_stats().kv_demand, j))
        if self.faults is not None and self.faults.dispatch_fails(i, t):
            return self._dispatch_failed(r, t)
        h = self.handles[r.rid]
        h.replica = i
        # a re-dispatch must not be served before `when` on the target's
        # (possibly lagging) clock, but the request keeps its TRUE
        # arrival for metrics — queueing delay honestly includes the
        # outage. ServingSession.submit stamps r.arrival; restore it.
        orig = r.arrival
        h._inner = self.sessions[i].submit(r, arrival=max(orig, t))
        r.arrival = orig
        if r.n_redispatched:
            a = self._anchor(r)
            if a is not None:
                self._template_home.setdefault(a, i)
        self.stats[i].dispatched += 1
        return i

    def _dispatch_failed(self, r: Request, t: float) -> Optional[int]:
        """Transient dispatch failure (injected, or no live replica):
        bounded retry with exponential backoff; a request that exhausts
        `max_dispatch_retries` is SHED with the typed `DispatchFailed`
        reason instead of spinning forever."""
        r.n_dispatch_retries += 1
        self.registry.inc("dispatch_retries")
        self.retry_priorities.append(r.priority)
        if self.tracer is not None:
            self.tracer.instant("retry", t, rid=r.rid,
                                attempt=r.n_dispatch_retries)
        if r.n_dispatch_retries > self.max_dispatch_retries:
            r.phase = Phase.SHED
            r.shed_reason = DispatchFailed.__name__
            r.finish_time = t
            self.shed.append(r)
            self.registry.inc("shed_total",
                              reason=DispatchFailed.__name__)
            h = self.handles[r.rid]
            h._inner = None
            h.replica = None
            self.recovery_log.append(
                f"t={t:.6f} shed {r.rid} (dispatch retries exhausted)")
            if self.tracer is not None:
                self.tracer.shed(r, t, DispatchFailed.__name__)
            return None
        delay = self.retry_backoff * (2 ** (r.n_dispatch_retries - 1))
        heapq.heappush(self._pending, (t + delay, next(self._seq), r))
        return None

    def _dispatch(self) -> Optional[int]:
        when, _, r = heapq.heappop(self._pending)
        return self._route(r, when=max(when, r.arrival))

    # --------------------------------------------------- failure / recovery
    def _restart(self, r: Request, now: float) -> None:
        """Reset an unwound request so the scheduler re-serves exactly
        the UNSTREAMED remainder. Tokens the dead incarnation already
        produced are folded into the prompt — real ids on the engine,
        per-request sentinel ids on the simulator (negative, so they can
        only ever prefix-match this request's own later restarts) — so
        context-length math (`prompt_len + tokens_out`) stays exact and
        the finish check yields precisely the remaining tokens.
        `tokens_out` is incarnation-local; `tokens_salvaged` carries the
        delivered count across incarnations. First/last token stamps
        survive: TTFT measures the FIRST incarnation's first token, and
        `max_tbt` honestly spans the outage gap."""
        produced = r.tokens_out
        if produced:
            if r.generated:
                r.prompt = list(r.prompt or []) \
                    + [int(t) for t in r.generated[:produced]]
                r.generated = []
            elif r.prompt is not None:
                base = r.tokens_salvaged
                r.prompt = list(r.prompt) \
                    + [-(base + k + 1) for k in range(produced)]
            r.prompt_len += produced
            r.output_len -= produced
            r.tokens_salvaged += produced
        r.tokens_out = 0
        r.phase = Phase.QUEUED
        r.prefill_start = -1.0
        r.prefill_done = 0
        r.n_chunks = 0
        r.cached_prompt_len = 0
        r.n_redispatched += 1
        self.redispatch_priorities.append(r.priority)
        self.registry.inc("redispatches")
        if self.tracer is not None:
            self.tracer.instant("redispatch", now, rid=r.rid,
                                n=r.n_redispatched,
                                salvaged=r.tokens_salvaged)

    def kill(self, i: int, reason: str = "manual",
             at: Optional[float] = None) -> None:
        """Hard-fail replica i NOW. Its parked arrivals return to the
        cluster heap untouched (nothing was in flight); every live
        request it owns is salvaged (streamed tokens preserved on the
        cluster handle), unwound through the cancel machinery (all its
        KV freed — the replica's memory is gone), restarted in place and
        re-dispatched through the routing policy. No request is lost or
        duplicated. The dead core is sanitizer-checked back to baseline;
        template homes pointing at the corpse are dropped. Idempotent on
        an already-dead replica. `at` is the virtual time the failure
        occurred (a fault event's stamp — the poll that delivers it may
        run a step later; the unwind is stamped at the failure)."""
        if not self.alive[i]:
            return
        now = self.clock() if at is None else at
        s = self.sessions[i]
        core = s.core
        self.alive[i] = False
        self.draining[i] = False
        self.registry.inc("replica_kills")
        self.recovery_log.append(f"t={now:.6f} kill r{i} ({reason})")
        if self.tracer is not None:
            self.tracer.instant("kill", now, replica=i, reason=reason)
        self._template_home = {a: j for a, j in self._template_home.items()
                               if j != i}
        parked = [e[2] for e in s._pending]
        s._pending.clear()
        live = list(core.waiting) + list(core.prefilling) \
            + list(core.decoding) + list(core.paused)
        for r in live:
            h = self.handles[r.rid]
            h._salvage()
            s.backend.cancel(r)
            # a kill is not a user cancel: pull it back out of the
            # replica's cancelled list before re-dispatching
            core.cancelled.remove(r)
            s.handles.pop(r.rid, None)
            self._restart(r, now)
        for r in parked:
            h = self.handles[r.rid]
            h._inner = None
            h.replica = None
            s.handles.pop(r.rid, None)
            heapq.heappush(self._pending,
                           (max(r.arrival, now), next(self._seq), r))
        # post-unwind: the dead core must be back at pool baseline
        # before anything is re-dispatched (S1-S9)
        if core.sanitizer is not None:
            core.sanitizer.check(core, full=True)
            core.sanitizer.check_recovery_baseline(core)
        for r in live:
            self._route(r, when=now)
        if live or parked:
            self.recovery_log.append(
                f"t={now:.6f} unwound r{i}: {len(live)} live "
                f"re-dispatched, {len(parked)} parked re-parked")

    def revive(self, i: int, at: Optional[float] = None) -> None:
        """Bring a killed replica back COLD at virtual time `at` (the
        shared clock when omitted): its clock advances to the recovery
        time and its prefix cache is dropped — replica memory did not
        survive the failure. New arrivals route to it immediately.
        Idempotent on a live replica."""
        if self.alive[i]:
            return
        t = self.clock() if at is None else at
        s = self.sessions[i]
        s.backend.advance_to(max(t, s.backend.clock()))
        s.core.bm.drop_cache()
        self.alive[i] = True
        self.draining[i] = False
        self.registry.inc("replica_recoveries")
        self.recovery_log.append(f"t={t:.6f} revive r{i}")
        if self.tracer is not None:
            self.tracer.instant("revive", t, replica=i)

    def drain_replica(self, i: int) -> None:
        """Gracefully retire replica i: new work routes elsewhere,
        queued-but-unstarted work re-routes immediately (it holds no
        KV), in-flight work finishes normally, and the replica is
        marked dead once empty (`_retire_drained`). No-op on a dead or
        already-draining replica."""
        if not self.alive[i] or self.draining[i]:
            return
        now = self.clock()
        self.draining[i] = True
        self.recovery_log.append(f"t={now:.6f} drain r{i}")
        if self.tracer is not None:
            self.tracer.instant("drain", now, replica=i)
        s = self.sessions[i]
        core = s.core
        parked = [e[2] for e in s._pending]
        s._pending.clear()
        queued = list(core.waiting)
        for r in queued:
            core.waiting.remove(r)
            core.release(r)   # drop any memoized plan on the old core
            h = self.handles[r.rid]
            h._inner = None
            h.replica = None
            s.handles.pop(r.rid, None)
        for r in parked:
            h = self.handles[r.rid]
            h._inner = None
            h.replica = None
            s.handles.pop(r.rid, None)
            heapq.heappush(self._pending,
                           (max(r.arrival, now), next(self._seq), r))
        for r in queued:
            self._route(r, when=now)
        self._retire_drained()

    def _retire_drained(self) -> None:
        for i, s in enumerate(self.sessions):
            if self.draining[i] and self.alive[i] \
                    and s.next_event_time() is None:
                self.alive[i] = False
                self.draining[i] = False
                self.recovery_log.append(
                    f"t={self.clock():.6f} retired r{i} (drained)")

    def _liveness_kill(self, nxt, frozen, now: float) -> bool:
        """Missing-heartbeat detection: a replica with due work whose
        clock lags the shared clock by more than `liveness_timeout`
        while frozen (fault-wedged or backpressure-stalled — the
        detector cannot tell, which is the point) is declared dead."""
        for t, i in nxt:
            if t is None or not self.alive[i] or i not in frozen:
                continue
            if now - t > self.liveness_timeout:
                self.kill(i, reason=f"liveness ({now - t:.3f}s silent)")
                return True
        return False

    # -------------------------------------------------------------- step
    def step(self) -> bool:
        """One cluster event: dispatch the next arrival if it precedes
        every live replica's next event, else step the replica whose
        next event is earliest. A replica that cannot progress (wedged
        on backpressure) is dropped from the event comparison — its
        frozen clock must stall neither the other replicas NOR the
        dispatch of parked arrivals they could serve; a dispatch that
        lands on a stalled replica revives it. Returns False only when
        nothing can progress anywhere.

        With a `FaultPlan` attached, due fault events fire first (on
        the shared clock, and again up to a parked arrival's stamp
        before it dispatches, so an arrival never outruns a fault);
        fault-wedged replicas are excluded from stepping until virtual
        time passes their window; a slowdown window stretches the
        stepped replica's elapsed time by the injected factor; and with
        `liveness_timeout` set, frozen replicas that lag too far are
        killed (detection + recovery, not oracle cleanup)."""
        if self.faults is not None:
            self.faults.poll(self, self.clock())
        self._retire_drained()
        stalled: set = set()
        while True:
            now = self.clock()
            nxt = [(s.next_event_time(), i)
                   for i, s in enumerate(self.sessions)]
            wedged: set = set()
            if self.faults is not None:
                wedged = {i for t, i in nxt
                          if t is not None and self.alive[i]
                          and self.faults.is_wedged(i, now)}
            if self.liveness_timeout is not None \
                    and self._liveness_kill(nxt, stalled | wedged, now):
                stalled.clear()
                continue
            busy = sorted((t, i) for t, i in nxt
                          if t is not None and self.alive[i]
                          and i not in stalled and i not in wedged)
            if self._pending and \
                    (not busy or self._pending[0][0] <= busy[0][0]):
                if self.faults is not None:
                    # fire any fault due before this arrival dispatches
                    self.faults.poll(self, max(now, self._pending[0][0]))
                i = self._dispatch()
                if i is None:
                    # dispatch failed: the request was parked for a
                    # backed-off retry or shed — observable progress,
                    # so hand control back (drain/stream re-evaluate
                    # instead of spinning the retries inside one step)
                    return True
                stalled.discard(i)
                continue
            if not busy:
                if self.faults is not None:
                    if wedged:
                        # only frozen replicas hold events: advance
                        # virtual time to the earliest wedge end so the
                        # outage window passes
                        j = min(wedged,
                                key=lambda k: self.faults.wedge_end(k))
                        self.sessions[j].backend.advance_to(
                            self.faults.wedge_end(j))
                        continue
                    if self.faults.has_pending():
                        # idle but faults still scheduled (e.g. a revive
                        # that unblocks parked retries): jump to them
                        self.faults.poll(
                            self, self.faults.next_event_time())
                        self._retire_drained()
                        continue
                return False
            t_i, i = busy[0]
            before = self.sessions[i].backend.clock()
            if self.sessions[i].step():
                st = self.stats[i]
                st.steps += 1
                st.peak_occupancy = max(st.peak_occupancy,
                                        self.sessions[i].core.occupancy())
                if self.faults is not None:
                    f = self.faults.slow_factor(i, before)
                    if f > 1.0:
                        after = self.sessions[i].backend.clock()
                        self.sessions[i].backend.advance_to(
                            after + (f - 1.0) * max(after - before, 0.0))
                return True
            stalled.add(i)

    @property
    def backlog(self) -> int:
        """Requests accepted but not yet prefilling, cluster-wide."""
        return len(self._pending) + sum(s.backlog for s in self.sessions)

    # ------------------------------------------------------------ stream
    def stream(self, handle: ClusterHandle) -> Iterator[int]:
        """Per-token iterator for one request; every replica advances
        normally while streaming."""
        while True:
            yield from handle.take_new()
            if handle.done:
                return
            if not self.step():
                if self._shed_blocked():
                    continue
                raise self._wedged()

    # ------------------------------------------------------------ cancel
    def cancel(self, handle: ClusterHandle) -> bool:
        """Cancel a live request. Dispatched requests route to the
        owning replica session (live unwind or replica-heap removal —
        the PR 4 path); an undispatched request is unwound entirely here
        (no replica ever saw it). Idempotent; False once done."""
        if handle._inner is not None:
            return handle._inner.cancel()
        return cancel_parked(self._pending, handle.request, self.clock(),
                             self.cancelled)

    # -------------------------------------------------------------- reap
    def reap(self, handle: ClusterHandle) -> Optional[Request]:
        """Release a done request's retained state, cluster-wide: the
        cluster handle plus the owning replica session's handle and
        done/cancelled/shed entry (or the cluster's own, for requests
        that never dispatched or were shed at dispatch)."""
        if not handle.done:
            return None
        r = handle.request
        self.handles.pop(r.rid, None)
        if handle._inner is not None:
            return self.sessions[handle.replica].reap(handle._inner)
        if r in self.cancelled:
            self.cancelled.remove(r)
        if r in self.shed:
            self.shed.remove(r)
        return r

    # ------------------------------------------------------------- drain
    def _wedged(self) -> AdmissionImpossible:
        for s in self.sessions:
            if s.core.waiting:
                return s.core.wedged_error()
        return AdmissionImpossible(
            "cluster wedged with no waiting request (bug)")

    def _shed_blocked(self) -> bool:
        """Graceful degradation at the cluster level: when nothing can
        progress anywhere, ask each replica to shed its blocking head
        (typed reason) rather than wedging — only with `shed_overload`
        on (`SchedulerCore.shed_blocked` is a no-op otherwise)."""
        now = self.clock()
        for s in self.sessions:
            if s.core.shed_blocked(now):
                return True
        return False

    def drain(self) -> List[Request]:
        """Run every replica empty; returns the finished requests in
        finish-time order (a cluster of 1 returns exactly the bare
        session's list — replica done-lists are already time-ordered
        and the sort is stable)."""
        while self._pending or \
                any(s.next_event_time() is not None for s in self.sessions):
            if not self.step():
                if self._shed_blocked():
                    continue
                raise self._wedged()
        for s in self.sessions:
            s.backend.finish()
        out = [r for s in self.sessions for r in s.core.done]
        out.sort(key=lambda r: r.finish_time)
        return out

    # ----------------------------------------------------------- metrics
    def metrics(self) -> SimMetrics:
        """Pooled metrics across replicas (simulator backends): raw
        latency series are concatenated BEFORE means/percentiles —
        averaging per-replica p99s would understate the tail whenever
        replicas are imbalanced, which is exactly what routing policies
        differ on. Requests cancelled or shed before dispatch are
        counted here (no replica ever saw them), as are the cluster's
        fault-tolerance counters."""
        m = SimMetrics.merge([s.backend.metrics() for s in self.sessions])
        m.n_cancelled += len(self.cancelled)
        m.n_shed += len(self.shed)
        m.shed_priorities += [r.priority for r in self.shed]
        m.shed_reasons += [r.shed_reason or "" for r in self.shed]
        m.n_retries += self.n_retries
        m.retry_priorities += list(self.retry_priorities)
        m.n_redispatched += len(self.redispatch_priorities)
        m.redispatch_priorities += list(self.redispatch_priorities)
        m.n_replica_kills += self.n_kills
        m.n_replica_recoveries += self.n_recoveries
        m.shed_rids += [r.rid for r in self.shed]
        return m

    def snapshot(self) -> dict:
        """One flat Prometheus-shaped counter/gauge snapshot for the
        whole fleet: each replica core's registry stamped
        ``replica="i"``, plus the cluster's own counters."""
        return MetricsRegistry.merge_snapshots(
            *[s.core.registry.snapshot(replica=str(i))
              for i, s in enumerate(self.sessions)],
            self.registry.snapshot())

    def perfetto(self) -> dict:
        """Chrome-trace JSON over every replica's event stream plus the
        fleet track, merged on the shared virtual clock. Requires the
        backends to have been built with `ServeConfig.trace`."""
        if self.tracer is None:
            raise ValueError(
                "tracing is off: construct the backends with "
                "ServeConfig(trace=True) to record events")
        from repro.obs.export import perfetto_trace
        tracers = [s.core.tracer for s in self.sessions] + [self.tracer]
        labels = [f"replica {i}" for i in range(self.n_replicas)] \
            + ["cluster"]
        return perfetto_trace(tracers, labels)

    def write_trace(self, path: str) -> None:
        import json
        with open(path, "w") as f:
            json.dump(self.perfetto(), f)

    # --------------------------------------------------------------- run
    def run(self, requests: List[Request]) -> List[Request]:
        """Batch convenience wrapper, mirroring the backends' run()."""
        for r in sorted(requests, key=lambda q: q.arrival):
            self.submit(r, arrival=r.arrival)
        return self.drain()
