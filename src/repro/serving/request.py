"""Request lifecycle and SLO metrics."""
from __future__ import annotations

import dataclasses
import enum
from typing import List, Optional


class Phase(enum.Enum):
    QUEUED = "queued"
    PREFILL = "prefill"
    DECODE = "decode"
    FINISHED = "finished"
    CANCELLED = "cancelled"          # unwound by ServingSession.cancel


@dataclasses.dataclass
class Request:
    rid: str
    prompt_len: int
    output_len: int                  # target generation length (EOS position)
    arrival: float = 0.0
    tpot_slo: float = 0.2            # seconds/token (paper Fig.8: 200 ms)
    ttft_slo: float = 3.0            # seconds (paper Fig.8: 3000 ms)
    prompt: Optional[list] = None    # token ids (real engine)

    phase: Phase = Phase.QUEUED
    prefill_start: float = -1.0
    first_token_time: float = -1.0   # TTFT reference point
    finish_time: float = -1.0
    tokens_out: int = 0
    decode_start: float = -1.0
    generated: List[int] = dataclasses.field(default_factory=list)

    # --- chunked-prefill progress (scheduler-owned) --------------------------
    prefill_done: int = 0            # prompt tokens whose KV is cached
    n_chunks: int = 0                # chunks this prefill was split into
    cached_prompt_len: int = 0       # prompt tokens served from the
    #                                  cross-request prefix cache (compute
    #                                  skipped; subset of prefill_done)

    @property
    def prefill_remaining(self) -> int:
        return max(self.prompt_len - self.prefill_done, 0)

    @property
    def prefill_complete(self) -> bool:
        return self.prefill_done >= self.prompt_len

    # --- derived metrics -----------------------------------------------------
    @property
    def ttft(self) -> float:
        return self.first_token_time - self.arrival

    @property
    def queuing_delay(self) -> float:
        return self.prefill_start - self.arrival

    @property
    def prefill_latency(self) -> float:
        return self.first_token_time - self.prefill_start

    @property
    def tpot(self) -> float:
        """Average time per output token after the first."""
        if self.tokens_out <= 1 or self.finish_time < 0:
            return 0.0
        return (self.finish_time - self.first_token_time) \
            / (self.tokens_out - 1)

    def current_tpot(self, now: float) -> float:
        """Running average time/token (paper: 'the current TPOT'),
        including waiting time between tokens."""
        if self.first_token_time < 0 or self.tokens_out <= 1:
            return 0.0
        return (now - self.first_token_time) / (self.tokens_out - 1)

    # --- scheduler state (paper Eq. 1) ---------------------------------------
    def t_past(self, now: float) -> float:
        """Decoding time already spent, incl. waiting between tokens."""
        if self.first_token_time < 0:
            return 0.0
        return now - self.first_token_time

    @property
    def n_past(self) -> int:
        return self.tokens_out

    def slo_violated(self) -> bool:
        if self.first_token_time >= 0 and self.ttft > self.ttft_slo:
            return True
        return self.tokens_out > 1 and self.tpot > self.tpot_slo
