"""Request lifecycle and SLO metrics."""
from __future__ import annotations

import dataclasses
import enum
from typing import List, Optional

from repro.core.units import Seconds, Tokens


class Phase(enum.Enum):
    """Request lifecycle states, shared by both backends."""
    QUEUED = "queued"
    PREFILL = "prefill"
    DECODE = "decode"
    PAUSED = "paused"                # preempted: KV parked on HOST, will
    #                                  resume losslessly (no recompute)
    FINISHED = "finished"
    CANCELLED = "cancelled"          # unwound by ServingSession.cancel
    SHED = "shed"                    # rejected under overload/fault
    #                                  (graceful degradation; reason in
    #                                  Request.shed_reason)


@dataclasses.dataclass
class Request:
    """One serving request plus its live scheduling state. SLO fields
    are in seconds; `priority`/`deadline` feed the `deadline` admission
    policy and the preemption controller (units in field comments)."""
    rid: str
    prompt_len: Tokens
    output_len: Tokens                  # target generation length (EOS position)
    arrival: Seconds = 0.0
    tpot_slo: Seconds = 0.2            # seconds/token (paper Fig.8: 200 ms)
    ttft_slo: Seconds = 3.0            # seconds (paper Fig.8: 3000 ms)
    prompt: Optional[list] = None    # token ids (real engine)
    priority: int = 0                # class rank; HIGHER preempts lower
    #                                  (0 = batch, 1 = interactive by
    #                                  convention). Only the 'deadline'
    #                                  admission policy and the preemption
    #                                  controller read it.
    deadline: Seconds = -1.0           # absolute first-token deadline
    #                                  (seconds on the virtual clock);
    #                                  < 0 derives arrival + ttft_slo

    phase: Phase = Phase.QUEUED
    prefill_start: Seconds = -1.0
    first_token_time: Seconds = -1.0   # TTFT reference point
    finish_time: Seconds = -1.0
    tokens_out: Tokens = 0
    decode_start: Seconds = -1.0
    generated: List[int] = dataclasses.field(default_factory=list)
    n_preempted: int = 0             # times this request was paused
    last_token_time: Seconds = -1.0    # stamp of the newest emitted token
    max_tbt: Seconds = 0.0             # widest gap between adjacent tokens

    # --- chunked-prefill progress (scheduler-owned) --------------------------
    prefill_done: Tokens = 0            # prompt tokens whose KV is cached
    n_chunks: int = 0                # chunks this prefill was split into
    cached_prompt_len: Tokens = 0       # prompt tokens served from the
    #                                  cross-request prefix cache (compute
    #                                  skipped; subset of prefill_done)

    # --- fault tolerance (cluster-owned) -------------------------------------
    shed_reason: Optional[str] = None  # AdmissionImpossible subclass name
    #                                    when phase is SHED
    n_redispatched: int = 0          # replica kills survived: each one
    #                                  folded the streamed tokens into the
    #                                  prompt and restarted the remainder
    tokens_salvaged: Tokens = 0         # tokens streamed by DEAD incarnations
    #                                  (already delivered; excluded from
    #                                  output_len, which counts down)
    n_dispatch_retries: int = 0      # transient dispatch failures retried

    @property
    def prefill_remaining(self) -> Tokens:
        return max(self.prompt_len - self.prefill_done, 0)

    @property
    def prefill_complete(self) -> bool:
        return self.prefill_done >= self.prompt_len

    # --- deadline / preemption ----------------------------------------------
    @property
    def effective_deadline(self) -> Seconds:
        """Absolute time the first token is due: the explicit `deadline`
        when set, else `arrival + ttft_slo` (so every request has one and
        the deadline policy degrades gracefully to TTFT-SLO ordering)."""
        return self.deadline if self.deadline >= 0.0 \
            else self.arrival + self.ttft_slo

    def deadline_met(self) -> bool:
        return self.first_token_time >= 0 \
            and self.first_token_time <= self.effective_deadline

    def note_token(self, now: Seconds) -> None:
        """Stamp a token emission at `now`; maintains the max inter-token
        gap (TBT) — the tail metric preemption trades against."""
        if self.last_token_time >= 0.0:
            self.max_tbt = max(self.max_tbt, now - self.last_token_time)
        self.last_token_time = now

    # --- derived metrics -----------------------------------------------------
    @property
    def ttft(self) -> Seconds:
        return self.first_token_time - self.arrival

    @property
    def queuing_delay(self) -> Seconds:
        return self.prefill_start - self.arrival

    @property
    def prefill_latency(self) -> Seconds:
        return self.first_token_time - self.prefill_start

    @property
    def tpot(self) -> float:
        """Average time per output token after the first."""
        if self.tokens_out <= 1 or self.finish_time < 0:
            return 0.0
        return (self.finish_time - self.first_token_time) \
            / (self.tokens_out - 1)

    def current_tpot(self, now: float) -> float:
        """Running average time/token (paper: 'the current TPOT'),
        including waiting time between tokens."""
        if self.first_token_time < 0 or self.tokens_out <= 1:
            return 0.0
        return (now - self.first_token_time) / (self.tokens_out - 1)

    # --- scheduler state (paper Eq. 1) ---------------------------------------
    def t_past(self, now: Seconds) -> Seconds:
        """Decoding time already spent, incl. waiting between tokens."""
        if self.first_token_time < 0:
            return 0.0
        return now - self.first_token_time

    @property
    def n_past(self) -> Tokens:
        return self.tokens_out

    def slo_violated(self) -> bool:
        if self.first_token_time >= 0 and self.ttft > self.ttft_slo:
            return True
        return self.tokens_out > 1 and self.tpot > self.tpot_slo
