"""Analytic serving cost model (paper Eq. 3 / Eq. 4) + hardware profiles.

Used by (a) the SLO-aware scheduler's admission decisions — exactly as the
paper does on real hardware — and (b) the discrete-event simulator that
reproduces the paper-scale figures on this CPU-only container.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig
from repro.core.units import Bytes, Seconds, Tokens, bytes_to_seconds, \
    tokens_to_bytes


@dataclasses.dataclass(frozen=True)
class HWProfile:
    """Accelerator price sheet the cost model reads (units per chip;
    bandwidths in bytes/s). Instances below (L20, A100, TPU_V5E, ...)
    are the `hw` argument of both serving backends."""
    name: str
    flops_per_s: float          # dense (bf16/fp16) peak per chip
    hbm_bw: float               # bytes/s per chip
    offload_bw: float           # bytes/s host<->device (PCIe or host DMA)
    ici_bw: float               # bytes/s per inter-chip link (collectives)
    mem_bytes: float            # device memory per chip
    f_precision: int = 2        # KV cache bytes per element

    def scaled(self, tp: int) -> "HWProfile":
        """Tensor-parallel aggregate view over `tp` chips. Offload bandwidth:
        the paper's testbed shares one PCIe link per two GPUs; we expose
        aggregate = offload_bw * tp (each shard moves its own KV slice)."""
        return dataclasses.replace(
            self, name=f"{self.name}x{tp}",
            flops_per_s=self.flops_per_s * tp,
            hbm_bw=self.hbm_bw * tp,
            offload_bw=self.offload_bw * tp,
            mem_bytes=self.mem_bytes * tp)


# NVIDIA L20 (the paper's testbed): 119.5 TFLOP/s FP16, 864 GB/s GDDR6,
# 48 GB; PCIe Gen4 x16 shared by two GPUs -> ~16 GB/s effective per GPU.
L20 = HWProfile("L20", 119.5e12, 864e9, 16e9, 64e9, 48e9)

# TPU v5e (our deployment target).
TPU_V5E = HWProfile("TPUv5e", 197e12, 819e9, 100e9, 50e9, 16e9)

PROFILES = {"L20": L20, "TPUv5e": TPU_V5E}


@dataclasses.dataclass
class CostModel:
    """Analytic latency/size model (paper Eq.3 / Eq.4): prices prefill
    and decode steps from model shape + `HWProfile`, derated by
    achievable MFU/MBU. The simulator uses it to advance the clock; the
    scheduler uses it for admission budgets and preemption pricing."""
    cfg: ModelConfig
    hw: HWProfile
    alpha: float = 1.15         # Eq.3 empirical correction (profiling fudge)
    beta: float = 1.1           # Eq.4 empirical correction
    mfu_prefill: float = 0.55   # achievable fraction of peak in prefill
    mbu_decode: float = 0.70    # achievable fraction of HBM bw in decode

    # ------------------------------------------------------------------ Eq.3
    def prefill_time(self, seqlen: Tokens) -> Seconds:
        """T_prefill = alpha * seqlen * (2 n_param + 2 seqlen n_hidden)
        / FLOPs  (paper Eq. 3), with FLOPs derated by achievable MFU."""
        n_param = self.cfg.active_param_count()
        n_hidden = self.cfg.d_model
        flops = 2 * n_param + 2 * seqlen * n_hidden
        return self.alpha * seqlen * flops / (
            self.hw.flops_per_s * self.mfu_prefill)

    def chunk_prefill_time(self, chunk_len: Tokens,
                           prefix_len: Tokens) -> Seconds:
        """Eq.3 cost of prefilling tokens [prefix, prefix+chunk) given that
        `prefix_len` tokens are already cached (chunked prefill). The
        quadratic attention term is split so chunk costs telescope exactly:
        sum over a request's chunks == prefill_time(prompt_len), i.e.
        chunking never changes total prefill compute, only its placement."""
        if chunk_len <= 0:
            return 0.0
        n_param = self.cfg.active_param_count()
        n_hidden = self.cfg.d_model
        end = prefix_len + chunk_len
        flops = 2 * n_param * chunk_len \
            + 2 * n_hidden * (end * end - prefix_len * prefix_len)
        return self.alpha * flops / (self.hw.flops_per_s * self.mfu_prefill)

    # ------------------------------------------------------------------ Eq.4
    def kv_bytes(self, seqlen: Tokens, n_layers: int | None = None) -> Bytes:
        """KV bytes for `seqlen` tokens across `n_layers` attention layers
        (default: all of them). 2 * d_heads * n_heads * f_precision per
        token-layer, with GQA heads."""
        L = self.cfg.n_attention_layers() if n_layers is None else n_layers
        hd = self.cfg.resolved_head_dim
        per_token = int(2 * L * self.cfg.n_kv_heads * hd
                        * self.hw.f_precision)
        return tokens_to_bytes(seqlen, per_token)

    def offload_time(self, seqlen: Tokens, n_offload_layers: int) -> Seconds:
        """T_offload = beta * seqlen * 2 (L-x) d_heads n_heads f / BW."""
        return self.beta * bytes_to_seconds(
            self.kv_bytes(seqlen, n_offload_layers), self.hw.offload_bw)

    def min_retained_layers(self, seqlen: Tokens) -> int:
        """Smallest x with T_offload(L - x) <= T_prefill(seqlen) (paper
        §3.1.1): retain x layers on device, offload the rest fully hidden
        under prefill compute."""
        L = self.cfg.n_attention_layers()
        t_pre = self.prefill_time(seqlen)
        for x in range(0, L + 1):
            if self.offload_time(seqlen, L - x) <= t_pre:
                return x
        return L

    # ---------------------------------------------------------------- decode
    def decode_step_time(self, batch_size: int, avg_ctx: Tokens,
                         host_kv_bytes: Bytes = 0) -> Seconds:
        """One decode iteration for a running batch. Memory-bound: stream
        active params once + the batch's KV; `host_kv_bytes` of KV resident
        on the host streams over the offload link overlapped with compute
        (paper §4), so the step takes max(HBM-bound compute, host reload)."""
        p_bytes = self.cfg.active_param_count() * self.hw.f_precision
        kv_total = self.kv_bytes(avg_ctx) * batch_size
        t_hbm = (p_bytes + kv_total) / (self.hw.hbm_bw * self.mbu_decode)
        t_reload = host_kv_bytes / self.hw.offload_bw
        return max(t_hbm, t_reload)

    # ----------------------------------------------------------- mixed batch
    def mixed_step_time(self, prefill_chunk_time: Seconds, batch_size: int,
                        avg_ctx: Tokens, host_kv_bytes: Bytes = 0,
                        fused: bool = False) -> Seconds:
        """One iteration that batches prefill-chunk tokens WITH the decode
        tokens (chunked prefill). The chunk portion is FLOPs-bound, the
        decode portion HBM-bound — the iteration takes the max of the two,
        not the sum (this overlap is the mixed-batching win).

        The default arm models the TWO-CALL executor (chunk forward +
        decode forward): each call streams the weights itself, so the
        decode side bills params + KV. The `fused` arm models the single
        `mixed_step` forward: ONE weight stream per iteration — the decode
        tokens ride the chunk's parameter pass, so the decode side bills
        only its KV (and host reload) traffic. With no chunk in the
        iteration the fused step degenerates to a plain decode step (the
        params must stream for the decode batch either way)."""
        t_dec = self.decode_step_time(batch_size, avg_ctx, host_kv_bytes) \
            if batch_size > 0 else 0.0
        if not fused or batch_size <= 0 or prefill_chunk_time <= 0.0:
            return max(prefill_chunk_time, t_dec)
        kv_total = self.kv_bytes(avg_ctx) * batch_size
        t_kv = kv_total / (self.hw.hbm_bw * self.mbu_decode)
        t_reload = host_kv_bytes / self.hw.offload_bw
        return max(prefill_chunk_time, t_kv, t_reload)
