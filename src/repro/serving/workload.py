"""Workload generators for the serving benchmarks.

`sharegpt_like` mirrors the ShareGPT trace statistics the paper uses
(conversations collected from ChatGPT-3.5: prompt/output lengths 4-2.3k
tokens, heavy-tailed) without requiring the dataset download in this
offline container: lognormal lengths clipped to the paper's range.

`shared_prefix` generates the scenario class the prefix cache targets:
requests whose prompts share leading tokens (system prompts, multi-turn
chat, RAG templates). These requests carry REAL token-id lists in
`Request.prompt` — the content-addressed cache hashes them, in both the
simulator and the real engine.

`multi_tenant` generates the traffic class the cluster ROUTER targets:
per-tenant shared-prefix templates with bursty on-off arrivals and a
skewed (Zipf) tenant popularity, so prefix-affinity dispatch (keep a
tenant's template on one replica's cache) versus load-aware dispatch
(spread the burst) is a real trade-off rather than a tie.
"""
from __future__ import annotations

import random
from typing import List

from repro.serving.request import Request


def fixed_length(n: int, prompt_len: int, output_len: int, rate: float,
                 seed: int = 0, tpot_slo: float = 0.2, ttft_slo: float = 3.0
                 ) -> List[Request]:
    """Poisson arrivals at `rate` req/s with fixed prompt/output lengths
    (paper Fig. 1/4/5 methodology)."""
    rng = random.Random(seed)
    t = 0.0
    out = []
    for i in range(n):
        t += rng.expovariate(rate)
        out.append(Request(rid=f"r{i}", prompt_len=prompt_len,
                           output_len=output_len, arrival=t,
                           tpot_slo=tpot_slo, ttft_slo=ttft_slo))
    return out


def sharegpt_like(n: int, rate: float, seed: int = 0, tpot_slo: float = 0.2,
                  ttft_slo: float = 3.0, min_len: int = 4,
                  max_len: int = 2300) -> List[Request]:
    """Heavy-tailed prompt/output lengths in the ShareGPT range."""
    rng = random.Random(seed)
    t = 0.0
    out = []
    for i in range(n):
        t += rng.expovariate(rate)
        p = int(min(max(rng.lognormvariate(5.6, 1.1), min_len), max_len))
        o = int(min(max(rng.lognormvariate(5.1, 0.9), min_len), max_len))
        out.append(Request(rid=f"r{i}", prompt_len=p, output_len=o,
                           arrival=t, tpot_slo=tpot_slo, ttft_slo=ttft_slo))
    return out


def _toks(rng: random.Random, n: int, vocab: int) -> List[int]:
    return [rng.randrange(vocab) for _ in range(n)]


def shared_prefix(n: int, rate: float, scenario: str = "system_prompt",
                  share_ratio: float = 0.5, prompt_len: int = 1024,
                  output_len: int = 128, n_templates: int = 4,
                  turns_per_conv: int = 4, vocab_size: int = 32000,
                  seed: int = 0, tpot_slo: float = 0.2,
                  ttft_slo: float = 3.0,
                  unique_frac: float = 0.0) -> List[Request]:
    """Poisson arrivals whose prompts share leading tokens.

    scenario:
      'system_prompt'  every request = one global system prompt of
                       ~share_ratio * prompt_len tokens + a unique user
                       suffix (heavy shared-system-prompt traffic);
      'rag_template'   `n_templates` instruction/context templates; each
                       request picks one (so sharing splits across
                       template groups) + a unique query suffix;
      'multi_turn'     conversations of `turns_per_conv` requests; turn k's
                       prompt extends turn k-1's full context (prompt +
                       answer + new user turn), so the shareable prefix
                       GROWS within a conversation. share_ratio sets the
                       first turn's length relative to prompt_len.

    All scenarios draw the unique suffix length ~ +-25% around its mean so
    block-boundary effects (partial tails, COW) are exercised.

    `unique_frac` mixes in cache-cold traffic: that fraction of requests
    (system_prompt / rag_template scenarios) get a fully unique prompt
    with NO shared prefix — the workload class the prefix-aware admission
    policy must serve without starving (its aging bound)."""
    rng = random.Random(seed)
    out: List[Request] = []
    t = 0.0

    def _arrive() -> float:
        nonlocal t
        t += rng.expovariate(rate)
        return t

    if scenario in ("system_prompt", "rag_template"):
        shared_len = max(int(prompt_len * share_ratio), 1)
        k = 1 if scenario == "system_prompt" else max(n_templates, 1)
        prefixes = [_toks(rng, shared_len, vocab_size) for _ in range(k)]
        for i in range(n):
            sfx_mean = max(prompt_len - shared_len, 1)
            sfx = max(1, int(sfx_mean * rng.uniform(0.75, 1.25)))
            # NB: no RNG draw when unique_frac is 0 — the default stream
            # (and every committed benchmark artifact) stays bit-stable
            if unique_frac > 0.0 and rng.random() < unique_frac:
                prompt = _toks(rng, shared_len + sfx, vocab_size)
            else:
                prompt = prefixes[rng.randrange(k)] \
                    + _toks(rng, sfx, vocab_size)
            out.append(Request(
                rid=f"r{i}", prompt_len=len(prompt), output_len=output_len,
                arrival=_arrive(), tpot_slo=tpot_slo, ttft_slo=ttft_slo,
                prompt=prompt))
        return out

    if scenario == "multi_turn":
        i = 0
        first_len = max(int(prompt_len * share_ratio), 1)
        while i < n:
            ctx = _toks(rng, first_len, vocab_size)
            for _ in range(min(turns_per_conv, n - i)):
                turn = max(
                    1, int((prompt_len - first_len)
                           / max(turns_per_conv - 1, 1)
                           * rng.uniform(0.75, 1.25)))
                prompt = list(ctx) + _toks(rng, turn, vocab_size)
                out.append(Request(
                    rid=f"r{i}", prompt_len=len(prompt),
                    output_len=output_len, arrival=_arrive(),
                    tpot_slo=tpot_slo, ttft_slo=ttft_slo, prompt=prompt))
                # next turn continues from this prompt + its answer
                ctx = prompt + _toks(rng, output_len, vocab_size)
                i += 1
        out.sort(key=lambda r: r.arrival)
        return out

    raise ValueError(f"unknown shared-prefix scenario: {scenario!r}")


def multi_tenant(n: int, rate: float, n_tenants: int = 4,
                 share_ratio: float = 0.5, prompt_len: int = 1024,
                 output_len: int = 128, zipf_s: float = 1.0,
                 burst_on: float = 4.0, burst_off: float = 8.0,
                 burst_cv: float = 2.0, vocab_size: int = 32000,
                 seed: int = 0, tpot_slo: float = 0.2,
                 ttft_slo: float = 3.0,
                 interactive_tenants: int = 0,
                 interactive_ttft_slo: float = 0.0,
                 interactive_tpot_slo: float = 0.0) -> List[Request]:
    """Per-tenant shared-prefix templates under bursty on-off arrivals.

    Each of `n_tenants` tenants owns one template prefix of
    ~share_ratio * prompt_len tokens; a tenant's request = its template
    + a unique suffix (+-25% length jitter, as in `shared_prefix`, so
    partial tails and COW are exercised). Tenant popularity is Zipf:
    tenant k gets weight (k+1)^-zipf_s of the aggregate `rate`, so a
    couple of templates are HOT — the traffic that makes
    `prefix_affinity` concentrate (and need its spillover) while
    `least_loaded` scatters the hot template across every replica's
    cache.

    Arrivals are an independent on-off (interrupted-Poisson) process
    per tenant: exponential ON periods of mean `burst_on` seconds at
    `burst_cv / duty` x the tenant's average rate, separated by
    exponential OFF gaps with no arrivals. The OFF mean is stretched to
    `burst_cv * (burst_on + burst_off) - burst_on`, which exactly
    cancels the burst_cv intensity boost — the long-run average stays
    at the tenant's share of `rate` while burst_cv only sharpens the
    peak-to-mean ratio. Bursts from different tenants overlap at
    random, so instantaneous cluster load swings well above and below
    its mean — queueing behaviour a load-oblivious router cannot see.
    `burst_cv=1` with `burst_off=0` degenerates to plain Poisson per
    tenant.

    Priority classes (the KV-competition workload, arXiv 2503.13773):
    the first `interactive_tenants` tenants are the INTERACTIVE class —
    their requests carry `priority=1` and the (typically tighter)
    `interactive_ttft_slo` / `interactive_tpot_slo` (0 = inherit the
    batch values); the remaining tenants are the BATCH class at
    `priority=0`. Because the hot Zipf tenants come first, making them
    interactive reproduces the paper-style mix: a latency-critical hot
    class competing for KV with long-running batch traffic. The default
    `interactive_tenants=0` draws the identical RNG stream as before
    (class assignment is by tenant index, never by a draw), so every
    committed artifact stays bit-stable.

    Tenant quotas are apportioned by largest remainder so exactly `n`
    requests are returned, in arrival order, rids `t{tenant}r{i}` so
    tests and benchmarks can group by tenant."""
    if n_tenants < 1:
        raise ValueError("multi_tenant needs at least one tenant")
    rng = random.Random(seed)
    shared_len = max(int(prompt_len * share_ratio), 1)
    templates = [_toks(rng, shared_len, vocab_size)
                 for _ in range(n_tenants)]
    weights = [(k + 1) ** -zipf_s for k in range(n_tenants)]
    wsum = sum(weights)
    # largest-remainder apportionment: sum(quota) == n exactly
    quota = [n * w / wsum for w in weights]
    n_per = [int(q) for q in quota]
    for k in sorted(range(n_tenants), key=lambda k: quota[k] - n_per[k],
                    reverse=True)[: n - sum(n_per)]:
        n_per[k] += 1
    cv = max(burst_cv, 1.0)
    off_mean = cv * (burst_on + burst_off) - burst_on \
        if burst_on + burst_off > 0 else 0.0
    out: List[Request] = []
    for k in range(n_tenants):
        interactive = k < interactive_tenants
        k_prio = 1 if interactive else 0
        k_ttft = interactive_ttft_slo \
            if interactive and interactive_ttft_slo > 0 else ttft_slo
        k_tpot = interactive_tpot_slo \
            if interactive and interactive_tpot_slo > 0 else tpot_slo
        tenant_rate = rate * weights[k] / wsum
        # arrivals only flow during ON windows, at burst_cv/duty x the
        # tenant's average rate; the stretched OFF mean above restores
        # the long-run average to exactly tenant_rate
        duty = burst_on / (burst_on + burst_off) \
            if burst_on + burst_off > 0 else 1.0
        on_rate = tenant_rate * cv / max(duty, 1e-9)
        n_k = n_per[k]
        t = rng.expovariate(1.0 / max(off_mean, 1e-9)) \
            if off_mean > 0 else 0.0
        i = 0
        while i < n_k:
            burst_end = t + rng.expovariate(1.0 / max(burst_on, 1e-9))
            while i < n_k:
                t += rng.expovariate(on_rate)
                if t >= burst_end:
                    t = burst_end
                    break
                sfx_mean = max(prompt_len - shared_len, 1)
                sfx = max(1, int(sfx_mean * rng.uniform(0.75, 1.25)))
                prompt = templates[k] + _toks(rng, sfx, vocab_size)
                out.append(Request(
                    rid=f"t{k}r{i}", prompt_len=len(prompt),
                    output_len=output_len, arrival=t,
                    tpot_slo=k_tpot, ttft_slo=k_ttft, prompt=prompt,
                    priority=k_prio))
                i += 1
            t += rng.expovariate(1.0 / max(off_mean, 1e-9)) \
                if off_mean > 0 else 0.0
    out.sort(key=lambda r: (r.arrival, r.rid))
    return out
