"""Workload generators for the serving benchmarks.

`sharegpt_like` mirrors the ShareGPT trace statistics the paper uses
(conversations collected from ChatGPT-3.5: prompt/output lengths 4-2.3k
tokens, heavy-tailed) without requiring the dataset download in this
offline container: lognormal lengths clipped to the paper's range.

`shared_prefix` generates the scenario class the prefix cache targets:
requests whose prompts share leading tokens (system prompts, multi-turn
chat, RAG templates). These requests carry REAL token-id lists in
`Request.prompt` — the content-addressed cache hashes them, in both the
simulator and the real engine.
"""
from __future__ import annotations

import random
from typing import List

from repro.serving.request import Request


def fixed_length(n: int, prompt_len: int, output_len: int, rate: float,
                 seed: int = 0, tpot_slo: float = 0.2, ttft_slo: float = 3.0
                 ) -> List[Request]:
    """Poisson arrivals at `rate` req/s with fixed prompt/output lengths
    (paper Fig. 1/4/5 methodology)."""
    rng = random.Random(seed)
    t = 0.0
    out = []
    for i in range(n):
        t += rng.expovariate(rate)
        out.append(Request(rid=f"r{i}", prompt_len=prompt_len,
                           output_len=output_len, arrival=t,
                           tpot_slo=tpot_slo, ttft_slo=ttft_slo))
    return out


def sharegpt_like(n: int, rate: float, seed: int = 0, tpot_slo: float = 0.2,
                  ttft_slo: float = 3.0, min_len: int = 4,
                  max_len: int = 2300) -> List[Request]:
    """Heavy-tailed prompt/output lengths in the ShareGPT range."""
    rng = random.Random(seed)
    t = 0.0
    out = []
    for i in range(n):
        t += rng.expovariate(rate)
        p = int(min(max(rng.lognormvariate(5.6, 1.1), min_len), max_len))
        o = int(min(max(rng.lognormvariate(5.1, 0.9), min_len), max_len))
        out.append(Request(rid=f"r{i}", prompt_len=p, output_len=o,
                           arrival=t, tpot_slo=tpot_slo, ttft_slo=ttft_slo))
    return out


def _toks(rng: random.Random, n: int, vocab: int) -> List[int]:
    return [rng.randrange(vocab) for _ in range(n)]


def shared_prefix(n: int, rate: float, scenario: str = "system_prompt",
                  share_ratio: float = 0.5, prompt_len: int = 1024,
                  output_len: int = 128, n_templates: int = 4,
                  turns_per_conv: int = 4, vocab_size: int = 32000,
                  seed: int = 0, tpot_slo: float = 0.2,
                  ttft_slo: float = 3.0,
                  unique_frac: float = 0.0) -> List[Request]:
    """Poisson arrivals whose prompts share leading tokens.

    scenario:
      'system_prompt'  every request = one global system prompt of
                       ~share_ratio * prompt_len tokens + a unique user
                       suffix (heavy shared-system-prompt traffic);
      'rag_template'   `n_templates` instruction/context templates; each
                       request picks one (so sharing splits across
                       template groups) + a unique query suffix;
      'multi_turn'     conversations of `turns_per_conv` requests; turn k's
                       prompt extends turn k-1's full context (prompt +
                       answer + new user turn), so the shareable prefix
                       GROWS within a conversation. share_ratio sets the
                       first turn's length relative to prompt_len.

    All scenarios draw the unique suffix length ~ +-25% around its mean so
    block-boundary effects (partial tails, COW) are exercised.

    `unique_frac` mixes in cache-cold traffic: that fraction of requests
    (system_prompt / rag_template scenarios) get a fully unique prompt
    with NO shared prefix — the workload class the prefix-aware admission
    policy must serve without starving (its aging bound)."""
    rng = random.Random(seed)
    out: List[Request] = []
    t = 0.0

    def _arrive() -> float:
        nonlocal t
        t += rng.expovariate(rate)
        return t

    if scenario in ("system_prompt", "rag_template"):
        shared_len = max(int(prompt_len * share_ratio), 1)
        k = 1 if scenario == "system_prompt" else max(n_templates, 1)
        prefixes = [_toks(rng, shared_len, vocab_size) for _ in range(k)]
        for i in range(n):
            sfx_mean = max(prompt_len - shared_len, 1)
            sfx = max(1, int(sfx_mean * rng.uniform(0.75, 1.25)))
            # NB: no RNG draw when unique_frac is 0 — the default stream
            # (and every committed benchmark artifact) stays bit-stable
            if unique_frac > 0.0 and rng.random() < unique_frac:
                prompt = _toks(rng, shared_len + sfx, vocab_size)
            else:
                prompt = prefixes[rng.randrange(k)] \
                    + _toks(rng, sfx, vocab_size)
            out.append(Request(
                rid=f"r{i}", prompt_len=len(prompt), output_len=output_len,
                arrival=_arrive(), tpot_slo=tpot_slo, ttft_slo=ttft_slo,
                prompt=prompt))
        return out

    if scenario == "multi_turn":
        i = 0
        first_len = max(int(prompt_len * share_ratio), 1)
        while i < n:
            ctx = _toks(rng, first_len, vocab_size)
            for _ in range(min(turns_per_conv, n - i)):
                turn = max(
                    1, int((prompt_len - first_len)
                           / max(turns_per_conv - 1, 1)
                           * rng.uniform(0.75, 1.25)))
                prompt = list(ctx) + _toks(rng, turn, vocab_size)
                out.append(Request(
                    rid=f"r{i}", prompt_len=len(prompt),
                    output_len=output_len, arrival=_arrive(),
                    tpot_slo=tpot_slo, ttft_slo=ttft_slo, prompt=prompt))
                # next turn continues from this prompt + its answer
                ctx = prompt + _toks(rng, output_len, vocab_size)
                i += 1
        out.sort(key=lambda r: r.arrival)
        return out

    raise ValueError(f"unknown shared-prefix scenario: {scenario!r}")
