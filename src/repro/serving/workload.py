"""Workload generators for the serving benchmarks.

`sharegpt_like` mirrors the ShareGPT trace statistics the paper uses
(conversations collected from ChatGPT-3.5: prompt/output lengths 4-2.3k
tokens, heavy-tailed) without requiring the dataset download in this
offline container: lognormal lengths clipped to the paper's range.
"""
from __future__ import annotations

import random
from typing import List

from repro.serving.request import Request


def fixed_length(n: int, prompt_len: int, output_len: int, rate: float,
                 seed: int = 0, tpot_slo: float = 0.2, ttft_slo: float = 3.0
                 ) -> List[Request]:
    """Poisson arrivals at `rate` req/s with fixed prompt/output lengths
    (paper Fig. 1/4/5 methodology)."""
    rng = random.Random(seed)
    t = 0.0
    out = []
    for i in range(n):
        t += rng.expovariate(rate)
        out.append(Request(rid=f"r{i}", prompt_len=prompt_len,
                           output_len=output_len, arrival=t,
                           tpot_slo=tpot_slo, ttft_slo=ttft_slo))
    return out


def sharegpt_like(n: int, rate: float, seed: int = 0, tpot_slo: float = 0.2,
                  ttft_slo: float = 3.0, min_len: int = 4,
                  max_len: int = 2300) -> List[Request]:
    """Heavy-tailed prompt/output lengths in the ShareGPT range."""
    rng = random.Random(seed)
    t = 0.0
    out = []
    for i in range(n):
        t += rng.expovariate(rate)
        p = int(min(max(rng.lognormvariate(5.6, 1.1), min_len), max_len))
        o = int(min(max(rng.lognormvariate(5.1, 0.9), min_len), max_len))
        out.append(Request(rid=f"r{i}", prompt_len=p, output_len=o,
                           arrival=t, tpot_slo=tpot_slo, ttft_slo=ttft_slo))
    return out
