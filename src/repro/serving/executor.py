"""Real JAX executor for the serving engine: paged KV pools + jitted steps.

Physical layout follows the paper's §4: ONE pooled tensor per memory tier
(device / host), shared by all layers — `(num_blocks, block_size, 2, KV, hd)`
— so any physical block can hold any (request, layer) slice; logical
placement lives in the block manager. Each pool carries ONE extra physical
block (`trash_block`) that the block manager never hands out: padded batch
rows scatter their garbage KV there, which is what lets every jitted entry
point run on shape-bucketed (power-of-two padded) batches.

Bucketed-shape contract: `prefill` pads the prompt buffer, `decode` the
batch width R, and `mixed_step` the chunk rows Tc / chunk segments Sc /
decode width Rb / output rows Sb — all to power-of-two buckets — while
block-table widths round to 8-block granularity; steady-state serving
triggers zero retraces. Every novel jit signature is counted in
`jit_retraces` and logged.

Decoder-only families (dense / moe) — the families the paper evaluates.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import logging
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.models import build_model, layers
from repro.models.model import _mask_pad_logits
from repro.obs.registry import MetricsRegistry

log = logging.getLogger(__name__)

# query-tile granularity of the fused mixed step: every chunk segment's
# tokens are padded to a multiple of TQ so a query tile never straddles
# two segments. 32 covers the default chunk budget in ONE tile — the ref
# backend gathers a segment's KV once per tile, and the Pallas kernel
# amortizes its block chase over the whole tile — at the cost of up to
# TQ-1 padded rows of extra (cheap) weight-stream compute per chunk
MIXED_TQ = 32


def _round_up(n, m):
    return -(-n // m) * m


def _bucket(n: int, lo: int = 1) -> int:
    """Smallest power-of-two >= n (and >= lo) — the jit shape bucket."""
    b = lo
    while b < n:
        b *= 2
    return b


@dataclasses.dataclass
class MixedChunk:
    """One prefill chunk riding the fused mixed step."""
    tokens: List[int]        # chunk token ids
    offset: int              # absolute position of tokens[0] (= prefill_done)
    tables: List[List[int]]  # per-layer LIVE block ids — only the
    #                          ceil((offset + len(tokens)) / BS) blocks that
    #                          hold valid KV, never the full allocation
    tiers: List[bool]        # per-layer: True = blocks live in the HOST pool


@dataclasses.dataclass
class MixedDecode:
    """One decode token riding the fused mixed step."""
    token: int               # last generated token (the step's input)
    ctx: int                 # tokens already cached; KV grows to ctx + 1
    tables: List[List[int]]  # per-layer DEVICE block ids


class PagedExecutor:
    """Owns the physical KV pools (device + host buffers, paged in
    `block_size`-token blocks) and runs model forwards against them:
    batched prefill, paged decode, chunked prefill, and the fused
    `mixed_step`. Pure mechanism — which blocks a request may touch is
    decided upstream by `SchedulerCore`/`LayerwiseBlockManager`."""

    def __init__(self, cfg: ModelConfig, params, num_device_blocks: int,
                 num_host_blocks: int, block_size: int, rng=None):
        assert cfg.family in ("dense", "moe"), cfg.family
        self.cfg = cfg
        self.model = build_model(cfg)
        self.params = params if params is not None else self.model.init(
            rng if rng is not None else jax.random.PRNGKey(0))
        hd = cfg.resolved_head_dim
        dt = jnp.dtype(cfg.dtype)
        self.block_size = block_size
        self.num_device_blocks = num_device_blocks
        self.num_host_blocks = num_host_blocks
        # +1: the trash block (id == num_*_blocks) absorbing padded rows'
        # scatter writes; the block manager never allocates it and no
        # block table with kv_len > 0 ever reads it
        self.device_pool = jnp.zeros(
            (num_device_blocks + 1, block_size, 2, cfg.n_kv_heads, hd), dt)
        self.host_pool = jnp.zeros(
            (num_host_blocks + 1, block_size, 2, cfg.n_kv_heads, hd), dt)
        self._decode_fn = jax.jit(self._paged_decode,
                                  donate_argnames=("dpool",))
        self._prefill_fn = jax.jit(
            functools.partial(self.model.prefill, dropless=True),
            static_argnames=())
        # retrace accounting: every novel (entry point, shape bucket)
        # signature is one XLA compile mid-serving — the bucketing above
        # exists to keep these counters flat in steady state. Counts
        # live in the obs registry; the owning engine swaps in the
        # core's registry so one snapshot() carries both.
        self.registry = MetricsRegistry()
        self._jit_sigs: set = set()

    @property
    def jit_retraces(self) -> collections.Counter:
        """Retrace counts per entry point (registry-backed Counter —
        the historical attribute shape)."""
        return self.registry.counter_view("jit_retraces", "fn")

    def _note_trace(self, fn: str, sig: tuple) -> None:
        if (fn, sig) not in self._jit_sigs:
            self._jit_sigs.add((fn, sig))
            self.registry.inc("jit_retraces", fn=fn)
            log.info("jit retrace #%d for %s%s",
                     int(self.registry.get("jit_retraces", fn=fn)),
                     fn, sig)

    # -------------------------------------------------------------- prefill
    def prefill(self, prompt: List[int], pad_to: int):
        """Run one request's prefill (B=1). `pad_to` is bucketed to the
        next power of two so novel prompt lengths reuse a compiled shape.
        Returns (next_token, k_layers, v_layers) with shapes
        (L, S_bucket, KV, hd); only the first len(prompt) positions are
        valid (callers slice what they need)."""
        S = len(prompt)
        pad_to = _bucket(pad_to, 16)
        self._note_trace("prefill", (pad_to,))
        toks = np.zeros((1, pad_to), np.int32)
        toks[0, :S] = prompt
        batch = {"tokens": jnp.asarray(toks),
                 "prompt_len": jnp.asarray([S], jnp.int32)}
        cache = self.model.init_cache(1, pad_to, self.cfg.dtype)
        logits, cache = self._prefill_fn(self.params, batch, cache)
        next_tok = int(jnp.argmax(logits[0]))
        k = cache["k"][:, 0]  # (L, S_bucket, KV, hd)
        v = cache["v"][:, 0]
        return next_tok, k, v

    # ---------------------------------------------------------- pool writes
    @functools.partial(jax.jit, static_argnums=0, donate_argnums=1)
    def _scatter_layer(self, pool, block_ids, k, v):
        """Write one layer's KV (S_pad, KV, hd) into `pool` blocks.
        block_ids: (nb,) int32; S_pad == nb * block_size."""
        nb = block_ids.shape[0]
        BS = pool.shape[1]
        kr = k.reshape(nb, BS, *k.shape[1:]).astype(pool.dtype)
        vr = v.reshape(nb, BS, *v.shape[1:]).astype(pool.dtype)
        kv = jnp.stack([kr, vr], axis=2)  # (nb, BS, 2, KV, hd)
        return pool.at[block_ids].set(kv)

    def write_layer(self, tier: str, block_ids: List[int], k, v):
        ids = jnp.asarray(block_ids, jnp.int32)
        S_pad = len(block_ids) * self.block_size
        k = k[:S_pad]
        v = v[:S_pad]
        if tier == "device":
            self.device_pool = self._scatter_layer(self.device_pool, ids, k, v)
        else:
            self.host_pool = self._scatter_layer(self.host_pool, ids, k, v)

    @functools.partial(jax.jit, static_argnums=0, donate_argnums=1)
    def _scatter_slice(self, pool, blk_ids, offs, k, v):
        """Write C tokens of one layer's KV into per-token (block, offset)
        slots — the partial-block append used by chunked prefill."""
        pool = pool.at[blk_ids, offs, 0].set(k.astype(pool.dtype))
        return pool.at[blk_ids, offs, 1].set(v.astype(pool.dtype))

    def write_layer_slice(self, tier: str, block_ids: List[int],
                          token_offset: int, k, v):
        """Append one layer's chunk KV (C, KV, hd) into `block_ids` starting
        at absolute token `token_offset` (need not be block-aligned)."""
        C = k.shape[0]
        pos = np.arange(token_offset, token_offset + C)
        blk = jnp.asarray(np.asarray(block_ids, np.int32)
                          [pos // self.block_size])
        off = jnp.asarray(pos % self.block_size, jnp.int32)
        if tier == "device":
            self.device_pool = self._scatter_slice(
                self.device_pool, blk, off, k, v)
        else:
            self.host_pool = self._scatter_slice(
                self.host_pool, blk, off, k, v)

    def gather_layer(self, tier: str, block_ids: List[int], kv_valid=None):
        """Dense (nb*BS, KV, hd) K and V views of one layer's block list —
        the contiguous prefix buffer legacy (two-call) chunked prefill and
        prefix-cache COW reads attend against. With `kv_valid` set, only
        the ceil(kv_valid / BS) blocks holding live tokens are physically
        gathered; the remaining rows come back zero (callers mask them via
        kv_len anyway), turning an O(allocated) copy into O(valid)."""
        pool = self.device_pool if tier == "device" else self.host_pool
        nb = len(block_ids)
        live = nb if kv_valid is None else min(
            _round_up(kv_valid, self.block_size) // self.block_size, nb)
        gathered = pool[jnp.asarray(block_ids[:live], jnp.int32)]
        k = gathered[:, :, 0].reshape(live * self.block_size, *pool.shape[3:])
        v = gathered[:, :, 1].reshape(live * self.block_size, *pool.shape[3:])
        if live < nb:
            pad = [(0, (nb - live) * self.block_size), (0, 0), (0, 0)]
            k = jnp.pad(k, pad)
            v = jnp.pad(v, pad)
        return k, v

    @functools.partial(jax.jit, static_argnums=0, donate_argnums=2)
    def _copy_blocks(self, src, dst, src_ids, dst_ids):
        return dst.at[dst_ids].set(src[src_ids])

    @functools.partial(jax.jit, static_argnums=0, donate_argnums=1)
    def _copy_blocks_within(self, pool, src_ids, dst_ids):
        """Same-pool copy (prefix-cache COW): a separate jit so the pool
        can still be donated — passing one buffer as both src and dst of
        `_copy_blocks` would alias a donated input."""
        return pool.at[dst_ids].set(pool[src_ids])

    def copy_blocks(self, src_tier: str, dst_tier: str, src_ids, dst_ids):
        """Physical block copy between (or within) tiers: d2h/h2d
        transfers and d2d copy-on-write duplication."""
        si = jnp.asarray(src_ids, jnp.int32)
        di = jnp.asarray(dst_ids, jnp.int32)
        if src_tier == dst_tier:
            if src_tier == "device":
                self.device_pool = self._copy_blocks_within(
                    self.device_pool, si, di)
            else:
                self.host_pool = self._copy_blocks_within(
                    self.host_pool, si, di)
            return
        src = self.device_pool if src_tier == "device" else self.host_pool
        if dst_tier == "device":
            self.device_pool = self._copy_blocks(src, self.device_pool, si, di)
        else:
            self.host_pool = self._copy_blocks(src, self.host_pool, si, di)

    # ------------------------------------------------------- chunked prefill
    @functools.partial(jax.jit, static_argnums=0)
    def _chunk_forward(self, params, tokens, kbuf, vbuf, offset, kv_valid):
        """One prefill chunk at absolute token `offset` — the LEGACY
        (two-call) chunk path. tokens: (C,) int32; kbuf/vbuf: (L, S_buf,
        KV, hd) dense prefix buffers gathered from the pools (rows >=
        offset ignored). Causal masking runs against the cached prefix via
        q_offset; kv_valid = offset + C masks the tail. Returns
        (last-position logits, k_chunk, v_chunk) with chunk KV shaped
        (L, C, KV, hd) for the caller to append into the pools. The fused
        path (`mixed_step`) replaces this with attention straight over the
        pools."""
        cfg = self.cfg
        C = tokens.shape[0]
        x = params["embed"][tokens][None]               # (1, C, d)
        positions = offset + jnp.arange(C)[None]        # (1, C)
        if cfg.pos_emb == "mrope":
            positions = jnp.broadcast_to(positions[None], (3, 1, C))
        ks_out, vs_out = [], []
        for l in range(cfg.n_layers):
            lp = jax.tree.map(lambda a, _l=l: a[_l], params["layers"])
            h = layers.apply_norm(cfg, lp["attn_norm"], x)
            q, k, v = layers.qkv_proj(cfg, lp["attn"], h)
            q = layers.apply_rope(cfg, q, positions)
            k = layers.apply_rope(cfg, k, positions)
            kb = jax.lax.dynamic_update_slice(
                kbuf[l], k[0].astype(kbuf.dtype), (offset, 0, 0))
            vb = jax.lax.dynamic_update_slice(
                vbuf[l], v[0].astype(vbuf.dtype), (offset, 0, 0))
            o = ops.flash_attention(q, kb[None], vb[None], causal=True,
                                    kv_len=kv_valid.reshape(1),
                                    q_offset=offset)
            x = x + layers.attn_out(cfg, lp["attn"], o)
            h = layers.apply_norm(cfg, lp["mlp_norm"], x)
            if cfg.family == "moe":
                from repro.models import moe as moe_mod
                f, _ = moe_mod.moe_ffn(cfg, lp["moe"], h, dropless=True)
            else:
                f = layers.mlp(cfg, lp["mlp"], h)
            x = x + f
            ks_out.append(k[0])
            vs_out.append(v[0])
        x = layers.apply_norm(cfg, params["final_norm"], x)
        w = (params["embed"].T if cfg.tie_embeddings
             else params["lm_head"])
        logits = _mask_pad_logits(cfg, x[0, -1] @ w)
        return logits, jnp.stack(ks_out), jnp.stack(vs_out)

    def prefill_chunk(self, chunk: List[int], offset: int, kbuf, vbuf):
        """Run `chunk` prompt tokens starting at `offset`. Returns
        (logits, k_chunk, v_chunk); logits stay on-device (async) — the
        caller argmaxes them only on a request's FINAL chunk, so
        intermediate chunks never force a host sync."""
        self._note_trace("chunk", (len(chunk), kbuf.shape[1]))
        return self._chunk_forward(
            self.params, jnp.asarray(chunk, jnp.int32), kbuf, vbuf,
            jnp.asarray(offset, jnp.int32),
            jnp.asarray(offset + len(chunk), jnp.int32))

    # ----------------------------------------------------------- fused step
    @functools.partial(jax.jit, static_argnums=(0, 18),
                       donate_argnums=(16, 17))
    def _mixed_forward(self, params, tokens, q_pos, off, blk_dev, blk_host,
                       c_seg, c_qpos, c_kvlens, c_tables, c_tier, d_tables,
                       d_kvlens, sample_idx, is_chunk, dpool, hpool,
                       has_host):
        """ONE forward for a whole serving iteration: prefill-chunk tokens
        and decode tokens ride the same flat batch, so each layer's
        weights stream exactly once. Per layer: project QKV for all T
        tokens, scatter the new KV into the pool(s) at per-token
        (block, offset) slots, then attend straight over the pool — no
        dense prefix gather, no staging buffer. The flat batch is
        [chunk part (Tc rows, segment-padded to the query tile) |
        decode part (Rb rows, one per sequence)]: chunk rows go through
        the paged-prefill kernel, decode rows through the (unpadded)
        paged decode kernel — two attention calls but ONE weight stream,
        which is where the two-call executor paid twice.

        tokens/q_pos/off: (T,) flat batch (T = Tc + Rb); blk_dev/blk_host:
        (L, T) scatter targets (trash block for rows that don't write that
        tier). Chunk part: c_seg/c_qpos (Tc,), c_kvlens (Sc,), c_tables
        (L, Sc, MAXBc), c_tier (L, Sc) host-resident flags. Decode part:
        d_tables (L, Rb, MAXBd), d_kvlens (Rb,) cached tokens (attends
        ctx+1 after the in-step scatter). sample_idx: (Sb,) flat position
        each output row samples; is_chunk selects pad-vocab masking to
        mirror the two-call paths bit-for-bit. Returns
        (next_tokens (Sb,), dpool, hpool)."""
        cfg = self.cfg
        Tc = c_seg.shape[0]
        Rb = d_kvlens.shape[0]
        x = params["embed"][tokens][None]               # (1, T, d)
        positions = q_pos[None].astype(jnp.int32)       # (1, T)
        if cfg.pos_emb == "mrope":
            positions = jnp.broadcast_to(
                positions[None], (3, 1, tokens.shape[0]))
        for l in range(cfg.n_layers):
            lp = jax.tree.map(lambda a, _l=l: a[_l], params["layers"])
            h = layers.apply_norm(cfg, lp["attn_norm"], x)
            q, k, v = layers.qkv_proj(cfg, lp["attn"], h)
            q = layers.apply_rope(cfg, q, positions)
            k = layers.apply_rope(cfg, k, positions)
            dpool = dpool.at[blk_dev[l], off, 0].set(
                k[0].astype(dpool.dtype))
            dpool = dpool.at[blk_dev[l], off, 1].set(
                v[0].astype(dpool.dtype))
            if has_host:
                hpool = hpool.at[blk_host[l], off, 0].set(
                    k[0].astype(hpool.dtype))
                hpool = hpool.at[blk_host[l], off, 1].set(
                    v[0].astype(hpool.dtype))
            parts = []
            if Tc:
                parts.append(ops.paged_prefill(
                    q[0, :Tc], dpool, c_tables[l], c_seg, c_qpos, c_kvlens,
                    host_pool=hpool if has_host else None,
                    tier=c_tier[l] if has_host else None, tq=MIXED_TQ))
            if Rb:
                parts.append(ops.paged_attention(
                    q[0, Tc:], dpool, d_tables[l], d_kvlens + 1))
            o = jnp.concatenate(parts) if len(parts) > 1 else parts[0]
            x = x + layers.attn_out(cfg, lp["attn"], o[None])
            h = layers.apply_norm(cfg, lp["mlp_norm"], x)
            if cfg.family == "moe":
                from repro.models import moe as moe_mod
                f, _ = moe_mod.moe_ffn(cfg, lp["moe"], h, dropless=True)
            else:
                f = layers.mlp(cfg, lp["mlp"], h)
            x = x + f
        x = layers.apply_norm(cfg, params["final_norm"], x)
        w = (params["embed"].T if cfg.tie_embeddings
             else params["lm_head"])
        feats = x[0][sample_idx]                        # (Sb, d)
        logits = feats @ w
        # chunk samples mask pad-vocab logits (as _chunk_forward does);
        # decode samples stay raw (as _paged_decode does)
        logits = jnp.where(is_chunk[:, None],
                           _mask_pad_logits(cfg, logits), logits)
        return jnp.argmax(logits, axis=-1), dpool, hpool

    def mixed_step(self, chunks: List[MixedChunk],
                   decodes: List[MixedDecode]) -> np.ndarray:
        """Run one fused iteration: all prefill chunks + the decode batch
        in one forward (one weight stream). Chunk KV and decode KV are
        scattered into the pools inside the step; attention reads the
        pools directly. Shapes are power-of-two bucketed (chunk rows Tc,
        chunk segments Sc, decode width Rb, output rows Sb; table widths
        round to 8 blocks) with padded rows writing the trash block, so
        steady state reuses compiled signatures. Returns the
        (n_chunks + n_decodes,) argmax'd next tokens (chunk rows are only
        meaningful for a request's final chunk)."""
        TQ = MIXED_TQ
        BS = self.block_size
        L = self.cfg.n_layers
        n_c, n_d = len(chunks), len(decodes)
        assert n_c + n_d > 0, "mixed_step needs at least one segment"
        pads = [_round_up(len(c.tokens), TQ) for c in chunks]
        Tc = _bucket(sum(pads), TQ) if n_c else 0
        Sc = _bucket(n_c) if n_c else 0
        Rb = _bucket(n_d) if n_d else 0
        Sb = _bucket(n_c + n_d)
        T = Tc + Rb
        MAXBc = _round_up(max((len(c.tables[0]) for c in chunks),
                              default=1), 8) if n_c else 0
        MAXBd = _round_up(max((len(d.tables[0]) for d in decodes),
                              default=1), 8) if n_d else 0

        tokens = np.zeros(T, np.int32)
        q_pos = np.zeros(T, np.int32)
        off = np.zeros(T, np.int32)
        blk_dev = np.full((L, T), self.num_device_blocks, np.int32)  # trash
        blk_host = np.full((L, T), self.num_host_blocks, np.int32)   # trash
        c_seg = np.full(Tc, max(Sc - 1, 0), np.int32)
        c_tables = np.zeros((L, Sc, MAXBc), np.int32)
        c_tier = np.zeros((L, Sc), bool)
        c_kvlens = np.zeros(Sc, np.int32)
        d_tables = np.full((L, Rb, MAXBd), self.num_device_blocks, np.int32)
        d_kvlens = np.zeros(Rb, np.int32)
        sample_idx = np.zeros(Sb, np.int32)
        is_chunk = np.zeros(Sb, bool)

        t0 = 0
        for i, c in enumerate(chunks):
            C = len(c.tokens)
            tokens[t0:t0 + C] = c.tokens
            q_pos[t0:t0 + pads[i]] = c.offset + np.arange(pads[i])
            c_seg[t0:t0 + pads[i]] = i
            pos = c.offset + np.arange(C)
            off[t0:t0 + C] = pos % BS
            nb = len(c.tables[0])
            for l in range(L):
                lblk = np.asarray(c.tables[l], np.int32)
                c_tables[l, i, :nb] = lblk
                c_tier[l, i] = c.tiers[l]
                dst = blk_host if c.tiers[l] else blk_dev
                dst[l, t0:t0 + C] = lblk[pos // BS]
            c_kvlens[i] = c.offset + C
            sample_idx[i] = t0 + C - 1
            is_chunk[i] = True
            t0 += pads[i]
        # chunk-part tail tiles: contiguous positions (a Pallas query
        # tile's base + row arithmetic must stay valid); they map to the
        # last chunk segment slot (a kv_len=0 dummy when Sc > n_c), write
        # only trash, and their outputs are discarded
        q_pos[t0:Tc] = np.arange(Tc - t0)
        for j, d in enumerate(decodes):
            t = Tc + j
            tokens[t] = d.token
            q_pos[t] = d.ctx
            off[t] = d.ctx % BS
            nb = len(d.tables[0])
            for l in range(L):
                d_tables[l, j, :nb] = d.tables[l]
                blk_dev[l, t] = d.tables[l][d.ctx // BS]
            d_kvlens[j] = d.ctx
            sample_idx[n_c + j] = t
        has_host = bool(c_tier.any())
        self._note_trace("mixed", (Tc, Sc, Rb, Sb, MAXBc, MAXBd, has_host))
        toks_out, self.device_pool, self.host_pool = self._mixed_forward(
            self.params, jnp.asarray(tokens), jnp.asarray(q_pos),
            jnp.asarray(off), jnp.asarray(blk_dev), jnp.asarray(blk_host),
            jnp.asarray(c_seg), jnp.asarray(q_pos[:Tc]),
            jnp.asarray(c_kvlens), jnp.asarray(c_tables),
            jnp.asarray(c_tier), jnp.asarray(d_tables),
            jnp.asarray(d_kvlens), jnp.asarray(sample_idx),
            jnp.asarray(is_chunk), self.device_pool, self.host_pool,
            has_host)
        return np.asarray(toks_out)[:n_c + n_d]

    # --------------------------------------------------------------- decode
    def _paged_decode(self, params, tokens, tables, kv_lens, dpool):
        """tokens: (R,) int32; tables: (L, R, MAXB) device block ids;
        kv_lens: (R,) tokens already cached. Returns (logits, dpool)."""
        cfg = self.cfg
        BS = self.block_size
        R = tokens.shape[0]
        x = params["embed"][tokens][:, None]  # (R,1,d)
        positions = kv_lens[:, None]  # new token's absolute position
        if cfg.pos_emb == "mrope":
            positions = jnp.broadcast_to(positions[None], (3, R, 1))
        r_idx = jnp.arange(R)
        cur_block = kv_lens // BS
        cur_off = kv_lens % BS
        for l in range(cfg.n_layers):
            lp = jax.tree.map(lambda a, _l=l: a[_l], params["layers"])
            h = layers.apply_norm(cfg, lp["attn_norm"], x)
            q, k, v = layers.decode_self_attention(
                cfg, lp["attn"], h, None, None, None, positions)
            # scatter the new token's KV into its block
            blk = tables[l][r_idx, cur_block]  # (R,)
            dpool = dpool.at[blk, cur_off, 0].set(
                k[:, 0].astype(dpool.dtype))
            dpool = dpool.at[blk, cur_off, 1].set(
                v[:, 0].astype(dpool.dtype))
            o = ops.paged_attention(q[:, 0], dpool, tables[l], kv_lens + 1)
            x = x + layers.attn_out(cfg, lp["attn"], o[:, None])
            h = layers.apply_norm(cfg, lp["mlp_norm"], x)
            if cfg.family == "moe":
                from repro.models import moe as moe_mod
                f, _ = moe_mod.moe_ffn(cfg, lp["moe"], h, dropless=True)
            else:
                f = layers.mlp(cfg, lp["mlp"], h)
            x = x + f
        x = layers.apply_norm(cfg, params["final_norm"], x)
        w = (params["embed"].T if cfg.tie_embeddings
             else params["lm_head"])
        return (x[:, 0] @ w), dpool

    def decode(self, tokens: List[int], tables: np.ndarray,
               kv_lens: List[int]) -> List[int]:
        """One decode iteration. tables: (L, R, MAXB) int32 into the DEVICE
        pool (caller guarantees residency). The batch width R is padded to
        a power-of-two bucket and the table width MAXB to 8-block
        granularity (pow2 doubling would waste up to 2x gather traffic on
        the ref backend; 8 blocks bounds the waste while retracing at most
        once per 8 blocks of context growth) — padded rows carry
        trash-block tables (kv_len 0), so novel batch shapes reuse
        compiled signatures instead of retracing mid-serving."""
        R = len(tokens)
        L, _, maxb = tables.shape
        Rb = _bucket(R)
        MAXBb = _round_up(max(maxb, 1), 8)
        self._note_trace("decode", (Rb, MAXBb))
        toks = np.zeros(Rb, np.int32)
        toks[:R] = tokens
        lens = np.zeros(Rb, np.int32)
        lens[:R] = kv_lens
        tab = np.full((L, Rb, MAXBb), self.num_device_blocks, np.int32)
        tab[:, :R, :maxb] = tables
        logits, self.device_pool = self._decode_fn(
            self.params, jnp.asarray(toks), jnp.asarray(tab),
            jnp.asarray(lens), self.device_pool)
        return [int(t) for t in jnp.argmax(logits[:R], axis=-1)]
