"""Real JAX executor for the serving engine: paged KV pools + jitted steps.

Physical layout follows the paper's §4: ONE pooled tensor per memory tier
(device / host), shared by all layers — `(num_blocks, block_size, 2, KV, hd)`
— so any physical block can hold any (request, layer) slice; logical
placement lives in the block manager.

Decoder-only families (dense / moe) — the families the paper evaluates.
"""
from __future__ import annotations

import functools
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.models import build_model, layers
from repro.models.model import _mask_pad_logits


def _round_up(n, m):
    return -(-n // m) * m


class PagedExecutor:
    def __init__(self, cfg: ModelConfig, params, num_device_blocks: int,
                 num_host_blocks: int, block_size: int, rng=None):
        assert cfg.family in ("dense", "moe"), cfg.family
        self.cfg = cfg
        self.model = build_model(cfg)
        self.params = params if params is not None else self.model.init(
            rng if rng is not None else jax.random.PRNGKey(0))
        hd = cfg.resolved_head_dim
        dt = jnp.dtype(cfg.dtype)
        self.block_size = block_size
        self.device_pool = jnp.zeros(
            (num_device_blocks, block_size, 2, cfg.n_kv_heads, hd), dt)
        self.host_pool = jnp.zeros(
            (num_host_blocks, block_size, 2, cfg.n_kv_heads, hd), dt)
        self._decode_fn = jax.jit(self._paged_decode,
                                  donate_argnames=("dpool",))
        self._prefill_fn = jax.jit(
            functools.partial(self.model.prefill, dropless=True),
            static_argnames=())

    # -------------------------------------------------------------- prefill
    def prefill(self, prompt: List[int], pad_to: int):
        """Run one request's prefill (B=1). Returns (next_token,
        k_layers, v_layers) with shapes (L, S_pad, KV, hd); only the first
        len(prompt) positions are valid."""
        S = len(prompt)
        toks = np.zeros((1, pad_to), np.int32)
        toks[0, :S] = prompt
        batch = {"tokens": jnp.asarray(toks),
                 "prompt_len": jnp.asarray([S], jnp.int32)}
        cache = self.model.init_cache(1, pad_to, self.cfg.dtype)
        logits, cache = self._prefill_fn(self.params, batch, cache)
        next_tok = int(jnp.argmax(logits[0]))
        k = cache["k"][:, 0]  # (L, S_pad, KV, hd)
        v = cache["v"][:, 0]
        return next_tok, k, v

    # ---------------------------------------------------------- pool writes
    @functools.partial(jax.jit, static_argnums=0, donate_argnums=1)
    def _scatter_layer(self, pool, block_ids, k, v):
        """Write one layer's KV (S_pad, KV, hd) into `pool` blocks.
        block_ids: (nb,) int32; S_pad == nb * block_size."""
        nb = block_ids.shape[0]
        BS = pool.shape[1]
        kr = k.reshape(nb, BS, *k.shape[1:]).astype(pool.dtype)
        vr = v.reshape(nb, BS, *v.shape[1:]).astype(pool.dtype)
        kv = jnp.stack([kr, vr], axis=2)  # (nb, BS, 2, KV, hd)
        return pool.at[block_ids].set(kv)

    def write_layer(self, tier: str, block_ids: List[int], k, v):
        ids = jnp.asarray(block_ids, jnp.int32)
        S_pad = len(block_ids) * self.block_size
        k = k[:S_pad]
        v = v[:S_pad]
        if tier == "device":
            self.device_pool = self._scatter_layer(self.device_pool, ids, k, v)
        else:
            self.host_pool = self._scatter_layer(self.host_pool, ids, k, v)

    @functools.partial(jax.jit, static_argnums=0, donate_argnums=1)
    def _scatter_slice(self, pool, blk_ids, offs, k, v):
        """Write C tokens of one layer's KV into per-token (block, offset)
        slots — the partial-block append used by chunked prefill."""
        pool = pool.at[blk_ids, offs, 0].set(k.astype(pool.dtype))
        return pool.at[blk_ids, offs, 1].set(v.astype(pool.dtype))

    def write_layer_slice(self, tier: str, block_ids: List[int],
                          token_offset: int, k, v):
        """Append one layer's chunk KV (C, KV, hd) into `block_ids` starting
        at absolute token `token_offset` (need not be block-aligned)."""
        C = k.shape[0]
        pos = np.arange(token_offset, token_offset + C)
        blk = jnp.asarray(np.asarray(block_ids, np.int32)
                          [pos // self.block_size])
        off = jnp.asarray(pos % self.block_size, jnp.int32)
        if tier == "device":
            self.device_pool = self._scatter_slice(
                self.device_pool, blk, off, k, v)
        else:
            self.host_pool = self._scatter_slice(
                self.host_pool, blk, off, k, v)

    def gather_layer(self, tier: str, block_ids: List[int]):
        """Dense (nb*BS, KV, hd) K and V views of one layer's block list —
        the contiguous prefix buffer a prefill chunk attends against."""
        pool = self.device_pool if tier == "device" else self.host_pool
        gathered = pool[jnp.asarray(block_ids, jnp.int32)]
        nb = len(block_ids)
        k = gathered[:, :, 0].reshape(nb * self.block_size, *pool.shape[3:])
        v = gathered[:, :, 1].reshape(nb * self.block_size, *pool.shape[3:])
        return k, v

    @functools.partial(jax.jit, static_argnums=0, donate_argnums=2)
    def _copy_blocks(self, src, dst, src_ids, dst_ids):
        return dst.at[dst_ids].set(src[src_ids])

    @functools.partial(jax.jit, static_argnums=0, donate_argnums=1)
    def _copy_blocks_within(self, pool, src_ids, dst_ids):
        """Same-pool copy (prefix-cache COW): a separate jit so the pool
        can still be donated — passing one buffer as both src and dst of
        `_copy_blocks` would alias a donated input."""
        return pool.at[dst_ids].set(pool[src_ids])

    def copy_blocks(self, src_tier: str, dst_tier: str, src_ids, dst_ids):
        """Physical block copy between (or within) tiers: d2h/h2d
        transfers and d2d copy-on-write duplication."""
        si = jnp.asarray(src_ids, jnp.int32)
        di = jnp.asarray(dst_ids, jnp.int32)
        if src_tier == dst_tier:
            if src_tier == "device":
                self.device_pool = self._copy_blocks_within(
                    self.device_pool, si, di)
            else:
                self.host_pool = self._copy_blocks_within(
                    self.host_pool, si, di)
            return
        src = self.device_pool if src_tier == "device" else self.host_pool
        if dst_tier == "device":
            self.device_pool = self._copy_blocks(src, self.device_pool, si, di)
        else:
            self.host_pool = self._copy_blocks(src, self.host_pool, si, di)

    # ------------------------------------------------------- chunked prefill
    @functools.partial(jax.jit, static_argnums=0)
    def _chunk_forward(self, params, tokens, kbuf, vbuf, offset, kv_valid):
        """One prefill chunk at absolute token `offset`. tokens: (C,) int32;
        kbuf/vbuf: (L, S_buf, KV, hd) dense prefix buffers gathered from the
        pools (rows >= offset ignored). Causal masking runs against the
        cached prefix via q_offset; kv_valid = offset + C masks the tail.
        Returns (last-position logits, k_chunk, v_chunk) with chunk KV
        shaped (L, C, KV, hd) for the caller to append into the pools."""
        cfg = self.cfg
        C = tokens.shape[0]
        x = params["embed"][tokens][None]               # (1, C, d)
        positions = offset + jnp.arange(C)[None]        # (1, C)
        if cfg.pos_emb == "mrope":
            positions = jnp.broadcast_to(positions[None], (3, 1, C))
        ks_out, vs_out = [], []
        for l in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[l], params["layers"])
            h = layers.apply_norm(cfg, lp["attn_norm"], x)
            q, k, v = layers.qkv_proj(cfg, lp["attn"], h)
            q = layers.apply_rope(cfg, q, positions)
            k = layers.apply_rope(cfg, k, positions)
            kb = jax.lax.dynamic_update_slice(
                kbuf[l], k[0].astype(kbuf.dtype), (offset, 0, 0))
            vb = jax.lax.dynamic_update_slice(
                vbuf[l], v[0].astype(vbuf.dtype), (offset, 0, 0))
            o = ops.flash_attention(q, kb[None], vb[None], causal=True,
                                    kv_len=kv_valid.reshape(1),
                                    q_offset=offset)
            x = x + layers.attn_out(cfg, lp["attn"], o)
            h = layers.apply_norm(cfg, lp["mlp_norm"], x)
            if cfg.family == "moe":
                from repro.models import moe as moe_mod
                f, _ = moe_mod.moe_ffn(cfg, lp["moe"], h, dropless=True)
            else:
                f = layers.mlp(cfg, lp["mlp"], h)
            x = x + f
            ks_out.append(k[0])
            vs_out.append(v[0])
        x = layers.apply_norm(cfg, params["final_norm"], x)
        w = (params["embed"].T if cfg.tie_embeddings
             else params["lm_head"])
        logits = _mask_pad_logits(cfg, x[0, -1] @ w)
        return logits, jnp.stack(ks_out), jnp.stack(vs_out)

    def prefill_chunk(self, chunk: List[int], offset: int, kbuf, vbuf):
        """Run `chunk` prompt tokens starting at `offset`. Returns
        (logits, k_chunk, v_chunk); logits stay on-device (async) — the
        caller argmaxes them only on a request's FINAL chunk, so
        intermediate chunks never force a host sync."""
        return self._chunk_forward(
            self.params, jnp.asarray(chunk, jnp.int32), kbuf, vbuf,
            jnp.asarray(offset, jnp.int32),
            jnp.asarray(offset + len(chunk), jnp.int32))

    # --------------------------------------------------------------- decode
    def _paged_decode(self, params, tokens, tables, kv_lens, dpool):
        """tokens: (R,) int32; tables: (L, R, MAXB) device block ids;
        kv_lens: (R,) tokens already cached. Returns (logits, dpool)."""
        cfg = self.cfg
        BS = self.block_size
        R = tokens.shape[0]
        x = params["embed"][tokens][:, None]  # (R,1,d)
        positions = kv_lens[:, None]  # new token's absolute position
        if cfg.pos_emb == "mrope":
            positions = jnp.broadcast_to(positions[None], (3, R, 1))
        r_idx = jnp.arange(R)
        cur_block = kv_lens // BS
        cur_off = kv_lens % BS
        for l in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[l], params["layers"])
            h = layers.apply_norm(cfg, lp["attn_norm"], x)
            q, k, v = layers.decode_self_attention(
                cfg, lp["attn"], h, None, None, None, positions)
            # scatter the new token's KV into its block
            blk = tables[l][r_idx, cur_block]  # (R,)
            dpool = dpool.at[blk, cur_off, 0].set(
                k[:, 0].astype(dpool.dtype))
            dpool = dpool.at[blk, cur_off, 1].set(
                v[:, 0].astype(dpool.dtype))
            o = ops.paged_attention(q[:, 0], dpool, tables[l], kv_lens + 1)
            x = x + layers.attn_out(cfg, lp["attn"], o[:, None])
            h = layers.apply_norm(cfg, lp["mlp_norm"], x)
            if cfg.family == "moe":
                from repro.models import moe as moe_mod
                f, _ = moe_mod.moe_ffn(cfg, lp["moe"], h, dropless=True)
            else:
                f = layers.mlp(cfg, lp["mlp"], h)
            x = x + f
        x = layers.apply_norm(cfg, params["final_norm"], x)
        w = (params["embed"].T if cfg.tie_embeddings
             else params["lm_head"])
        return (x[:, 0] @ w), dpool

    def decode(self, tokens: List[int], tables: np.ndarray,
               kv_lens: List[int]) -> List[int]:
        """One decode iteration. tables: (L, R, MAXB) int32 into the DEVICE
        pool (caller guarantees residency)."""
        logits, self.device_pool = self._decode_fn(
            self.params, jnp.asarray(tokens, jnp.int32),
            jnp.asarray(tables, jnp.int32),
            jnp.asarray(kv_lens, jnp.int32), self.device_pool)
        return [int(t) for t in jnp.argmax(logits, axis=-1)]
