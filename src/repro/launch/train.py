"""Training driver CLI.

    PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \
        --smoke --steps 200 --batch 8 --seq 128

--smoke uses the reduced config (CPU-runnable); the full config is intended
for the production mesh (see repro.launch.dryrun for the compile proof).
"""
from __future__ import annotations

import argparse
import dataclasses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--dtype", default="float32")
    ap.add_argument("--ckpt", default="")
    args = ap.parse_args()

    from repro.configs import get_config, get_smoke_config
    from repro.training.data import DataConfig
    from repro.training.optimizer import AdamWConfig
    from repro.training.train_loop import train

    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    cfg = dataclasses.replace(cfg, dtype=args.dtype)
    res = train(
        cfg, steps=args.steps,
        dc=DataConfig(batch_size=args.batch, seq_len=args.seq),
        opt=AdamWConfig(lr=args.lr, total_steps=args.steps,
                        warmup_steps=max(args.steps // 10, 5)),
        ckpt_path=args.ckpt or None)
    print(f"final loss {res.final_loss:.4f} "
          f"({res.tokens_per_s:.0f} tokens/s)")


if __name__ == "__main__":
    main()
