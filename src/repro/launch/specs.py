"""Input ShapeDtypeStructs + sharding rules for every (arch x shape x mesh).

`input_specs` mirrors the shannon/kernels pattern: weak-type-correct,
shardable stand-ins, zero device allocation. The modality frontends are
stubs per spec: audio/VLM entries receive precomputed frame/patch
embeddings of the right shape.
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig, SHAPES
from repro.launch.mesh import data_axes
from repro.models import build_model
from repro.training.optimizer import AdamWConfig, init_opt_state

# leaf-name -> which dim gets the 'model' axis
_LAST_DIM_MODEL = {
    "wq", "wk", "wv", "wg", "wu", "w1", "w_in", "w_up", "w_gates",
    "bq", "bk", "bv", "b1", "conv_w", "conv_b", "lm_head",
}
_PENULT_DIM_MODEL = {"wo", "wd", "w2", "w_out", "w_down"}
_EXPERT_SHARDED = {"we_gate", "we_up", "we_down"}  # expert axis -> 'model'
_REPLICATED = {
    "w", "b", "b2", "router", "A_log", "D", "dt_bias", "norm_w", "r_gates",
    "b_gates", "w_i", "w_f", "b_i", "b_f",
}


def _fit(mesh, spec: P, shape: tuple) -> P:
    """Drop axes whose extent does not divide the dim (pjit argument
    shardings require exact divisibility; oddball dims like vocab=49155
    fall back to replication on that dim)."""
    fixed = []
    for dim, entry in zip(shape, tuple(spec) + (None,) * len(shape)):
        if entry is None:
            fixed.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        fixed.append(entry if dim % size == 0 else None)
    return P(*fixed)


def _param_spec(path, leaf) -> P:
    name = None
    for entry in reversed(path):
        if hasattr(entry, "key"):
            name = entry.key
            break
    nd = leaf.ndim
    none = (None,) * nd
    if name == "embed":
        return P("model", None)
    if name in _REPLICATED or nd == 0:
        return P(*none)
    if name in _EXPERT_SHARDED:
        # stacked: (L, E, d, f) -> expert axis is -3
        spec = list(none)
        spec[-3] = "model"
        return P(*spec)
    if name in _LAST_DIM_MODEL:
        spec = list(none)
        spec[-1] = "model"
        return P(*spec)
    if name in _PENULT_DIM_MODEL:
        spec = list(none)
        if nd >= 2:
            spec[-2] = "model"
        return P(*spec)
    return P(*none)


def _add_fsdp(mesh, spec: P, shape: tuple, dp_axes: tuple) -> P:
    """2D sharding: put the data(+pod) axes on the largest still-unsharded
    divisible dim (ZeRO-3/FSDP-style weight sharding on GSPMD)."""
    if len(shape) < 2:
        return spec
    dp_size = 1
    for a in dp_axes:
        dp_size *= mesh.shape[a]
    entries = list(tuple(spec) + (None,) * (len(shape) - len(tuple(spec))))
    cands = [(d, i) for i, (d, e) in enumerate(zip(shape, entries))
             if e is None and d % dp_size == 0 and d >= dp_size]
    if not cands:
        return spec
    _, idx = max(cands)
    entries[idx] = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    return P(*entries)


def param_shardings(mesh, params_shape, fsdp: bool = False) -> Any:
    dp_axes = data_axes(mesh)

    def assign(path, leaf):
        spec = _fit(mesh, _param_spec(path, leaf), leaf.shape)
        if fsdp:
            spec = _add_fsdp(mesh, spec, leaf.shape, dp_axes)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(assign, params_shape)


def _cache_spec(mesh, name, leaf, dp, seq_sharded: bool) -> NamedSharding:
    nd = leaf.ndim
    none = [None] * nd
    if name in ("k", "v", "ck", "cv", "k_scale", "v_scale"):
        # (L, B, S, KV[, hd]): batch over dp; KV-cache sequence over 'model'
        # (flash-decoding-style partial softmax, GSPMD inserts the reduce);
        # int8 quantization scales shard exactly like their cache
        spec = none[:]
        spec[1] = dp
        if seq_sharded:
            spec[2] = "model"
        return NamedSharding(mesh, P(*spec))
    if name == "ssm_state":        # (n_sb, per_sb, B, H, N, P)
        return NamedSharding(mesh, P(None, None, dp, "model", None, None))
    if name == "conv":             # (n_sb, per_sb, B, K-1, C)
        return NamedSharding(mesh, P(None, None, dp, None, "model"))
    if name == "mC":               # (n_sb, n_m, B, H, hd, hd)
        return NamedSharding(mesh, P(None, None, dp, None, "model", None))
    if name in ("mn", "mm"):
        spec = none[:]
        spec[2] = dp
        return NamedSharding(mesh, P(*spec))
    if name in ("sc", "sn", "sm", "sh"):  # (n_sb, B, H, hd)
        return NamedSharding(mesh, P(None, dp, None, "model"))
    if name == "len":
        return NamedSharding(mesh, P(dp))
    return NamedSharding(mesh, P(*none))


def cache_shardings(mesh, cache_shape, batch: int, seq_sharded=True):
    dp_axes = data_axes(mesh)
    dp_size = 1
    for a in dp_axes:
        dp_size *= mesh.shape[a]
    dp = dp_axes if (batch >= dp_size and dp_axes) else None

    def assign(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else None
        s = _cache_spec(mesh, name, leaf, dp, seq_sharded)
        return NamedSharding(mesh, _fit(mesh, s.spec, leaf.shape))

    return jax.tree_util.tree_map_with_path(assign, cache_shape)


def batch_shardings(mesh, batch_shape, batch: int):
    dp_axes = data_axes(mesh)
    dp_size = 1
    for a in dp_axes:
        dp_size *= mesh.shape[a]
    dp = dp_axes if (batch >= dp_size and dp_axes) else None

    def assign(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else None
        nd = leaf.ndim
        if name == "mrope_pos":     # (3, B, S)
            spec = P(None, dp, None)
        else:
            s = [None] * nd
            if nd >= 1:
                s[0] = dp
            spec = P(*s)
        return NamedSharding(mesh, _fit(mesh, spec, leaf.shape))

    return jax.tree_util.tree_map_with_path(assign, batch_shape)


# ---------------------------------------------------------------------------
# input ShapeDtypeStructs per (arch, shape)
# ---------------------------------------------------------------------------

def train_batch_struct(cfg: ModelConfig, shp: InputShape) -> Dict[str, Any]:
    B, S = shp.global_batch, shp.seq_len
    i32 = jnp.int32
    batch = {"tokens": jax.ShapeDtypeStruct((B, S), i32),
             "labels": jax.ShapeDtypeStruct((B, S), i32)}
    if cfg.family == "vlm":
        batch["embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                               jnp.dtype(cfg.dtype))
        batch["mrope_pos"] = jax.ShapeDtypeStruct((3, B, S), i32)
    if cfg.family == "encdec":
        batch["enc_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder_len, cfg.d_model), jnp.dtype(cfg.dtype))
    return batch


def decode_cache_len(cfg: ModelConfig, shp: InputShape) -> int:
    """KV buffer length for a decode shape. long_500k requires
    sub-quadratic attention: dense/vlm/encdec/hybrid archs use their
    sliding-window variant (ring buffer of `sliding_window`)."""
    if shp.seq_len > 32768 and cfg.sliding_window:
        return cfg.sliding_window
    return shp.seq_len


def params_struct(cfg: ModelConfig):
    model = build_model(cfg)
    return model, jax.eval_shape(model.init, jax.random.PRNGKey(0))


def input_specs(cfg: ModelConfig, shape_name: str, mesh,
                with_opt: bool = True, microbatches: int = 1):
    """Returns (step_fn, args_structs, in_shardings) ready for
    jax.jit(step_fn, in_shardings=...).lower(*args_structs)."""
    shp = SHAPES[shape_name]
    tp = mesh.shape["model"]
    # pad query heads to the TP degree (and a KV-group multiple): GSPMD
    # resharding of non-dividing head counts (40H / 28H over 16) falls back
    # to full rematerialization = replicated activations
    if cfg.n_heads % tp:
        padded = -(-cfg.n_heads // tp) * tp
        while padded % cfg.n_kv_heads:
            padded += tp
        import dataclasses as _dc
        cfg = _dc.replace(cfg, head_pad_to=padded)
    model, p_struct = params_struct(cfg)
    # FSDP when TP-only sharding cannot hold the weights (llama4-scout:
    # 108B total params; 16-way TP leaves 13.5 GiB/chip of bf16 weights)
    fsdp = cfg.param_count() * 2 / tp > 6e9 or shp.kind == "train"
    p_shard = param_shardings(mesh, p_struct, fsdp=fsdp)
    B = shp.global_batch

    if shp.kind == "train":
        batch = train_batch_struct(cfg, shp)
        b_shard = batch_shardings(mesh, batch, B)
        opt_cfg = AdamWConfig()
        o_struct = jax.eval_shape(init_opt_state, p_struct)
        o_shard = type(o_struct)(
            NamedSharding(mesh, P()),
            param_shardings(mesh, o_struct.mu, fsdp=fsdp),
            param_shardings(mesh, o_struct.nu, fsdp=fsdp))

        from repro.training.optimizer import adamw_update

        k = microbatches

        out_shard = (p_shard, o_shard, NamedSharding(mesh, P()))

        def train_step(params, opt_state, batch):
            if k <= 1:
                (loss, _), grads = jax.value_and_grad(
                    model.loss, has_aux=True)(params, batch)
            else:
                # gradient accumulation: scan over k microbatches so live
                # activations are 1/k of the global batch
                def mb_slice(b, i):
                    def sl(x):
                        if x.ndim >= 2 and x.shape[0] == B:
                            m = B // k
                            return jax.lax.dynamic_slice_in_dim(x, i * m, m, 0)
                        if x.ndim >= 2 and x.shape[1] == B:  # mrope (3,B,S)
                            m = B // k
                            return jax.lax.dynamic_slice_in_dim(x, i * m, m, 1)
                        return x
                    return jax.tree.map(sl, b)

                def mb_step(acc, i):
                    (l, _), g = jax.value_and_grad(
                        model.loss, has_aux=True)(params, mb_slice(batch, i))
                    acc_l, acc_g = acc
                    return (acc_l + l / k,
                            jax.tree.map(lambda a, b_: a + b_ / k,
                                         acc_g, g)), None

                zero_g = jax.tree.map(
                    lambda x: jnp.zeros(x.shape, jnp.float32), params)
                (loss, grads), _ = jax.lax.scan(
                    mb_step, (jnp.zeros((), jnp.float32), zero_g),
                    jnp.arange(k))
            params, opt_state, om = adamw_update(opt_cfg, grads, opt_state,
                                                 params)
            return params, opt_state, loss

        return (train_step, (p_struct, o_struct, batch),
                (p_shard, o_shard, b_shard), out_shard)

    def _logits_shard():
        spec = _fit(mesh, P(data_axes(mesh), "model"),
                    (B, cfg.padded_vocab))
        return NamedSharding(mesh, spec)

    if shp.kind == "prefill":
        batch = train_batch_struct(cfg, shp)
        batch.pop("labels")
        b_shard = batch_shardings(mesh, batch, B)
        cache = jax.eval_shape(
            functools.partial(model.init_cache, B, shp.seq_len,
                              cfg.dtype))
        c_shard = cache_shardings(mesh, cache, B)

        def prefill_step(params, batch, cache):
            return model.prefill(params, batch, cache)

        return (prefill_step, (p_struct, batch, cache),
                (p_shard, b_shard, c_shard), (_logits_shard(), c_shard))

    # decode: ONE new token against a full cache
    cache_len = decode_cache_len(cfg, shp)
    cache = jax.eval_shape(
        functools.partial(model.init_cache, B, cache_len, cfg.dtype))
    # cache arrives 'full': len = seq_len - 1 (ring-buffered if windowed)
    c_shard = cache_shardings(mesh, cache, B)
    tokens = jax.ShapeDtypeStruct((B,), jnp.int32)
    t_shard = batch_shardings(mesh, {"t": tokens}, B)["t"]

    def serve_step(params, tokens, cache):
        return model.decode(params, tokens, cache)

    return (serve_step, (p_struct, tokens, cache),
            (p_shard, t_shard, c_shard), (_logits_shard(), c_shard))
