"""Serving driver CLI: run the LayerKV engine on a synthetic workload
through a live session — requests are submitted online and every
generated token is printed as its iteration produces it.

    PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b --smoke \
        --policy layerkv --requests 16 --device-blocks 64

All six scheduling axes are exposed: --policy, --no-slo-aware,
--chunked, --fused, --prefix-cache, --preemption (plus --chunk-size for
the chunked per-iteration token budget), the admission ordering
(--admission fcfs|prefix_aware|deadline), and --interactive-every to
tag every k-th request as a priority-1 interactive request with a tight
deadline. `--replicas N` serves through a `ClusterSession`
over N identical engines with a pluggable dispatch policy (--router
round_robin|least_loaded|prefix_affinity|slo_aware); a cluster of 1 is
bit-identical to a bare session. Real JAX execution with paged KV
pools; prints the per-token stream, per-request TTFT, a per-replica
occupancy/hit-rate line at drain, and the offload-ledger summary.

Fault tolerance: `--fault-plan SPEC` injects deterministic failures on
the shared virtual clock (grammar: `crash@0.5:r0:recover=1.0;
wedge@0.2:r1:dur=0.3` or `random:SEED[:n=N]` — serving/faults.py);
`--liveness-timeout` arms missing-heartbeat detection, `--shed-overload`
turns wedging overload into typed request shedding. The drain report
then includes the recovery trace and kill/retry/shed counters.
"""
from __future__ import annotations

import argparse
import dataclasses
import statistics

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--policy", default="layerkv",
                    choices=["layerkv", "vllm"])
    ap.add_argument("--no-slo-aware", action="store_true")
    ap.add_argument("--chunked", action="store_true",
                    help="chunked prefill + mixed batching")
    ap.add_argument("--fused", action="store_true",
                    help="ONE forward per iteration (implies --chunked)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="ref-counted cross-request prefix sharing")
    ap.add_argument("--chunk-size", type=int, default=32,
                    help="per-iteration prefill token budget (chunked)")
    ap.add_argument("--admission", default="fcfs",
                    choices=["fcfs", "prefix_aware", "deadline"],
                    help="waiting-queue admission ordering")
    ap.add_argument("--preemption", action="store_true",
                    help="lossless priority preemption: pause "
                         "lower-priority KV to HOST, resume later "
                         "(pairs with --admission deadline)")
    ap.add_argument("--interactive-every", type=int, default=0,
                    help="every k-th request is interactive: priority 1, "
                         "TTFT SLO (and deadline) tightened 4x (0 = all "
                         "batch)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="engine replicas behind the cluster router")
    ap.add_argument("--router", default="round_robin",
                    choices=["round_robin", "least_loaded",
                             "prefix_affinity", "slo_aware"],
                    help="cluster dispatch policy (--replicas > 1)")
    ap.add_argument("--fault-plan", default=None,
                    help="deterministic fault injection, e.g. "
                         "'crash@0.5:r0:recover=1.0;wedge@0.2:r1:dur=0.3' "
                         "or 'random:SEED[:n=N]' (serving/faults.py)")
    ap.add_argument("--liveness-timeout", type=float, default=None,
                    help="kill any replica whose next due event lags the "
                         "shared clock by more than this many seconds "
                         "while frozen (heartbeat failure detection)")
    ap.add_argument("--shed-overload", action="store_true",
                    help="graceful degradation: shed blocked requests "
                         "with a typed reason instead of wedging")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--shared-len", type=int, default=0,
                    help="leading tokens shared by every prompt "
                         "(exercises --prefix-cache)")
    ap.add_argument("--output-len", type=int, default=16)
    ap.add_argument("--rate", type=float, default=20.0)
    ap.add_argument("--device-blocks", type=int, default=64)
    ap.add_argument("--host-blocks", type=int, default=1024)
    ap.add_argument("--block-size", type=int, default=8)
    ap.add_argument("--quiet", action="store_true",
                    help="suppress the per-token stream printout")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="record the full event stream (lifecycle spans, "
                         "scheduler decision records, TTFT attribution) "
                         "and write Chrome-trace JSON here at drain — "
                         "load it at ui.perfetto.dev")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax
    from repro.configs import get_config, get_smoke_config
    from repro.serving.cluster import ClusterSession
    from repro.serving.engine import LayerKVEngine
    from repro.serving.faults import FaultPlan
    from repro.serving.request import Request
    from repro.serving.scheduler import ServeConfig

    if not 0 <= args.shared_len < args.prompt_len:
        ap.error(f"--shared-len {args.shared_len} must be in "
                 f"[0, --prompt-len {args.prompt_len}): every prompt "
                 "needs at least one unique token")
    if args.replicas < 1:
        ap.error("--replicas must be >= 1")
    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    cfg = dataclasses.replace(cfg, dtype="float32")
    rng = np.random.RandomState(args.seed)
    shared = [int(x) for x in
              rng.randint(0, cfg.vocab_size, args.shared_len)]
    t = 0.0
    reqs = []
    for i in range(args.requests):
        t += rng.exponential(1.0 / args.rate)
        sfx = args.prompt_len - len(shared)
        interactive = args.interactive_every > 0 \
            and i % args.interactive_every == 0
        reqs.append(Request(
            rid=f"r{i}", prompt_len=args.prompt_len,
            output_len=args.output_len, arrival=t,
            priority=1 if interactive else 0,
            ttft_slo=3.0 / 4 if interactive else 3.0,
            prompt=shared + [int(x) for x in
                             rng.randint(0, cfg.vocab_size, sfx)]))

    sc = ServeConfig.for_engine(
        policy=args.policy,
        slo_aware=not args.no_slo_aware,
        chunked=args.chunked or args.fused,
        fused=args.fused,
        prefix_cache=args.prefix_cache,
        preemption=args.preemption,
        admission=args.admission,
        max_prefill_tokens=args.chunk_size,
        num_device_blocks=args.device_blocks,
        num_host_blocks=args.host_blocks,
        block_size=args.block_size,
        shed_overload=args.shed_overload,
        trace=bool(args.trace))
    plan = FaultPlan.parse(args.fault_plan, n_replicas=args.replicas) \
        if args.fault_plan else None
    if plan is not None:
        print("fault plan:")
        for line in plan.describe():
            print(f"  {line}")
    # every replica loads the SAME weights (one PRNG seed): a cluster is
    # N copies of one model behind a router, not N different models
    engines = [LayerKVEngine(cfg, None, sc, rng=jax.random.PRNGKey(args.seed))
               for _ in range(args.replicas)]

    # submit everything up front (arrivals dispatch as the shared clock
    # reaches them) and pump the cluster one event at a time, printing
    # the token stream live as each iteration produces it
    session = ClusterSession(engines, router=args.router,
                             fault_plan=plan,
                             liveness_timeout=args.liveness_timeout)
    handles = [session.submit(r, arrival=r.arrival) for r in reqs]
    while session.step():
        for h in handles:
            new = h.take_new()
            if new and not args.quiet:
                star = "*" if h.request.cached_prompt_len else " "
                where = "?" if h.replica is None else h.replica
                print(f"[t={session.clock() * 1e3:9.3f}ms] {h.rid:>4}{star}"
                      f"@{where} +{len(new)} -> {new}")
    done = session.drain()

    ttfts = [r.ttft for r in done]
    print(f"policy={args.policy} chunked={args.chunked or args.fused} "
          f"fused={args.fused} prefix_cache={args.prefix_cache} "
          f"preemption={args.preemption} admission={args.admission} "
          f"replicas={args.replicas} router={args.router}")
    if args.preemption:
        print(f"preemptions={sum(e.core.n_preempted for e in engines)} "
              f"resumes={sum(e.core.n_resumed for e in engines)}")
    if ttfts:
        print(f"requests={len(done)} "
              f"mean_ttft={statistics.mean(ttfts)*1e3:.1f}ms "
              f"p99_ttft={sorted(ttfts)[-1]*1e3:.1f}ms")
    if session.recovery_log or plan is not None:
        shed = len(session.shed) \
            + sum(len(e.core.shed) for e in engines)
        print(f"faults: kills={session.n_kills} "
              f"recoveries={session.n_recoveries} "
              f"redispatched={len(session.redispatch_priorities)} "
              f"dispatch_retries={session.n_retries} shed={shed}")
        for line in session.recovery_log:
            print(f"  {line}")
    for i, (eng, st) in enumerate(zip(engines, session.stats)):
        served = len(eng.core.done)
        hit = f"{eng.bm.cache.hit_rate:.2f}" \
            if eng.bm.cache is not None else "-"
        print(f"replica {i}: dispatched={st.dispatched} served={served} "
              f"iterations={st.steps} "
              f"peak_occupancy={st.peak_occupancy:.2f} "
              f"prefix_hit_rate={hit}")
    off = [x for eng in engines for x in eng.off.ledger.log
           if x.kind == "offload"]
    rel = [x for eng in engines for x in eng.off.ledger.log
           if x.kind == "reload"]
    print(f"layer-wise transfers: {len(off)} offloads "
          f"({sum(x.nbytes for x in off)/2**20:.2f} MiB), "
          f"{len(rel)} reloads "
          f"({sum(x.nbytes for x in rel)/2**20:.2f} MiB)")
    if args.trace:
        session.write_trace(args.trace)
        n_ev = sum(len(e.core.tracer.events) for e in engines) \
            + len(session.tracer.events)
        print(f"trace: {n_ev} events -> {args.trace} "
              "(load at ui.perfetto.dev)")
    if done:
        sample = done[0]
        print(f"sample output ({sample.rid}): {sample.generated[:8]}...")


if __name__ == "__main__":
    main()
