"""Serving driver CLI: run the LayerKV engine on a synthetic workload.

    PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b --smoke \
        --policy layerkv --requests 16 --device-blocks 64

Real JAX execution with paged KV pools; prints per-request TTFT and the
offload-ledger summary.
"""
from __future__ import annotations

import argparse
import dataclasses
import statistics

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--policy", default="layerkv",
                    choices=["layerkv", "vllm"])
    ap.add_argument("--no-slo-aware", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--output-len", type=int, default=16)
    ap.add_argument("--rate", type=float, default=20.0)
    ap.add_argument("--device-blocks", type=int, default=64)
    ap.add_argument("--host-blocks", type=int, default=1024)
    ap.add_argument("--block-size", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax
    from repro.configs import get_config, get_smoke_config
    from repro.serving.engine import EngineConfig, LayerKVEngine
    from repro.serving.request import Request

    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    cfg = dataclasses.replace(cfg, dtype="float32")
    rng = np.random.RandomState(args.seed)
    t = 0.0
    reqs = []
    for i in range(args.requests):
        t += rng.exponential(1.0 / args.rate)
        reqs.append(Request(
            rid=f"r{i}", prompt_len=args.prompt_len,
            output_len=args.output_len, arrival=t,
            prompt=[int(x) for x in
                    rng.randint(0, cfg.vocab_size, args.prompt_len)]))

    eng = LayerKVEngine(
        cfg, None,
        EngineConfig(policy=args.policy,
                     slo_aware=not args.no_slo_aware,
                     num_device_blocks=args.device_blocks,
                     num_host_blocks=args.host_blocks,
                     block_size=args.block_size),
        rng=jax.random.PRNGKey(args.seed))
    done = eng.run(reqs)
    ttfts = [r.ttft for r in done]
    print(f"policy={args.policy} requests={len(done)} "
          f"mean_ttft={statistics.mean(ttfts)*1e3:.1f}ms "
          f"p99_ttft={sorted(ttfts)[-1]*1e3:.1f}ms")
    off = [x for x in eng.off.ledger.log if x.kind == "offload"]
    rel = [x for x in eng.off.ledger.log if x.kind == "reload"]
    print(f"layer-wise transfers: {len(off)} offloads "
          f"({sum(x.nbytes for x in off)/2**20:.2f} MiB), "
          f"{len(rel)} reloads "
          f"({sum(x.nbytes for x in rel)/2**20:.2f} MiB)")
    sample = done[0]
    print(f"sample output ({sample.rid}): {sample.generated[:8]}...")


if __name__ == "__main__":
    main()
