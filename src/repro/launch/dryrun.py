"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh).

Proves the distribution config is coherent without hardware: 512 placeholder
CPU devices stand in for 2 pods x 256 v5e chips. MUST be imported/run as a
fresh process (`python -m repro.launch.dryrun ...`) so the XLA flag below
precedes any jax initialization.
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse      # noqa: E402
import contextlib    # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
from jax.sharding import PartitionSpec as P              # noqa: E402

from repro.configs import ARCH_IDS, SHAPES, get_config   # noqa: E402
from repro.launch.mesh import make_production_mesh       # noqa: E402
from repro.launch.specs import input_specs               # noqa: E402
from repro.models.act_sharding import (                    # noqa: E402
    activation_sharding, kv_sharding, moe_buffer_sharding, state_sharding)

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "bf16": 2, "f16": 2, "s16": 2,
                "u16": 2, "f32": 4, "s32": 4, "u32": 4, "f64": 8, "s64": 8,
                "u64": 8}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _bytes_of_shapes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum result-shape bytes of every collective op in the (SPMD-
    partitioned) HLO, split into loop-BODY ops (inside while-loop
    computations: scan bodies appear ONCE in the text but execute
    trip-count times — the roofline analyzer multiplies them by the layer /
    microbatch iteration counts) and TOP-level ops."""
    out = {k: 0 for k in _COLLECTIVES}
    count = {k: 0 for k in _COLLECTIVES}
    body_bytes = 0
    top_bytes = 0
    in_body = False
    for line in hlo_text.splitlines():
        ls = line.rstrip()
        if ls and not ls.startswith(" ") and "{" in ls:
            # computation header, e.g. "%region_12.345 (...) -> ... {"
            name = ls.split(" ")[0]
            in_body = ("body" in name or "region" in name
                       or "while" in name)
        s = line.strip()
        if s.startswith("%") or s.startswith("ROOT"):
            m = re.search(r"=\s*(.+?)\s+(\w[\w-]*)\(", s)
            if not m:
                continue
            shape_part, op = m.groups()
            if op in _COLLECTIVES:
                b = _bytes_of_shapes(shape_part)
                out[op] += b
                count[op] += 1
                if in_body:
                    body_bytes += b
                else:
                    top_bytes += b
    return {"bytes": out, "counts": count,
            "total_bytes": sum(out.values()),
            "body_bytes": body_bytes, "top_bytes": top_bytes}


DEFAULT_MICROBATCHES = 4  # train_4k grad-accumulation factor

# per-arch grad-accumulation overrides: live activations must fit 16 GiB
# HBM alongside FSDP-sharded optimizer state
MICROBATCH = {
    "llama4-scout-17b-a16e": 16,
    "qwen2-vl-7b": 16,
    "codeqwen1.5-7b": 8,
    "chatglm3-6b": 8,
    "deepseek-moe-16b": 8,
    "xlstm-1.3b": 8,
    "zamba2-2.7b": 8,
}


def run_one(arch: str, shape_name: str, multi_pod: bool,
            keep_hlo: bool = False,
            microbatches: int = 0) -> dict:
    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    kind = SHAPES[shape_name].kind
    if not microbatches:
        microbatches = MICROBATCH.get(arch, DEFAULT_MICROBATCHES)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "n_devices": mesh.devices.size,
           "microbatches": microbatches if kind == "train" else 1}
    t0 = time.time()
    try:
        fn, args, shardings, out_shardings = input_specs(
            cfg, shape_name, mesh,
            microbatches=microbatches if kind == "train" else 1)
        # sequence-parallel activation sharding for full-sequence passes
        # (Megatron-SP): the remat carry lives (batch, seq)-sharded
        dp = ("pod", "data") if multi_pod else ("data",)
        ctx = (activation_sharding(P(dp, "model", None),
                                   P(dp, None, None))
               if SHAPES[shape_name].kind in ("train", "prefill")
               else contextlib.nullcontext())
        # fine-grained MoE (experts >> TP degree) benefits from pinning the
        # dispatch buffer expert-sharded; with E == TP (llama4) the pin
        # forces a pathological gather layout (+17 GiB, §Perf log)
        moe_ctx = (moe_buffer_sharding(P("model", dp, None))
                   if cfg.family == "moe" and
                   cfg.moe.n_experts > mesh.shape["model"] and
                   SHAPES[shape_name].kind in ("train", "prefill")
                   else contextlib.nullcontext())
        kv_ctx = (kv_sharding(P(dp, "model", None, None))
                  if SHAPES[shape_name].kind == "prefill"
                  else contextlib.nullcontext())
        # recurrent chunk states (mLSTM C matrices): head-dim over 'model'
        st_ctx = (state_sharding(P(dp, None, None, "model", None))
                  if cfg.family in ("ssm", "hybrid") and
                  SHAPES[shape_name].kind in ("train", "prefill")
                  else contextlib.nullcontext())
        # deployment-faithful buffer donation: params+opt for train, the
        # KV cache for decode
        donate = {"train": (0, 1), "prefill": (2,), "decode": (2,)}[kind]
        with mesh, ctx, moe_ctx, kv_ctx, st_ctx:
            lowered = jax.jit(fn, in_shardings=shardings,
                              out_shardings=out_shardings,
                              donate_argnums=donate).lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis() or {}
        hlo = compiled.as_text()
        coll = collective_bytes(hlo)
        rec.update(
            ok=True,
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            memory=dict(
                argument_size=mem.argument_size_in_bytes,
                output_size=mem.output_size_in_bytes,
                temp_size=mem.temp_size_in_bytes,
                alias_size=mem.alias_size_in_bytes,
                host_argument_size=mem.host_argument_size_in_bytes,
                host_temp_size=mem.host_temp_size_in_bytes,
            ),
            # NB: sizes are per-device (SPMD module)
            bytes_per_device=(mem.argument_size_in_bytes
                              + mem.temp_size_in_bytes
                              - mem.alias_size_in_bytes),
            flops=cost.get("flops", 0.0),
            bytes_accessed=cost.get("bytes accessed", 0.0),
            collectives=coll,
        )
        if keep_hlo:
            rec["hlo"] = hlo
    except Exception as e:  # noqa: BLE001 — failures are data here
        rec.update(ok=False, error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    rec["wall_s"] = round(time.time() - t0, 2)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help="arch id or 'all'")
    ap.add_argument("--shape", default="all",
                    help="input shape name or 'all'")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="dryrun_results.json")
    ap.add_argument("--append", action="store_true")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    results = []
    if args.append and os.path.exists(args.out):
        results = json.load(open(args.out))
    seen = {(r["arch"], r["shape"], r["mesh"]) for r in results}

    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                key = (arch, shape, "2x16x16" if mp else "16x16")
                if key in seen:
                    continue
                print(f"=== {arch} x {shape} x {key[2]} ===", flush=True)
                rec = run_one(arch, shape, mp)
                status = "OK" if rec["ok"] else f"FAIL {rec['error'][:120]}"
                gb = rec.get("bytes_per_device", 0) / 2**30
                print(f"    {status}  mem/dev={gb:.2f}GiB "
                      f"wall={rec['wall_s']}s", flush=True)
                results.append(rec)
                json.dump(results, open(args.out, "w"), indent=1)
    n_ok = sum(r["ok"] for r in results)
    print(f"\n{n_ok}/{len(results)} combinations compiled")


if __name__ == "__main__":
    main()
