"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first jax
init, and nothing here may run earlier.
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod ('data','model'); multi_pod adds a
    leading 'pod' axis of 2 (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def data_axes(mesh) -> tuple:
    """Axes that shard the batch dimension."""
    names = mesh.axis_names
    return tuple(a for a in names if a in ("pod", "data"))
