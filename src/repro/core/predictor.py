"""Bucketed generation-length predictor (paper §3.1, following [31]).

The paper frames output-length prediction as multi-class classification over
percentile buckets; the conservative lower bound of the predicted bucket
feeds N_future (Eq. 1) and the bucket median feeds the Released(t) forecast
(Eq. 5).

Two implementations behind one interface:
  * HistogramPredictor — feature-free running histogram of observed output
    lengths (cold-start prior = workload config); always available.
  * OraclePredictor(accuracy=p) — returns the true bucket with probability p
    else a random one; lets benchmarks ablate prediction quality the same
    way the paper's proxy-model accuracy would vary.
"""
from __future__ import annotations

import bisect
import random
from typing import List, Optional, Sequence, Tuple


class LengthPredictor:
    """Percentile-bucketed length prediction."""

    def __init__(self, bucket_edges: Sequence[int]):
        """bucket_edges: ascending interior edges, e.g. [64, 128, 256, 512]
        makes buckets [1,64), [64,128), ..., [512, inf)."""
        self.edges = list(bucket_edges)

    # -- bucket helpers ------------------------------------------------------
    def bucket_of(self, length: int) -> int:
        return bisect.bisect_right(self.edges, length)

    def bucket_bounds(self, b: int) -> Tuple[int, int]:
        lo = 1 if b == 0 else self.edges[b - 1]
        hi = self.edges[b] if b < len(self.edges) else 4 * self.edges[-1]
        return lo, hi

    def lower_bound(self, b: int) -> int:
        return self.bucket_bounds(b)[0]

    def median(self, b: int) -> int:
        lo, hi = self.bucket_bounds(b)
        return (lo + hi) // 2

    # -- interface -----------------------------------------------------------
    def predict_bucket(self, request) -> int:
        raise NotImplementedError

    def observe(self, output_len: int) -> None:
        pass

    def n_future(self, request, n_past: int) -> int:
        """Conservative remaining-length estimate (paper: bucket lower bound
        minus tokens already generated, clamped positive)."""
        return max(1, self.lower_bound(self.predict_bucket(request)) - n_past)

    def n_median_total(self, request) -> int:
        return self.median(self.predict_bucket(request))


class HistogramPredictor(LengthPredictor):
    def __init__(self, bucket_edges: Sequence[int],
                 prior_counts: Optional[List[int]] = None):
        super().__init__(bucket_edges)
        n = len(bucket_edges) + 1
        self.counts = list(prior_counts) if prior_counts else [1] * n

    def observe(self, output_len: int) -> None:
        self.counts[self.bucket_of(output_len)] += 1

    def predict_bucket(self, request) -> int:
        return max(range(len(self.counts)), key=lambda i: self.counts[i])


class OraclePredictor(LengthPredictor):
    """Knows each request's true output length (sim only); degrades to a
    random bucket with probability 1-accuracy."""

    def __init__(self, bucket_edges: Sequence[int], accuracy: float = 1.0,
                 seed: int = 0):
        super().__init__(bucket_edges)
        self.accuracy = accuracy
        self.rng = random.Random(seed)

    def predict_bucket(self, request) -> int:
        true_b = self.bucket_of(request.output_len)
        if self.rng.random() < self.accuracy:
            return true_b
        return self.rng.randrange(len(self.edges) + 1)
