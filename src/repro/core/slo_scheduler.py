"""SLO-aware scheduler (paper §3.1, Algorithm 1).

Decides, at each scheduling event, how many queued requests' prefill stages
may run *now* without pushing any currently-decoding request past its TPOT
SLO. The slack of decoding request i (Eq. 1):

    T_allow^i = T_tpot^i * (N_past^i + N_future^i) - (T_past^i + T_future^i)

and prefills q_1..q_n are admitted while  sum_k T_prefill(q_k) < min_i
T_allow^i  (Eq. 2), with T_prefill estimated by the Eq. 3 cost model and
N_future by the bucketed length predictor.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Callable, Optional, Sequence

from repro.core.predictor import LengthPredictor
from repro.core.units import Bytes, Seconds, Tokens, bytes_to_seconds

if TYPE_CHECKING:  # pragma: no cover — import cycle (serving -> core)
    from repro.serving.costmodel import CostModel
    from repro.serving.request import Request


@dataclasses.dataclass
class SLOScheduler:
    cost: CostModel
    predictor: LengthPredictor
    # requests with no TPOT headroom would block admissions forever; the
    # paper's fairness guarantee comes from admitting at least one prefill
    # whenever no decode slack is violated *yet* — keep a small floor.
    min_admit_when_idle: int = 1

    # ------------------------------------------------------------------ Eq.1
    def allow_prefill_budget(self, decoding: Sequence[Request], now: Seconds
                             ) -> Seconds:
        """min_i T_allow^i over decoding requests; +inf if none decoding."""
        budget = float("inf")
        for r in decoding:
            n_future = self.predictor.n_future(r, r.n_past)
            cur = r.current_tpot(now)
            if cur <= 0.0:
                cur = self.cost.decode_step_time(max(len(decoding), 1),
                                                 r.prompt_len)
            t_future = cur * n_future
            t_allow = r.tpot_slo * (r.n_past + n_future) \
                - (r.t_past(now) + t_future)
            budget = min(budget, t_allow)
        return budget

    # ------------------------------------------------------------- Alg.1
    def max_prefills(self, queue: Sequence[Request],
                     decoding: Sequence[Request], now: Seconds,
                     cached_len: Optional[Callable[[Request], Tokens]] = None
                     ) -> int:
        """Maximum n such that the first n queued prefills fit in the
        minimum TPOT slack (Eq. 2). `queue` arrives in the caller's
        admission order — FCFS by default (paper §1: no reordering, no
        starvation), or an `AdmissionPolicy` ordering (e.g. prefix_aware,
        whose bounded aging window carries the no-starvation guarantee
        instead). Since hits price only their uncached suffix, a
        hits-first order also fits MORE prefills into the same slack.
        `cached_len(q)` reports the prompt tokens a prefix-cache hit
        would skip: the Eq.3 estimate must price only the UNCACHED
        suffix, or admission over-throttles exactly the workloads the
        cache accelerates (chunk_prefill_time(p, 0) == prefill_time(p),
        so the uncached case telescopes to the original estimate)."""
        if not queue:
            return 0
        budget = self.allow_prefill_budget(decoding, now)
        if not decoding:
            return len(queue)  # nothing to protect
        total, n = 0.0, 0
        for q in queue:
            c = cached_len(q) if cached_len is not None else 0
            total += self.cost.chunk_prefill_time(q.prompt_len - c, c)
            if total < budget:
                n += 1
            else:
                break
        return n

    # ------------------------------------------------- preemption pricing
    def preempt_slack(self, r: Request, now: Seconds) -> Seconds:
        """Deadline slack of one request, for victim selection:

          * not yet decoding — first-token headroom, its effective
            deadline minus `now` (a prefill-phase victim loses TTFT);
          * decoding — its own Eq.1 T_allow (a decode-phase victim loses
            inter-token time against its TPOT SLO).

        Negative slack means the request is already past its budget."""
        if r.first_token_time < 0:
            return r.effective_deadline - now
        return self.allow_prefill_budget([r], now)

    def victim_affordable(self, r: Request, now: Seconds,
                          resume_bytes: Bytes, offload_bw: float) -> bool:
        """Can `r` absorb being preempted without blowing its own SLO?
        The price of pausing r is the h2d promotion it must later pay to
        resume (its whole KV crossing the offload link back); affordable
        means that reload time fits inside r's current deadline slack.
        The preemption controller prefers affordable victims and touches
        unaffordable ones only for a preemptor that is itself already
        past its deadline."""
        return self.preempt_slack(r, now) \
            >= bytes_to_seconds(resume_bytes, max(offload_bw, 1e-9))

    # ------------------------------------------------- chunked prefill budget
    def max_chunk_tokens(self, decoding: Sequence[Request], now: Seconds,
                         cap: Tokens, floor: Tokens = 16) -> Tokens:
        """Per-iteration prefill-TOKEN budget for chunked prefill (the
        token-budget analogue of Alg.1). With mixed batching decodes are
        not stalled by a prefill, but the iteration stretches to the chunk
        compute time — so the chunk is sized to fit the minimum Eq.1 TPOT
        slack. A small floor guarantees prefill progress (same fairness
        rationale as `min_admit_when_idle`); `cap` is the engine's
        max_prefill_tokens."""
        if not decoding:
            return cap
        slack = self.allow_prefill_budget(decoding, now)
        if slack == float("inf"):
            return cap
        if slack <= 0.0:
            return min(floor, cap)
        # Eq.3 linear term gives a conservative (attention-free) per-token
        # cost; inverting it bounds the chunk that fits in the slack.
        per_token = self.cost.chunk_prefill_time(1, 0)
        n = int(slack / max(per_token, 1e-12))
        return max(min(floor, cap), min(cap, n))
