"""The paper's primary contribution: layer-wise KV cache management.

block_manager   layer-wise paged allocator over DEVICE + HOST pools
offload_engine  Eq.4 retention policy, interleaving, link ledger (§3.1.3)
slo_scheduler   Algorithm 1 / Eq.1-3 admission control
predictor       bucketed generation-length prediction
forecast        Eq.5 availability state transition
"""
from repro.core.block_manager import (
    CACHE_OWNER,
    DEVICE,
    HOST,
    LayerwiseBlockManager,
    PoolExhausted,
    PrefixAcquisition,
    PrefixCache,
    block_hashes,
)
from repro.core.forecast import AvailabilityForecast
from repro.core.offload_engine import (
    LinkLedger,
    OffloadEngine,
    OffloadPlan,
    interleave_offload_layers,
)
from repro.core.predictor import (
    HistogramPredictor,
    LengthPredictor,
    OraclePredictor,
)
from repro.core.slo_scheduler import SLOScheduler

__all__ = [
    "CACHE_OWNER", "DEVICE", "HOST", "LayerwiseBlockManager",
    "PoolExhausted", "PrefixAcquisition", "PrefixCache", "block_hashes",
    "AvailabilityForecast", "LinkLedger", "OffloadEngine", "OffloadPlan",
    "interleave_offload_layers", "HistogramPredictor", "LengthPredictor",
    "OraclePredictor", "SLOScheduler",
]
