"""Layer-wise paged KV block allocator (paper §3.1.1-§3.1.2).

Two physical pools — DEVICE (GPU/TPU HBM) and HOST — each a flat set of
fixed-size blocks backed by one pooled tensor (paper §4: a single tensor so
any block can serve any layer of any request). On top, a block table maps
(request, layer, logical_block) -> (pool, physical_block). Residency is
tracked per (request, layer): a layer's KV lives wholly on one pool at a
time (the paper offloads whole layers), with per-layer interleaving chosen
by the offload engine.

Invariants (enforced + property-tested):
  * a physical block belongs to at most one (request, layer) at a time;
  * free + allocated == pool size, always;
  * freeing is idempotent only via free_request (double-free of a live
    handle raises);
  * request state never references a freed block.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

DEVICE = "device"
HOST = "host"


class PoolExhausted(Exception):
    pass


class _Pool:
    def __init__(self, name: str, num_blocks: int):
        self.name = name
        self.num_blocks = num_blocks
        self._free: List[int] = list(range(num_blocks - 1, -1, -1))
        self._owner: Dict[int, Tuple[str, int]] = {}  # block -> (req, layer)

    @property
    def num_free(self) -> int:
        return len(self._free)

    def alloc(self, n: int, owner: Tuple[str, int]) -> List[int]:
        if n > len(self._free):
            raise PoolExhausted(
                f"{self.name}: want {n}, have {len(self._free)}")
        blocks = [self._free.pop() for _ in range(n)]
        for b in blocks:
            self._owner[b] = owner
        return blocks

    def free(self, blocks: List[int]) -> None:
        for b in blocks:
            if b not in self._owner:
                raise KeyError(f"{self.name}: double free of block {b}")
            del self._owner[b]
            self._free.append(b)

    def check(self) -> None:
        assert len(self._free) + len(self._owner) == self.num_blocks
        assert set(self._free).isdisjoint(self._owner)


@dataclasses.dataclass
class LayerAllocation:
    pool: str                    # DEVICE or HOST
    blocks: List[int]            # physical ids, logical order
    num_tokens: int = 0          # valid tokens written


class LayerwiseBlockManager:
    """Per-layer block accounting for one engine replica."""

    def __init__(self, num_device_blocks: int, num_host_blocks: int,
                 block_size: int, n_layers: int):
        self.block_size = block_size
        self.n_layers = n_layers
        self.pools = {DEVICE: _Pool(DEVICE, num_device_blocks),
                      HOST: _Pool(HOST, num_host_blocks)}
        # request -> layer -> LayerAllocation
        self.tables: Dict[str, Dict[int, LayerAllocation]] = {}

    # ------------------------------------------------------------- queries
    def num_free(self, pool: str = DEVICE) -> int:
        return self.pools[pool].num_free

    def blocks_for_tokens(self, n_tokens: int) -> int:
        return -(-n_tokens // self.block_size)

    def request_blocks(self, n_tokens: int, n_layers: Optional[int] = None):
        """Blocks needed to hold `n_tokens` of KV for `n_layers` layers
        (request-wise baseline passes n_layers = all)."""
        L = self.n_layers if n_layers is None else n_layers
        return self.blocks_for_tokens(n_tokens) * L

    def layers_on(self, req: str, pool: str) -> List[int]:
        return [l for l, a in self.tables.get(req, {}).items()
                if a.pool == pool]

    def allocation(self, req: str, layer: int) -> LayerAllocation:
        return self.tables[req][layer]

    def live_requests(self) -> List[str]:
        return list(self.tables)

    # ---------------------------------------------------------- allocation
    def can_alloc(self, n_blocks: int, pool: str = DEVICE) -> bool:
        return self.pools[pool].num_free >= n_blocks

    def alloc_layer(self, req: str, layer: int, n_tokens: int,
                    pool: str = DEVICE) -> LayerAllocation:
        assert 0 <= layer < self.n_layers
        tbl = self.tables.setdefault(req, {})
        assert layer not in tbl, f"{req} layer {layer} already allocated"
        n = self.blocks_for_tokens(n_tokens)
        blocks = self.pools[pool].alloc(n, (req, layer))
        alloc = LayerAllocation(pool, blocks, n_tokens)
        tbl[layer] = alloc
        return alloc

    def extend_layer(self, req: str, layer: int, n_new_tokens: int = 1):
        """Grow a layer's allocation for newly decoded tokens (same pool)."""
        a = self.tables[req][layer]
        need = self.blocks_for_tokens(a.num_tokens + n_new_tokens) \
            - len(a.blocks)
        if need > 0:
            a.blocks.extend(self.pools[a.pool].alloc(need, (req, layer)))
        a.num_tokens += n_new_tokens
        return a

    # ----------------------------------------------------------- migration
    def move_layer(self, req: str, layer: int, to_pool: str
                   ) -> Tuple[List[int], List[int]]:
        """Migrate one layer's KV between pools. Returns (src_blocks,
        dst_blocks) so the caller can issue the physical copies; accounting
        is updated immediately (the engine's transfer ledger owns timing)."""
        a = self.tables[req][layer]
        if a.pool == to_pool:
            return (a.blocks, a.blocks)
        src = list(a.blocks)
        dst = self.pools[to_pool].alloc(len(src), (req, layer))
        self.pools[a.pool].free(src)
        a.pool, a.blocks = to_pool, dst
        return src, dst

    # ------------------------------------------------------------- release
    def free_request(self, req: str) -> int:
        """Release every block of a finished request. Returns #blocks freed
        on DEVICE (feeds Eq.5 Released(t))."""
        tbl = self.tables.pop(req, {})
        dev_freed = 0
        for a in tbl.values():
            self.pools[a.pool].free(a.blocks)
            if a.pool == DEVICE:
                dev_freed += len(a.blocks)
        return dev_freed

    def check(self) -> None:
        for p in self.pools.values():
            p.check()
        owned = {}
        for req, tbl in self.tables.items():
            for layer, a in tbl.items():
                for b in a.blocks:
                    key = (a.pool, b)
                    assert key not in owned, f"block {key} double-owned"
                    owned[key] = (req, layer)
