"""Layer-wise paged KV block allocator (paper §3.1.1-§3.1.2) with
ref-counted cross-request prefix caching (Apt-Serve-style hybrid sharing).

Two physical pools — DEVICE (GPU/TPU HBM) and HOST — each a flat set of
fixed-size blocks backed by one pooled tensor (paper §4: a single tensor so
any block can serve any layer of any request). On top, a block table maps
(request, layer, logical_block) -> (pool, physical_block). Residency is
tracked per (request, layer): a layer's KV lives wholly on one pool at a
time (the paper offloads whole layers), with per-layer interleaving chosen
by the offload engine.

Prefix caching (enabled with `prefix_cache=True`): every FULL block of a
prompt is content-addressed by the hash chain of its token ids, one cache
entry per (layer, chain-hash). A later request whose prompt shares the
token prefix maps the same physical blocks (refcount += 1 per mapping) and
skips prefill compute for the shared tokens. Sharing is full-block
granular; the block containing the first *recomputed* token is
copy-on-write: the new request gets a private copy of the cached block and
writes its recomputed tail there, never mutating the shared original.
Blocks whose refcount drops to 0 stay resident as reclaimable cache (LRU):
allocation prefers the free list, then evicts LRU unreferenced cache
blocks — demoting them to the HOST tier when it has room (hierarchical
context caching a la Strata) before dropping them outright. Physical
copies the cache decides on (COW, promotion, demotion) are surfaced
through the `on_copy` hook so the executor moves real bytes and the
simulator charges the link ledger — the manager itself stays logical.

Invariants (enforced + property-tested):
  * free + allocated == pool size, always (cache-retained blocks count as
    allocated);
  * an UNSHARED physical block belongs to at most one (request, layer);
    a shared block's table multiplicity equals its cache refcount;
  * a shared block is never freed or migrated while another request still
    references it;
  * copy-on-write never mutates the shared source block;
  * request state never references a freed block.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterable, List, Optional, Tuple

from repro.core.units import Blocks, LayerIdx, Tokens, tokens_to_blocks

DEVICE = "device"
HOST = "host"

CACHE_OWNER = "<prefix-cache>"

# (src_pool, src_block, dst_pool, dst_block) -> None
CopyHook = Callable[[str, int, str, int], None]


class PoolExhausted(Exception):
    pass


class _Pool:
    def __init__(self, name: str, num_blocks: Blocks) -> None:
        self.name = name
        self.num_blocks: Blocks = num_blocks
        self._free: List[int] = list(range(num_blocks - 1, -1, -1))
        self._owner: Dict[int, Tuple[str, int]] = {}  # block -> (req, layer)

    @property
    def num_free(self) -> Blocks:
        return len(self._free)

    def alloc(self, n: int, owner: Tuple[str, int]) -> List[int]:
        if n > len(self._free):
            raise PoolExhausted(
                f"{self.name}: want {n}, have {len(self._free)}")
        blocks = [self._free.pop() for _ in range(n)]
        for b in blocks:
            self._owner[b] = owner
        return blocks

    def free(self, blocks: List[int]) -> None:
        for b in blocks:
            if b not in self._owner:
                raise KeyError(f"{self.name}: double free of block {b}")
            del self._owner[b]
            self._free.append(b)

    def chown(self, block: int, owner: Tuple[str, int]) -> None:
        assert block in self._owner, f"{self.name}: chown of free {block}"
        self._owner[block] = owner

    def check(self) -> None:
        assert len(self._free) + len(self._owner) == self.num_blocks
        assert set(self._free).isdisjoint(self._owner)


@dataclasses.dataclass
class LayerAllocation:
    pool: str                    # DEVICE or HOST
    blocks: List[int]            # physical ids, logical order
    num_tokens: Tokens = 0       # valid tokens written


@dataclasses.dataclass
class CachedBlock:
    """One content-addressed full block of prompt KV for one layer."""
    key: Tuple[int, int]         # (layer, chain hash)
    pool: str                    # current residency tier
    block: int                   # physical id in `pool`
    ref: int = 0                 # live (request, layer) mappings
    tick: int = 0                # LRU stamp, meaningful at ref == 0
    tokens: Optional[Tuple[int, ...]] = None  # this block's token ids —
    #   verified on match so a 64-bit chain-hash collision can never map
    #   another prompt's KV (stored once per layer-0 entry)


def block_hashes(tokens: Iterable[int], block_size: int) -> List[int]:
    """Chain hashes of the FULL blocks of a token sequence: block i's hash
    commits to every token in blocks 0..i, so equal hashes imply equal
    prefixes (CPython int/tuple hashing is deterministic in-process)."""
    toks = list(tokens)
    out: List[int] = []
    h = 0
    for s in range(0, len(toks) - block_size + 1, block_size):
        h = hash((h, tuple(toks[s:s + block_size])))
        out.append(h)
    return out


@dataclasses.dataclass
class PrefixAcquisition:
    """Result of mapping a cached prefix into a request's block tables.
    The physical copies were already issued through `on_copy`; the lists
    here are for accounting/tests."""
    cached_len: Tokens                            # prompt tokens skipped
    cow_copies: List[Tuple[int, int, int]]        # (layer, src, dst) d2d
    promotions: List[Tuple[int, int, int]]        # (layer, host src, dst)


class PrefixCache:
    """Content-addressed registry of full prompt blocks, per layer."""

    def __init__(self) -> None:
        self.entries: Dict[Tuple[int, int], CachedBlock] = {}
        self.by_block: Dict[Tuple[str, int], CachedBlock] = {}
        self._tick = 0
        # unreferenced (reclaimable) entries per pool in LRU order: CPython
        # dicts preserve insertion order, so popping the FIRST key is the
        # least-recently-unreferenced entry — every transition is O(1)
        # (a sorted scan here was the hot path of the whole simulator)
        self.lru: Dict[str, Dict[Tuple[int, int], CachedBlock]] = {
            DEVICE: {}, HOST: {}}
        # stats (token-granular so hit rate is meaningful)
        self.hit_tokens = 0
        self.lookup_tokens = 0
        self.n_hits = 0
        self.n_lookups = 0

    def tick(self) -> int:
        self._tick += 1
        return self._tick

    def n_unref(self, pool: str) -> int:
        return len(self.lru[pool])

    def lookup(self, pool: str, block: int) -> Optional[CachedBlock]:
        return self.by_block.get((pool, block))

    def incref(self, e: CachedBlock) -> None:
        if e.ref == 0:
            del self.lru[e.pool][e.key]
        e.ref += 1

    def decref(self, e: CachedBlock) -> None:
        assert e.ref > 0
        e.ref -= 1
        if e.ref == 0:
            e.tick = self.tick()
            self.lru[e.pool][e.key] = e

    def add(self, key: Tuple[int, int], pool: str, block: int,
            ref: int, tokens: Optional[Tuple[int, ...]] = None
            ) -> CachedBlock:
        assert key not in self.entries
        e = CachedBlock(key, pool, block, ref, self.tick(), tokens)
        self.entries[key] = e
        self.by_block[(pool, block)] = e
        if ref == 0:
            self.lru[pool][key] = e
        return e

    def count(self, lookup_tokens: Tokens, hit_tokens: Tokens) -> None:
        """Record one admission's lookup — called ONCE per admitted
        request (not per retry), so hit_rate measures workload sharing."""
        self.lookup_tokens += lookup_tokens
        self.hit_tokens += hit_tokens
        self.n_lookups += 1
        self.n_hits += int(hit_tokens > 0)

    def relocate(self, e: CachedBlock, pool: str, block: int) -> None:
        del self.by_block[(e.pool, e.block)]
        if e.ref == 0:
            del self.lru[e.pool][e.key]
            self.lru[pool][e.key] = e
        e.pool, e.block = pool, block
        self.by_block[(pool, block)] = e

    def drop(self, e: CachedBlock) -> None:
        del self.entries[e.key]
        del self.by_block[(e.pool, e.block)]
        if e.ref == 0:
            del self.lru[e.pool][e.key]

    def pop_lru(self, pool: str) -> Optional[CachedBlock]:
        """Least-recently-unreferenced entry on `pool`, or None."""
        lru = self.lru[pool]
        if not lru:
            return None
        return next(iter(lru.values()))

    @property
    def hit_rate(self) -> float:
        return self.hit_tokens / self.lookup_tokens \
            if self.lookup_tokens else 0.0


class LayerwiseBlockManager:
    """Per-layer block accounting for one engine replica."""

    def __init__(self, num_device_blocks: int, num_host_blocks: int,
                 block_size: int, n_layers: int,
                 prefix_cache: bool = False) -> None:
        self.block_size = block_size
        self.n_layers = n_layers
        self.pools = {DEVICE: _Pool(DEVICE, num_device_blocks),
                      HOST: _Pool(HOST, num_host_blocks)}
        # request -> layer -> LayerAllocation
        self.tables: Dict[str, Dict[int, LayerAllocation]] = {}
        self.cache: Optional[PrefixCache] = \
            PrefixCache() if prefix_cache else None
        # physical-copy hook: the executor moves the bytes, the simulator
        # charges the link ledger. No-op by default (pure accounting runs).
        self.on_copy: Optional[CopyHook] = None
        # prompt-object -> hash chain memo: the scheduler probes the same
        # immutable prompt many times per iteration (admission estimates,
        # device-need gates, per-chunk registration) — hash it once
        self._hash_memo: Dict[int, Tuple[list, List[int]]] = {}

    # ------------------------------------------------------------- queries
    def num_free(self, pool: str = DEVICE) -> Blocks:
        """Allocatable blocks: the free list plus unreferenced cache blocks
        (reclaimed on demand inside `_alloc_blocks`)."""
        n = self.pools[pool].num_free
        if self.cache is not None:
            n += self.cache.n_unref(pool)
        return n

    def blocks_for_tokens(self, n_tokens: Tokens) -> Blocks:
        return tokens_to_blocks(n_tokens, self.block_size)

    def request_blocks(self, n_tokens: Tokens,
                       n_layers: Optional[int] = None) -> Blocks:
        """Blocks needed to hold `n_tokens` of KV for `n_layers` layers
        (request-wise baseline passes n_layers = all)."""
        L = self.n_layers if n_layers is None else n_layers
        return self.blocks_for_tokens(n_tokens) * L

    def layers_on(self, req: str, pool: str) -> List[int]:
        return [l for l, a in self.tables.get(req, {}).items()
                if a.pool == pool]

    def allocation(self, req: str, layer: LayerIdx) -> LayerAllocation:
        return self.tables[req][layer]

    def live_requests(self) -> List[str]:
        return list(self.tables)

    def layer_shared(self, req: str, layer: LayerIdx) -> bool:
        """True when any block of (req, layer) is also referenced by
        another live request — such layers must not migrate or be evicted
        out from under the sharer."""
        if self.cache is None:
            return False
        a = self.tables[req][layer]
        for b in a.blocks:
            e = self.cache.lookup(a.pool, b)
            if e is not None and e.ref > 1:
                return True
        return False

    # ---------------------------------------------------------- allocation
    def can_alloc(self, n_blocks: Blocks, pool: str = DEVICE) -> bool:
        return self.num_free(pool) >= n_blocks

    def _copy(self, src_pool: str, src: int, dst_pool: str,
              dst: int) -> None:
        if self.on_copy is not None:
            self.on_copy(src_pool, src, dst_pool, dst)

    def _alloc_blocks(self, pool: str, n: int, owner: Tuple[str, int]
                      ) -> List[int]:
        """Pool allocation that reclaims LRU unreferenced cache blocks when
        the free list runs short. Reclaimed DEVICE blocks are demoted to
        the HOST tier while it has room (their cached KV survives there);
        otherwise the entry is dropped."""
        p = self.pools[pool]
        if self.cache is not None and p.num_free < n:
            host = self.pools[HOST]
            while p.num_free < n:
                e = self.cache.pop_lru(pool)
                if e is None:
                    break
                if pool == DEVICE and host.num_free > 0:
                    (dst,) = host.alloc(1, (CACHE_OWNER, e.key[0]))
                    self._copy(DEVICE, e.block, HOST, dst)
                    p.free([e.block])
                    self.cache.relocate(e, HOST, dst)
                else:
                    p.free([e.block])
                    self.cache.drop(e)
        return p.alloc(n, owner)

    def alloc_layer(self, req: str, layer: LayerIdx, n_tokens: Tokens,
                    pool: str = DEVICE) -> LayerAllocation:
        assert 0 <= layer < self.n_layers
        tbl = self.tables.setdefault(req, {})
        assert layer not in tbl, f"{req} layer {layer} already allocated"
        n = self.blocks_for_tokens(n_tokens)
        blocks = self._alloc_blocks(pool, n, (req, layer))
        alloc = LayerAllocation(pool, blocks, n_tokens)
        tbl[layer] = alloc
        return alloc

    def extend_layer(self, req: str, layer: LayerIdx,
                     n_new_tokens: Tokens = 1) -> LayerAllocation:
        """Grow a layer's allocation for newly decoded tokens (same pool)."""
        a = self.tables[req][layer]
        need = self.blocks_for_tokens(a.num_tokens + n_new_tokens) \
            - len(a.blocks)
        if need > 0:
            a.blocks.extend(self._alloc_blocks(a.pool, need, (req, layer)))
        a.num_tokens += n_new_tokens
        return a

    # -------------------------------------------------------- prefix cache
    def _hashes(self, tokens: List[int]) -> List[int]:
        """Memoized chain hashes of `tokens` (prompts are immutable; the
        chain for a prefix is a prefix of the chain)."""
        key = id(tokens)
        hit = self._hash_memo.get(key)
        if hit is not None and hit[0] is tokens:
            return hit[1]
        if len(self._hash_memo) > 4096:
            self._hash_memo.clear()
        hs = block_hashes(tokens, self.block_size)
        self._hash_memo[key] = (tokens, hs)
        return hs

    def match_prefix(self, tokens: Optional[List[int]]) -> Tokens:
        """Longest cached prompt prefix, in tokens. Full-block granular,
        capped at len(tokens)-1 so at least one token is always recomputed
        (its logits produce the first output token). A block counts as
        cached only when ALL layers hold an entry for it — prefill compute
        is skipped for all layers at once or not at all. The stored token
        ids are compared on match, so a chain-hash collision degrades to a
        miss instead of mapping another prompt's KV. Stat counting lives
        in PrefixCache.count (once per admission, not per probe)."""
        if self.cache is None or not tokens:
            return 0
        BS = self.block_size
        matched = 0
        for i, h in enumerate(self._hashes(tokens)):
            e0 = self.cache.entries.get((0, h))
            if e0 is None or any((l, h) not in self.cache.entries
                                 for l in range(1, self.n_layers)):
                break
            if e0.tokens is not None \
                    and e0.tokens != tuple(tokens[i * BS:(i + 1) * BS]):
                break  # 64-bit collision: verify, never trust
            matched += BS
        return min(matched, len(tokens) - 1)

    def acquire_prefix(self, req: str, tokens: List[int]
                       ) -> Optional[PrefixAcquisition]:
        """Map the cached prefix of `tokens` into `req`'s tables (all
        layers, DEVICE tier) and allocate nothing else; the caller then
        extends each layer with the uncached suffix. Returns None on a
        miss or when the device pool cannot host the promotions/COW
        copies; a None return leaves every pool and refcount as it found
        them.

        Per needed entry, three resolutions:
          * device-resident, fully reused     -> map the block, ref += 1;
          * device-resident, partial tail     -> COW: private d2d copy;
          * host-resident. If cache-owned (no live mapper) the entry is
            PROMOTED back to device and shared; if a live request still
            maps it on the host tier (it was detach-evicted there), the
            acquirer gets a private h2d copy instead — the mapper's block
            is never freed or relocated out from under it."""
        assert req not in self.tables, f"{req} already has allocations"
        cached_len = self.match_prefix(tokens)
        if cached_len <= 0:
            return None
        n_shared = cached_len // self.block_size       # fully shared blocks
        tail = cached_len % self.block_size            # tokens COW-reused
        n_used = n_shared + (1 if tail else 0)
        hashes = self._hashes(tokens)
        # Pin every entry we are about to touch: a pinned (ref > 0) entry
        # can neither be reclaimed nor demoted by the allocations below.
        pinned: List[CachedBlock] = []
        for l in range(self.n_layers):
            for i in range(n_used):
                e = self.cache.entries[(l, hashes[i])]
                self.cache.incref(e)
                pinned.append(e)
        cow: List[Tuple[int, int, int]] = []
        promos: List[Tuple[int, int, int]] = []
        unpin: List[CachedBlock] = []    # resolved private: pin is dropped
        private: List[int] = []          # device blocks to free on rollback

        def _resolve(e: CachedBlock, l: int, want_private: bool) -> int:
            if e.pool == HOST and e.ref > 1:
                # a live request maps this block on host (post-detach):
                # private h2d copy, never disturb the mapper
                (dst,) = self._alloc_blocks(DEVICE, 1, (req, l))
                self._copy(HOST, e.block, DEVICE, dst)
                promos.append((l, e.block, dst))
                unpin.append(e)
                private.append(dst)
                return dst
            if e.pool == HOST:
                # cache-owned (our pin is the only ref): promote the entry
                (dst,) = self._alloc_blocks(DEVICE, 1, (CACHE_OWNER, l))
                self._copy(HOST, e.block, DEVICE, dst)
                promos.append((l, e.block, dst))
                self.pools[HOST].free([e.block])
                self.cache.relocate(e, DEVICE, dst)
            if not want_private:
                return e.block
            # copy-on-write: private copy of the partially-reused cached
            # block; the recomputed tokens [cached_len, block end) land in
            # the copy, never in the shared original
            (dst,) = self._alloc_blocks(DEVICE, 1, (req, l))
            self._copy(DEVICE, e.block, DEVICE, dst)
            cow.append((l, e.block, dst))
            unpin.append(e)
            private.append(dst)
            return dst

        tbl: Dict[int, LayerAllocation] = {}
        try:
            for l in range(self.n_layers):
                blocks: List[int] = []
                for i in range(n_shared):
                    e = self.cache.entries[(l, hashes[i])]
                    blocks.append(_resolve(e, l, want_private=False))
                if tail:
                    e = self.cache.entries[(l, hashes[n_shared])]
                    blocks.append(_resolve(e, l, want_private=True))
                tbl[l] = LayerAllocation(DEVICE, blocks, cached_len)
        except PoolExhausted:
            # roll back refs and private copies; promotions already
            # physically copied stay coherent (the entry moved tiers)
            for e in pinned:
                self.cache.decref(e)
            for dst in private:
                self.pools[DEVICE].free([dst])
            return None
        for e in unpin:
            self.cache.decref(e)
        self.tables[req] = tbl
        return PrefixAcquisition(cached_len, cow, promos)

    def register_prefix(self, req: str, tokens: List[int],
                        upto: Optional[Tokens] = None) -> Blocks:
        """Publish `req`'s full prompt blocks into the cache, for the
        blocks wholly inside [0, upto) (default: the whole prompt) — call
        as their KV is written (chunked prefill registers incrementally).
        Hashes already present are skipped — when `req` acquired them, its
        mapping was counted at acquire time. Returns #blocks newly
        cached."""
        if self.cache is None or req not in self.tables:
            return 0
        BS = self.block_size
        hashes = self._hashes(tokens)
        n_full = len(hashes) if upto is None \
            else min(len(hashes), upto // BS)
        added = 0
        for l, a in self.tables[req].items():
            for i in range(n_full):
                if i >= len(a.blocks):
                    break
                h = hashes[i]
                if (l, h) in self.cache.entries:
                    continue
                b = a.blocks[i]
                if self.cache.lookup(a.pool, b) is not None:
                    continue  # block already published under another key
                chunk = tuple(tokens[i * BS:(i + 1) * BS]) if l == 0 \
                    else None
                self.cache.add((l, h), a.pool, b, ref=1, tokens=chunk)
                added += 1
        return added

    # ----------------------------------------------------------- migration
    def move_layer(self, req: str, layer: LayerIdx, to_pool: str,
                   detach: bool = False) -> Tuple[List[int], List[int]]:
        """Migrate one layer's KV between pools. Returns (src_blocks,
        dst_blocks) so the caller can issue the physical copies; accounting
        is updated immediately (the engine's transfer ledger owns timing).

        Cache entries owned solely by `req` follow the move. Blocks SHARED
        with another live request are never pulled out from under the
        sharer: with `detach=False` such a layer refuses to migrate;
        eviction paths pass `detach=True`, which COPIES the shared blocks
        out (the request gets private replicas on `to_pool`, its refcounts
        drop, the shared originals stay where the sharers map them)."""
        a = self.tables[req][layer]
        if a.pool == to_pool:
            return (a.blocks, a.blocks)
        if self.layer_shared(req, layer) and not detach:
            raise ValueError(
                f"layer {layer} of {req} holds shared blocks; migration "
                "would pull them out from under another request "
                "(pass detach=True to copy them out)")
        src = list(a.blocks)
        dst = self._alloc_blocks(to_pool, len(src), (req, layer))
        for s, d in zip(src, dst, strict=True):
            e = self.cache.lookup(a.pool, s) \
                if self.cache is not None else None
            if e is not None and e.ref > 1:
                # copy-out: the shared source block survives untouched
                self.cache.decref(e)
                continue
            if e is not None:
                self.cache.relocate(e, to_pool, d)
            self.pools[a.pool].free([s])
        a.pool, a.blocks = to_pool, dst
        return src, dst

    # ------------------------------------------------------------- release
    def free_request(self, req: str) -> Blocks:
        """Release every block of a finished request. Cache-registered
        blocks are decref'd and retained (reclaimable LRU) instead of
        freed. Returns #blocks made available on DEVICE (free or
        reclaimable — feeds Eq.5 Released(t))."""
        tbl = self.tables.pop(req, {})
        dev_freed = 0
        for l, a in tbl.items():
            for b in a.blocks:
                e = self.cache.lookup(a.pool, b) \
                    if self.cache is not None else None
                if e is not None and e.ref > 0:
                    self.cache.decref(e)
                    if e.ref == 0:
                        self.pools[a.pool].chown(b, (CACHE_OWNER, l))
                        if a.pool == DEVICE:
                            dev_freed += 1  # reclaimable on demand
                    continue
                self.pools[a.pool].free([b])
                if a.pool == DEVICE:
                    dev_freed += 1
        return dev_freed

    def drop_cache(self) -> Blocks:
        """Drop every unreferenced cache entry (test/maintenance hook)."""
        if self.cache is None:
            return 0
        n = 0
        for e in list(self.cache.entries.values()):
            if e.ref == 0:
                self.pools[e.pool].free([e.block])
                self.cache.drop(e)
                n += 1
        return n

    def check(self) -> None:
        for p in self.pools.values():
            p.check()
        # table multiplicity of every physical block
        mult: Dict[Tuple[str, int], int] = {}
        for req, tbl in self.tables.items():
            for layer, a in tbl.items():
                for b in a.blocks:
                    mult[(a.pool, b)] = mult.get((a.pool, b), 0) + 1
        for key, m in mult.items():
            e = self.cache.lookup(*key) if self.cache is not None else None
            if e is None:
                assert m == 1, f"block {key} double-owned"
            else:
                assert m == e.ref, \
                    f"block {key}: {m} mappings but refcount {e.ref}"
        if self.cache is not None:
            for pool in (DEVICE, HOST):
                unref = {e.key for e in self.cache.entries.values()
                         if e.pool == pool and e.ref == 0}
                assert unref == set(self.cache.lru[pool]), \
                    f"{pool}: LRU index out of sync with entries"
            for e in self.cache.entries.values():
                key = (e.pool, e.block)
                assert e.ref == mult.get(key, 0), \
                    f"cache entry {e.key}: refcount {e.ref} but " \
                    f"{mult.get(key, 0)} mappings"
                # cached blocks are always pool-allocated, never free
                assert key[1] in self.pools[e.pool]._owner, \
                    f"cache entry {e.key} points at freed block {key}"
