"""Unit-dimension vocabulary for the KV-accounting surface.

Every accounting bug fixed in PRs 2, 6 and 8 was a unit confusion:
token counts compared against block counts, bytes priced as tokens,
layer indices used as sizes. The Eq.1/3/4 pipeline converts between
five dimensions constantly, so the conversions are made *first-class*
here and everything else is forbidden from mixing dimensions at all.

The aliases are `typing.NewType`-style in intent but implemented as
transparent `TypeAlias`es: a `Tokens` value is a plain `int` at runtime
and to mypy (so arithmetic, dataclass fields and third-party call sites
keep working untouched); the *checking* is supplied by the UNIT001
repro-lint rule (tools/analyze/units.py), which propagates these
dimensions through assignments, arithmetic, calls and returns and flags
any cross-dimension mixing that does not go through a sanctioned
converter below (or an annotated converting method such as
`LayerwiseBlockManager.blocks_for_tokens`).

Sanctioned converters (the ONLY blessed casts — see the table in
docs/ARCHITECTURE.md "Invariants & analysis"):

    tokens_to_blocks   Tokens -> Blocks   ceil-divide by block_size
    blocks_to_tokens   Blocks -> Tokens   multiply by block_size
    tokens_to_bytes    Tokens -> Bytes    multiply by bytes/token
    blocks_to_bytes    Blocks -> Bytes    via blocks_to_tokens
    bytes_to_seconds   Bytes  -> Seconds  divide by link bandwidth
"""
from __future__ import annotations

from typing import TypeAlias

# Dimension aliases. Transparent on purpose: UNIT001 reads these NAMES
# out of annotations; the runtime and mypy see plain int/float.
Tokens: TypeAlias = int      # prompt/generated token counts
Blocks: TypeAlias = int      # paged-KV block counts (device or host)
Bytes: TypeAlias = int       # raw KV byte counts (ledger, link pricing)
LayerIdx: TypeAlias = int    # a transformer layer index (NOT a size)
Seconds: TypeAlias = float   # virtual-clock durations and stamps


def tokens_to_blocks(n_tokens: Tokens, block_size: int) -> Blocks:
    """Blocks needed to hold `n_tokens` (ceil: a partial block is a
    whole block — the same rounding every pool allocation pays)."""
    return -(-n_tokens // block_size) if n_tokens > 0 else 0


def blocks_to_tokens(n_blocks: Blocks, block_size: int) -> Tokens:
    """Token CAPACITY of `n_blocks` (the upper edge of the ceil above:
    converting back and forth can only grow, never lose, capacity)."""
    return n_blocks * block_size


def tokens_to_bytes(n_tokens: Tokens, bytes_per_token: int) -> Bytes:
    """KV bytes for `n_tokens` at a per-token KV footprint (the cost
    model's 2 * d_model * dtype_bytes per layer, times layers)."""
    return n_tokens * bytes_per_token


def blocks_to_bytes(n_blocks: Blocks, block_size: int,
                    bytes_per_token: int) -> Bytes:
    """KV bytes held by `n_blocks` full blocks."""
    return tokens_to_bytes(blocks_to_tokens(n_blocks, block_size),
                           bytes_per_token)


def bytes_to_seconds(n_bytes: Bytes, bandwidth: float) -> Seconds:
    """Link occupancy for `n_bytes` at `bandwidth` bytes/second (the
    ledger's pricing of one offload/reload transfer)."""
    return n_bytes / bandwidth
