"""Layer-wise KV offload policy + transfer ledger (paper §3.1.1-§3.1.3).

Three responsibilities:
  1. choose WHICH layers to retain on device (Eq. 4 overlap condition via
     the cost model, evenly interleaved across depth per §3.1.2);
  2. track WHEN transfers complete on the offload link — a simple busy-time
     ledger that both the real engine and the simulator share;
  3. avoid link contention with collectives (§3.1.3): transfers are cut
     into sub-units and each sub-unit defers while the link is reserved
     (the all-reduce critical path on PCIe testbeds; disjoint fabrics on
     TPU, where this policy simply never triggers).
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, List, Optional, Tuple

if TYPE_CHECKING:  # pragma: no cover — import cycle (serving -> core)
    from repro.serving.costmodel import CostModel


def interleave_offload_layers(n_layers: int, retain: int) -> List[int]:
    """Indices of layers to OFFLOAD, evenly spread across depth. With 8
    layers and retain=4 the paper keeps 1,3,5,7 and offloads 0,2,4,6."""
    retain = max(0, min(retain, n_layers))
    n_off = n_layers - retain
    if n_off <= 0:
        return []
    if retain == 0:
        return list(range(n_layers))
    # distribute offloaded layers as evenly as possible, starting at 0
    out, acc = [], 0.0
    step = n_layers / n_off
    for i in range(n_off):
        out.append(min(n_layers - 1, int(round(i * step))))
    # dedupe while preserving count (fall back to first free slots)
    seen, fixed = set(), []
    for l in out:
        while l in seen:
            l += 1
        seen.add(l)
        fixed.append(l)
    return sorted(fixed)


@dataclasses.dataclass
class Transfer:
    start: float      # when bytes actually began moving (post-queueing)
    end: float
    nbytes: int
    kind: str         # 'offload' (d2h) | 'reload' (h2d)
    submitted: float = 0.0  # when the transfer was queued; start - submitted
    #                         is the link-queueing delay


class LinkLedger:
    """Serialized offload-link occupancy with §3.1.3 contention avoidance."""

    def __init__(self, bandwidth: float, chunk_bytes: int = 4 << 20,
                 check_backoff: float = 0.2):
        self.bw = bandwidth
        self.chunk = chunk_bytes
        self.backoff = check_backoff  # fraction of reservation to wait
        self.busy_until = 0.0
        self.reservations: List[Tuple[float, float]] = []  # collectives
        self.log: List[Transfer] = []

    # collectives (all-reduce) reserve the link on non-NVLink testbeds
    def reserve(self, start: float, dur: float) -> None:
        # prune expired windows so _blocked stays O(live reservations)
        self.reservations = [(s, e) for s, e in self.reservations
                             if e > start]
        self.reservations.append((start, start + dur))

    def _blocked(self, t: float) -> Optional[float]:
        for s, e in self.reservations:
            if s <= t < e:
                return e
        return None

    def submit(self, now: float, nbytes: int, kind: str) -> float:
        """Queue a transfer at `now`; returns completion time. The transfer
        is chunked; each chunk checks the link and defers by a fraction of
        the blocking reservation when occupied (paper §3.1.3). The logged
        `start` is when the FIRST byte moves — after both the link-busy
        queue and any reservation deferrals — not the submit time."""
        t = max(now, self.busy_until)
        remaining = nbytes
        start = None
        while remaining > 0:
            blk = self._blocked(t)
            if blk is not None:
                t += max((blk - t) * self.backoff, 1e-6)
                continue
            if start is None:
                start = t
            sz = min(self.chunk, remaining)
            t += sz / self.bw
            remaining -= sz
        self.busy_until = t
        self.log.append(Transfer(start if start is not None else t, t,
                                 nbytes, kind, submitted=now))
        return t

    def idle_at(self, now: float) -> bool:
        return now >= self.busy_until and self._blocked(now) is None


@dataclasses.dataclass
class OffloadPlan:
    retain_layers: List[int]     # stay on device
    offload_layers: List[int]    # go to host during prefill
    x: int                       # = len(retain_layers)


class OffloadEngine:
    """Policy front-end used by both the real engine and the simulator."""

    def __init__(self, cost: CostModel, n_layers: int,
                 ledger: Optional[LinkLedger] = None):
        self.cost = cost
        self.n_layers = n_layers
        self.ledger = ledger or LinkLedger(cost.hw.offload_bw)

    def plan_for_prompt(self, prompt_len: int) -> OffloadPlan:
        """Eq. 4: retain the minimum x layers whose offload cannot hide
        under prefill compute; long prompts drive x to 0."""
        x = self.cost.min_retained_layers(prompt_len)
        off = interleave_offload_layers(self.n_layers, x)
        retain = [l for l in range(self.n_layers) if l not in set(off)]
        return OffloadPlan(retain, off, x)

    def prefill_offload_done(self, now: float, prompt_len: int,
                             plan: OffloadPlan) -> float:
        """Completion time of the prefill-stage d2h copies (they start as
        soon as each layer's KV is produced; paper §4 overlaps them with
        the same layer's compute)."""
        nbytes = self.cost.kv_bytes(prompt_len, len(plan.offload_layers))
        if nbytes == 0:
            return now
        return self.ledger.submit(now, nbytes, "offload")

    def proactive_offload(self, now: float, ctx_len: int,
                          n_layers_to_evict: int) -> float:
        nbytes = self.cost.kv_bytes(ctx_len, n_layers_to_evict)
        if nbytes == 0:
            return now
        return self.ledger.submit(now, nbytes, "offload")

    def decode_reload_time(self, batch_size: int, avg_ctx: int,
                           host_layers: int) -> float:
        """Per-step h2d streaming of host-resident layers (overlapped; the
        cost model already takes max(compute, reload))."""
        return self.cost.kv_bytes(avg_ctx, host_layers) * batch_size \
            / self.cost.hw.offload_bw
