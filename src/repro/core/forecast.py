"""GPU KV block availability forecast (paper Eq. 5).

    Avail(t+1) = Avail(t) + Released(t) - Allocated(t)

Rolls the block ledger forward over a horizon of decode stages to decide
*proactively* whether the retained x layers of recent requests must be
offloaded before the pool runs dry (paper §3.1.1 last paragraph).
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, List, Sequence

from repro.core.predictor import LengthPredictor

if TYPE_CHECKING:  # annotation-only: a runtime import would cycle via
    from repro.serving.request import Request  # repro.core.units


@dataclasses.dataclass
class AvailabilityForecast:
    predictor: LengthPredictor
    block_size: int

    def forecast(self, avail_now: int, decoding: Sequence[Request],
                 horizon: int, prefill_blocks_per_stage: int = 0
                 ) -> List[int]:
        """Projected free DEVICE blocks at the start of the next `horizon`
        stages. Released(t): blocks of sequences predicted (bucket median)
        to finish at stage t. Allocated(t): one block per live sequence
        (conservative, paper §3.1.1) + the controlled prefill allocation."""
        # predicted remaining tokens per decoding request
        remaining = []
        for r in decoding:
            med = self.predictor.n_median_total(r)
            remaining.append(max(1, med - r.tokens_out))
        # device blocks a finished request releases (its device-resident
        # share; callers pass per-request block counts via closure if they
        # want exactness — the paper uses the same rough estimate)
        avail = avail_now
        out = []
        live = list(remaining)
        for t in range(1, horizon + 1):
            released = 0
            still = []
            for rem, r in zip(live, decoding):
                if rem == t:  # predicted to finish at this stage
                    released += sum(
                        1 for _ in range(self._req_device_blocks(r)))
                else:
                    still.append((rem, r))
            allocated = len([rem for rem, _ in still if rem > t]) \
                + prefill_blocks_per_stage
            avail = avail + released - allocated
            out.append(avail)
        return out

    def _req_device_blocks(self, r: Request) -> int:
        # rough: ceil(ctx/block) blocks for ONE device-resident layer; the
        # engine overrides with exact numbers via `blocks_of`.
        ctx = r.prompt_len + r.tokens_out
        return -(-ctx // self.block_size)

    def needs_proactive_offload(self, avail_now: int,
                                decoding: Sequence[Request],
                                horizon: int, threshold: int,
                                prefill_blocks_per_stage: int = 0) -> bool:
        fc = self.forecast(avail_now, decoding, horizon,
                           prefill_blocks_per_stage)
        return any(a < threshold for a in fc)
