"""Opt-in runtime KV-accounting sanitizer (``ServeConfig.sanitize``).

The block manager, prefix cache, and transfer ledger each keep their own
books; the identity/property tests check those books at *run end*. The
sanitizer turns that into "every intermediate state is consistent": it
interposes on the mutation API of one `LayerwiseBlockManager` (pool
alloc/free/chown, cache incref/decref/add/drop/relocate, `move_layer`,
the `_copy` hook) and the `LinkLedger.submit` path, maintains an
INDEPENDENT shadow model from the observed event stream, and compares
shadow against reality after every scheduler step on either backend.

Invariants checked (see docs/ARCHITECTURE.md "Invariants & analysis"):

  S1  pool mirror        shadow owner map == pool._owner and shadow free
                         count == len(pool._free), per pool — a mutation
                         that bypassed the pool API (or a double
                         accounting inside it) diverges the mirror;
  S2  conservation       free + owned == pool size, per pool, where
                         owned splits into live (request, layer) mappings
                         and CACHE_OWNER-retained ref==0 blocks;
  S3  single tier        every block of a (request, layer) allocation is
                         owned in exactly the allocation's pool; a block
                         is never simultaneously free and owned;
  S4  refcounts          shadow refcount == cache entry refcount == live
                         table multiplicity, for every cache entry, and
                         never negative (a decref below zero raises at
                         the event, not at the next check);
  S5  ledger h2d         cumulative "reload" bytes == bytes implied by
                         shadow-observed host->device layer movements and
                         cache promotions (every h2d charge in the stack
                         is movement-driven, so this is an equality);
  S6  ledger d2h         cumulative "offload" bytes >= bytes implied by
                         shadow-observed device->host movements (prefill
                         d2h STREAMING of freshly produced KV is charged
                         on top of movements, so d2h is one-sided);
  S7  phase/queue        every live request sits in exactly the
                         SchedulerCore queue its Phase names
                         (scheduler.PHASE_QUEUES — the same registry the
                         PHASE001 lint rule keeps total over the enum),
                         and every block table belongs to a live request;
  S8  baseline           with no live requests, both pools are back to
                         baseline: nothing owned except ref==0 cache
                         retentions (cancel/preempt/resume unwound
                         everything they touched);
  S9  recovery baseline  after a replica kill unwinds every request the
                         dead replica owned (`ClusterSession.kill`), the
                         core must be FULLY at baseline — no live
                         requests in any queue, no block tables, nothing
                         owned by non-cache owners, all cache refcounts
                         zero — before any work is re-dispatched
                         (`check_recovery_baseline`, an unconditional
                         strict form of S8).

Cost discipline — ``check`` runs after EVERY scheduler step, so it is
tiered: the count/conservation halves of S1/S2, the ledger totals
(S5/S6), and the phase/queue scan (S7) are O(pools + live requests) and
run on every call; the deep structural comparison (owner-map equality,
the full table walk behind S3/S4, per-entry refcounts) is O(mapped
blocks) and runs every ``check_interval`` steps, whenever the core goes
idle (so S8 always sees a deep-checked baseline), and on
``check(core, full=True)``.  Mutation-time traps (double free, negative
refcount) fire at the offending event regardless of cadence.  The full
free-list/owner disjointness scan (part of S3) is additionally skipped
for pools larger than ``FULL_SCAN_MAX_BLOCKS`` (the sim's default host
pool is 2^20 blocks); S1/S2 still catch free-list corruption there via
counts and the owner mirror.

Test hooks: ``inject_double_free`` / ``inject_refcount_leak`` /
``inject_ledger_mismatch`` plant exactly the historical bug classes the
sanitizer exists for, bypassing the structure's own guards the way a
buggy caller would; a regression test asserts ``check()`` catches each.
"""
from __future__ import annotations

import math
from typing import TYPE_CHECKING, Dict, List, Optional, Tuple

from repro.core.block_manager import (
    CACHE_OWNER, DEVICE, HOST, CachedBlock, LayerwiseBlockManager, _Pool,
)
from repro.core.offload_engine import OffloadEngine

if TYPE_CHECKING:  # pragma: no cover - import cycle (scheduler -> here)
    from repro.serving.costmodel import CostModel
    from repro.serving.scheduler import SchedulerCore

# pools at or under this size get the full free-list/owner disjointness
# scan every step; larger pools rely on the owner mirror + counts
FULL_SCAN_MAX_BLOCKS = 8192


class SanitizerError(AssertionError):
    """An accounting invariant broke. Carries the invariant id (S1..S9)
    in the message so regression tests can pin which check fired."""


class _ShadowPool:
    """Event-sourced mirror of one `_Pool`: owner map + free count,
    updated ONLY from intercepted alloc/free/chown calls."""

    def __init__(self, pool: _Pool):
        self.name = pool.name
        self.total = pool.num_blocks
        self.free_count = pool.num_free
        self.owner: Dict[int, Tuple[str, int]] = dict(pool._owner)


class KVSanitizer:
    """Shadow-tracks one block manager + offload engine. Construct once
    per `SchedulerCore` (both backends); call `check(core)` after each
    scheduler step."""

    # deep structural comparison cadence (see module docstring)
    check_interval = 16

    def __init__(self, bm: LayerwiseBlockManager, off: OffloadEngine,
                 cost: "CostModel"):
        self.bm = bm
        self.off = off
        self.cost = cost
        self.n_checks = 0
        self.n_full_checks = 0
        self.n_events = 0
        self.shadow_pools = {name: _ShadowPool(p)
                             for name, p in bm.pools.items()}
        # cache key -> shadow refcount (entries mirrored at install time)
        self.shadow_refs: Dict[Tuple[int, int], int] = {}
        if bm.cache is not None:
            self.shadow_refs = {k: e.ref for k, e in bm.cache.entries.items()}
        # ledger accounting: bytes the ledger charged per direction vs
        # bytes the observed layer movements imply
        self.charged_h2d = 0.0
        self.charged_d2h = 0.0
        self.expected_h2d = 0.0
        self.expected_d2h = 0.0
        self._install()

    # ------------------------------------------------------------ wiring
    def _install(self) -> None:
        for pool in self.bm.pools.values():
            self._wrap_pool(pool)
        if self.bm.cache is not None:
            self._wrap_cache()
        self._wrap_moves()
        self._wrap_ledger()

    def _wrap_pool(self, pool: _Pool) -> None:
        sp = self.shadow_pools[pool.name]
        orig_alloc, orig_free, orig_chown = pool.alloc, pool.free, pool.chown

        def alloc(n: int, owner: Tuple[str, int]) -> List[int]:
            blocks = orig_alloc(n, owner)
            self.n_events += 1
            sp.free_count -= len(blocks)
            for b in blocks:
                if b in sp.owner:
                    raise SanitizerError(
                        f"S1 {sp.name}: alloc handed out owned block {b}")
                sp.owner[b] = owner
            return blocks

        def free(blocks: List[int]) -> None:
            # shadow first: a double free must be caught even if the
            # pool's own guard were broken (that guard is the bug class)
            self.n_events += 1
            for b in blocks:
                if b not in sp.owner:
                    raise SanitizerError(
                        f"S1 {sp.name}: free of unowned block {b} "
                        "(double free)")
                del sp.owner[b]
                sp.free_count += 1
            orig_free(blocks)

        def chown(block: int, owner: Tuple[str, int]) -> None:
            self.n_events += 1
            if block not in sp.owner:
                raise SanitizerError(
                    f"S1 {sp.name}: chown of free block {block}")
            sp.owner[block] = owner
            orig_chown(block, owner)

        pool.alloc, pool.free, pool.chown = alloc, free, chown

    def _wrap_cache(self) -> None:
        cache = self.bm.cache
        refs = self.shadow_refs
        orig = {m: getattr(cache, m)
                for m in ("incref", "decref", "add", "drop")}

        def incref(e: CachedBlock) -> None:
            self.n_events += 1
            refs[e.key] = refs.get(e.key, 0) + 1
            orig["incref"](e)

        def decref(e: CachedBlock) -> None:
            self.n_events += 1
            if refs.get(e.key, 0) <= 0:
                raise SanitizerError(
                    f"S4 cache entry {e.key}: refcount would drop below "
                    "zero")
            refs[e.key] -= 1
            orig["decref"](e)

        def add(key, pool, block, ref, tokens=None) -> CachedBlock:
            self.n_events += 1
            refs[key] = ref
            return orig["add"](key, pool, block, ref, tokens)

        def drop(e: CachedBlock) -> None:
            self.n_events += 1
            refs.pop(e.key, None)
            orig["drop"](e)

        cache.incref, cache.decref = incref, decref
        cache.add, cache.drop = add, drop

    def _wrap_moves(self) -> None:
        bm = self.bm
        orig_move, orig_copy = bm.move_layer, bm._copy

        def move_layer(req: str, layer: int, to_pool: str,
                       detach: bool = False):
            a = bm.tables[req][layer]
            crossed = a.pool != to_pool
            nbytes = self.cost.kv_bytes(a.num_tokens, 1) if crossed else 0.0
            from_pool = a.pool
            out = orig_move(req, layer, to_pool, detach=detach)
            if crossed:
                self.n_events += 1
                if from_pool == HOST and to_pool == DEVICE:
                    self.expected_h2d += nbytes
                elif from_pool == DEVICE and to_pool == HOST:
                    self.expected_d2h += nbytes
            return out

        def _copy(src_pool: str, src: int, dst_pool: str, dst: int):
            # charges only flow when a copy hook is installed
            # (SchedulerCore.cache_copy); d2d COW never touches the link
            if bm.on_copy is not None and src_pool != dst_pool:
                self.n_events += 1
                nbytes = self.cost.kv_bytes(bm.block_size, 1)
                if src_pool == HOST and dst_pool == DEVICE:
                    self.expected_h2d += nbytes
                else:
                    self.expected_d2h += nbytes
            orig_copy(src_pool, src, dst_pool, dst)

        bm.move_layer, bm._copy = move_layer, _copy

    def _wrap_ledger(self) -> None:
        ledger = self.off.ledger
        orig_submit = ledger.submit

        def submit(now: float, nbytes: float, kind: str) -> float:
            self.n_events += 1
            if kind == "reload":
                self.charged_h2d += nbytes
            else:
                self.charged_d2h += nbytes
            return orig_submit(now, nbytes, kind)

        ledger.submit = submit

    # ------------------------------------------------------------ checks
    @staticmethod
    def _fail(msg: str) -> None:
        raise SanitizerError(msg)

    def _check_counts(self) -> None:
        """Every-step S1/S2 skim: count-level mirror + conservation,
        O(number of pools)."""
        for name, pool in self.bm.pools.items():
            sp = self.shadow_pools[name]
            if sp.free_count != pool.num_free:
                self._fail(
                    f"S1 {name}: shadow free count {sp.free_count} != "
                    f"pool free list {pool.num_free}")
            if len(sp.owner) != len(pool._owner):
                self._fail(
                    f"S1 {name}: shadow owns {len(sp.owner)} blocks, "
                    f"pool owns {len(pool._owner)}")
            if sp.free_count + len(sp.owner) != sp.total:
                self._fail(
                    f"S2 {name}: free {sp.free_count} + owned "
                    f"{len(sp.owner)} != pool size {sp.total}")

    def _check_pools(self) -> Dict[Tuple[str, int], Tuple[str, int]]:
        """S1-S3 pool side; returns the combined (pool, block) -> owner
        map for the table checks."""
        owners: Dict[Tuple[str, int], Tuple[str, int]] = {}
        for name, pool in self.bm.pools.items():
            sp = self.shadow_pools[name]
            if sp.owner != pool._owner:
                only_s = set(sp.owner) - set(pool._owner)
                only_p = set(pool._owner) - set(sp.owner)
                self._fail(
                    f"S1 {name}: shadow owner map diverged from pool "
                    f"(shadow-only {sorted(only_s)[:4]}, pool-only "
                    f"{sorted(only_p)[:4]})")
            if sp.free_count != pool.num_free:
                self._fail(
                    f"S1 {name}: shadow free count {sp.free_count} != "
                    f"pool free list {pool.num_free}")
            if sp.free_count + len(sp.owner) != sp.total:
                self._fail(
                    f"S2 {name}: free {sp.free_count} + owned "
                    f"{len(sp.owner)} != pool size {sp.total}")
            if sp.total <= FULL_SCAN_MAX_BLOCKS:
                free_set = set(pool._free)
                if len(free_set) != pool.num_free:
                    self._fail(f"S3 {name}: duplicate ids on the free list")
                inter = free_set & set(pool._owner)
                if inter:
                    self._fail(
                        f"S3 {name}: blocks {sorted(inter)[:4]} are both "
                        "free and owned")
            for b, owner in sp.owner.items():
                owners[(name, b)] = owner
        return owners

    def _check_tables(
            self, owners: Dict[Tuple[str, int], Tuple[str, int]]
    ) -> Dict[Tuple[str, int], int]:
        """S3/S4 table side; returns live multiplicity per block."""
        cache = self.bm.cache
        mult: Dict[Tuple[str, int], int] = {}
        for req, tbl in self.bm.tables.items():
            for layer, a in tbl.items():
                for b in a.blocks:
                    key = (a.pool, b)
                    mult[key] = mult.get(key, 0) + 1
                    if key not in owners:
                        self._fail(
                            f"S3 {req} layer {layer}: maps block {b} on "
                            f"{a.pool} but the pool does not own it "
                            "(freed or wrong tier)")
                    if cache is None or cache.lookup(a.pool, b) is None:
                        if owners[key] != (req, layer):
                            self._fail(
                                f"S3 uncached block {key} mapped by "
                                f"({req}, {layer}) but owned by "
                                f"{owners[key]}")
        for key, m in mult.items():
            e = cache.lookup(*key) if cache is not None else None
            if e is None and m != 1:
                self._fail(f"S3 uncached block {key} mapped {m} times")
        return mult

    def _check_cache(self, mult: Dict[Tuple[str, int], int]) -> int:
        """S4 + the cache half of S2; returns #cache-retained blocks."""
        cache = self.bm.cache
        if cache is None:
            if self.shadow_refs:
                self._fail("S4 shadow has refs but the cache is off")
            return 0
        if set(self.shadow_refs) != set(cache.entries):
            self._fail(
                "S4 shadow entry set diverged from the cache "
                f"({len(self.shadow_refs)} shadow vs "
                f"{len(cache.entries)} actual)")
        retained = 0
        for key, e in cache.entries.items():
            sref = self.shadow_refs[key]
            if sref < 0:
                self._fail(f"S4 cache entry {key}: negative shadow "
                           f"refcount {sref}")
            if sref != e.ref:
                self._fail(
                    f"S4 cache entry {key}: shadow refcount {sref} != "
                    f"entry refcount {e.ref}")
            if e.ref != mult.get((e.pool, e.block), 0):
                self._fail(
                    f"S4 cache entry {key}: refcount {e.ref} but "
                    f"{mult.get((e.pool, e.block), 0)} live mappings")
            if e.ref == 0:
                retained += 1
        return retained

    def _check_ledger(self) -> None:
        if not math.isclose(self.charged_h2d, self.expected_h2d,
                            rel_tol=1e-9, abs_tol=1.0):
            self._fail(
                f"S5 ledger reload bytes {self.charged_h2d:.0f} != "
                f"shadow-observed h2d movement bytes "
                f"{self.expected_h2d:.0f}")
        if self.charged_d2h < self.expected_d2h - 1.0:
            self._fail(
                f"S6 ledger offload bytes {self.charged_d2h:.0f} < "
                f"shadow-observed d2h movement bytes "
                f"{self.expected_d2h:.0f} (a movement went uncharged)")

    def _check_lifecycle(self, core: "SchedulerCore") -> None:
        from repro.serving.scheduler import LIVE_QUEUES, PHASE_QUEUES
        live_rids = set()
        for phase, qname in PHASE_QUEUES.items():
            for r in getattr(core, qname):
                if r.phase is not phase:
                    self._fail(
                        f"S7 request {r.rid} sits in '{qname}' but its "
                        f"phase is {r.phase.name} (expected {phase.name})")
                if qname in LIVE_QUEUES:
                    live_rids.add(r.rid)
        stray = set(self.bm.tables) - live_rids
        if stray:
            self._fail(
                f"S7 block tables for {sorted(stray)[:4]} but no live "
                "request owns them (leak on a retire/cancel path)")

    def _check_baseline(self, core: "SchedulerCore") -> None:
        if core.prefilling or core.decoding or core.paused \
                or self.bm.tables:
            return
        for name, sp in self.shadow_pools.items():
            non_cache = [b for b, (req, _) in sp.owner.items()
                         if req != CACHE_OWNER]
            if non_cache:
                self._fail(
                    f"S8 {name}: idle core but blocks "
                    f"{sorted(non_cache)[:4]} are still owned by "
                    "non-cache owners (unwind leaked them)")
        for key, ref in self.shadow_refs.items():
            if ref != 0:
                self._fail(
                    f"S8 cache entry {key}: idle core but refcount {ref}")

    def check_recovery_baseline(self, core: "SchedulerCore") -> None:
        """S9: post-kill pool accounting. `ClusterSession.kill` calls
        this after unwinding everything the dead replica owned and
        before re-dispatching any of it — unlike S8 (which silently
        skips while anything looks live), a non-empty queue or a
        leftover block table here IS the failure: the kill path missed
        something, and re-dispatch would double-account it."""
        for qname in ("waiting", "prefilling", "decoding", "paused"):
            q = getattr(core, qname)
            if q:
                self._fail(
                    f"S9 recovery: '{qname}' still holds "
                    f"{[r.rid for r in q][:4]} after the kill unwind")
        if self.bm.tables:
            self._fail(
                f"S9 recovery: block tables survive for "
                f"{sorted(self.bm.tables)[:4]} (KV not freed)")
        for name, sp in self.shadow_pools.items():
            non_cache = [b for b, (req, _) in sp.owner.items()
                         if req != CACHE_OWNER]
            if non_cache:
                self._fail(
                    f"S9 recovery: {name} blocks {sorted(non_cache)[:4]} "
                    "still owned by non-cache owners")
        for key, ref in self.shadow_refs.items():
            if ref != 0:
                self._fail(
                    f"S9 recovery: cache entry {key} refcount {ref} != 0")

    def check(self, core: Optional["SchedulerCore"] = None,
              full: Optional[bool] = None) -> None:
        """Assert the invariants against the current state. Called by
        the backends after each step. ``full=None`` lets the cadence
        decide (every ``check_interval``-th call, or whenever the core
        is idle); ``full=True`` forces the deep structural comparison
        (tests use this), ``full=False`` forces the cheap tier only."""
        self.n_checks += 1
        self._check_counts()
        self._check_ledger()
        idle = core is not None and not (
            core.prefilling or core.decoding or core.paused
            or self.bm.tables)
        if core is not None:
            self._check_lifecycle(core)
        if full is None:
            full = idle or self.n_checks % self.check_interval == 0
        if full:
            self.n_full_checks += 1
            owners = self._check_pools()
            mult = self._check_tables(owners)
            self._check_cache(mult)
            if core is not None:
                self._check_baseline(core)

    # -------------------------------------------------------- test hooks
    def inject_double_free(self) -> None:
        """Plant a free-list/owner overlap: an owned block re-enters the
        free list behind the pool API's back (the effect of freeing a
        block twice through a path that skips the guard)."""
        pool = self.bm.pools[DEVICE]
        if not pool._owner:
            raise RuntimeError("need at least one owned device block")
        b = next(iter(pool._owner))
        pool._free.append(b)

    def inject_refcount_leak(self) -> None:
        """Bump a cache entry's refcount with no table mapping behind it
        (the effect of an incref whose mapping was rolled back)."""
        cache = self.bm.cache
        if cache is None or not cache.entries:
            raise RuntimeError("need a populated prefix cache")
        e = next(iter(cache.entries.values()))
        e.ref += 1

    def inject_ledger_mismatch(self) -> None:
        """Charge the link for an h2d transfer no layer movement backs
        (the double-accounting class the PR 2 `_promote` fix removed)."""
        self.off.ledger.submit(0.0, float(self.cost.kv_bytes(1, 1)),
                               "reload")
