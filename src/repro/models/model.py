"""Composable model definitions for every assigned architecture family.

Uniform interface (`Model`):
    params = model.init(rng)
    logits, aux = model.train_logits(params, batch)        # full-seq teacher forcing
    loss, metrics = model.loss(params, batch)
    cache = model.init_cache(batch_size, cache_len, dtype)  # decode state buffers
    logits, cache = model.prefill(params, batch, cache)     # fill cache, last-pos logits
    logits, cache = model.decode(params, tokens, cache)     # one token per sequence

`batch` keys by family:
    dense/moe:  tokens (B,S) int32, labels (B,S)
    vlm:        embeds (B,S,d) [stub ViT output incl. text emb], mrope_pos (3,B,S),
                labels (B,S); decode takes token ids (text continuation)
    encdec:     enc_embeds (B,T,d) [stub conv/mel frontend], tokens (B,S), labels
    ssm/hybrid: tokens, labels

Layers run under `lax.scan` over stacked params; hybrid/xlstm scan over
uniform superblocks. Sliding-window decode uses a ring-buffer KV cache.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.models import layers, moe, ssm
from repro.models.act_sharding import (constrain, constrain_compute,
                                       constrain_kv, constrain_kv_stack)


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _stack_init(fn, key, n):
    return jax.vmap(fn)(jax.random.split(key, n))


def _positions_for(cfg: ModelConfig, B, S, offset=0):
    pos = jnp.arange(S)[None] + jnp.asarray(offset).reshape(-1, 1)  # (B?,S)
    pos = jnp.broadcast_to(pos, (B, S))
    if cfg.pos_emb == "mrope":
        return jnp.broadcast_to(pos[None], (3, B, S))  # text-only stream
    return pos


def cross_entropy(logits, labels, mask=None):
    """logits (B,S,V) any-dtype, labels (B,S) int32."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is None:
        return nll.mean()
    mask = mask.astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def _mask_pad_logits(cfg: ModelConfig, logits):
    """Embeddings/heads are padded to cfg.padded_vocab for even model-axis
    sharding; pad positions must never win argmax nor leak into logsumexp."""
    if cfg.padded_vocab == cfg.vocab_size:
        return logits
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    return jnp.where(iota < cfg.vocab_size, logits, -1e30)


def _write_kv(k_cache, v_cache, k_new, v_new, write_idx):
    """Scatter one new token's KV into (B, S_buf, KV, hd) at per-seq index."""
    B = k_cache.shape[0]
    b = jnp.arange(B)
    k_cache = k_cache.at[b, write_idx].set(k_new[:, 0].astype(k_cache.dtype))
    v_cache = v_cache.at[b, write_idx].set(v_new[:, 0].astype(v_cache.dtype))
    return k_cache, v_cache


# ---------------------------------------------------------------------------
# attention layer (dense / moe / vlm share it)
# ---------------------------------------------------------------------------

def init_block(cfg: ModelConfig, key, dtype):
    ka, km, kn = jax.random.split(key, 3)
    p = {
        "attn_norm": layers.init_norm(cfg, kn, cfg.d_model, dtype),
        "attn": layers.init_attention(cfg, ka, dtype),
        "mlp_norm": layers.init_norm(cfg, kn, cfg.d_model, dtype),
    }
    if cfg.family == "moe":
        p["moe"] = moe.init_moe(cfg, km, dtype)
    else:
        p["mlp"] = layers.init_mlp(cfg, km, dtype)
    return p


def block_forward(cfg: ModelConfig, p, x, positions, *, window=0,
                  kv_len=None, collect_kv=False, dropless=False):
    """Full-sequence transformer block. Returns (x, kv, aux)."""
    h = layers.apply_norm(cfg, p["attn_norm"], x)
    attn, kv = layers.self_attention(cfg, p["attn"], h, positions,
                                     causal=True, window=window,
                                     kv_len=kv_len)
    x = x + attn
    h = layers.apply_norm(cfg, p["mlp_norm"], x)
    if cfg.family == "moe":
        f, aux = moe.moe_ffn(cfg, p["moe"], h, dropless=dropless)
    else:
        f, aux = layers.mlp(cfg, p["mlp"], h), {}
    x = x + f
    return x, (kv if collect_kv else None), aux


def block_decode(cfg: ModelConfig, p, x, k_cache, v_cache, kv_len, positions,
                 write_idx):
    """One-token block step. x: (B,1,d). Caches (B,S_buf,KV,hd)."""
    h = layers.apply_norm(cfg, p["attn_norm"], x)
    q, k, v = layers.decode_self_attention(cfg, p["attn"], h, k_cache,
                                           v_cache, kv_len, positions)
    k_cache, v_cache = _write_kv(k_cache, v_cache, k, v, write_idx)
    o = ops.decode_attention(q, k_cache, v_cache, kv_len)
    x = x + layers.attn_out(cfg, p["attn"], o)
    h = layers.apply_norm(cfg, p["mlp_norm"], x)
    if cfg.family == "moe":
        f, _ = moe.moe_ffn(cfg, p["moe"], h, dropless=True)
    else:
        f = layers.mlp(cfg, p["mlp"], h)
    x = x + f
    return x, k_cache, v_cache


# ===========================================================================
# Model container
# ===========================================================================

@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    init: Callable
    train_logits: Callable      # (params, batch) -> (logits, aux)
    prefill: Callable           # (params, batch, cache) -> (logits, cache)
    decode: Callable            # (params, tokens, cache) -> (logits, cache)
    init_cache: Callable        # (B, cache_len, dtype) -> cache

    def loss(self, params, batch):
        logits, aux = self.train_logits(params, batch)
        ce = cross_entropy(logits, batch["labels"], batch.get("loss_mask"))
        total = ce
        metrics = {"ce": ce}
        if "lb_loss" in aux:
            total = total + 0.01 * aux["lb_loss"]
            metrics.update(lb_loss=aux["lb_loss"],
                           dropped_frac=aux.get("dropped_frac", 0.0))
        metrics["loss"] = total
        return total, metrics


def build_model(cfg: ModelConfig) -> Model:
    if cfg.family in ("dense", "moe", "vlm"):
        return _decoder_model(cfg)
    if cfg.family == "encdec":
        return _encdec_model(cfg)
    if cfg.family == "hybrid":
        return _hybrid_model(cfg)
    if cfg.family == "ssm":
        return _xlstm_model(cfg)
    raise ValueError(cfg.family)


# ===========================================================================
# decoder-only (dense / moe / vlm)
# ===========================================================================

def _decoder_model(cfg: ModelConfig) -> Model:
    dtype = jnp.dtype(cfg.dtype)

    def init(rng):
        ke, kl, kh = jax.random.split(rng, 3)
        p = {
            "embed": layers.embed_init(ke, cfg.padded_vocab, cfg.d_model, dtype),
            "layers": _stack_init(
                lambda k: init_block(cfg, k, dtype), kl, cfg.n_layers),
            "final_norm": layers.init_norm(cfg, kh, cfg.d_model, dtype),
        }
        if not cfg.tie_embeddings:
            p["lm_head"] = layers.dense_init(kh, cfg.d_model, cfg.padded_vocab,
                                             dtype)
        return p

    def _unembed(p, x):
        w = p["embed"].T if cfg.tie_embeddings else p["lm_head"]
        return _mask_pad_logits(cfg, x @ w)

    def _embed_batch(p, batch):
        if cfg.family == "vlm" and "embeds" in batch:
            return batch["embeds"].astype(dtype)
        return p["embed"][batch["tokens"]]

    def _run_layers(p, x, positions, *, window=0, kv_len=None,
                    collect_kv=False, remat=False, dropless=False):
        body = functools.partial(block_forward, cfg, positions=positions,
                                 window=window, kv_len=kv_len,
                                 collect_kv=collect_kv, dropless=dropless)

        def scan_fn(x, lp):
            x, kv, aux = body(lp, constrain_compute(x))
            return constrain(x), (constrain_kv(kv), aux.get("lb_loss"),
                                  aux.get("dropped_frac"))

        if remat:
            scan_fn = jax.checkpoint(scan_fn)
        x, (kvs, lb, dropped) = jax.lax.scan(scan_fn, x, p["layers"])
        aux = {}
        if lb is not None and cfg.family == "moe":
            aux = {"lb_loss": jnp.mean(lb), "dropped_frac": jnp.mean(dropped)}
        return x, kvs, aux

    def train_logits(p, batch, remat=True):
        x = _embed_batch(p, batch)
        B, S = x.shape[:2]
        positions = (batch["mrope_pos"] if cfg.pos_emb == "mrope"
                     and "mrope_pos" in batch else _positions_for(cfg, B, S))
        x, _, aux = _run_layers(p, x, positions, remat=remat)
        x = layers.apply_norm(cfg, p["final_norm"], x)
        return _unembed(p, x), aux

    def init_cache(B, cache_len, cache_dtype=None):
        cd = jnp.dtype(cache_dtype or cfg.dtype)
        hd = cfg.resolved_head_dim
        shape = (cfg.n_layers, B, cache_len, cfg.n_kv_heads, hd)
        cache = {
            "len": jnp.zeros((B,), jnp.int32),
            "window": jnp.array(
                cache_len if cfg.sliding_window and
                cache_len <= cfg.sliding_window else 0, jnp.int32),
        }
        if cfg.kv_quant:
            cache["k"] = jnp.zeros(shape, jnp.int8)
            cache["v"] = jnp.zeros(shape, jnp.int8)
            cache["k_scale"] = jnp.zeros(shape[:-1], jnp.bfloat16)
            cache["v_scale"] = jnp.zeros(shape[:-1], jnp.bfloat16)
        else:
            cache["k"] = jnp.zeros(shape, cd)
            cache["v"] = jnp.zeros(shape, cd)
        return cache

    def _quantize(t):
        """(..., hd) -> int8 values + per-(token, head) scale."""
        amax = jnp.max(jnp.abs(t.astype(jnp.float32)), axis=-1)
        scale = jnp.maximum(amax / 127.0, 1e-8)
        q = jnp.clip(jnp.round(t.astype(jnp.float32) / scale[..., None]),
                     -127, 127).astype(jnp.int8)
        return q, scale.astype(jnp.bfloat16)

    def _dequantize(q, scale):
        return (q.astype(jnp.float32)
                * scale.astype(jnp.float32)[..., None]).astype(dtype)

    def prefill(p, batch, cache, dropless=False):
        x = _embed_batch(p, batch)
        B, S = x.shape[:2]
        positions = (batch["mrope_pos"] if cfg.pos_emb == "mrope"
                     and "mrope_pos" in batch else _positions_for(cfg, B, S))
        kv_len = batch.get("prompt_len")
        x, kvs, _ = _run_layers(p, x, positions, kv_len=kv_len,
                                collect_kv=True, dropless=dropless)
        ks, vs = kvs  # (L, B, S, KV, hd)
        ks, vs = constrain_kv_stack(ks, vs)
        S_buf = cache["k"].shape[2]
        if S > S_buf:  # sliding-window: keep the trailing window
            ks = ks[:, :, S - S_buf:]
            vs = vs[:, :, S - S_buf:]
        W = min(S, S_buf)
        if cfg.kv_quant:
            kq, kscale = _quantize(ks)
            vq, vscale = _quantize(vs)
            cache["k"] = cache["k"].at[:, :, :W].set(kq)
            cache["v"] = cache["v"].at[:, :, :W].set(vq)
            cache["k_scale"] = cache["k_scale"].at[:, :, :W].set(kscale)
            cache["v_scale"] = cache["v_scale"].at[:, :, :W].set(vscale)
        else:
            cache["k"] = cache["k"].at[:, :, :W].set(
                ks.astype(cache["k"].dtype))
            cache["v"] = cache["v"].at[:, :, :W].set(
                vs.astype(cache["v"].dtype))
        new_len = (kv_len if kv_len is not None
                   else jnp.full((B,), S, jnp.int32))
        cache["len"] = new_len
        x = layers.apply_norm(cfg, p["final_norm"], x)
        last = jnp.take_along_axis(
            x, (new_len - 1)[:, None, None].astype(jnp.int32), axis=1)[:, 0] \
            if kv_len is not None else x[:, -1]
        return _unembed(p, last), cache

    def decode(p, tokens, cache):
        B = tokens.shape[0]
        x = p["embed"][tokens.reshape(B, 1)]
        cur = cache["len"]  # absolute position of the new token
        S_buf = cache["k"].shape[2]
        ring = cache["window"] > 0
        write_idx = jnp.where(ring, cur % S_buf, jnp.minimum(cur, S_buf - 1))
        kv_len = jnp.minimum(cur + 1, S_buf)
        positions = _positions_for(cfg, B, 1, offset=cur)

        # cache lives in the scan CARRY (updated in place per layer) so XLA
        # keeps ONE buffer instead of double-buffering scan xs->ys
        if cfg.kv_quant:
            def scan_fn(carry, lp_i):
                x, ks, vs, ksc, vsc = carry
                lp, i = lp_i
                take = lambda a: jax.lax.dynamic_index_in_dim(
                    a, i, 0, keepdims=False)
                kc = _dequantize(take(ks), take(ksc))
                vc = _dequantize(take(vs), take(vsc))
                x, kc, vc = block_decode(cfg, lp, x, kc, vc, kv_len,
                                         positions, write_idx)
                # requantize only the newly written row
                b = jnp.arange(B)
                kq, kscale = _quantize(kc[b, write_idx])
                vq, vscale = _quantize(vc[b, write_idx])
                put = jax.lax.dynamic_update_index_in_dim
                ks = put(ks, take(ks).at[b, write_idx].set(kq), i, 0)
                vs = put(vs, take(vs).at[b, write_idx].set(vq), i, 0)
                ksc = put(ksc, take(ksc).at[b, write_idx].set(kscale), i, 0)
                vsc = put(vsc, take(vsc).at[b, write_idx].set(vscale), i, 0)
                return (x, ks, vs, ksc, vsc), None

            (x, ks, vs, ksc, vsc), _ = jax.lax.scan(
                scan_fn,
                (x, cache["k"], cache["v"], cache["k_scale"],
                 cache["v_scale"]),
                (p["layers"], jnp.arange(cfg.n_layers)))
            cache.update(k=ks, v=vs, k_scale=ksc, v_scale=vsc)
        else:
            def scan_fn(carry, lp_i):
                x, ks, vs = carry
                lp, i = lp_i
                kc = jax.lax.dynamic_index_in_dim(ks, i, 0, keepdims=False)
                vc = jax.lax.dynamic_index_in_dim(vs, i, 0, keepdims=False)
                x, kc, vc = block_decode(cfg, lp, x, kc, vc, kv_len,
                                         positions, write_idx)
                ks = jax.lax.dynamic_update_index_in_dim(ks, kc, i, 0)
                vs = jax.lax.dynamic_update_index_in_dim(vs, vc, i, 0)
                return (x, ks, vs), None

            (x, ks, vs), _ = jax.lax.scan(
                scan_fn, (x, cache["k"], cache["v"]),
                (p["layers"], jnp.arange(cfg.n_layers)))
            cache["k"], cache["v"] = ks, vs
        cache["len"] = cur + 1
        x = layers.apply_norm(cfg, p["final_norm"], x)
        return _unembed(p, x[:, 0]), cache

    return Model(cfg, init, train_logits, prefill, decode, init_cache)


# ===========================================================================
# encoder-decoder (whisper backbone)
# ===========================================================================

def _encdec_model(cfg: ModelConfig) -> Model:
    dtype = jnp.dtype(cfg.dtype)

    def init_enc_layer(key):
        ka, km, kn = jax.random.split(key, 3)
        return {
            "attn_norm": layers.init_norm(cfg, kn, cfg.d_model, dtype),
            "attn": layers.init_attention(cfg, ka, dtype),
            "mlp_norm": layers.init_norm(cfg, kn, cfg.d_model, dtype),
            "mlp": layers.init_mlp(cfg, km, dtype),
        }

    def init_dec_layer(key):
        ka, kc, km, kn = jax.random.split(key, 4)
        return {
            "attn_norm": layers.init_norm(cfg, kn, cfg.d_model, dtype),
            "attn": layers.init_attention(cfg, ka, dtype),
            "cross_norm": layers.init_norm(cfg, kn, cfg.d_model, dtype),
            "cross": layers.init_attention(cfg, kc, dtype),
            "mlp_norm": layers.init_norm(cfg, kn, cfg.d_model, dtype),
            "mlp": layers.init_mlp(cfg, km, dtype),
        }

    def init(rng):
        ke, k1, k2, kh = jax.random.split(rng, 4)
        return {
            "embed": layers.embed_init(ke, cfg.padded_vocab, cfg.d_model, dtype),
            "enc_layers": _stack_init(init_enc_layer, k1, cfg.n_encoder_layers),
            "dec_layers": _stack_init(init_dec_layer, k2, cfg.n_layers),
            "enc_norm": layers.init_norm(cfg, kh, cfg.d_model, dtype),
            "final_norm": layers.init_norm(cfg, kh, cfg.d_model, dtype),
        }

    def encode(p, enc_embeds):
        B, T, _ = enc_embeds.shape
        pos = _positions_for(cfg, B, T)
        x = enc_embeds.astype(dtype) \
            + layers.sinusoid_pos_emb(pos, cfg.d_model).astype(dtype)

        def scan_fn(x, lp):
            x = constrain_compute(x)
            h = layers.apply_norm(cfg, lp["attn_norm"], x)
            a, _ = layers.self_attention(cfg, lp["attn"], h, pos, causal=False)
            x = x + a
            h = layers.apply_norm(cfg, lp["mlp_norm"], x)
            return constrain(x + layers.mlp(cfg, lp["mlp"], h)), None

        x, _ = jax.lax.scan(scan_fn, x, p["enc_layers"])
        return layers.apply_norm(cfg, p["enc_norm"], x)

    def _dec_embed(p, tokens, offset=0):
        B, S = tokens.shape
        pos = _positions_for(cfg, B, S, offset)
        return (p["embed"][tokens]
                + layers.sinusoid_pos_emb(pos, cfg.d_model).astype(dtype)), pos

    def train_logits(p, batch, remat=True):
        enc = encode(p, batch["enc_embeds"])
        x, pos = _dec_embed(p, batch["tokens"])

        def scan_fn(x, lp):
            x = constrain_compute(x)
            h = layers.apply_norm(cfg, lp["attn_norm"], x)
            a, _ = layers.self_attention(cfg, lp["attn"], h, pos, causal=True)
            x = x + a
            h = layers.apply_norm(cfg, lp["cross_norm"], x)
            ck, cv = layers.cross_kv(cfg, lp["cross"], enc)
            x = x + layers.cross_attention(cfg, lp["cross"], h, ck, cv)
            h = layers.apply_norm(cfg, lp["mlp_norm"], x)
            return constrain(x + layers.mlp(cfg, lp["mlp"], h)), None

        if remat:
            scan_fn = jax.checkpoint(scan_fn)
        x, _ = jax.lax.scan(scan_fn, x, p["dec_layers"])
        x = layers.apply_norm(cfg, p["final_norm"], x)
        return _mask_pad_logits(cfg, x @ p["embed"].T), {}

    def init_cache(B, cache_len, cache_dtype=None):
        cd = jnp.dtype(cache_dtype or cfg.dtype)
        hd = cfg.resolved_head_dim
        L = cfg.n_layers
        return {
            "k": jnp.zeros((L, B, cache_len, cfg.n_kv_heads, hd), cd),
            "v": jnp.zeros((L, B, cache_len, cfg.n_kv_heads, hd), cd),
            "ck": jnp.zeros((L, B, cfg.encoder_len, cfg.n_kv_heads, hd), cd),
            "cv": jnp.zeros((L, B, cfg.encoder_len, cfg.n_kv_heads, hd), cd),
            "len": jnp.zeros((B,), jnp.int32),
            "window": jnp.array(0, jnp.int32),
        }

    def prefill(p, batch, cache):
        enc = encode(p, batch["enc_embeds"])
        tokens = batch["tokens"]
        B, S = tokens.shape
        x, pos = _dec_embed(p, tokens)

        def scan_fn(x, lp):
            x = constrain_compute(x)
            h = layers.apply_norm(cfg, lp["attn_norm"], x)
            a, kv = layers.self_attention(cfg, lp["attn"], h, pos, causal=True)
            x = x + a
            h = layers.apply_norm(cfg, lp["cross_norm"], x)
            ck, cv = layers.cross_kv(cfg, lp["cross"], enc)
            x = x + layers.cross_attention(cfg, lp["cross"], h, ck, cv)
            h = layers.apply_norm(cfg, lp["mlp_norm"], x)
            return constrain(x + layers.mlp(cfg, lp["mlp"], h)), (constrain_kv(kv), (ck, cv))

        x, (kvs, ckvs) = jax.lax.scan(scan_fn, x, p["dec_layers"])
        cache["k"] = cache["k"].at[:, :, :S].set(kvs[0].astype(cache["k"].dtype))
        cache["v"] = cache["v"].at[:, :, :S].set(kvs[1].astype(cache["v"].dtype))
        cache["ck"] = ckvs[0].astype(cache["ck"].dtype)
        cache["cv"] = ckvs[1].astype(cache["cv"].dtype)
        cache["len"] = jnp.full((B,), S, jnp.int32)
        x = layers.apply_norm(cfg, p["final_norm"], x)
        return _mask_pad_logits(cfg, x[:, -1] @ p["embed"].T), cache

    def decode(p, tokens, cache):
        B = tokens.shape[0]
        cur = cache["len"]
        x, pos = _dec_embed(p, tokens.reshape(B, 1), offset=cur)
        S_buf = cache["k"].shape[2]
        write_idx = jnp.minimum(cur, S_buf - 1)
        kv_len = jnp.minimum(cur + 1, S_buf)

        def scan_fn(carry, lp_i):
            x, ks, vs = carry
            lp, ck, cv, i = lp_i
            kc = jax.lax.dynamic_index_in_dim(ks, i, 0, keepdims=False)
            vc = jax.lax.dynamic_index_in_dim(vs, i, 0, keepdims=False)
            h = layers.apply_norm(cfg, lp["attn_norm"], x)
            q, k, v = layers.decode_self_attention(cfg, lp["attn"], h, kc, vc,
                                                   kv_len, pos)
            kc, vc = _write_kv(kc, vc, k, v, write_idx)
            o = ops.decode_attention(q, kc, vc, kv_len)
            x = x + layers.attn_out(cfg, lp["attn"], o)
            h = layers.apply_norm(cfg, lp["cross_norm"], x)
            x = x + layers.cross_attention(cfg, lp["cross"], h, ck, cv)
            h = layers.apply_norm(cfg, lp["mlp_norm"], x)
            x = x + layers.mlp(cfg, lp["mlp"], h)
            ks = jax.lax.dynamic_update_index_in_dim(ks, kc, i, 0)
            vs = jax.lax.dynamic_update_index_in_dim(vs, vc, i, 0)
            return (x, ks, vs), None

        (x, ks, vs), _ = jax.lax.scan(
            scan_fn, (x, cache["k"], cache["v"]),
            (p["dec_layers"], cache["ck"], cache["cv"],
             jnp.arange(cfg.n_layers)))
        cache["k"], cache["v"] = ks, vs
        cache["len"] = cur + 1
        x = layers.apply_norm(cfg, p["final_norm"], x)
        return _mask_pad_logits(cfg, x[:, 0] @ p["embed"].T), cache

    return Model(cfg, init, train_logits, prefill, decode, init_cache)


# ===========================================================================
# hybrid (zamba2: mamba2 backbone + one shared attention/MLP block)
# ===========================================================================

def _hybrid_model(cfg: ModelConfig) -> Model:
    dtype = jnp.dtype(cfg.dtype)
    per_sb = cfg.hybrid_attn_every
    assert cfg.n_layers % per_sb == 0
    n_sb = cfg.n_layers // per_sb  # superblocks, each: shared-attn + k mamba

    def init_mamba_layer(key):
        kn, km = jax.random.split(key)
        return {
            "norm": layers.init_norm(cfg, kn, cfg.d_model, dtype),
            "mamba": ssm.init_mamba(cfg, km, dtype),
        }

    def init(rng):
        ke, km, ka, kf, kh = jax.random.split(rng, 5)
        sb_init = lambda k: _stack_init(init_mamba_layer, k, per_sb)
        return {
            "embed": layers.embed_init(ke, cfg.padded_vocab, cfg.d_model, dtype),
            "mamba_sb": _stack_init(sb_init, km, n_sb),  # (n_sb, per_sb, ...)
            "shared_attn": {
                "attn_norm": layers.init_norm(cfg, ka, cfg.d_model, dtype),
                "attn": layers.init_attention(cfg, ka, dtype),
                "mlp_norm": layers.init_norm(cfg, kf, cfg.d_model, dtype),
                "mlp": layers.init_mlp(cfg, kf, dtype),
            },
            "final_norm": layers.init_norm(cfg, kh, cfg.d_model, dtype),
            "lm_head": layers.dense_init(kh, cfg.d_model, cfg.padded_vocab,
                                         dtype),
        }

    def _shared_attn_full(p, x, pos, window, kv_len=None):
        sp = p["shared_attn"]
        h = layers.apply_norm(cfg, sp["attn_norm"], x)
        a, kv = layers.self_attention(cfg, sp["attn"], h, pos, causal=True,
                                      window=window, kv_len=kv_len)
        x = x + a
        h = layers.apply_norm(cfg, sp["mlp_norm"], x)
        return x + layers.mlp(cfg, sp["mlp"], h), kv

    def train_logits(p, batch, remat=True):
        x = p["embed"][batch["tokens"]]
        B, S = x.shape[:2]
        pos = _positions_for(cfg, B, S)
        window = cfg.sliding_window if S > cfg.sliding_window > 0 else 0

        def sb_fn(x, sb_params):
            x, _ = _shared_attn_full(p, constrain_compute(x), pos, window)

            def mamba_fn(x, lp):
                h = layers.apply_norm(cfg, lp["norm"], x)
                out, _ = ssm.mamba_forward(cfg, lp["mamba"], h)
                return x + out, None

            x, _ = jax.lax.scan(mamba_fn, x, sb_params)
            return constrain(x), None

        if remat:
            sb_fn = jax.checkpoint(sb_fn)
        x, _ = jax.lax.scan(sb_fn, x, p["mamba_sb"])
        x = layers.apply_norm(cfg, p["final_norm"], x)
        return _mask_pad_logits(cfg, x @ p["lm_head"]), {}

    def init_cache(B, cache_len, cache_dtype=None):
        cd = jnp.dtype(cache_dtype or cfg.dtype)
        hd = cfg.resolved_head_dim
        d_in, H, P, N, G = ssm.mamba_dims(cfg)
        conv_ch = d_in + 2 * G * N
        K = cfg.ssm.conv_dim
        return {
            "k": jnp.zeros((n_sb, B, cache_len, cfg.n_kv_heads, hd), cd),
            "v": jnp.zeros((n_sb, B, cache_len, cfg.n_kv_heads, hd), cd),
            "ssm_state": jnp.zeros((n_sb, per_sb, B, H, N, P), jnp.float32),
            "conv": jnp.zeros((n_sb, per_sb, B, K - 1, conv_ch), cd),
            "len": jnp.zeros((B,), jnp.int32),
            "window": jnp.array(
                cache_len if cfg.sliding_window and
                cache_len <= cfg.sliding_window else 0, jnp.int32),
        }

    def prefill(p, batch, cache):
        x = p["embed"][batch["tokens"]]
        B, S = x.shape[:2]
        pos = _positions_for(cfg, B, S)
        S_buf = cache["k"].shape[2]
        window = cfg.sliding_window if S > S_buf else 0

        def sb_fn(x, sb):
            sb_params = sb
            x, kv = _shared_attn_full(p, constrain_compute(x), pos, window)

            def mamba_fn(x, lp):
                h = layers.apply_norm(cfg, lp["norm"], x)
                out, st = ssm.mamba_forward(cfg, lp["mamba"], h)
                return x + out, st

            x, states = jax.lax.scan(mamba_fn, x, sb_params)
            return constrain(x), (constrain_kv(kv), states)

        x, (kvs, states) = jax.lax.scan(sb_fn, x, p["mamba_sb"])
        ks, vs = kvs
        if S <= S_buf:
            cache["k"] = cache["k"].at[:, :, :S].set(ks.astype(cache["k"].dtype))
            cache["v"] = cache["v"].at[:, :, :S].set(vs.astype(cache["v"].dtype))
        else:
            cache["k"] = ks[:, :, S - S_buf:].astype(cache["k"].dtype)
            cache["v"] = vs[:, :, S - S_buf:].astype(cache["v"].dtype)
        cache["ssm_state"] = states[0]
        cache["conv"] = states[1].astype(cache["conv"].dtype)
        cache["len"] = jnp.full((B,), S, jnp.int32)
        x = layers.apply_norm(cfg, p["final_norm"], x)
        return _mask_pad_logits(cfg, x[:, -1] @ p["lm_head"]), cache

    def decode(p, tokens, cache):
        B = tokens.shape[0]
        x = p["embed"][tokens.reshape(B, 1)]
        cur = cache["len"]
        S_buf = cache["k"].shape[2]
        ring = cache["window"] > 0
        write_idx = jnp.where(ring, cur % S_buf, jnp.minimum(cur, S_buf - 1))
        kv_len = jnp.minimum(cur + 1, S_buf)
        pos = _positions_for(cfg, B, 1, offset=cur)
        sp = p["shared_attn"]

        def sb_fn(carry, sb):
            x, ks, vs = carry
            sb_params, sstate, sconv, i = sb
            kc = jax.lax.dynamic_index_in_dim(ks, i, 0, keepdims=False)
            vc = jax.lax.dynamic_index_in_dim(vs, i, 0, keepdims=False)
            h = layers.apply_norm(cfg, sp["attn_norm"], x)
            q, k, v = layers.decode_self_attention(cfg, sp["attn"], h, kc, vc,
                                                   kv_len, pos)
            kc, vc = _write_kv(kc, vc, k, v, write_idx)
            o = ops.decode_attention(q, kc, vc, kv_len)
            x = x + layers.attn_out(cfg, sp["attn"], o)
            h = layers.apply_norm(cfg, sp["mlp_norm"], x)
            x = x + layers.mlp(cfg, sp["mlp"], h)
            ks = jax.lax.dynamic_update_index_in_dim(ks, kc, i, 0)
            vs = jax.lax.dynamic_update_index_in_dim(vs, vc, i, 0)

            def mamba_fn(x, lp_state):
                lp, st, cv_ = lp_state
                h = layers.apply_norm(cfg, lp["norm"], x)
                out, (st, cv_) = ssm.mamba_decode(cfg, lp["mamba"], h, st, cv_)
                return x + out, (st, cv_)

            x, (sstate, sconv) = jax.lax.scan(mamba_fn, x,
                                              (sb_params, sstate, sconv))
            return (x, ks, vs), (sstate, sconv)

        (x, ks, vs), (states, convs) = jax.lax.scan(
            sb_fn, (x, cache["k"], cache["v"]),
            (p["mamba_sb"], cache["ssm_state"], cache["conv"],
             jnp.arange(n_sb)))
        cache["k"], cache["v"] = ks, vs
        cache["ssm_state"], cache["conv"] = states, convs
        cache["len"] = cur + 1
        x = layers.apply_norm(cfg, p["final_norm"], x)
        return _mask_pad_logits(cfg, x[:, 0] @ p["lm_head"]), cache

    return Model(cfg, init, train_logits, prefill, decode, init_cache)


# ===========================================================================
# xLSTM (superblocks of (k-1) mLSTM + 1 sLSTM)
# ===========================================================================

def _xlstm_model(cfg: ModelConfig) -> Model:
    dtype = jnp.dtype(cfg.dtype)
    per_sb = cfg.xlstm_slstm_every
    assert cfg.n_layers % per_sb == 0
    n_sb = cfg.n_layers // per_sb
    n_m = per_sb - 1  # mLSTM layers per superblock

    def init_m(key):
        kn, km = jax.random.split(key)
        return {"norm": layers.init_norm(cfg, kn, cfg.d_model, dtype),
                "mlstm": ssm.init_mlstm(cfg, km, dtype)}

    def init_s(key):
        kn, ks_ = jax.random.split(key)
        return {"norm": layers.init_norm(cfg, kn, cfg.d_model, dtype),
                "slstm": ssm.init_slstm(cfg, ks_, dtype)}

    def init(rng):
        ke, km, ks_, kh = jax.random.split(rng, 4)
        return {
            "embed": layers.embed_init(ke, cfg.padded_vocab, cfg.d_model, dtype),
            "mlstm_sb": _stack_init(
                lambda k: _stack_init(init_m, k, n_m), km, n_sb),
            "slstm_sb": _stack_init(init_s, ks_, n_sb),
            "final_norm": layers.init_norm(cfg, kh, cfg.d_model, dtype),
            "lm_head": layers.dense_init(kh, cfg.d_model, cfg.padded_vocab,
                                         dtype),
        }

    def train_logits(p, batch, remat=True):
        x = p["embed"][batch["tokens"]]

        def sb_fn(x, sb):
            mp, sp = sb
            x = constrain_compute(x)

            def m_fn(x, lp):
                h = layers.apply_norm(cfg, lp["norm"], x)
                out, _ = ssm.mlstm_forward(cfg, lp["mlstm"], h)
                return x + out, None

            x, _ = jax.lax.scan(m_fn, x, mp)
            h = layers.apply_norm(cfg, sp["norm"], x)
            out, _ = ssm.slstm_forward(cfg, sp["slstm"], h)
            return constrain(x + out), None

        if remat:
            sb_fn = jax.checkpoint(sb_fn)
        x, _ = jax.lax.scan(sb_fn, x, (p["mlstm_sb"], p["slstm_sb"]))
        x = layers.apply_norm(cfg, p["final_norm"], x)
        return _mask_pad_logits(cfg, x @ p["lm_head"]), {}

    def init_cache(B, cache_len, cache_dtype=None):
        d_in, H, hd = ssm.mlstm_dims(cfg)
        Hs, hds = cfg.n_heads, cfg.d_model // cfg.n_heads
        return {
            "mC": jnp.zeros((n_sb, n_m, B, H, hd, hd), jnp.float32),
            "mn": jnp.zeros((n_sb, n_m, B, H, hd), jnp.float32),
            "mm": jnp.full((n_sb, n_m, B, H), -1e30, jnp.float32),
            "sc": jnp.zeros((n_sb, B, Hs, hds), jnp.float32),
            "sn": jnp.zeros((n_sb, B, Hs, hds), jnp.float32),
            "sm": jnp.full((n_sb, B, Hs, hds), -10.0, jnp.float32),
            "sh": jnp.zeros((n_sb, B, Hs, hds), jnp.float32),
            "len": jnp.zeros((B,), jnp.int32),
        }

    def _run_with_state(p, x, cache, decode_mode):
        def sb_fn(x, sb):
            mp, sp, mC, mn, mm, sc, sn, sm, sh = sb
            x = constrain_compute(x)

            def m_fn(x, lp_state):
                lp, C, n, m = lp_state
                h = layers.apply_norm(cfg, lp["norm"], x)
                if decode_mode:
                    out, (C, n, m) = ssm.mlstm_decode(cfg, lp["mlstm"], h,
                                                      (C, n, m))
                else:
                    out, (C, n, m) = ssm.mlstm_forward(cfg, lp["mlstm"], h,
                                                       state=(C, n, m))
                return x + out, (C, n, m)

            x, (mC, mn, mm) = jax.lax.scan(m_fn, x, (mp, mC, mn, mm))
            h = layers.apply_norm(cfg, sp["norm"], x)
            out, (sc, sn, sm, sh) = ssm.slstm_forward(
                cfg, sp["slstm"], h, state=(sc, sn, sm, sh))
            x = constrain(x + out)
            return x, (mC, mn, mm, sc, sn, sm, sh)

        x, new = jax.lax.scan(
            sb_fn, x,
            (p["mlstm_sb"], p["slstm_sb"], cache["mC"], cache["mn"],
             cache["mm"], cache["sc"], cache["sn"], cache["sm"], cache["sh"]))
        for key_, val in zip(("mC", "mn", "mm", "sc", "sn", "sm", "sh"), new):
            cache[key_] = val
        return x, cache

    def prefill(p, batch, cache):
        x = p["embed"][batch["tokens"]]
        B, S = x.shape[:2]
        x, cache = _run_with_state(p, x, cache, decode_mode=False)
        cache["len"] = jnp.full((B,), S, jnp.int32)
        x = layers.apply_norm(cfg, p["final_norm"], x)
        return _mask_pad_logits(cfg, x[:, -1] @ p["lm_head"]), cache

    def decode(p, tokens, cache):
        B = tokens.shape[0]
        x = p["embed"][tokens.reshape(B, 1)]
        x, cache = _run_with_state(p, x, cache, decode_mode=True)
        cache["len"] = cache["len"] + 1
        x = layers.apply_norm(cfg, p["final_norm"], x)
        return _mask_pad_logits(cfg, x[:, 0] @ p["lm_head"]), cache

    return Model(cfg, init, train_logits, prefill, decode, init_cache)
