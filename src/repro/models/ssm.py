"""Sequence-state models: Mamba2 (SSD), and xLSTM's mLSTM / sLSTM blocks.

Design note for roofline accounting: all quadratic/intra-chunk work is
computed *in parallel across chunks* (plain einsums, counted by XLA cost
analysis); only the O(B*H*N*P) elementwise state propagation lives inside
`lax.scan` bodies, whose trip-count undercounting is negligible.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import act_sharding, layers

MAMBA_HEAD_DIM = 64


# ===========================================================================
# Mamba2 (SSD)
# ===========================================================================

def mamba_dims(cfg: ModelConfig):
    d_in = cfg.ssm.expand * cfg.d_model
    H = d_in // MAMBA_HEAD_DIM
    return d_in, H, MAMBA_HEAD_DIM, cfg.ssm.state_dim, cfg.ssm.n_groups


def init_mamba(cfg: ModelConfig, key, dtype):
    d = cfg.d_model
    d_in, H, P, N, G = mamba_dims(cfg)
    conv_ch = d_in + 2 * G * N
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        # fused in_proj -> [z(d_in), xBC(d_in+2GN), dt(H)]
        "w_in": layers.dense_init(k1, d, 2 * d_in + 2 * G * N + H, dtype),
        "conv_w": (jax.random.normal(k2, (cfg.ssm.conv_dim, conv_ch))
                   * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm_w": jnp.ones((d_in,), dtype),
        "w_out": layers.dense_init(k3, d_in, d, dtype),
    }


def _split_zxbcdt(cfg, zxbcdt):
    d_in, H, P, N, G = mamba_dims(cfg)
    z = zxbcdt[..., :d_in]
    xBC = zxbcdt[..., d_in:d_in + d_in + 2 * G * N]
    dt = zxbcdt[..., -H:]
    return z, xBC, dt


def _causal_conv(xBC, w, b, conv_cache=None):
    """Depthwise causal conv. xBC: (B, S, C); w: (K, C).
    conv_cache: (B, K-1, C) trailing inputs from the previous call or None.
    Returns (out, new_cache)."""
    B, S, C = xBC.shape
    K = w.shape[0]
    if conv_cache is None:
        conv_cache = jnp.zeros((B, K - 1, C), xBC.dtype)
    xp = jnp.concatenate([conv_cache, xBC], axis=1)  # (B, S+K-1, C)
    out = sum(xp[:, i:i + S] * w[i] for i in range(K)) + b
    new_cache = xp[:, -(K - 1):] if K > 1 else jnp.zeros((B, 0, C), xBC.dtype)
    return jax.nn.silu(out), new_cache


def ssd_chunked(x, dt, A, Bm, Cm, *, chunk=128, initial_state=None):
    """Chunked SSD. x: (B,S,H,P); dt: (B,S,H); A: (H,) negative;
    Bm, Cm: (B,S,G,N) with G dividing H. Returns (y, final_state) where
    state: (B,H,N,P)."""
    B, S, H, Pd = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    chunk = min(chunk, S)
    assert S % chunk == 0
    nc = S // chunk

    Bh = jnp.repeat(Bm, rep, axis=2)  # (B,S,H,N)
    Ch = jnp.repeat(Cm, rep, axis=2)
    xr = x.reshape(B, nc, chunk, H, Pd).astype(jnp.float32)
    dtr = dt.reshape(B, nc, chunk, H).astype(jnp.float32)
    Br = Bh.reshape(B, nc, chunk, H, N).astype(jnp.float32)
    Cr = Ch.reshape(B, nc, chunk, H, N).astype(jnp.float32)

    dA = dtr * A  # (B,nc,Q,H), negative
    cum = jnp.cumsum(dA, axis=2)  # within-chunk inclusive cumsum

    # --- intra-chunk (parallel over chunks) --------------------------------
    # decay L[i,j] = exp(cum_i - cum_j) for i >= j. Mask in LOG space so the
    # gradient of exp never sees the (overflowing) upper triangle.
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,Qi,Qj,H)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    diff = jnp.where(mask[None, None, :, :, None], diff, -1e30)
    Lmat = jnp.exp(diff)
    scores = jnp.einsum("bcihn,bcjhn->bcijh", Cr, Br) * Lmat \
        * dtr[:, :, None, :, :]  # (B,nc,Qi,Qj,H)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", scores, xr)

    # --- chunk-local end states -------------------------------------------
    # state_c = sum_j exp(cum[Q-1] - cum[j]) * dt_j * B_j (x) x_j
    dec_end = jnp.exp(cum[:, :, -1:, :] - cum)  # (B,nc,Q,H)
    states = jnp.einsum("bcjh,bcjhn,bcjhp->bchnp",
                        dec_end * dtr, Br, xr)  # (B,nc,H,N,P)
    chunk_decay = jnp.exp(cum[:, :, -1, :])  # (B,nc,H) total decay per chunk

    # --- inter-chunk state propagation (elementwise scan) ------------------
    s0 = (jnp.zeros((B, H, N, Pd), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    def step(s, inp):
        dec, st = inp  # (B,H), (B,H,N,P)
        s_out = s  # state entering this chunk
        s = s * dec[..., None, None] + st
        return s, s_out

    dec_t = jnp.moveaxis(chunk_decay, 1, 0)  # (nc,B,H)
    st_t = jnp.moveaxis(states, 1, 0)        # (nc,B,H,N,P)
    final_state, entry_states = jax.lax.scan(step, s0, (dec_t, st_t))
    entry_states = jnp.moveaxis(entry_states, 0, 1)  # (B,nc,H,N,P)

    # --- inter-chunk contribution (parallel) --------------------------------
    y_inter = jnp.einsum("bcihn,bchnp->bcihp",
                         Cr * jnp.exp(cum)[..., None], entry_states)
    y = (y_intra + y_inter).reshape(B, S, H, Pd)
    return y.astype(x.dtype), final_state


def mamba_forward(cfg: ModelConfig, p, x, *, state=None, conv_cache=None,
                  chunk=128):
    """Full-sequence Mamba2 block. x: (B,S,d). Returns
    (out, (final_state, conv_cache))."""
    d_in, H, P, N, G = mamba_dims(cfg)
    zxbcdt = x @ p["w_in"]
    z, xBC, dt = _split_zxbcdt(cfg, zxbcdt)
    xBC, new_conv = _causal_conv(xBC, p["conv_w"], p["conv_b"], conv_cache)
    B_, S = x.shape[0], x.shape[1]
    xs = xBC[..., :d_in].reshape(B_, S, H, P)
    Bm = xBC[..., d_in:d_in + G * N].reshape(B_, S, G, N)
    Cm = xBC[..., d_in + G * N:].reshape(B_, S, G, N)
    dtp = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, fstate = ssd_chunked(xs, dtp, A, Bm, Cm, chunk=chunk,
                            initial_state=state)
    y = y + (p["D"][:, None] * xs.astype(jnp.float32)).astype(y.dtype)
    y = y.reshape(B_, S, d_in)
    y = layers.rmsnorm(y * jax.nn.silu(z), p["norm_w"])
    return y @ p["w_out"], (fstate, new_conv)


def mamba_decode(cfg: ModelConfig, p, x, state, conv_cache):
    """Single-token recurrent step. x: (B,1,d); state: (B,H,N,P);
    conv_cache: (B,K-1,C). Returns (out, (state, conv_cache))."""
    d_in, H, P, N, G = mamba_dims(cfg)
    zxbcdt = x @ p["w_in"]
    z, xBC, dt = _split_zxbcdt(cfg, zxbcdt)
    K = p["conv_w"].shape[0]
    xp = jnp.concatenate([conv_cache, xBC], axis=1)  # (B,K,C)
    conv_out = jax.nn.silu((xp * p["conv_w"][None]).sum(axis=1)
                           + p["conv_b"])[:, None]  # (B,1,C)
    new_conv = xp[:, 1:]
    B_ = x.shape[0]
    xs = conv_out[..., :d_in].reshape(B_, H, P)
    Bm = conv_out[..., d_in:d_in + G * N].reshape(B_, G, N)
    Cm = conv_out[..., d_in + G * N:].reshape(B_, G, N)
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=1)  # (B,H,N)
    Ch = jnp.repeat(Cm, rep, axis=1)
    dtp = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["A_log"])
    dec = jnp.exp(dtp * A)  # (B,H)
    xs32 = xs.astype(jnp.float32)
    state = state * dec[..., None, None] + jnp.einsum(
        "bh,bhn,bhp->bhnp", dtp, Bh.astype(jnp.float32), xs32)
    y = jnp.einsum("bhn,bhnp->bhp", Ch.astype(jnp.float32), state)
    y = y + p["D"][:, None] * xs32
    y = y.reshape(B_, 1, d_in).astype(x.dtype)
    y = layers.rmsnorm(y * jax.nn.silu(z), p["norm_w"])
    return y @ p["w_out"], (state, new_conv)


# ===========================================================================
# xLSTM: mLSTM (matrix memory) and sLSTM (scalar memory)
# ===========================================================================

def mlstm_dims(cfg: ModelConfig):
    d_in = cfg.ssm.expand * cfg.d_model
    H = cfg.n_heads
    return d_in, H, d_in // H


def init_mlstm(cfg: ModelConfig, key, dtype):
    d = cfg.d_model
    d_in, H, hd = mlstm_dims(cfg)
    ks = jax.random.split(key, 7)
    return {
        "w_up": layers.dense_init(ks[0], d, 2 * d_in, dtype),  # [x_path, z gate]
        "wq": layers.dense_init(ks[1], d_in, d_in, dtype),
        "wk": layers.dense_init(ks[2], d_in, d_in, dtype),
        "wv": layers.dense_init(ks[3], d_in, d_in, dtype),
        "w_i": layers.dense_init(ks[4], d_in, H, jnp.float32),
        "w_f": layers.dense_init(ks[5], d_in, H, jnp.float32),
        "b_i": jnp.zeros((H,), jnp.float32),
        "b_f": jnp.full((H,), 3.0, jnp.float32),  # bias toward remembering
        "norm_w": jnp.ones((d_in,), dtype),
        "w_down": layers.dense_init(ks[6], d_in, d, dtype),
    }


def _mlstm_core_chunked(q, k, v, ig, fg, *, chunk=128, state=None):
    """Chunkwise stabilized mLSTM. q,k,v: (B,S,H,hd); ig,fg: (B,S,H) raw gate
    pre-activations. state: (C, n, m) with C: (B,H,hd,hd), n: (B,H,hd),
    m: (B,H). Returns (h, state)."""
    B, S, H, hd = q.shape
    chunk = min(chunk, S)
    assert S % chunk == 0
    nc = S // chunk
    scale = hd ** -0.5

    qf = (q.astype(jnp.float32) * scale).reshape(B, nc, chunk, H, hd)
    kf = k.astype(jnp.float32).reshape(B, nc, chunk, H, hd)
    vf = v.astype(jnp.float32).reshape(B, nc, chunk, H, hd)
    logf = jax.nn.log_sigmoid(fg.astype(jnp.float32)).reshape(B, nc, chunk, H)
    logi = ig.astype(jnp.float32).reshape(B, nc, chunk, H)
    cumf = jnp.cumsum(logf, axis=2)  # inclusive within-chunk

    # per-position source strength for key j: a_j = cumf_end - cumf_j + logi_j
    # intra decay: D[i,j] = cumf_i - cumf_j + logi_j (i >= j)
    diff = cumf[:, :, :, None, :] - cumf[:, :, None, :, :] \
        + logi[:, :, None, :, :]  # (B,nc,Qi,Qj,H)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    diff = jnp.where(mask[None, None, :, :, None], diff, -1e30)
    # inter decay for query i: b_i = cumf_i + m_prev (handled via scan below)
    chunk_f = cumf[:, :, -1, :]  # (B,nc,H) total log-forget per chunk
    # chunk-local state contribution (unstabilized exponents relative to
    # chunk end): s_j = cumf_end - cumf_j + logi_j
    s_end = chunk_f[:, :, None, :] - cumf + logi  # (B,nc,Q,H)
    m_loc = jnp.max(s_end, axis=2)  # (B,nc,H) local stabilizer
    w_end = jnp.exp(s_end - m_loc[:, :, None, :])
    C_loc = act_sharding.constrain_state(
        jnp.einsum("bcjh,bcjhd,bcjhe->bchde", w_end, kf, vf))
    n_loc = jnp.einsum("bcjh,bcjhd->bchd", w_end, kf)

    # --- inter-chunk scan over (C, n, m) — elementwise only -----------------
    if state is None:
        C0 = jnp.zeros((B, H, hd, hd), jnp.float32)
        n0 = jnp.zeros((B, H, hd), jnp.float32)
        m0 = jnp.full((B, H), -jnp.inf, jnp.float32)
    else:
        C0, n0, m0 = state

    def step(carry, inp):
        C, n, m = carry
        cf, ml, Cl, nl = inp  # chunk_f, m_loc, C_loc, n_loc for this chunk
        entry = (C, n, m)
        m_new = jnp.maximum(cf + m, ml)
        w_old = jnp.exp(cf + m - m_new)
        w_new = jnp.exp(ml - m_new)
        C = C * w_old[..., None, None] + Cl * w_new[..., None, None]
        n = n * w_old[..., None] + nl * w_new[..., None]
        return (C, n, m_new), entry

    inp = (jnp.moveaxis(chunk_f, 1, 0), jnp.moveaxis(m_loc, 1, 0),
           jnp.moveaxis(C_loc, 1, 0), jnp.moveaxis(n_loc, 1, 0))
    (Cf, nf, mf), entries = jax.lax.scan(step, (C0, n0, m0), inp)
    C_in = act_sharding.constrain_state(
        jnp.moveaxis(entries[0], 0, 1))  # (B,nc,H,hd,hd) chunk-entry state
    n_in = jnp.moveaxis(entries[1], 0, 1)
    m_in = jnp.moveaxis(entries[2], 0, 1)  # (B,nc,H)

    # --- combine intra + inter (parallel) -----------------------------------
    # query-side stabilizer: m_i = max(max_j diff[i,j], cumf_i + m_in)
    m_intra = jnp.max(diff, axis=3)  # (B,nc,Qi,H)
    b_i = cumf + m_in[:, :, None, :]  # (B,nc,Qi,H)
    m_i = jnp.maximum(m_intra, b_i)
    m_i = jnp.maximum(m_i, -1e30)  # guard -inf (empty context, zero state)
    w_intra = jnp.exp(diff - m_i[:, :, :, None, :])  # (B,nc,Qi,Qj,H)
    scores = jnp.einsum("bcihd,bcjhd->bcijh", qf, kf) * w_intra
    h_intra = jnp.einsum("bcijh,bcjhe->bcihe", scores, vf)
    n_intra = jnp.einsum("bcijh,bcjhd->bcihd", w_intra, kf)
    # inter: decays exp(b_i - m_i) applied to entry state
    w_inter = jnp.exp(b_i - m_i)  # (B,nc,Qi,H)
    h_inter = jnp.einsum("bcihd,bchde->bcihe", qf, C_in) \
        * w_inter[..., None]
    n_inter = n_in[:, :, None] * w_inter[..., None]  # (B,nc,Qi,H,hd)

    h_num = h_intra + h_inter
    n_tot = jnp.einsum("bcihd,bcihd->bcih", qf, n_intra + n_inter)
    denom = jnp.maximum(jnp.abs(n_tot), jnp.exp(-m_i))  # xLSTM normalizer
    h = h_num / denom[..., None]
    h = h.reshape(B, S, H, hd)
    return h.astype(q.dtype), (Cf, nf, mf)


def mlstm_forward(cfg: ModelConfig, p, x, *, state=None, chunk=128):
    """x: (B,S,d) -> (out, state)."""
    d_in, H, hd = mlstm_dims(cfg)
    B, S, _ = x.shape
    up = x @ p["w_up"]
    xi, z = up[..., :d_in], up[..., d_in:]
    q = (xi @ p["wq"]).reshape(B, S, H, hd)
    k = (xi @ p["wk"]).reshape(B, S, H, hd)
    v = (xi @ p["wv"]).reshape(B, S, H, hd)
    ig = xi.astype(jnp.float32) @ p["w_i"] + p["b_i"]
    fg = xi.astype(jnp.float32) @ p["w_f"] + p["b_f"]
    h, new_state = _mlstm_core_chunked(q, k, v, ig, fg, chunk=chunk,
                                       state=state)
    h = h.reshape(B, S, d_in)
    h = layers.rmsnorm(h * jax.nn.silu(z), p["norm_w"])
    return h @ p["w_down"], new_state


def mlstm_decode(cfg: ModelConfig, p, x, state):
    """One-token recurrent mLSTM step (exact recurrence)."""
    d_in, H, hd = mlstm_dims(cfg)
    B = x.shape[0]
    up = x @ p["w_up"]
    xi, z = up[..., :d_in], up[..., d_in:]
    q = (xi @ p["wq"]).reshape(B, H, hd).astype(jnp.float32) * hd ** -0.5
    k = (xi @ p["wk"]).reshape(B, H, hd).astype(jnp.float32)
    v = (xi @ p["wv"]).reshape(B, H, hd).astype(jnp.float32)
    ig = (xi.astype(jnp.float32) @ p["w_i"] + p["b_i"])[:, 0]  # (B,H)
    fg = (xi.astype(jnp.float32) @ p["w_f"] + p["b_f"])[:, 0]
    C, n, m = state
    logf = jax.nn.log_sigmoid(fg)
    m_new = jnp.maximum(logf + m, ig)
    w_old = jnp.exp(logf + m - m_new)
    w_new = jnp.exp(ig - m_new)
    C = C * w_old[..., None, None] + w_new[..., None, None] \
        * (k[..., :, None] * v[..., None, :])
    n = n * w_old[..., None] + w_new[..., None] * k
    num = jnp.einsum("bhd,bhde->bhe", q, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q, n)),
                      jnp.exp(-m_new))
    h = (num / den[..., None]).reshape(B, 1, d_in).astype(x.dtype)
    h = layers.rmsnorm(h * jax.nn.silu(z), p["norm_w"])
    return h @ p["w_down"], (C, n, m_new)


def init_slstm(cfg: ModelConfig, key, dtype):
    d = cfg.d_model
    H = cfg.n_heads
    hd = d // H
    ks = jax.random.split(key, 3)
    return {
        "w_gates": layers.dense_init(ks[0], d, 4 * d, dtype),  # i,f,z,o
        # block-diagonal recurrent weights per head: (4, H, hd, hd)
        "r_gates": (jax.random.normal(ks[1], (4, H, hd, hd)) * 0.02
                    ).astype(jnp.float32),
        "b_gates": jnp.concatenate([
            jnp.zeros((d,)), jnp.full((d,), 3.0), jnp.zeros((2 * d,))
        ]).astype(jnp.float32),
        "norm_w": jnp.ones((d,), dtype),
        "w_out": layers.dense_init(ks[2], d, d, dtype),
    }


def slstm_forward(cfg: ModelConfig, p, x, *, state=None):
    """Sequential sLSTM over the full sequence. x: (B,S,d).
    state: (c, n, m, h) each (B, H, hd) except m: (B, H, hd)."""
    d = cfg.d_model
    H = cfg.n_heads
    hd = d // H
    B, S, _ = x.shape
    pre = (x @ p["w_gates"]).astype(jnp.float32) + p["b_gates"]  # (B,S,4d)
    pre = pre.reshape(B, S, 4, H, hd)
    if state is None:
        z = jnp.zeros((B, H, hd), jnp.float32)
        state = (z, z, z - 10.0, z)  # c, n, m, h

    def step(carry, pre_t):
        c, n, m, h = carry
        rec = jnp.einsum("ghde,bhd->bghe", p["r_gates"], h)  # (B,4,H,hd)
        g = pre_t + rec
        ig, fg, zg, og = g[:, 0], g[:, 1], g[:, 2], g[:, 3]
        logf = jax.nn.log_sigmoid(fg)
        m_new = jnp.maximum(logf + m, ig)
        i_p = jnp.exp(ig - m_new)
        f_p = jnp.exp(logf + m - m_new)
        c = f_p * c + i_p * jnp.tanh(zg)
        n = f_p * n + i_p
        h = jax.nn.sigmoid(og) * c / jnp.maximum(n, 1.0)
        return (c, n, m_new, h), h

    pre_t = jnp.moveaxis(pre, 1, 0)  # (S,B,4,H,hd)
    new_state, hs = jax.lax.scan(step, state, pre_t)
    hs = jnp.moveaxis(hs, 0, 1).reshape(B, S, d).astype(x.dtype)
    hs = layers.rmsnorm(hs, p["norm_w"])
    return hs @ p["w_out"], new_state


def slstm_decode(cfg: ModelConfig, p, x, state):
    out, new_state = slstm_forward(cfg, p, x, state=state)
    return out, new_state
