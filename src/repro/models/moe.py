"""Mixture-of-experts FFN: shared experts + routed top-k, Switch-style
capacity-buffer dispatch (scatter in / gather out).

Covers both assigned MoE archs: deepseek-moe-16b (fine-grained, 64e top-6 +
2 shared) and llama4-scout (16e top-1 + 1 shared).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import act_sharding, layers


def init_moe(cfg: ModelConfig, key, dtype):
    d, m = cfg.d_model, cfg.moe
    kr, ke1, ke2, ke3, ks = jax.random.split(key, 5)
    p = {
        "router": layers.dense_init(kr, d, m.n_experts, jnp.float32),
        # routed experts, stacked on expert axis: (E, d, f) / (E, f, d)
        "we_gate": jax.vmap(
            lambda k: layers.dense_init(k, d, m.d_expert, dtype))(
                jax.random.split(ke1, m.n_experts)),
        "we_up": jax.vmap(
            lambda k: layers.dense_init(k, d, m.d_expert, dtype))(
                jax.random.split(ke2, m.n_experts)),
        "we_down": jax.vmap(
            lambda k: layers.dense_init(k, m.d_expert, d, dtype))(
                jax.random.split(ke3, m.n_experts)),
    }
    if m.n_shared:
        # shared experts fused into one dense SwiGLU of width n_shared*d_expert
        f = m.n_shared * m.d_expert
        k1, k2, k3 = jax.random.split(ks, 3)
        p["shared"] = {
            "wg": layers.dense_init(k1, d, f, dtype),
            "wu": layers.dense_init(k2, d, f, dtype),
            "wd": layers.dense_init(k3, f, d, dtype),
        }
    return p


def moe_ffn(cfg: ModelConfig, p, x, *, capacity_factor=1.25, dropless=False):
    """x: (B, S, d) -> (B, S, d), plus aux dict (load-balance loss terms).

    dropless=True sizes the capacity buffer at T*K (worst case) so no token is
    ever dropped — used by the serving engine so that layer-wise offloading is
    provably lossless; training/dry-run use the capacity factor.
    """
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    E, K = m.n_experts, m.top_k
    # the (B,S)->T reshape merges a sharded with an unsharded dim; GSPMD
    # loses the sharding, so re-pin the token axis explicitly
    xf = act_sharding.constrain_moe_tokens(x.reshape(T, d))

    logits = (xf.astype(jnp.float32) @ p["router"])  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)  # (T, K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)  # renormalize over top-k

    # --- capacity-buffer dispatch -----------------------------------------
    # NB: all intermediates stay (T, ...)-shaped and token-sharded; a naive
    # (T*K, d) gather materializes tens of GiB replicated under GSPMD.
    C = T * K if dropless else max(1, int(T * K / E * capacity_factor))
    flat_expert = act_sharding.constrain_moe_tokens(
        expert_idx.reshape(T * K))
    onehot = act_sharding.constrain_moe_tokens(
        jax.nn.one_hot(flat_expert, E, dtype=jnp.int32))  # (T*K, E)
    pos_in_expert = (jnp.cumsum(onehot, axis=0) - onehot)  # exclusive cumsum
    pos = (pos_in_expert * onehot).sum(-1)  # (T*K,)
    keep = pos < C
    pos2 = pos.reshape(T, K)
    keep2 = keep.reshape(T, K)

    # GSPMD partitions payload-scatters poorly (it replicates the (T, d)
    # updates); instead scatter only an int32 slot->token map and move the
    # payload with gathers.
    slot = flat_expert * C + jnp.minimum(pos, C - 1)     # (T*K,)
    tok_idx = jnp.repeat(jnp.arange(T), K).reshape(T, K).reshape(T * K)
    slot_src = jnp.full((E * C,), T, jnp.int32)          # T = empty sentinel
    slot_src = slot_src.at[jnp.where(keep, slot, E * C)].set(
        tok_idx.astype(jnp.int32), mode="drop")
    # clamped gather + mask (a (T+1)-row pad table would break even
    # sharding of the token dim and replicate everything)
    filled = (slot_src < T)[:, None].astype(x.dtype)
    buf = xf[jnp.minimum(slot_src, T - 1)] * filled
    buf = buf.reshape(E, C, d)
    buf = act_sharding.constrain_moe_buffer(buf)

    # --- expert compute: (E, C, d) x (E, d, f) ------------------------------
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["we_gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", buf, p["we_up"])
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["we_down"])  # (E, C, d)
    out_buf = act_sharding.constrain_moe_buffer(out_buf)

    # --- combine ------------------------------------------------------------
    routed = jnp.zeros((T, d), x.dtype)
    gate2 = gate_vals.astype(x.dtype)
    flat_out = out_buf.reshape(E * C, d)
    slot2 = slot.reshape(T, K)
    for kk in range(K):  # K gathers of (T, d) — never (T*K, d)
        g = act_sharding.constrain_moe_tokens(flat_out[slot2[:, kk]])
        routed = routed + g * (gate2[:, kk]
                               * keep2[:, kk].astype(x.dtype))[:, None]

    out = routed
    if m.n_shared:
        out = out + _shared_mlp(p["shared"], xf)

    # load-balance aux (Switch aux loss terms)
    me = probs.mean(0)                                   # mean router prob
    ce = jnp.bincount(flat_expert, length=E) / (T * K)   # fraction dispatched
    aux = {"lb_loss": E * jnp.sum(me * ce),
           "dropped_frac": 1.0 - keep.mean()}
    return out.reshape(B, S, d), aux


def _shared_mlp(p, xf):
    return (jax.nn.silu(xf @ p["wg"]) * (xf @ p["wu"])) @ p["wd"]
