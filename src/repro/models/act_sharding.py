"""Sequence-parallel activation sharding (Megatron-SP on GSPMD).

The scan-over-layers remat carry is the dominant training activation cost:
(B, S, d) per layer. Constraining it to P(data, 'model', None) at layer
boundaries lets the checkpoint stack live sequence-sharded; GSPMD inserts
the all-gather before attention and reduce-scatters after, exactly like
Megatron sequence parallelism.

Off by default (smoke tests and single-device runs see no constraint);
the launcher enables it under a mesh context.
"""
from __future__ import annotations

import contextlib
from typing import Optional

import jax

_SAVED_SPEC: Optional[object] = None    # layer-boundary (checkpointed) layout
_COMPUTE_SPEC: Optional[object] = None  # in-layer layout


@contextlib.contextmanager
def activation_sharding(saved, compute=None):
    """saved: PartitionSpec for the (B, S, d) activations crossing layer
    boundaries (what remat stores, typically seq-sharded on 'model');
    compute: layout restored at layer entry (typically seq-replicated so
    attention partitions normally)."""
    global _SAVED_SPEC, _COMPUTE_SPEC
    prev = (_SAVED_SPEC, _COMPUTE_SPEC)
    _SAVED_SPEC, _COMPUTE_SPEC = saved, compute
    try:
        yield
    finally:
        _SAVED_SPEC, _COMPUTE_SPEC = prev


def constrain(x):
    """Layer-boundary constraint (applied to the scan carry)."""
    if _SAVED_SPEC is not None and x.ndim == 3 and x.shape[1] > 1:
        return jax.lax.with_sharding_constraint(x, _SAVED_SPEC)
    return x


def constrain_compute(x):
    """Layer-entry constraint (gather back to the compute layout)."""
    if _COMPUTE_SPEC is not None and x.ndim == 3 and x.shape[1] > 1:
        return jax.lax.with_sharding_constraint(x, _COMPUTE_SPEC)
    return x


_KV_SPEC = None  # PartitionSpec for collected per-layer KV (B, S, KV, hd)


@contextlib.contextmanager
def kv_sharding(spec):
    global _KV_SPEC
    prev = _KV_SPEC
    _KV_SPEC = spec
    try:
        yield
    finally:
        _KV_SPEC = prev


def constrain_kv(kv):
    """Constrain a prefill-collected (k, v) pair before lax.scan stacks it
    into the (L, B, S, KV, hd) cache — otherwise XLA may materialize the
    stack sequence-replicated."""
    if _KV_SPEC is None or kv is None:
        return kv
    k, v = kv
    if k.ndim != 4:
        return kv
    return (jax.lax.with_sharding_constraint(k, _KV_SPEC),
            jax.lax.with_sharding_constraint(v, _KV_SPEC))


def constrain_kv_stack(k, v):
    """Pin the stacked (L, B, S, KV, hd) prefill KV to the cache layout.
    GSPMD otherwise picks a (KV x hd) sharding for the stack and its
    'involuntary full rematerialization' fallback replicates the whole
    cache when writing it (205 GiB at llama4 prefill scale)."""
    if _KV_SPEC is None or k.ndim != 5:
        return k, v
    spec = jax.sharding.PartitionSpec(None, *tuple(_KV_SPEC))
    return (jax.lax.with_sharding_constraint(k, spec),
            jax.lax.with_sharding_constraint(v, spec))


_STATE_SPEC = None  # PartitionSpec for recurrent chunk states (B, nc, H, hd, hd)


@contextlib.contextmanager
def state_sharding(spec):
    """Pin mLSTM/SSD chunkwise state tensors (rank-5 (B, nc, H, hd, hd) and
    rank-4 (B, nc|H, ..., hd)) so their einsums don't bounce layouts."""
    global _STATE_SPEC
    prev = _STATE_SPEC
    _STATE_SPEC = spec
    try:
        yield
    finally:
        _STATE_SPEC = prev


def constrain_state(x):
    if _STATE_SPEC is None or x.ndim != 5:
        return x
    return jax.lax.with_sharding_constraint(x, _STATE_SPEC)


_MOE_SPEC = None  # PartitionSpec for the (E, C, d) expert dispatch buffer


@contextlib.contextmanager
def moe_buffer_sharding(spec):
    global _MOE_SPEC
    prev = _MOE_SPEC
    _MOE_SPEC = spec
    try:
        yield
    finally:
        _MOE_SPEC = prev


def constrain_moe_buffer(buf):
    if _MOE_SPEC is not None and buf.ndim == 3:
        return jax.lax.with_sharding_constraint(buf, _MOE_SPEC)
    return buf


def constrain_moe_tokens(x):
    """Keep per-token MoE intermediates sharded on the token axis (dim 0).
    Uses the batch axes of the active MoE buffer spec."""
    if _MOE_SPEC is None:
        return x
    dp = tuple(_MOE_SPEC)[1]  # (E, C, d) -> C carries the data axes
    if dp is None:
        return x
    spec = jax.sharding.PartitionSpec(dp, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, spec)
