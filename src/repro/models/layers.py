"""Shared neural-net building blocks (pure JAX, dict-pytree params).

Conventions:
  * params are nested dicts of jnp arrays; layer-stacked params carry a
    leading (L, ...) axis consumed by lax.scan.
  * activations default to the config dtype (bf16 at scale, f32 in smoke
    tests); norms and softmax accumulate in f32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def dense_init(key, in_dim, out_dim, dtype):
    scale = (2.0 / (in_dim + out_dim)) ** 0.5
    return (jax.random.normal(key, (in_dim, out_dim)) * scale).astype(dtype)


def embed_init(key, vocab, dim, dtype):
    return (jax.random.normal(key, (vocab, dim)) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm(x, w, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


def layernorm(x, w, b, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = xf.mean(axis=-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(axis=-1, keepdims=True)
    return (((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype)) * w + b


def apply_norm(cfg: ModelConfig, p, x):
    if cfg.norm == "rmsnorm":
        return rmsnorm(x, p["w"])
    return layernorm(x, p["w"], p["b"])


def init_norm(cfg: ModelConfig, key, dim, dtype):
    if cfg.norm == "rmsnorm":
        return {"w": jnp.ones((dim,), dtype)}
    return {"w": jnp.ones((dim,), dtype), "b": jnp.zeros((dim,), dtype)}


# ---------------------------------------------------------------------------
# rotary position embeddings (rope / rope2d / mrope)
# ---------------------------------------------------------------------------

def _rope_freqs(dim, theta):
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def _rotate(x, cos, sin):
    """x: (..., D_rot) with paired layout [d0 d1 d2 ...] rotated as complex
    pairs (x_even, x_odd)."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def apply_rope(cfg: ModelConfig, x, positions):
    """x: (B, S, N, D); positions: (B, S) int32 for 'rope'/'rope2d',
    (3, B, S) for 'mrope'. Returns same shape/dtype as x."""
    D = x.shape[-1]
    if cfg.pos_emb in ("none", "learned", "sinusoid"):
        return x
    if cfg.pos_emb == "rope":
        freqs = _rope_freqs(D, cfg.rope_theta)  # (D/2,)
        ang = positions[..., None].astype(jnp.float32) * freqs  # (B,S,D/2)
        cos, sin = jnp.cos(ang)[:, :, None], jnp.sin(ang)[:, :, None]
        return _rotate(x.astype(jnp.float32), cos, sin).astype(x.dtype)
    if cfg.pos_emb == "rope2d":
        # ChatGLM half-rotary: rotate first half of head_dim, pass the rest.
        Dr = D // 2
        freqs = _rope_freqs(Dr, cfg.rope_theta)
        ang = positions[..., None].astype(jnp.float32) * freqs
        cos, sin = jnp.cos(ang)[:, :, None], jnp.sin(ang)[:, :, None]
        xr, xp = x[..., :Dr], x[..., Dr:]
        xr = _rotate(xr.astype(jnp.float32), cos, sin).astype(x.dtype)
        return jnp.concatenate([xr, xp], axis=-1)
    if cfg.pos_emb == "mrope":
        # Qwen2-VL multimodal rope: head_dim/2 freq slots split into three
        # sections (t, h, w) = (1/4, 3/8, 3/8), each driven by its own
        # position id stream. positions: (3, B, S).
        half = D // 2
        st = half // 4
        sh = (half - st) // 2
        sections = [st, sh, half - st - sh]
        freqs = _rope_freqs(D, cfg.rope_theta)  # (half,)
        parts, off = [], 0
        for i, sec in enumerate(sections):
            ang = positions[i][..., None].astype(jnp.float32) * freqs[off:off + sec]
            parts.append(ang)
            off += sec
        ang = jnp.concatenate(parts, axis=-1)  # (B,S,half)
        cos, sin = jnp.cos(ang)[:, :, None], jnp.sin(ang)[:, :, None]
        return _rotate(x.astype(jnp.float32), cos, sin).astype(x.dtype)
    raise ValueError(cfg.pos_emb)


def sinusoid_pos_emb(positions, dim):
    """positions: (B, S) -> (B, S, dim) float32 sinusoidal embedding."""
    half = dim // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half) / max(half - 1, 1))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# attention block
# ---------------------------------------------------------------------------

def init_attention(cfg: ModelConfig, key, dtype):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    H = cfg.n_q_heads  # incl. TP padding; pad wo rows are zero
    kq, kk, kv_, ko, kb = jax.random.split(key, 5)
    p = {
        "wq": dense_init(kq, d, H * hd, dtype),
        "wk": dense_init(kk, d, cfg.n_kv_heads * hd, dtype),
        "wv": dense_init(kv_, d, cfg.n_kv_heads * hd, dtype),
        "wo": dense_init(ko, H * hd, d, dtype),
    }
    if cfg.head_pad_to > cfg.n_heads:
        # zero the padded heads' output rows so they cannot affect results
        wo = p["wo"]
        wo = wo.reshape(H, hd, d).at[cfg.n_heads:].set(0.0)
        p["wo"] = wo.reshape(H * hd, d)
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), dtype)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), dtype)
    return p


def qkv_proj(cfg: ModelConfig, p, x):
    """x: (B, S, d) -> q (B,S,H,hd), k/v (B,S,KV,hd)."""
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    return (q.reshape(B, S, cfg.n_q_heads, hd),
            k.reshape(B, S, cfg.n_kv_heads, hd),
            v.reshape(B, S, cfg.n_kv_heads, hd))


def attn_out(cfg: ModelConfig, p, o):
    B, S = o.shape[:2]
    return o.reshape(B, S, -1) @ p["wo"]


def self_attention(cfg: ModelConfig, p, x, positions, *, causal=True,
                   window=0, kv_len=None):
    """Full self-attention over x (train / encoder). Returns (out, (k, v))."""
    q, k, v = qkv_proj(cfg, p, x)
    q = apply_rope(cfg, q, positions)
    k = apply_rope(cfg, k, positions)
    o = ops.flash_attention(q, k, v, causal=causal, window=window,
                            kv_len=kv_len)
    return attn_out(cfg, p, o), (k, v)


def cross_attention(cfg: ModelConfig, p, x, k, v, enc_len=None):
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q = (x @ p["wq"])
    if cfg.qkv_bias:
        q = q + p["bq"]
    q = q.reshape(B, S, cfg.n_q_heads, hd)
    o = ops.flash_attention(q, k, v, causal=False, kv_len=enc_len)
    return attn_out(cfg, p, o)


def cross_kv(cfg: ModelConfig, p, enc_out):
    B, T, _ = enc_out.shape
    hd = cfg.resolved_head_dim
    k = enc_out @ p["wk"]
    v = enc_out @ p["wv"]
    if cfg.qkv_bias:
        k, v = k + p["bk"], v + p["bv"]
    return (k.reshape(B, T, cfg.n_kv_heads, hd),
            v.reshape(B, T, cfg.n_kv_heads, hd))


def decode_self_attention(cfg: ModelConfig, p, x, k_cache, v_cache, kv_len,
                          positions):
    """One-token decode. x: (B, 1, d); caches (B, S, KV, hd); kv_len (B,)
    counts valid entries INCLUDING the new token once written by the caller.

    Returns (out, k_new, v_new) — the caller owns cache insertion so that
    ring-buffer (sliding-window) and paged layouts can share this code.
    """
    q, k, v = qkv_proj(cfg, p, x)
    q = apply_rope(cfg, q, positions)
    k = apply_rope(cfg, k, positions)
    return q, k, v


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def init_mlp(cfg: ModelConfig, key, dtype, d_ff=None):
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.act == "silu":
        return {"wg": dense_init(k1, d, f, dtype),
                "wu": dense_init(k2, d, f, dtype),
                "wd": dense_init(k3, f, d, dtype)}
    return {"w1": dense_init(k1, d, f, dtype), "b1": jnp.zeros((f,), dtype),
            "w2": dense_init(k2, f, d, dtype), "b2": jnp.zeros((d,), dtype)}


def mlp(cfg: ModelConfig, p, x):
    if cfg.act == "silu":
        return (jax.nn.silu(x @ p["wg"]) * (x @ p["wu"])) @ p["wd"]
    return jax.nn.gelu(x @ p["w1"] + p["b1"]) @ p["w2"] + p["b2"]
