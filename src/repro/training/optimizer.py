"""AdamW + LR schedule + global-norm clipping, in plain JAX pytrees."""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


class OptState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


def lr_at(cfg: AdamWConfig, step):
    """Linear warmup then cosine decay to min_lr_frac * lr."""
    step = jnp.asarray(step, jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 \
        * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    return OptState(jnp.zeros((), jnp.int32), zeros,
                    jax.tree.map(jnp.copy, zeros))


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, grads, state: OptState, params):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)
    step = state.step + 1
    lr = lr_at(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    mu = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g,
                      state.mu, grads)
    nu = jax.tree.map(lambda v, g: cfg.b2 * v + (1 - cfg.b2) * g * g,
                      state.nu, grads)

    def upd(p, m, v):
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay \
            * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, OptState(step, mu, nu), {"grad_norm": gnorm, "lr": lr}
