"""Minimal dependency-free checkpointing: npz for arrays + json manifest."""
from __future__ import annotations

import json
import os
from typing import Any, Tuple

import jax
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = np.asarray(tree)
    return out


def save(path: str, tree: Any, meta: dict | None = None) -> None:
    os.makedirs(path, exist_ok=True)
    flat = _flatten(tree)
    # npz cannot hold bfloat16: widen to f32 and record the original dtype
    dtypes = {k: str(v.dtype) for k, v in flat.items()}
    flat = {k: (v.astype(np.float32) if v.dtype.name == "bfloat16" else v)
            for k, v in flat.items()}
    np.savez(os.path.join(path, "arrays.npz"), **flat)
    treedef = jax.tree.structure(tree)
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump({"treedef": str(treedef), "meta": meta or {},
                   "keys": sorted(flat), "dtypes": dtypes}, f)


def load(path: str, like: Any) -> Tuple[Any, dict]:
    """Restore into the structure of `like` (names must match)."""
    import ml_dtypes
    data = np.load(os.path.join(path, "arrays.npz"))
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    meta = manifest["meta"]
    dtypes = manifest.get("dtypes", {})
    flat = _flatten(like)
    restored = {}
    for k in flat:
        arr = data[k]
        if dtypes.get(k) == "bfloat16":
            arr = arr.astype(ml_dtypes.bfloat16)
        restored[k] = arr

    def rebuild(tree, prefix=""):
        if isinstance(tree, dict):
            return {k: rebuild(v, f"{prefix}{k}/") for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            vals = [rebuild(v, f"{prefix}{i}/") for i, v in enumerate(tree)]
            return type(tree)(vals)
        return restored[prefix[:-1]]

    return rebuild(like), meta
