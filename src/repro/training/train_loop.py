"""Training loop: jitted train_step (loss + grad + AdamW) and the driver."""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax

from repro.configs.base import ModelConfig
from repro.models import build_model
from repro.training.checkpoint import save as ckpt_save
from repro.training.data import DataConfig, SyntheticLM
from repro.training.optimizer import (
    AdamWConfig, OptState, adamw_update, init_opt_state,
)


def make_train_step(cfg: ModelConfig, opt: AdamWConfig,
                    donate: bool = True) -> Callable:
    model = build_model(cfg)

    def train_step(params, opt_state: OptState, batch):
        (loss, metrics), grads = jax.value_and_grad(
            model.loss, has_aux=True)(params, batch)
        params, opt_state, opt_metrics = adamw_update(
            opt, grads, opt_state, params)
        metrics = {**metrics, **opt_metrics}
        return params, opt_state, metrics

    return jax.jit(train_step, donate_argnums=(0, 1) if donate else ())


@dataclasses.dataclass
class TrainResult:
    losses: list
    final_loss: float
    steps: int
    tokens_per_s: float


def train(cfg: ModelConfig, steps: int = 200, dc: Optional[DataConfig] = None,
          opt: Optional[AdamWConfig] = None, seed: int = 0,
          ckpt_path: Optional[str] = None, ckpt_every: int = 0,
          log_every: int = 20, verbose: bool = True) -> TrainResult:
    dc = dc or DataConfig()
    opt = opt or AdamWConfig(lr=1e-3, total_steps=steps,
                             warmup_steps=max(steps // 10, 5))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    opt_state = init_opt_state(params)
    step_fn = make_train_step(cfg, opt)
    data = SyntheticLM(cfg, dc).batches()

    losses = []
    t0 = time.perf_counter()
    tokens = 0
    for step in range(steps):
        batch = next(data)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        tokens += dc.batch_size * dc.seq_len
        if verbose and (step % log_every == 0 or step == steps - 1):
            print(f"step {step:5d} loss {loss:8.4f} "
                  f"grad_norm {float(metrics['grad_norm']):7.3f} "
                  f"lr {float(metrics['lr']):.2e}")
        if ckpt_path and ckpt_every and (step + 1) % ckpt_every == 0:
            ckpt_save(ckpt_path, {"params": params},
                      meta={"step": step + 1, "loss": loss})
    dt = time.perf_counter() - t0
    if ckpt_path:
        ckpt_save(ckpt_path, {"params": params},
                  meta={"step": steps, "loss": losses[-1]})
    return TrainResult(losses, losses[-1], steps, tokens / dt)
