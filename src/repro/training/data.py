"""Synthetic autoregressive data pipeline.

Deterministic, seedable token streams with enough structure that a model's
loss measurably drops within a few hundred steps (a noisy order-k Markov
process over the vocab), plus the stub modality frontends for the audio /
VLM architectures (precomputed frame/patch embeddings per spec).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass
class DataConfig:
    batch_size: int = 8
    seq_len: int = 128
    seed: int = 0
    markov_order: int = 1
    noise: float = 0.1


class SyntheticLM:
    """Order-k Markov chain over the model vocab: next = hash(prev_k) with
    probability 1-noise, else uniform. Learnable by any competent LM."""

    def __init__(self, cfg: ModelConfig, dc: DataConfig):
        self.cfg = cfg
        self.dc = dc
        self.rng = np.random.RandomState(dc.seed)
        V = cfg.vocab_size
        self._mults = self.rng.randint(1, V, size=dc.markov_order) * 2 + 1

    def _next(self, context: np.ndarray) -> np.ndarray:
        """context: (B, k) -> (B,) deterministic successor."""
        V = self.cfg.vocab_size
        h = np.zeros(context.shape[0], np.int64)
        for i in range(self.dc.markov_order):
            h = h * 1000003 + context[:, i] * self._mults[i]
        return (h % V).astype(np.int32)

    def batches(self) -> Iterator[Dict[str, jnp.ndarray]]:
        B, S = self.dc.batch_size, self.dc.seq_len
        V = self.cfg.vocab_size
        k = self.dc.markov_order
        while True:
            toks = np.zeros((B, S + 1), np.int32)
            toks[:, :k] = self.rng.randint(0, V, size=(B, k))
            for t in range(k, S + 1):
                nxt = self._next(toks[:, t - k:t])
                flip = self.rng.rand(B) < self.dc.noise
                nxt[flip] = self.rng.randint(0, V, size=flip.sum())
                toks[:, t] = nxt
            batch = {"tokens": jnp.asarray(toks[:, :-1]),
                     "labels": jnp.asarray(toks[:, 1:])}
            yield self._add_frontend_stubs(batch, B, S)

    def _add_frontend_stubs(self, batch, B, S):
        cfg = self.cfg
        if cfg.family == "vlm":
            # stub ViT/projector output: embeddings for the token stream
            # (in training, vision patches + text share the stream)
            key = jax.random.PRNGKey(int(self.rng.randint(1 << 30)))
            batch["embeds"] = jax.random.normal(
                key, (B, S, cfg.d_model), jnp.float32).astype(cfg.dtype) * 0.02
            batch["mrope_pos"] = jnp.broadcast_to(
                jnp.arange(S)[None, None], (3, B, S))
        if cfg.family == "encdec":
            key = jax.random.PRNGKey(int(self.rng.randint(1 << 30)))
            batch["enc_embeds"] = jax.random.normal(
                key, (B, cfg.encoder_len, cfg.d_model),
                jnp.float32).astype(cfg.dtype) * 0.02
        return batch
