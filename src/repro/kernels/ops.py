"""Public jit'd kernel entry points with backend selection.

Backends:
  'ref'       pure-jnp chunked oracle (default; lowers cleanly under GSPMD on
              any platform — this is what the dry-run compiles)
  'pallas'    Pallas TPU kernels; on CPU they run in interpret mode (used by
              kernel tests), on TPU they compile to Mosaic.

Select globally via `set_backend` or per-call via `backend=`.
"""
from __future__ import annotations

from repro.kernels import ref as _ref

_BACKEND = "ref"


def set_backend(name: str) -> None:
    global _BACKEND
    assert name in ("ref", "pallas"), name
    _BACKEND = name


def get_backend() -> str:
    return _BACKEND


def flash_attention(q, k, v, *, causal=True, window=0, kv_len=None, q_offset=0,
                    q_chunk=512, kv_chunk=512, softmax_scale=None,
                    backend=None):
    b = backend or _BACKEND
    if b == "pallas":
        from repro.kernels import flash_prefill
        return flash_prefill.flash_attention_pallas(
            q, k, v, causal=causal, window=window, kv_len=kv_len,
            q_offset=q_offset, softmax_scale=softmax_scale)
    return _ref.flash_attention_reference(
        q, k, v, causal=causal, window=window, kv_len=kv_len,
        q_offset=q_offset, q_chunk=q_chunk, kv_chunk=kv_chunk,
        softmax_scale=softmax_scale)


def decode_attention(q, k_cache, v_cache, kv_len, *, window=0,
                     softmax_scale=None, backend=None):
    return _ref.decode_attention_reference(
        q, k_cache, v_cache, kv_len, window=window,
        softmax_scale=softmax_scale)


def paged_attention(q, kv_pool, block_table, kv_len, *, softmax_scale=None,
                    backend=None):
    b = backend or _BACKEND
    if b == "pallas":
        from repro.kernels import paged_attention as _pa
        return _pa.paged_attention_pallas(
            q, kv_pool, block_table, kv_len, softmax_scale=softmax_scale)
    return _ref.paged_attention_reference(
        q, kv_pool, block_table, kv_len, softmax_scale=softmax_scale)


def paged_prefill(q, kv_pool, block_table, seg_ids, q_pos, kv_len, *,
                  host_pool=None, tier=None, tq=8, softmax_scale=None,
                  backend=None):
    """Segmented prefill/decode attention straight over the paged pool(s).

    q: (T, H, D) flat token batch — per-request segments each padded to a
    multiple of `tq` (so a query tile never straddles segments); the
    chunk's own KV must already be scattered into the pool. block_table:
    (S, MAXB); seg_ids/q_pos: (T,); kv_len: (S,). With `tier` (S,) bool,
    a True segment's blocks are read from `host_pool`. Returns (T, H, D).
    """
    b = backend or _BACKEND
    if b == "pallas":
        from repro.kernels import paged_prefill as _pp
        return _pp.paged_prefill_pallas(
            q, kv_pool, block_table, seg_ids, q_pos, kv_len,
            host_pool=host_pool, tier=tier, tq=tq,
            softmax_scale=softmax_scale)
    return _ref.paged_prefill_reference(
        q, kv_pool, block_table, seg_ids, q_pos, kv_len,
        host_pool=host_pool, tier=tier, tq=tq, softmax_scale=softmax_scale)
