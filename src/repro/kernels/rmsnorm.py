"""Pallas TPU fused RMSNorm kernel.

Norms run twice per layer per token in decode — at batch 128 that is
~10k launches/s of a bandwidth-bound op, worth fusing into one
VMEM-resident pass (read x once, write once; the f32 accumulation for the
mean-square lives in registers).

Grid: one program per row-tile; d_model rides whole in the lane dim
(128-aligned for every assigned arch).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)          # (rows, d)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps)).astype(o_ref.dtype) \
        * w_ref[...]


@functools.partial(jax.jit,
                   static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm_pallas(x, w, *, eps=1e-6, block_rows=128, interpret=None):
    """x: (..., d); w: (d,). Returns rmsnorm(x) * w in x.dtype."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    orig_shape = x.shape
    d = x.shape[-1]
    rows = 1
    for s in x.shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    br = min(block_rows, rows)
    pad = (-rows) % br
    if pad:
        x2 = jnp.pad(x2, [(0, pad), (0, 0)])
    n = x2.shape[0] // br
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(n,),
        in_specs=[pl.BlockSpec((br, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        interpret=interpret,
    )(x2, w)
    if pad:
        out = out[:rows]
    return out.reshape(orig_shape)
