"""Pallas TPU flash-attention prefill kernel (causal / sliding-window, GQA).

TPU adaptation notes (vs. the usual CUDA flash kernel):
  * tiling is chosen for VMEM + MXU: q/k tiles default to 128 rows and the
    head dim rides along whole (128-aligned for every assigned arch except
    whisper/zamba2/granite, where 64/80 still maps onto the MXU with padding);
  * the KV loop is the innermost *sequential* grid dimension — on TPU the
    grid is executed in order, so the online-softmax state (m, l, acc) lives
    in VMEM scratch that persists across that dimension;
  * fully-masked KV tiles are skipped with @pl.when (causal upper triangle
    and out-of-window tiles), halving the causal FLOPs.

Validated against `ref.flash_attention_reference` in interpret mode on CPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, acc_sc, *,
                  bq, bk, n_kv_blocks, causal, window, q_offset, scale):
    iq = pl.program_id(3)
    ik = pl.program_id(4)

    @pl.when(ik == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    q_start = iq * bq + q_offset
    k_start = ik * bk
    # tile-level skip: is any (i, j) pair in this tile live?
    live = jnp.array(True)
    if causal:
        live &= k_start <= q_start + bq - 1
    if window:
        live &= k_start + bk - 1 > q_start - window

    @pl.when(live)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32) * scale   # (bq, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)           # (bk, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (bq,bk)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), dtype=bool)
        if causal:
            mask &= q_pos >= k_pos
        if window:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev, l_prev = m_sc[...], l_sc[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_sc[...] = l_prev * corr + p.sum(axis=1)
        m_sc[...] = m_new
        acc_sc[...] = acc_sc[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ik == n_kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_sc[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_sc[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "q_offset", "softmax_scale",
                     "block_q", "block_k", "interpret"))
def flash_attention_pallas(q, k, v, *, causal=True, window=0, kv_len=None,
                           q_offset=0, softmax_scale=None, block_q=128,
                           block_k=128, interpret=None):
    """q: (B, Sq, H, D); k, v: (B, Skv, KV, D). kv_len unsupported here
    (engine prefills exact-length sequences); q_offset must be static."""
    assert kv_len is None, "pallas prefill kernel expects exact-length batches"
    B, Sq, H, D = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = softmax_scale if softmax_scale is not None else D ** -0.5
    bq = min(block_q, Sq)
    bk = min(block_k, Skv)
    assert Sq % bq == 0 and Skv % bk == 0
    nq, nk = Sq // bq, Skv // bk
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    grid = (B, KV, G, nq, nk)
    kernel = functools.partial(
        _flash_kernel, bq=bq, bk=bk, n_kv_blocks=nk, causal=causal,
        window=window, q_offset=q_offset, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, 1, D),
                         lambda b, kh, g, iq, ik: (b, iq, kh * G + g, 0)),
            pl.BlockSpec((1, bk, 1, D),
                         lambda b, kh, g, iq, ik: (b, ik, kh, 0)),
            pl.BlockSpec((1, bk, 1, D),
                         lambda b, kh, g, iq, ik: (b, ik, kh, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, D),
                               lambda b, kh, g, iq, ik: (b, iq, kh * G + g, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),      # m (online-softmax max)
            pltpu.VMEM((bq,), jnp.float32),      # l (normalizer)
            pltpu.VMEM((bq, D), jnp.float32),    # acc (output accumulator)
        ],
        interpret=interpret,
    )(q, k, v)
