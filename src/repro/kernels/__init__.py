"""Pallas TPU kernels (flash prefill, paged decode attention, fused
rmsnorm) with jnp oracles in ref.py and jit'd wrappers in ops.py."""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
