"""Pallas TPU paged chunk-prefill attention kernel.

A prefill chunk attends **directly against the pooled KV tensor** — no
dense prefix gather (`gather_layer`), no `dynamic_update_slice` staging
buffer. This is the prefill-side twin of `paged_attention.py` and the
kernel behind the fused mixed step (one forward per serving iteration):

  * the flat token batch is a concatenation of per-request *segments*
    (a prefill chunk = its chunk tokens, a decode request = one token),
    each padded to the query tile `TQ` so a tile never straddles two
    segments;
  * grid = (KV_heads, n_q_tiles, MAXB): query-tile x block-table-chase.
    The per-segment block table, tile->segment map, tile base positions
    and per-segment KV lengths are **scalar-prefetched**
    (pltpu.PrefetchScalarGridSpec) so the BlockSpec index_map itself
    chases the page table — the DMA engine gathers KV blocks HBM->VMEM;
  * the KV-block axis is the innermost sequential dimension with
    online-softmax state in VMEM scratch; causal masking of the
    in-chunk tail runs against absolute positions (`q_offset` per tile
    base), so already-cached prefix KV and the chunk's freshly scattered
    KV are handled by one mask;
  * fully-masked tiles (causal upper triangle past the chunk, blocks
    beyond kv_len) are skipped with @pl.when;
  * all G = H/KV query heads of a KV group ride in the tile as a
    (TQ*G, D) x (D, BS) MXU matmul per page.

With `tier`/`host_pool` set (layer-wise offload mid-prefill: a segment's
blocks live in the HOST pool), both pools' candidate blocks are fetched
and the live one selected in-kernel. That costs 2x KV DMA for the
host-resident variant — acceptable because mid-prefill host residency is
the exception; a production TPU deployment would pin the host tier in
device-mappable memory or pre-stage, which this repo models at the
block-manager level.

Validated against `ref.paged_prefill_reference` in interpret mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _prefill_body(q_ref, o_ref, m_sc, l_sc, acc_sc, k, v, *, ib, bs, g, tq,
                  scale, q0, kv_len):
    """Shared online-softmax update for one (q_tile, kv_block) pair.
    k/v: (BS, D) f32 already selected from the right pool. `ib` is passed
    in: pl.program_id is read once at kernel top level (this jax version
    cannot lower it inside a pl.when body in interpret mode)."""
    D = k.shape[-1]
    q = q_ref[0, :, 0].astype(jnp.float32).reshape(tq * g, D) * scale
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (tq*g, BS)
    row = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // g
    q_abs = q0 + row
    k_pos = ib * bs + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = (q_abs >= k_pos) & (k_pos < kv_len)
    s = jnp.where(mask, s, NEG_INF)
    m_prev, l_prev = m_sc[...], l_sc[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_sc[...] = l_prev * corr + p.sum(axis=1)
    m_sc[...] = m_new
    acc_sc[...] = acc_sc[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def _init_finalize(o_ref, m_sc, l_sc, acc_sc, *, ib, g, tq):
    @pl.when(ib == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    def _finalize():
        l = jnp.maximum(l_sc[...], 1e-30)
        out = acc_sc[...] / l[:, None]
        o_ref[0, :, 0] = out.reshape(tq, g, out.shape[-1]).astype(o_ref.dtype)
    return _finalize


def _paged_prefill_kernel(tab_ref, tseg_ref, tqpos_ref, len_ref, q_ref,
                          pool_ref, o_ref, m_sc, l_sc, acc_sc, *, bs, g, tq,
                          n_blocks, scale):
    it, ib = pl.program_id(1), pl.program_id(2)
    finalize = _init_finalize(o_ref, m_sc, l_sc, acc_sc, ib=ib, g=g, tq=tq)
    seg = tseg_ref[it]
    kv_len = len_ref[seg]
    q0 = tqpos_ref[it]
    live = (ib * bs < kv_len) & (ib * bs <= q0 + tq - 1)

    @pl.when(live)
    def _compute():
        k = pool_ref[0, :, 0, 0, :].astype(jnp.float32)   # (BS, D)
        v = pool_ref[0, :, 1, 0, :].astype(jnp.float32)
        _prefill_body(q_ref, o_ref, m_sc, l_sc, acc_sc, k, v, ib=ib, bs=bs,
                      g=g, tq=tq, scale=scale, q0=q0, kv_len=kv_len)

    pl.when(ib == n_blocks - 1)(finalize)


def _paged_prefill_kernel_tiered(tab_ref, tier_ref, tseg_ref, tqpos_ref,
                                 len_ref, q_ref, dpool_ref, hpool_ref, o_ref,
                                 m_sc, l_sc, acc_sc, *, bs, g, tq, n_blocks,
                                 scale):
    """Two-pool variant: a segment whose layer was offloaded mid-prefill
    reads its blocks from the HOST pool (tier flag), everything else from
    the device pool. Both candidate blocks ride the tile (2x KV DMA)."""
    it, ib = pl.program_id(1), pl.program_id(2)
    finalize = _init_finalize(o_ref, m_sc, l_sc, acc_sc, ib=ib, g=g, tq=tq)
    seg = tseg_ref[it]
    kv_len = len_ref[seg]
    q0 = tqpos_ref[it]
    is_host = tier_ref[seg] != 0
    live = (ib * bs < kv_len) & (ib * bs <= q0 + tq - 1)

    @pl.when(live)
    def _compute():
        kd = dpool_ref[0, :, 0, 0, :].astype(jnp.float32)
        vd = dpool_ref[0, :, 1, 0, :].astype(jnp.float32)
        kh = hpool_ref[0, :, 0, 0, :].astype(jnp.float32)
        vh = hpool_ref[0, :, 1, 0, :].astype(jnp.float32)
        k = jnp.where(is_host, kh, kd)
        v = jnp.where(is_host, vh, vd)
        _prefill_body(q_ref, o_ref, m_sc, l_sc, acc_sc, k, v, ib=ib, bs=bs,
                      g=g, tq=tq, scale=scale, q0=q0, kv_len=kv_len)

    pl.when(ib == n_blocks - 1)(finalize)


@functools.partial(jax.jit, static_argnames=("tq", "softmax_scale",
                                             "interpret"))
def paged_prefill_pallas(q, kv_pool, block_table, seg_ids, q_pos, kv_len, *,
                         host_pool=None, tier=None, tq=8, softmax_scale=None,
                         interpret=None):
    """q: (T, H, D) flat segment-padded token batch, T % tq == 0, with each
    tq-row tile entirely inside one segment; kv_pool: (NB, BS, 2, KV, D);
    block_table: (S, MAXB) int32; seg_ids/q_pos: (T,) int32; kv_len: (S,)
    int32. Optional host_pool (NBH, BS, 2, KV, D) + tier (S,) selects the
    pool per segment. Returns (T, H, D).

    The caller guarantees the chunk's own KV is already scattered into the
    pool — the kernel reads prefix AND in-chunk keys through the table,
    with the causal mask (q_pos >= k_pos) handling the in-chunk tail."""
    T, H, D = q.shape
    BS, KV = kv_pool.shape[1], kv_pool.shape[3]
    MAXB = block_table.shape[1]
    G = H // KV
    NT = T // tq
    scale = softmax_scale if softmax_scale is not None else D ** -0.5
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    q4 = q.reshape(NT, tq, KV, G, D)
    tile_seg = seg_ids.reshape(NT, tq)[:, 0].astype(jnp.int32)
    tile_qpos = q_pos.reshape(NT, tq)[:, 0].astype(jnp.int32)
    grid = (KV, NT, MAXB)
    scratch = [
        pltpu.VMEM((tq * G,), jnp.float32),
        pltpu.VMEM((tq * G,), jnp.float32),
        pltpu.VMEM((tq * G, D), jnp.float32),
    ]
    q_spec = pl.BlockSpec(
        (1, tq, 1, G, D), lambda kh, it, ib, *pf: (it, 0, kh, 0, 0))
    out_spec = pl.BlockSpec(
        (1, tq, 1, G, D), lambda kh, it, ib, *pf: (it, 0, kh, 0, 0))

    if tier is None:
        kernel = functools.partial(_paged_prefill_kernel, bs=BS, g=G, tq=tq,
                                   n_blocks=MAXB, scale=scale)
        pool_spec = pl.BlockSpec(
            (1, BS, 2, 1, D),
            lambda kh, it, ib, tab, tseg, tqp, lens:
                (tab[tseg[it], ib], 0, 0, kh, 0))
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=4, grid=grid,
            in_specs=[q_spec, pool_spec], out_specs=out_spec,
            scratch_shapes=scratch)
        out = pl.pallas_call(
            kernel, grid_spec=grid_spec,
            out_shape=jax.ShapeDtypeStruct(q4.shape, q.dtype),
            interpret=interpret,
        )(block_table, tile_seg, tile_qpos, kv_len, q4, kv_pool)
    else:
        kernel = functools.partial(_paged_prefill_kernel_tiered, bs=BS, g=G,
                                   tq=tq, n_blocks=MAXB, scale=scale)
        # a host-resident segment's ids index the HOST pool (and vice
        # versa) — clamp the not-applicable fetch into range; the kernel's
        # `where` discards it
        nbd, nbh = kv_pool.shape[0], host_pool.shape[0]
        dpool_spec = pl.BlockSpec(
            (1, BS, 2, 1, D),
            lambda kh, it, ib, tab, tier_, tseg, tqp, lens:
                (jnp.minimum(tab[tseg[it], ib], nbd - 1), 0, 0, kh, 0))
        hpool_spec = pl.BlockSpec(
            (1, BS, 2, 1, D),
            lambda kh, it, ib, tab, tier_, tseg, tqp, lens:
                (jnp.minimum(tab[tseg[it], ib], nbh - 1), 0, 0, kh, 0))
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=5, grid=grid,
            in_specs=[q_spec, dpool_spec, hpool_spec], out_specs=out_spec,
            scratch_shapes=scratch)
        out = pl.pallas_call(
            kernel, grid_spec=grid_spec,
            out_shape=jax.ShapeDtypeStruct(q4.shape, q.dtype),
            interpret=interpret,
        )(block_table, tier.astype(jnp.int32), tile_seg, tile_qpos, kv_len,
          q4, kv_pool, host_pool)
    return out.reshape(T, H, D)
