"""Pallas TPU paged GQA decode-attention kernel.

One query token per sequence attends over KV stored in a *single pooled
tensor* of fixed-size blocks (paper §4: one physical tensor, logical
per-layer allocation), addressed through a block table.

TPU adaptation of the CUDA PagedAttention kernel:
  * the block table and per-sequence lengths are **scalar-prefetched**
    (pltpu.PrefetchScalarGridSpec) so the BlockSpec index_map itself chases
    the page table — the DMA engine gathers KV blocks HBM->VMEM directly,
    there is no software gather;
  * grid = (B, KV_heads, n_blocks); the KV-block axis is the innermost
    sequential dimension, with online-softmax state in VMEM scratch
    (same structure as the prefill kernel);
  * all G = H/KV query heads of a KV group ride in one tile so the MXU sees
    a (G, D) x (D, BS) matmul per page instead of G vector products.

Validated against `ref.paged_attention_reference` in interpret mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(tab_ref, len_ref, q_ref, pool_ref, o_ref, m_sc, l_sc,
                  acc_sc, *, bs, n_blocks, scale):
    b = pl.program_id(0)
    ib = pl.program_id(2)

    @pl.when(ib == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    kv_len = len_ref[b]
    block_live = ib * bs < kv_len

    @pl.when(block_live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale     # (G, D)
        k = pool_ref[0, :, 0, 0, :].astype(jnp.float32)  # (BS, D)
        v = pool_ref[0, :, 1, 0, :].astype(jnp.float32)  # (BS, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (G,BS)
        pos = ib * bs + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < kv_len, s, NEG_INF)
        m_prev, l_prev = m_sc[...], l_sc[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_sc[...] = l_prev * corr + p.sum(axis=1)
        m_sc[...] = m_new
        acc_sc[...] = acc_sc[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ib == n_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_sc[...], 1e-30)
        o_ref[0, 0] = (acc_sc[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("softmax_scale", "interpret"))
def paged_attention_pallas(q, kv_pool, block_table, kv_len, *,
                           softmax_scale=None, interpret=None):
    """q: (B, H, D); kv_pool: (NB, BS, 2, KV, D); block_table: (B, MAXB)
    int32; kv_len: (B,) int32. Returns (B, H, D)."""
    B, H, D = q.shape
    NB, BS, _, KV, _ = kv_pool.shape
    MAXB = block_table.shape[1]
    G = H // KV
    scale = softmax_scale if softmax_scale is not None else D ** -0.5
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    grid = (B, KV, MAXB)
    kernel = functools.partial(_paged_kernel, bs=BS, n_blocks=MAXB,
                               scale=scale)
    # q viewed as (B, KV, G, D) so one tile holds a KV group's query heads
    q4 = q.reshape(B, KV, G, D)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, G, D),
                         lambda b, kh, ib, tab, lens: (b, kh, 0, 0)),
            # page-table chase: physical block id comes from the prefetched
            # table; KV head rides in the block
            pl.BlockSpec((1, BS, 2, 1, D),
                         lambda b, kh, ib, tab, lens: (tab[b, ib], 0, 0, kh, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D),
                               lambda b, kh, ib, tab, lens: (b, kh, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, D), q.dtype),
        interpret=interpret,
    )(block_table, kv_len, q4, kv_pool)
    return out.reshape(B, H, D)
