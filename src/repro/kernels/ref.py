"""Pure-jnp oracles for every kernel in this package.

These are also the default lowering path at scale (the chunked flash oracle is
memory-O(S * chunk) and GSPMD-friendly), so they must be jit/scan-clean.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _gqa_logits(q, k):
    """q: (B, Sq, KV, G, D), k: (B, Skv, KV, D) -> (B, KV, G, Sq, Skv)."""
    return jnp.einsum("bqkgd,bskd->bkgqs", q, k)


def _gqa_out(p, v):
    """p: (B, KV, G, Sq, Skv), v: (B, Skv, KV, D) -> (B, Sq, KV, G, D)."""
    return jnp.einsum("bkgqs,bskd->bqkgd", p, v)


def mha_reference(q, k, v, *, causal=True, window=0, kv_len=None, q_offset=0,
                  softmax_scale=None):
    """Unchunked masked GQA attention oracle.

    q: (B, Sq, H, D); k, v: (B, Skv, KV, D); H % KV == 0.
    kv_len: (B,) valid KV prefix length (None -> all valid).
    q_offset: absolute position of q[0] (int or (B,) array) for causal masking
      when Sq < Skv (decode / chunked prefill).
    window: >0 -> sliding-window attention (each query sees the last `window`
      keys, inclusive of itself).
    """
    B, Sq, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = softmax_scale if softmax_scale is not None else D ** -0.5
    qh = (q * scale).reshape(B, Sq, KV, G, D)
    logits = _gqa_logits(qh, k).astype(jnp.float32)  # (B,KV,G,Sq,Skv)

    Skv = k.shape[1]
    q_pos = jnp.asarray(q_offset)[..., None] + jnp.arange(Sq)  # (Sq,) or (B,Sq)
    k_pos = jnp.arange(Skv)
    if q_pos.ndim == 1:
        q_pos = q_pos[None]  # (1, Sq)
    mask = jnp.ones((q_pos.shape[0], Sq, Skv), dtype=bool)
    if causal:
        mask &= q_pos[:, :, None] >= k_pos[None, None, :]
    if window:
        mask &= k_pos[None, None, :] > q_pos[:, :, None] - window
    if kv_len is not None:
        mask &= k_pos[None, None, :] < jnp.asarray(kv_len).reshape(-1, 1, 1)
    logits = jnp.where(mask[:, None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = _gqa_out(p.astype(v.dtype), v)
    return out.reshape(B, Sq, H, D)


def flash_attention_reference(q, k, v, *, causal=True, window=0, kv_len=None,
                              q_offset=0, q_chunk=512, kv_chunk=512,
                              softmax_scale=None):
    """Chunked online-softmax GQA attention (flash oracle).

    Memory O(Sq/qc * Skv_chunk); numerically matches `mha_reference`.
    Shapes as in `mha_reference`. Sq % q_chunk == 0, Skv % kv_chunk == 0
    (callers pad); chunks larger than the dims are clamped.
    """
    B, Sq, H, D = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Skv)
    # pad ragged sequence lengths up to the chunk grid; padded KV positions
    # are masked via kv_len, padded q rows are sliced off the output
    orig_Sq, orig_Skv = Sq, Skv
    pad_q = (-Sq) % q_chunk
    pad_kv = (-Skv) % kv_chunk
    if pad_kv:
        kpad = [(0, 0), (0, pad_kv), (0, 0), (0, 0)]
        k = jnp.pad(k, kpad)
        v = jnp.pad(v, kpad)
        if kv_len is None:
            kv_len = jnp.full((B,), orig_Skv, jnp.int32)
        Skv += pad_kv
    if pad_q:
        q = jnp.pad(q, [(0, 0), (0, pad_q), (0, 0), (0, 0)])
        Sq += pad_q
    nq, nk = Sq // q_chunk, Skv // kv_chunk
    scale = softmax_scale if softmax_scale is not None else D ** -0.5

    # NB: keep q/k/v in their storage dtype here — f32 casts happen
    # per-chunk inside the scan bodies, otherwise a full-tensor f32 copy of
    # the activations lives across the whole attention call (at 32k prefill
    # that is GiBs per layer)
    qh = q.reshape(B, nq, q_chunk, KV, G, D)
    kh = k.reshape(B, nk, kv_chunk, KV, D)
    vh = v.reshape(B, nk, kv_chunk, KV, D)
    q_off = jnp.asarray(q_offset).reshape(-1, 1)  # (1or B,1)
    kv_len_arr = None if kv_len is None else jnp.asarray(kv_len).reshape(-1, 1, 1)

    def q_step(_, qi):
        qc = qh[:, qi].astype(jnp.float32) * scale  # (B, qc, KV, G, D)
        q_pos = q_off + qi * q_chunk + jnp.arange(q_chunk)  # (1orB, qc)

        def kv_step(carry, ki):
            m, l, acc = carry
            kc = kh[:, ki].astype(jnp.float32)
            vc = vh[:, ki].astype(jnp.float32)
            k_pos = ki * kv_chunk + jnp.arange(kv_chunk)
            logits = _gqa_logits(qc, kc)  # (B,KV,G,qc,kc) f32
            mask = jnp.ones((q_pos.shape[0], q_chunk, kv_chunk), dtype=bool)
            if causal:
                mask &= q_pos[:, :, None] >= k_pos[None, None, :]
            if window:
                mask &= k_pos[None, None, :] > q_pos[:, :, None] - window
            if kv_len_arr is not None:
                mask &= k_pos[None, None, :] < kv_len_arr
            logits = jnp.where(mask[:, None, None], logits, NEG_INF)
            m_new = jnp.maximum(m, logits.max(axis=-1))
            p = jnp.exp(logits - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bskd->bkgqd", p, vc)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KV, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, KV, G, q_chunk, D), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30)[..., None]  # (B,KV,G,qc,D)
        # stack in storage dtype: the f32 stack would be the biggest live
        # buffer of the whole prefill
        return None, out.transpose(0, 3, 1, 2, 4).astype(q.dtype)

    _, outs = jax.lax.scan(q_step, None, jnp.arange(nq))  # (nq,B,qc,KV,G,D)
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, H, D)
    if pad_q:
        out = out[:, :orig_Sq]
    return out.astype(q.dtype)


def decode_attention_reference(q, k_cache, v_cache, kv_len, *, window=0,
                               softmax_scale=None):
    """Single-token GQA decode attention over a dense cache.

    q: (B, 1, H, D); k_cache/v_cache: (B, S, KV, D); kv_len: (B,) number of
    valid entries. window: ring-buffer semantics are the caller's concern —
    here it only limits the attended span to the last `window` positions.
    """
    return mha_reference(
        q, k_cache, v_cache, causal=False, window=0,
        kv_len=kv_len, softmax_scale=softmax_scale,
    ) if window == 0 else mha_reference(
        # with a ring buffer every cached slot is within the window already
        q, k_cache, v_cache, causal=False, window=0, kv_len=kv_len,
        softmax_scale=softmax_scale,
    )


def paged_prefill_reference(q, kv_pool, block_table, seg_ids, q_pos, kv_len,
                            *, host_pool=None, tier=None, tq=8,
                            softmax_scale=None):
    """Segmented GQA prefill attention straight over a paged KV pool
    (oracle for `paged_prefill.paged_prefill_pallas`).

    The token batch is a flat concatenation of per-request *segments*: a
    prefill chunk contributes its chunk tokens (a decode token is the
    degenerate one-token segment), each padded to a multiple of the query
    tile `tq` so a tile never straddles two segments — the same layout
    contract as the Pallas kernel. Every query attends causally against
    its segment's KV **in the pool** (the chunk's own KV must already be
    scattered in) — no dense prefix gather, no staging buffer. KV is
    gathered per query TILE (T/tq rows), not per token, so the oracle's
    memory traffic is O(T/tq * MAXB*BS), mirroring the kernel's per-tile
    block chase.

    q:           (T, H, D)   flat token batch, T % tq == 0 (padding rows
                 allowed; their outputs are garbage the caller discards)
    kv_pool:     (NB, BS, 2, KV, D) device pool; [..., 0/1, :, :] = K/V
    block_table: (S, MAXB) int32 physical block ids per segment
    seg_ids:     (T,) int32 segment of each token
    q_pos:       (T,) int32 absolute position of each token in its sequence
    kv_len:      (S,) int32 valid tokens per segment (prefix + chunk)
    host_pool/tier: when `tier` (S,) bool marks a segment's blocks as
                 host-resident, its KV is gathered from `host_pool` instead
                 (layer-wise offload mid-prefill). Both pools are gathered
                 and selected — fine for the oracle, 2x traffic.
    returns      (T, H, D)
    """
    T, H, D = q.shape
    S, MAXB = block_table.shape
    BS, KV = kv_pool.shape[1], kv_pool.shape[3]
    G = H // KV
    NT = T // tq
    scale = softmax_scale if softmax_scale is not None else D ** -0.5
    tile_seg = seg_ids.reshape(NT, tq)[:, 0]
    tab_t = block_table[tile_seg]            # (NT, MAXB)
    # a host-resident segment's ids index the HOST pool and vice versa —
    # clamp the not-applicable gather into range, `where` discards it
    g = kv_pool[jnp.minimum(tab_t, kv_pool.shape[0] - 1)]
    if tier is not None:                     # (NT, MAXB, BS, 2, KV, D)
        gh = host_pool[jnp.minimum(tab_t, host_pool.shape[0] - 1)]
        tt = tier[tile_seg]
        g = jnp.where(tt[:, None, None, None, None, None], gh, g)
    k = g[:, :, :, 0].reshape(NT, MAXB * BS, KV, D)
    v = g[:, :, :, 1].reshape(NT, MAXB * BS, KV, D)
    qh = (q * scale).reshape(NT, tq, KV, G, D)
    logits = jnp.einsum("ntkgd,nskd->nkgts", qh, k).astype(jnp.float32)
    k_pos = jnp.arange(MAXB * BS)
    qp = q_pos.reshape(NT, tq)
    mask = (qp[:, :, None] >= k_pos[None, None]) \
        & (k_pos[None, None] < kv_len[tile_seg][:, None, None])
    logits = jnp.where(mask[:, None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)      # (NT, KV, G, tq, Skv)
    out = jnp.einsum("nkgts,nskd->ntkgd", p.astype(v.dtype), v)
    return out.reshape(T, H, D)


def paged_attention_reference(q, kv_pool, block_table, kv_len, *,
                              softmax_scale=None):
    """Decode GQA attention over a paged KV pool (oracle for the Pallas kernel).

    q:           (B, H, D)       one query token per sequence
    kv_pool:     (N_blocks, BS, 2, KV, D)  single pooled tensor (paper §4),
                 [..., 0, :, :] = K, [..., 1, :, :] = V
    block_table: (B, MAX_BLOCKS) int32 physical block ids (padding: any id —
                 masked out by kv_len)
    kv_len:      (B,) valid token count per sequence
    returns      (B, H, D)
    """
    B, H, D = q.shape
    NB, BS = kv_pool.shape[0], kv_pool.shape[1]
    KV = kv_pool.shape[3]
    MAX_BLOCKS = block_table.shape[1]
    # Gather per-sequence K/V: (B, MAX_BLOCKS, BS, 2, KV, D)
    gathered = kv_pool[block_table]
    k = gathered[:, :, :, 0].reshape(B, MAX_BLOCKS * BS, KV, D)
    v = gathered[:, :, :, 1].reshape(B, MAX_BLOCKS * BS, KV, D)
    out = mha_reference(q[:, None], k, v, causal=False, kv_len=kv_len,
                        softmax_scale=softmax_scale)
    return out[:, 0]
