"""Unified counter/gauge registry — the always-on half of the
observability layer.

This module is deliberately tiny and dependency-free (pure dict
operations, no tracing imports): `SchedulerCore`, `PagedExecutor` and
`ClusterSession` create one eagerly and route their previously-scattered
counters (`jit_retraces`, preemption/resume counts, shed/retry/
re-dispatch/kill tallies) through it, so one `snapshot()` returns
everything and the Prometheus exporter has a single source of truth.
The event-tracing half (`repro.obs.trace`) is imported ONLY when
`ServeConfig.trace` is on — keeping it out of this module is what makes
trace-off runs zero-overhead (tests/test_obs.py asserts the module is
never even imported).

Label values render Prometheus-style: ``name{label="value"}``.
"""
from __future__ import annotations

import collections
from typing import Dict, Tuple

LabelKey = Tuple[Tuple[str, str], ...]


def _labels_key(labels: Dict[str, str]) -> LabelKey:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def render_key(name: str, labels: LabelKey) -> str:
    """``name{a="x",b="y"}`` (bare ``name`` when unlabelled)."""
    if not labels:
        return name
    inner = ",".join(f'{k}="{v}"' for k, v in labels)
    return f"{name}{{{inner}}}"


class MetricsRegistry:
    """Labelled counters and gauges behind one namespace.

    Counters (`inc`) are monotone; gauges (`set_gauge`) are
    last-write-wins. Both share the storage — the distinction only
    matters to the writer. Reads never create entries, so probing a
    counter that never fired costs nothing and returns 0.
    """

    def __init__(self) -> None:
        self._data: Dict[str, Dict[LabelKey, float]] = {}

    # ------------------------------------------------------------ writes
    def inc(self, name: str, amount: float = 1.0, **labels: str) -> None:
        series = self._data.setdefault(name, {})
        key = _labels_key(labels)
        series[key] = series.get(key, 0.0) + amount

    def set_gauge(self, name: str, value: float, **labels: str) -> None:
        self._data.setdefault(name, {})[_labels_key(labels)] = value

    # ------------------------------------------------------------- reads
    def get(self, name: str, **labels: str) -> float:
        """Value of one (name, labels) series; 0.0 when it never fired."""
        return self._data.get(name, {}).get(_labels_key(labels), 0.0)

    def total(self, name: str) -> float:
        """Sum over every label combination of `name`."""
        return sum(self._data.get(name, {}).values())

    def counter_view(self, name: str, label: str) -> collections.Counter:
        """The series of `name` sliced by one label, as a Counter —
        back-compat shape for code that used a bare
        ``collections.Counter`` (e.g. ``PagedExecutor.jit_retraces``)."""
        out: collections.Counter = collections.Counter()
        for key, v in self._data.get(name, {}).items():
            for k, val in key:
                if k == label:
                    out[val] += int(v)
        return out

    def snapshot(self, **extra_labels: str) -> Dict[str, float]:
        """Flat ``rendered_key -> value`` dict of every series.
        `extra_labels` are folded into every key (a cluster stamps
        ``replica="i"`` when merging per-replica registries)."""
        out: Dict[str, float] = {}
        for name, series in sorted(self._data.items()):
            for key, v in sorted(series.items()):
                merged = dict(key)
                merged.update({k: str(v2) for k, v2
                               in extra_labels.items()})
                out[render_key(name, _labels_key(merged))] = v
        return out

    @staticmethod
    def merge_snapshots(*snaps: Dict[str, float]) -> Dict[str, float]:
        """Combine rendered snapshots; identical keys sum (counters from
        different replicas pool, which is the cluster semantics)."""
        out: Dict[str, float] = {}
        for snap in snaps:
            for k, v in snap.items():
                out[k] = out.get(k, 0.0) + v
        return out
