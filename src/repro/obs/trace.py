"""Event tracer: per-request lifecycle spans, scheduler decision
records, and exact TTFT attribution.

Imported ONLY when `ServeConfig.trace` is on (the guarded
`SchedulerCore.__init__` install mirrors the sanitizer); with tracing
off this module never enters `sys.modules` and the hot paths carry a
single ``tracer is None`` test — the overhead guard in
tests/test_obs.py pins both.

Event vocabulary (`EVENT_TYPES` below — docs/ARCHITECTURE.md must list
every member, enforced by tools/check_docs.py):

  spans     queued, prefill, prefill_chunk, decode, paused
  request   first_token, preempt, resume, finish, cancel, shed
  scheduler sched_pass  (one per admission pass: who got in, who was
            blocked on which gate, pool occupancy per layer/tier,
            transfer-ledger activity)
  cluster   fault, kill, revive, drain, retry, redispatch

TTFT attribution (the paper's Figure-2 decomposition, made exact): each
request carries a running partition of [arrival, first_token_time] into
cause-labelled intervals. The protocol is *forward-pending*: every
interval is attributed to the cause diagnosed at its START (the gate
observed at an admission pass explains the wait until the next pass;
"arrival_sync" covers the stretch before the scheduler first examined
the request). Every advance telescopes `last_t`, so

    sum(ttft_breakdown(rid).values()) == first_token_time - arrival

holds EXACTLY by construction — tests/test_obs.py asserts it on both
backends across the scheduling axes. A vLLM recompute-preemption resets
`first_token_time`; the tracer reopens the partition with the thrown-away
decode time attributed to "recompute_lost" so the invariant holds for
the NEW first token too. Causes (docs/ARCHITECTURE.md "Observability"):

  arrival_sync         waiting before/between scheduler examinations
  gate:max_batch_size  admission pass stopped on the batch-slot cap
  gate:alg1_budget     stopped on the Alg.1 SLO admission budget
  gate:token_budget    stopped on the Eq.1 per-pass token budget
  gate:device_blocks   stopped on the device KV-block gate
  gate:host_reserve    stopped on host-pool reservation / allocation
  preempted            paused by the lossless preemption controller
  prefill              prefill compute (incl. the offload overlap)
  prefill_stall        in the chunk queue but given no chunk this
                       iteration (budget went to decode / other chunks)
  recompute_lost       decode progress discarded by a recompute
                       preemption (vllm policy)
  recompute_requeue    re-queued after a recompute preemption, not yet
                       re-examined

Timestamps are the backend's virtual clock (seconds); the engine
additionally stamps wall-clock seconds on every event (`wall_clock`
hook) so real-execution traces carry both timelines.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional

from repro.core import DEVICE, HOST

EVENT_TYPES = (
    # spans (t0/t1)
    "queued", "prefill", "prefill_chunk", "decode", "paused",
    # request instants
    "first_token", "preempt", "resume", "finish", "cancel", "shed",
    # scheduler decision record
    "sched_pass",
    # cluster instants
    "fault", "kill", "revive", "drain", "retry", "redispatch",
)

ATTRIBUTION_CAUSES = (
    "arrival_sync", "gate:max_batch_size", "gate:alg1_budget",
    "gate:token_budget", "gate:device_blocks", "gate:host_reserve",
    "preempted", "prefill", "prefill_stall", "recompute_lost",
    "recompute_requeue",
)


class _Attr:
    """Per-request attribution state: a telescoping partition of
    [queue start, now] into cause-labelled intervals."""

    __slots__ = ("last_t", "pending", "queue_t0", "intervals", "final")

    def __init__(self, t0: float) -> None:
        self.last_t = t0
        self.pending = "arrival_sync"
        self.queue_t0 = t0            # start of the current queued span
        self.intervals: Dict[str, float] = {}
        self.final = False


class Tracer:
    """One tracer per `SchedulerCore` (the cluster adds its own for
    fleet-level instants). Every emission site in src/repro is guarded
    by a ``tracer is not None`` test (repro-lint rule OBS001)."""

    def __init__(self) -> None:
        self.events: List[dict] = []
        self._attr: Dict[str, _Attr] = {}
        self._pause_t: Dict[str, float] = {}
        # engine hook: () -> wall seconds, stamped as ev["wall"]
        self.wall_clock: Optional[Callable[[], float]] = None

    # ------------------------------------------------------ raw emission
    def _emit(self, ev: dict) -> None:
        assert ev["type"] in EVENT_TYPES, ev["type"]
        if self.wall_clock is not None:
            ev["wall"] = self.wall_clock()
        self.events.append(ev)

    def span(self, etype: str, rid: Optional[str], t0: float, t1: float,
             **args: object) -> None:
        self._emit({"type": etype, "rid": rid, "t0": t0, "t1": t1,
                    "args": args})

    def instant(self, etype: str, t: float, rid: Optional[str] = None,
                **args: object) -> None:
        self._emit({"type": etype, "rid": rid, "t": t, "args": args})

    # ------------------------------------------------------- attribution
    def _ensure(self, r) -> _Attr:
        a = self._attr.get(r.rid)
        if a is None:
            a = self._attr[r.rid] = _Attr(r.arrival)
        return a

    @staticmethod
    def _advance(a: _Attr, t: float, cause: str) -> None:
        dt = t - a.last_t
        if dt > 0.0:
            a.intervals[cause] = a.intervals.get(cause, 0.0) + dt
            a.last_t = t

    def ttft_breakdown(self, rid: str) -> Dict[str, float]:
        """cause -> seconds partition of this request's TTFT (complete
        once its first token is out; empty for an unknown rid)."""
        a = self._attr.get(rid)
        return dict(a.intervals) if a is not None else {}

    def breakdowns(self) -> Dict[str, Dict[str, float]]:
        """Finalized TTFT partitions for every first-tokened request."""
        return {rid: dict(a.intervals) for rid, a in self._attr.items()
                if a.final}

    # -------------------------------------------------- lifecycle hooks
    def sched_pass(self, core, now: float, admitted: List,
                   stop_gate: Optional[str],
                   immediate_mode: bool = False) -> None:
        """One admission pass: close the queue-wait intervals of admitted
        requests, stamp the blocking gate onto every request still
        waiting, and emit the decision record (who/why + pool occupancy
        per layer/tier + ledger activity)."""
        for r in admitted:
            a = self._ensure(r)
            if r.first_token_time < 0.0 or (immediate_mode
                                            and not a.final):
                t0 = r.prefill_start if r.prefill_start >= 0.0 else now
                self._advance(a, t0, a.pending)
                a.pending = "prefill"
                self.span("queued", r.rid, a.queue_t0, t0)
                if immediate_mode and r.first_token_time >= t0:
                    # exclusive engine: the whole prefill already ran
                    # inside this pass — close the prefill span + first
                    # token too. (A redispatched request keeps its dead
                    # incarnation's EARLIER stamp and stays open: no new
                    # first token is coming, so no finalization.)
                    self.first_token(r, r.first_token_time)
        gate = stop_gate or "arrival_sync"
        blocked: Dict[str, str] = {}
        for r in core.waiting:
            a = self._ensure(r)
            if r.first_token_time < 0.0 and not a.final:
                self._advance(a, now, a.pending)
                a.pending = gate
            blocked[r.rid] = gate
        ldev = [0] * core.L
        lhost = [0] * core.L
        for layers in core.bm.tables.values():
            for layer, alloc in layers.items():
                tgt = ldev if alloc.pool == DEVICE else lhost
                tgt[layer] += len(alloc.blocks)
        self.instant(
            "sched_pass", now,
            admitted=[r.rid for r in admitted], blocked=blocked,
            stop_gate=stop_gate, in_flight=core.in_flight(),
            paused=len(core.paused),
            pool={
                DEVICE: {"total": core.bm.pools[DEVICE].num_blocks,
                         "free": core.bm.num_free(DEVICE)},
                HOST: {"total": core.bm.pools[HOST].num_blocks,
                       "free": core.bm.num_free(HOST)},
            },
            layer_device_blocks=ldev, layer_host_blocks=lhost,
            ledger={"busy_until": core.off.ledger.busy_until,
                    "n_transfers": len(core.off.ledger.log)})

    def chunk_iteration(self, core, t0: float, t1: float,
                        chunk_work: List,
                        done: Optional[Dict[str, int]] = None) -> None:
        """One chunked iteration [t0, t1]: a prefill_chunk span per
        chunk, `prefill` attribution for requests that ran a chunk,
        `prefill_stall` for prefilling requests that got none. `done`
        maps rid -> prompt tokens completed AFTER this chunk — pass it
        when the caller already folded the chunk into `prefill_done`
        (the engine); the simulator calls pre-bookkeeping and omits it."""
        ran = set()
        for r, c in chunk_work:
            ran.add(r.rid)
            d = done[r.rid] if done is not None else r.prefill_done + c
            self.span("prefill_chunk", r.rid, t0, t1, tokens=c, done=d)
            a = self._attr.get(r.rid)
            if a is not None and r.first_token_time < 0.0:
                self._advance(a, t1, "prefill")
        for r in core.prefilling:
            if r.rid in ran:
                continue
            a = self._attr.get(r.rid)
            if a is not None and r.first_token_time < 0.0:
                self._advance(a, t1, "prefill_stall")

    def first_token(self, r, t: float) -> None:
        """First token at `t`: close the partition (exactness: `last_t`
        telescoped from arrival, so the intervals sum to t - arrival)."""
        a = self._ensure(r)
        self._advance(a, t, "prefill")
        if r.prefill_start >= 0.0:
            self.span("prefill", r.rid, r.prefill_start, t,
                      chunks=r.n_chunks, cached=r.cached_prompt_len)
        self.instant("first_token", t, rid=r.rid,
                     ttft=t - r.arrival)
        a.final = True
        # if a recompute preemption later discards this request's decode
        # progress, the reopened partition charges that stretch here
        a.pending = "recompute_lost"

    def preempt(self, r, t: float, mode: str) -> None:
        """`mode` is "pause" (lossless, KV parked on HOST) or
        "recompute" (vllm: KV dropped, request re-queued)."""
        self.instant("preempt", t, rid=r.rid, mode=mode,
                     n=r.n_preempted)
        a = self._attr.get(r.rid)
        if a is None:
            return
        if mode == "pause":
            self._pause_t[r.rid] = t
            if r.first_token_time < 0.0 and not a.final:
                self._advance(a, t, a.pending)
                a.pending = "preempted"
        else:
            # first_token_time was just reset: reopen the partition so
            # it stays exact for the NEW first token
            self._advance(a, t, a.pending)
            a.pending = "recompute_requeue"
            a.queue_t0 = t
            a.final = False

    def resume(self, r, t: float) -> None:
        self.instant("resume", t, rid=r.rid)
        t0 = self._pause_t.pop(r.rid, None)
        if t0 is not None:
            self.span("paused", r.rid, t0, t)
        a = self._attr.get(r.rid)
        if a is not None and r.first_token_time < 0.0 and not a.final:
            self._advance(a, t, a.pending)
            a.pending = "prefill"

    def finish(self, r, t: float) -> None:
        if r.first_token_time >= 0.0:
            self.span("decode", r.rid, r.first_token_time, t,
                      tokens=r.tokens_out)
        self.instant("finish", t, rid=r.rid, tokens=r.tokens_out)

    def cancel(self, r, t: float) -> None:
        self.instant("cancel", t, rid=r.rid)

    def shed(self, r, t: float, reason: str) -> None:
        self.instant("shed", t, rid=r.rid, reason=reason)
