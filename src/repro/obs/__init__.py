"""Observability layer: counter/gauge registry (always on, pure dict
ops), event tracer with exact TTFT attribution, and Perfetto/Prometheus
exporters.

The registry is imported eagerly (schedulers route their counters
through it); the tracer and exporters are PEP 562 lazy re-exports so a
`trace=False` run never imports them — the zero-overhead-when-off
contract tests/test_obs.py pins by asserting ``repro.obs.trace`` stays
out of ``sys.modules``.
"""
from __future__ import annotations

import importlib

from repro.obs.registry import MetricsRegistry

__all__ = ["MetricsRegistry", "Tracer", "EVENT_TYPES",
           "ATTRIBUTION_CAUSES", "perfetto_trace", "prometheus_text",
           "write_trace"]

_LAZY = {
    "Tracer": "repro.obs.trace",
    "EVENT_TYPES": "repro.obs.trace",
    "ATTRIBUTION_CAUSES": "repro.obs.trace",
    "perfetto_trace": "repro.obs.export",
    "prometheus_text": "repro.obs.export",
    "write_trace": "repro.obs.export",
}


def __getattr__(name: str):
    mod = _LAZY.get(name)
    if mod is None:
        raise AttributeError(f"module 'repro.obs' has no attribute {name!r}")
    return getattr(importlib.import_module(mod), name)
