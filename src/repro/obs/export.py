"""Trace/metric exporters: Chrome-trace (Perfetto-loadable) JSON and a
Prometheus-style text snapshot.

Chrome trace mapping (load the file at https://ui.perfetto.dev or
chrome://tracing):

  * one PROCESS (pid) per tracer — replica 0..N-1, plus the cluster
    stream when a `ClusterSession` traces fleet events. Streams from
    different replicas merge naturally because every timestamp is the
    SHARED virtual clock (microseconds in the file);
  * within a process, tid 0 is the scheduler track (sched_pass decision
    records and fleet instants) and each request gets its own tid in
    first-seen order — its queued/prefill/decode/paused spans nest on
    one line;
  * spans export as complete events (ph "X", ts+dur), everything else
    as instants (ph "i"); process/thread names ride metadata (ph "M").

Events are sorted by (ts, -dur) so enclosing spans precede their
children and per-track timestamps are monotone (tests/test_obs.py
validates both on the exported file).
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional, Sequence

_US = 1e6  # seconds (virtual clock) -> Chrome trace microseconds


def _track_events(tracer, pid: int, label: str) -> List[dict]:
    out: List[dict] = [
        {"ph": "M", "pid": pid, "tid": 0, "name": "process_name",
         "args": {"name": label}},
        {"ph": "M", "pid": pid, "tid": 0, "name": "thread_name",
         "args": {"name": "scheduler"}},
    ]
    tids: Dict[str, int] = {}

    def tid_of(rid: Optional[str]) -> int:
        if rid is None:
            return 0
        if rid not in tids:
            tids[rid] = len(tids) + 1
            out.append({"ph": "M", "pid": pid, "tid": tids[rid],
                        "name": "thread_name", "args": {"name": rid}})
        return tids[rid]

    for ev in tracer.events:
        args = dict(ev.get("args") or {})
        if "wall" in ev:
            args["wall_s"] = ev["wall"]
        row: dict = {"name": ev["type"], "cat": "serving",
                     "pid": pid, "tid": tid_of(ev.get("rid")),
                     "args": args}
        if "t0" in ev:
            row["ph"] = "X"
            row["ts"] = ev["t0"] * _US
            row["dur"] = max(ev["t1"] - ev["t0"], 0.0) * _US
        else:
            row["ph"] = "i"
            row["ts"] = ev["t"] * _US
            row["s"] = "t" if ev.get("rid") is not None else "p"
        out.append(row)
    return out


def perfetto_trace(tracers: Sequence, labels: Optional[Sequence[str]]
                   = None) -> dict:
    """Merge one or more tracers into a Chrome-trace JSON object.
    `labels` names each process (default ``replica i``)."""
    events: List[dict] = []
    for i, tracer in enumerate(tracers):
        if tracer is None:
            continue
        label = labels[i] if labels is not None else f"replica {i}"
        events.extend(_track_events(tracer, pid=i, label=label))
    meta = [e for e in events if e["ph"] == "M"]
    rest = sorted((e for e in events if e["ph"] != "M"),
                  key=lambda e: (e["ts"], -e.get("dur", 0.0)))
    return {"traceEvents": meta + rest, "displayTimeUnit": "ms"}


def write_trace(tracers: Sequence, path: str,
                labels: Optional[Sequence[str]] = None) -> None:
    with open(path, "w") as f:
        json.dump(perfetto_trace(tracers, labels), f)


def prometheus_text(snapshot: Dict[str, float]) -> str:
    """Prometheus exposition format over a rendered registry snapshot
    (`MetricsRegistry.snapshot()` keys are already
    ``name{label="v"}``-shaped)."""
    lines = [f"{key} {value:g}" for key, value in sorted(snapshot.items())]
    return "\n".join(lines) + ("\n" if lines else "")
