"""xlstm-1.3b [ssm]: alternating mLSTM / sLSTM blocks, attention-free.

[arXiv:2405.04517] 48L d_model=2048 4H (kv=4) d_ff=0 vocab=50304.
LayerKV is inapplicable (no attention KV); see DESIGN.md §Arch-applicability.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    pos_emb="none",
    ssm=SSMConfig(state_dim=512, conv_dim=4, n_groups=1, expand=2),
    xlstm_slstm_every=8,  # 6 superblocks of (7 mLSTM + 1 sLSTM) ~ xLSTM[7:1]
    max_seq_len=524288,
    source="arXiv:2405.04517 (xLSTM)",
)

SMOKE = ModelConfig(
    arch_id="xlstm-1.3b-smoke",
    family="ssm",
    n_layers=2,
    d_model=128,
    n_heads=2,
    n_kv_heads=2,
    d_ff=0,
    vocab_size=512,
    pos_emb="none",
    ssm=SSMConfig(state_dim=64, conv_dim=4, n_groups=1, expand=2),
    xlstm_slstm_every=2,
    max_seq_len=256,
    source="reduced xlstm",
)
