"""qwen2-vl-7b [vlm]: M-RoPE (3-axis), dynamic-resolution ViT frontend stubbed.

[arXiv:2409.12191] 28L d_model=3584 28H (kv=4) d_ff=18944 vocab=152064.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen2-vl-7b",
    family="vlm",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18944,
    vocab_size=152064,
    pos_emb="mrope",
    rope_theta=1000000.0,
    qkv_bias=True,
    vision_stub=True,
    sliding_window=8192,
    max_seq_len=524288,
    source="arXiv:2409.12191 (Qwen2-VL)",
)

SMOKE = ModelConfig(
    arch_id="qwen2-vl-7b-smoke",
    family="vlm",
    n_layers=2,
    d_model=256,
    n_heads=4,
    n_kv_heads=2,
    d_ff=512,
    vocab_size=512,
    pos_emb="mrope",
    qkv_bias=True,
    vision_stub=True,
    max_seq_len=256,
    source="reduced qwen2-vl",
)
