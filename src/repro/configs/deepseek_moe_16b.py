"""deepseek-moe-16b [moe]: fine-grained MoE, 2 shared + 64 routed top-6.

[arXiv:2401.06066] 28L d_model=2048 16H (kv=16) d_ff=1408 vocab=102400.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=102400,
    pos_emb="rope",
    moe=MoEConfig(n_experts=64, top_k=6, n_shared=2, d_expert=1408),
    sliding_window=8192,
    max_seq_len=524288,
    source="arXiv:2401.06066 (DeepSeekMoE)",
)

SMOKE = ModelConfig(
    arch_id="deepseek-moe-16b-smoke",
    family="moe",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    d_ff=64,
    vocab_size=512,
    pos_emb="rope",
    moe=MoEConfig(n_experts=4, top_k=2, n_shared=1, d_expert=64),
    max_seq_len=256,
    source="reduced deepseek-moe",
)
