"""zamba2-2.7b [hybrid]: Mamba2 backbone + shared attention block every 6 layers.

[arXiv:2411.15242] 54L d_model=2560 32H (kv=32) d_ff=10240 vocab=32000 ssm_state=64.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    pos_emb="rope",
    ssm=SSMConfig(state_dim=64, conv_dim=4, n_groups=1, expand=2),
    hybrid_attn_every=6,
    sliding_window=8192,
    max_seq_len=524288,
    source="arXiv:2411.15242 (Zamba2)",
)

SMOKE = ModelConfig(
    arch_id="zamba2-2.7b-smoke",
    family="hybrid",
    n_layers=4,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    d_ff=256,
    vocab_size=512,
    pos_emb="rope",
    ssm=SSMConfig(state_dim=16, conv_dim=4, n_groups=1, expand=2),
    hybrid_attn_every=2,
    max_seq_len=256,
    source="reduced zamba2",
)
