"""Model / input-shape configuration dataclasses.

Every assigned architecture gets one module in this package exporting CONFIG
(the exact published config) and SMOKE (a reduced variant of the same family:
<=2 layers, d_model<=512, <=4 experts) for CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
import importlib


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0          # routed experts
    top_k: int = 0
    n_shared: int = 0           # always-on shared experts
    d_expert: int = 0           # per-expert FFN hidden size


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 64         # per-head SSM state (Mamba2 "N")
    conv_dim: int = 4           # depthwise conv width
    n_groups: int = 1
    expand: int = 2             # d_inner = expand * d_model


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // n_heads
    # positional encoding: 'rope' | 'rope2d' (chatglm half-rotary) | 'mrope'
    # (qwen2-vl 3-axis) | 'learned' (whisper) | 'none' (xlstm)
    pos_emb: str = "rope"
    rope_theta: float = 10000.0
    qkv_bias: bool = False
    tie_embeddings: bool = False
    norm: str = "rmsnorm"       # rmsnorm | layernorm
    act: str = "silu"           # silu (SwiGLU) | gelu
    moe: MoEConfig = MoEConfig()
    ssm: SSMConfig = SSMConfig()
    # hybrid (zamba2): attention block shared across depth, applied every k layers
    hybrid_attn_every: int = 0
    # xlstm: pattern of block kinds per scan step
    xlstm_slstm_every: int = 0  # every k-th block is sLSTM, rest mLSTM
    # enc-dec (whisper)
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_len: int = 1500     # fixed stub-frontend sequence length
    # vlm stub frontend
    vision_stub: bool = False
    audio_stub: bool = False
    # distribution: pad query heads up to this count (0 = no padding).
    # Set by the launcher when n_heads does not divide the TP degree
    # (llama4's 40H / qwen2-vl's 28H over 16-way TP); pad heads' wo rows
    # are zero in a real deployment so outputs are unchanged.
    head_pad_to: int = 0
    # int8 KV cache (per-token-head symmetric scales) — the paper's named
    # future-work direction; beyond-paper optimization in §Perf
    kv_quant: bool = False
    # serving / long-context
    sliding_window: int = 0     # 0 = full attention; >0 enables SW variant
    max_seq_len: int = 32768
    dtype: str = "bfloat16"
    source: str = ""            # citation

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def n_q_heads(self) -> int:
        """Query heads incl. TP padding (see head_pad_to)."""
        return max(self.head_pad_to, self.n_heads)

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 256 so logits/embeddings shard
        evenly on the model axis (pad logits are masked in the loss)."""
        return -(-self.vocab_size // 256) * 256

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm" and self.hybrid_attn_every == 0

    def n_attention_layers(self) -> int:
        """Number of layers that hold sequence-proportional KV cache."""
        if self.family == "ssm":
            return 0
        if self.family == "hybrid" and self.hybrid_attn_every:
            return self.n_layers // self.hybrid_attn_every
        return self.n_layers

    def kv_bytes_per_token(self, f_precision: int = 2) -> int:
        """Per-token KV footprint across all attention layers (paper Eq. 4 term)."""
        hd = self.resolved_head_dim
        return 2 * self.n_attention_layers() * self.n_kv_heads * hd * f_precision

    def param_count(self) -> int:
        """Approximate total parameter count (embeddings included)."""
        d, hd = self.d_model, self.resolved_head_dim
        attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) \
            + (self.n_heads * hd) * d
        if self.family == "moe" and self.moe.n_experts:
            routed = 3 * d * self.moe.d_expert * self.moe.n_experts
            shared = 3 * d * self.moe.d_expert * self.moe.n_shared
            ffn = routed + shared + d * self.moe.n_experts  # router
        elif self.family == "ssm":
            d_in = self.ssm.expand * d
            ffn = 0
            attn = 2 * d * d_in + d_in * d  # rough ssm block proj count
        else:
            mult = 3 if self.act == "silu" else 2
            ffn = mult * d * self.d_ff
        per_layer = attn + ffn + 2 * d
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        total = per_layer * self.n_layers + emb
        if self.is_encoder_decoder:
            total += per_layer * self.n_encoder_layers
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: shared + top_k experts only)."""
        if self.family != "moe" or not self.moe.n_experts:
            return self.param_count()
        d = self.d_model
        hd = self.resolved_head_dim
        attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) \
            + (self.n_heads * hd) * d
        ffn = 3 * d * self.moe.d_expert * (self.moe.n_shared + self.moe.top_k) \
            + d * self.moe.n_experts
        per_layer = attn + ffn + 2 * d
        return per_layer * self.n_layers + self.vocab_size * d * 2


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}

ARCH_IDS = [
    "whisper-base",
    "chatglm3-6b",
    "qwen2.5-3b",
    "qwen2-vl-7b",
    "deepseek-moe-16b",
    "codeqwen1.5-7b",
    "llama4-scout-17b-a16e",
    "zamba2-2.7b",
    "granite-3-2b",
    "xlstm-1.3b",
]


def _module_for(arch_id: str):
    mod = arch_id.replace("-", "_").replace(".", "_")
    return importlib.import_module(f"repro.configs.{mod}")


def get_config(arch_id: str) -> ModelConfig:
    return _module_for(arch_id).CONFIG


def get_smoke_config(arch_id: str) -> ModelConfig:
    return _module_for(arch_id).SMOKE


def all_configs() -> dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
