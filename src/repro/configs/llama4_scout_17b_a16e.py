"""llama4-scout-17b-a16e [moe]: 16 routed experts top-1 + shared expert, early fusion.

[hf:meta-llama/Llama-4-Scout-17B-16E] 48L d_model=5120 40H (kv=8) d_ff=8192
vocab=202048.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    pos_emb="rope",
    rope_theta=500000.0,
    moe=MoEConfig(n_experts=16, top_k=1, n_shared=1, d_expert=8192),
    sliding_window=8192,
    max_seq_len=524288,
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)

SMOKE = ModelConfig(
    arch_id="llama4-scout-smoke",
    family="moe",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    pos_emb="rope",
    moe=MoEConfig(n_experts=4, top_k=1, n_shared=1, d_expert=128),
    max_seq_len=256,
    source="reduced llama4-scout",
)
