"""codeqwen1.5-7b [dense]: qwen1.5 arch, MHA (kv=32), QKV bias.

[hf:Qwen/CodeQwen1.5-7B] 32L d_model=4096 32H (kv=32) d_ff=13440 vocab=92416.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="codeqwen1.5-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=13440,
    vocab_size=92416,
    pos_emb="rope",
    rope_theta=1000000.0,
    qkv_bias=True,
    sliding_window=8192,
    max_seq_len=524288,
    source="hf:Qwen/CodeQwen1.5-7B",
)

SMOKE = ModelConfig(
    arch_id="codeqwen1.5-7b-smoke",
    family="dense",
    n_layers=2,
    d_model=256,
    n_heads=4,
    n_kv_heads=4,
    d_ff=512,
    vocab_size=512,
    pos_emb="rope",
    qkv_bias=True,
    max_seq_len=256,
    source="reduced codeqwen1.5",
)
