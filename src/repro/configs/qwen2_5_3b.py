"""qwen2.5-3b [dense]: GQA kv=2, QKV bias.

[hf:Qwen/Qwen2.5-0.5B family] 36L d_model=2048 16H (kv=2) d_ff=11008 vocab=151936.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen2.5-3b",
    family="dense",
    n_layers=36,
    d_model=2048,
    n_heads=16,
    n_kv_heads=2,
    d_ff=11008,
    vocab_size=151936,
    pos_emb="rope",
    rope_theta=1000000.0,
    qkv_bias=True,
    sliding_window=8192,
    max_seq_len=524288,
    source="hf:Qwen/Qwen2.5-0.5B (scaled per assignment)",
)

SMOKE = ModelConfig(
    arch_id="qwen2.5-3b-smoke",
    family="dense",
    n_layers=2,
    d_model=256,
    n_heads=4,
    n_kv_heads=2,
    d_ff=512,
    vocab_size=512,
    pos_emb="rope",
    qkv_bias=True,
    max_seq_len=256,
    source="reduced qwen2.5",
)
