from repro.configs.base import (
    ARCH_IDS,
    SHAPES,
    InputShape,
    ModelConfig,
    MoEConfig,
    SSMConfig,
    all_configs,
    get_config,
    get_smoke_config,
)

__all__ = [
    "ARCH_IDS",
    "SHAPES",
    "InputShape",
    "ModelConfig",
    "MoEConfig",
    "SSMConfig",
    "all_configs",
    "get_config",
    "get_smoke_config",
]
