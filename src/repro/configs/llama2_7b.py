"""llama2-7b: the paper's own primary evaluation model (Fig. 1, 4, 8).

[arXiv:2307.09288] 32L d_model=4096 32H (kv=32, MHA) d_ff=11008 vocab=32000.
Not part of the assigned pool; used by the paper-figure benchmarks.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="llama2-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=11008,
    vocab_size=32000,
    pos_emb="rope",
    sliding_window=0,
    max_seq_len=16384,
    source="arXiv:2307.09288 (Llama 2)",
)

SMOKE = ModelConfig(
    arch_id="llama2-7b-smoke",
    family="dense",
    n_layers=2,
    d_model=256,
    n_heads=4,
    n_kv_heads=4,
    d_ff=512,
    vocab_size=512,
    pos_emb="rope",
    max_seq_len=256,
    source="reduced llama2",
)
