"""granite-3-2b [dense]: GQA kv=8.

[hf:ibm-granite/granite-3.0-2b-base] 40L d_model=2048 32H (kv=8) d_ff=8192 vocab=49155.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="granite-3-2b",
    family="dense",
    n_layers=40,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=49155,
    pos_emb="rope",
    rope_theta=10000.0,
    tie_embeddings=True,
    sliding_window=8192,
    max_seq_len=524288,
    source="hf:ibm-granite/granite-3.0-2b-base",
)

SMOKE = ModelConfig(
    arch_id="granite-3-2b-smoke",
    family="dense",
    n_layers=2,
    d_model=256,
    n_heads=8,
    n_kv_heads=2,
    d_ff=512,
    vocab_size=512,
    pos_emb="rope",
    tie_embeddings=True,
    max_seq_len=256,
    source="reduced granite-3",
)
