"""whisper-base [audio]: enc-dec transformer backbone, conv/mel frontend stubbed.

[arXiv:2212.04356] 6L d_model=512 8H (kv=8) d_ff=2048 vocab=51865.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="whisper-base",
    family="encdec",
    n_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    pos_emb="sinusoid",  # whisper: sinusoid enc / learned dec; we use sinusoid
    qkv_bias=True,
    norm="layernorm",
    act="gelu",
    is_encoder_decoder=True,
    n_encoder_layers=6,
    encoder_len=1500,
    audio_stub=True,
    tie_embeddings=True,
    sliding_window=8192,
    max_seq_len=524288,
    source="arXiv:2212.04356 (Whisper)",
)

SMOKE = ModelConfig(
    arch_id="whisper-base-smoke",
    family="encdec",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    d_ff=256,
    vocab_size=512,
    pos_emb="sinusoid",
    qkv_bias=True,
    norm="layernorm",
    act="gelu",
    is_encoder_decoder=True,
    n_encoder_layers=2,
    encoder_len=32,
    audio_stub=True,
    tie_embeddings=True,
    max_seq_len=256,
    source="reduced whisper-base",
)
