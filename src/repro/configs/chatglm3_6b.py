"""chatglm3-6b [dense]: RoPE-2d (half-rotary), GQA kv=2.

[arXiv:2406.12793] 28L d_model=4096 32H (kv=2) d_ff=13696 vocab=65024.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="chatglm3-6b",
    family="dense",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab_size=65024,
    pos_emb="rope2d",
    qkv_bias=True,   # chatglm uses bias on QKV only
    sliding_window=8192,
    max_seq_len=524288,
    source="arXiv:2406.12793 (ChatGLM)",
)

SMOKE = ModelConfig(
    arch_id="chatglm3-6b-smoke",
    family="dense",
    n_layers=2,
    d_model=256,
    n_heads=8,
    n_kv_heads=2,
    d_ff=512,
    vocab_size=512,
    pos_emb="rope2d",
    qkv_bias=True,
    max_seq_len=256,
    source="reduced chatglm3",
)
