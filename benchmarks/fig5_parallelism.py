"""Paper Figure 5: varying degree of (tensor) parallelism, Yi-34B-200K.

Yi-34B: 60L d_model=7168 56H GQA kv=8 d_ff=20480 vocab=64000 (200k ctx)
[hf:01-ai/Yi-34B-200K] — built here inline since it is the paper's own
evaluation model, not part of the assigned pool.

TP > 1 on the L20 testbed shares the PCIe link between KV offload traffic
and the tensor-parallel all-reduce, so each sim reserves the link for a
fraction of every prefill iteration (`collective_reserve_frac`, paper
§3.1.3): KV transfers are cut into sub-units that defer around the
reservation instead of colliding with the collective's critical path. The
emitted rows report how many transfers deferred (`deferred_n`) and the
mean queueing delay the ledger observed.
"""
from __future__ import annotations

import os
import sys
import time

if __package__ in (None, ""):  # `python benchmarks/fig5_parallelism.py`
    sys.path.insert(
        0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.common import emit
from repro.configs.base import ModelConfig
from repro.serving.costmodel import L20
from repro.serving.scheduler import ServeConfig
from repro.serving.sim import ServingSimulator
from repro.serving.workload import fixed_length

YI_34B = ModelConfig(
    arch_id="yi-34b-200k", family="dense", n_layers=60, d_model=7168,
    n_heads=56, n_kv_heads=8, d_ff=20480, vocab_size=64000,
    pos_emb="rope", max_seq_len=200000,
    source="hf:01-ai/Yi-34B-200K (paper evaluation model)")


def main(n_requests: int = 80, smoke: bool = False) -> None:
    for dop in ([2] if smoke else [2, 4, 8]):
        t0 = time.perf_counter()
        hw = L20.scaled(dop)
        # TP shares the PCIe link with the all-reduce: reserve it for a
        # slice of each prefill iteration (§3.1.3 contention avoidance)
        frac = 0.25 if dop > 1 else 0.0
        mk = lambda: fixed_length(n_requests, 2048, 384, rate=1.0, seed=4)
        mv = ServingSimulator(YI_34B, hw, ServeConfig.for_sim(
            policy="vllm", collective_reserve_frac=frac)).run(mk())
        sim_l = ServingSimulator(YI_34B, hw, ServeConfig.for_sim(
            policy="layerkv", collective_reserve_frac=frac))
        ml = sim_l.run(mk())
        us = (time.perf_counter() - t0) * 1e6
        log = sim_l.off.ledger.log
        deferred = [t for t in log if t.start > t.submitted + 1e-12]
        mean_q = (sum(t.start - t.submitted for t in deferred)
                  / len(deferred)) if deferred else 0.0
        emit(f"fig5.dop{dop}", us,
             f"vllm_ttft_s={mv.mean_ttft:.3f};lkv_ttft_s={ml.mean_ttft:.3f};"
             f"ttft_speedup_x={mv.mean_ttft/max(ml.mean_ttft,1e-9):.2f};"
             f"thr_gap_pct={(1-ml.throughput/max(mv.throughput,1e-9))*100:.1f};"
             f"deferred_n={len(deferred)};"
             f"mean_link_queue_ms={mean_q*1e3:.2f}")


if __name__ == "__main__":
    main()
