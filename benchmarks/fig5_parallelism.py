"""Paper Figure 5: varying degree of (tensor) parallelism, Yi-34B-200K.

Yi-34B: 60L d_model=7168 56H GQA kv=8 d_ff=20480 vocab=64000 (200k ctx)
[hf:01-ai/Yi-34B-200K] — built here inline since it is the paper's own
evaluation model, not part of the assigned pool.
"""
from __future__ import annotations

import time

from benchmarks.common import emit
from repro.configs.base import ModelConfig
from repro.serving.costmodel import L20
from repro.serving.sim import ServingSimulator, SimConfig
from repro.serving.workload import fixed_length

YI_34B = ModelConfig(
    arch_id="yi-34b-200k", family="dense", n_layers=60, d_model=7168,
    n_heads=56, n_kv_heads=8, d_ff=20480, vocab_size=64000,
    pos_emb="rope", max_seq_len=200000,
    source="hf:01-ai/Yi-34B-200K (paper evaluation model)")


def main(n_requests: int = 80, smoke: bool = False) -> None:
    for dop in ([2] if smoke else [2, 4, 8]):
        t0 = time.perf_counter()
        hw = L20.scaled(dop)
        mk = lambda: fixed_length(n_requests, 2048, 384, rate=1.0, seed=4)
        mv = ServingSimulator(YI_34B, hw, SimConfig(policy="vllm")).run(mk())
        ml = ServingSimulator(YI_34B, hw,
                              SimConfig(policy="layerkv")).run(mk())
        us = (time.perf_counter() - t0) * 1e6
        emit(f"fig5.dop{dop}", us,
             f"vllm_ttft_s={mv.mean_ttft:.3f};lkv_ttft_s={ml.mean_ttft:.3f};"
             f"ttft_speedup_x={mv.mean_ttft/max(ml.mean_ttft,1e-9):.2f};"
             f"thr_gap_pct={(1-ml.throughput/max(mv.throughput,1e-9))*100:.1f}")


if __name__ == "__main__":
    main()
