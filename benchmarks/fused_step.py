"""Fused mixed-step vs two-call per-iteration comparison (ISSUE 3).

Steady-state serving iteration at several context lengths: ONE prefilling
request (a 32-token chunk at offset = ctx) batched with an 8-request
decode batch. Arms:

  two_call  the legacy executor sequence — `prefill_chunk` against dense
            gathered prefix buffers + `write_layer_slice` appends +
            `decode` (two full weight streams per iteration);
  fused     `PagedExecutor.mixed_step` — one forward, chunk tokens
            attending straight against the paged pool, KV scattered
            in-step.

Also measured: the O(ctx) `gather_layer` prefix copy the fused path
eliminates (the two-call engine pays it on every request's first chunk
and re-materializes it after evictions).

    PYTHONPATH=src python benchmarks/fused_step.py  # -> BENCH_fused_step.json

us_per_call is harness wall time; `derived` carries per-iteration wall
time and tokens/s per arm. Absolute numbers are CPU-backend wall times —
the relative fused/two-call gap is the signal.
"""
from __future__ import annotations

import dataclasses
import json
import os
import sys
import time

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from benchmarks.common import emit  # noqa: E402
from repro.configs import get_smoke_config  # noqa: E402
from repro.serving.executor import (MixedChunk, MixedDecode,  # noqa: E402
                                    PagedExecutor)

CHUNK = 32
R_DECODE = 8


def _timeit(fn, warmup=2, iters=15):
    """Best-of-N wall time (us): the minimum is the standard
    microbenchmark estimator — it excludes scheduler/allocator noise,
    which on this shared CPU box swamps the median."""
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return min(ts) * 1e6


def _setup(cfg, ctx):
    """One prefilling request (prefill_done=ctx) + R_DECODE decode
    requests at ctx tokens, blocks laid out disjointly in one pool."""
    BS = 16
    L = cfg.n_layers
    nb_chunk = -(-(ctx + CHUNK) // BS)
    nb_dec = -(-(ctx + 2) // BS)
    ndb = L * (nb_chunk + R_DECODE * nb_dec) + 8
    ex = PagedExecutor(cfg, None, ndb, 16, BS, rng=jax.random.PRNGKey(0))
    # real-looking pool contents (attention numerics don't affect timing)
    ex.device_pool = jax.random.normal(
        jax.random.PRNGKey(1), ex.device_pool.shape, ex.device_pool.dtype)
    nxt = 0
    chunk_tabs = []
    for _ in range(L):
        chunk_tabs.append(list(range(nxt, nxt + nb_chunk)))
        nxt += nb_chunk
    dec_tabs = []
    for _ in range(R_DECODE):
        tabs = []
        for _ in range(L):
            tabs.append(list(range(nxt, nxt + nb_dec)))
            nxt += nb_dec
        dec_tabs.append(tabs)
    rng = np.random.RandomState(0)
    chunk_toks = [int(t) for t in rng.randint(0, cfg.vocab_size, CHUNK)]
    dec_toks = [int(t) for t in rng.randint(0, cfg.vocab_size, R_DECODE)]
    return ex, chunk_tabs, dec_tabs, chunk_toks, dec_toks


def _bench_ctx(cfg, ctx):
    BS = 16
    L = cfg.n_layers
    ex, chunk_tabs, dec_tabs, chunk_toks, dec_toks = _setup(cfg, ctx)

    # ---- two-call arm: gather once (steady-state cached buffers), then
    # per iteration: chunk forward + per-layer appends + decode forward
    import jax.numpy as jnp
    ks, vs = [], []
    for l in range(L):
        k, v = ex.gather_layer("device", chunk_tabs[l], kv_valid=ctx)
        ks.append(k)
        vs.append(v)
    kbuf, vbuf = jnp.stack(ks), jnp.stack(vs)
    maxb = max(len(chunk_tabs[0]), len(dec_tabs[0][0]))
    tables = np.zeros((L, R_DECODE, maxb), np.int32)
    for r in range(R_DECODE):
        for l in range(L):
            tables[l, r, :len(dec_tabs[r][l])] = dec_tabs[r][l]
    kv_lens = [ctx] * R_DECODE

    def two_call():
        logits, kc, vc = ex.prefill_chunk(chunk_toks, ctx, kbuf, vbuf)
        for l in range(L):
            ex.write_layer_slice("device", chunk_tabs[l], ctx, kc[l], vc[l])
        ex.decode(dec_toks, tables, kv_lens)
        logits.block_until_ready()

    # ---- fused arm: one mixed_step (assembly included — it is part of
    # the per-iteration cost)
    def fused():
        chunks = [MixedChunk(tokens=chunk_toks, offset=ctx,
                             tables=[t[:] for t in chunk_tabs],
                             tiers=[False] * L)]
        decodes = [MixedDecode(token=dec_toks[r], ctx=ctx,
                               tables=[t[:] for t in dec_tabs[r]])
                   for r in range(R_DECODE)]
        ex.mixed_step(chunks, decodes)

    def gather():
        for l in range(L):
            k, v = ex.gather_layer("device", chunk_tabs[l])
        k.block_until_ready()

    us_two = _timeit(two_call)
    us_fused = _timeit(fused)
    us_gather = _timeit(gather)
    toks = CHUNK + R_DECODE
    return {
        "ctx": ctx,
        "block_size": BS,
        "two_call_iter_us": us_two,
        "fused_iter_us": us_fused,
        "speedup": us_two / us_fused,
        "two_call_tok_s": toks / (us_two * 1e-6),
        "fused_tok_s": toks / (us_fused * 1e-6),
        "eliminated_gather_us": us_gather,
    }


def main(smoke: bool = False) -> None:
    # 6 layers (vs the 2-layer smoke shape): the fused win is the
    # eliminated second weight stream + per-layer dispatch, which scales
    # with depth — at 2 layers it drowns in CPU timing noise
    cfg = dataclasses.replace(get_smoke_config("granite-3-2b"),
                              dtype="float32",
                              n_layers=2 if smoke else 6)
    ctxs = [64, 128] if smoke else [128, 256, 512, 1024]
    arms = []
    for ctx in ctxs:
        arm = _bench_ctx(cfg, ctx)
        arms.append(arm)
        emit(f"fused_step.ctx{ctx}", arm["fused_iter_us"],
             f"two_call_us={arm['two_call_iter_us']:.0f};"
             f"speedup={arm['speedup']:.2f}x;"
             f"fused_tok_s={arm['fused_tok_s']:.0f};"
             f"gather_us={arm['eliminated_gather_us']:.0f}")
    out = {
        "experiment": "fused mixed-step vs two-call per-iteration time",
        "model": "granite-3-2b (smoke shape at n_layers=6, float32, "
                 "CPU backend)",
        "chunk_tokens": CHUNK,
        "decode_batch": R_DECODE,
        "note": "wall time of one serving iteration; two_call = "
                "prefill_chunk + write_layer_slice appends + decode "
                "(two weight streams), fused = one mixed_step forward; "
                "eliminated_gather_us is the O(ctx) dense prefix copy "
                "the fused path never performs",
        "arms": arms,
    }
    if not smoke:
        path = os.path.join(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), "BENCH_fused_step.json")
        with open(path, "w") as f:
            json.dump(out, f, indent=2)
        print(f"# wrote {path}", flush=True)


if __name__ == "__main__":
    main()
