"""Paper Figure 4: LayerKV vs vLLM across context lengths (Llama2-7B,
1 req/s) — TTFT (top row) and throughput (bottom row)."""
from __future__ import annotations

import time

from benchmarks.common import emit
from repro.configs.llama2_7b import CONFIG as LLAMA2_7B
from repro.serving.costmodel import L20
from repro.serving.sim import ServingSimulator, SimConfig
from repro.serving.workload import fixed_length

CTX = [512, 1024, 2048, 4096, 8192]


def main(n_requests: int = 100) -> None:
    for ctx in CTX:
        t0 = time.perf_counter()
        mv = ServingSimulator(LLAMA2_7B, L20, SimConfig(policy="vllm")).run(
            fixed_length(n_requests, ctx, 512, rate=1.0, seed=1))
        ml = ServingSimulator(LLAMA2_7B, L20,
                              SimConfig(policy="layerkv")).run(
            fixed_length(n_requests, ctx, 512, rate=1.0, seed=1))
        us = (time.perf_counter() - t0) * 1e6
        speedup = mv.mean_ttft / max(ml.mean_ttft, 1e-9)
        thr_gap = 1.0 - ml.throughput / max(mv.throughput, 1e-9)
        emit(f"fig4.ctx{ctx}", us,
             f"vllm_ttft_s={mv.mean_ttft:.3f};lkv_ttft_s={ml.mean_ttft:.3f};"
             f"ttft_speedup_x={speedup:.2f};"
             f"vllm_tpot_ms={mv.mean_tpot*1e3:.1f};"
             f"lkv_tpot_ms={ml.mean_tpot*1e3:.1f};"
             f"thr_gap_pct={thr_gap*100:.1f}")


if __name__ == "__main__":
    main()
