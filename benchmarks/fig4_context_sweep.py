"""Paper Figure 4: LayerKV vs vLLM across context lengths (Llama2-7B,
1 req/s) — TTFT (top row) and throughput (bottom row) — plus a
layerkv+chunked arm (chunked prefill with mixed batching, this repo's
extension beyond the paper).

``main(json_out=...)`` additionally dumps the three-arm TTFT comparison to
a JSON file; `BENCH_chunked_prefill.json` in the repo root is that
artifact, committed so future PRs have a perf trajectory to diff against:

    PYTHONPATH=src python benchmarks/fig4_context_sweep.py
"""
from __future__ import annotations

import json
import os
import sys
import time
from typing import Optional

if __package__ in (None, ""):  # `python benchmarks/fig4_context_sweep.py`
    sys.path.insert(
        0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.common import emit
from repro.configs.llama2_7b import CONFIG as LLAMA2_7B
from repro.serving.costmodel import L20
from repro.serving.scheduler import ServeConfig
from repro.serving.sim import ServingSimulator
from repro.serving.workload import fixed_length

CTX = [512, 1024, 2048, 4096, 8192]


def main(n_requests: int = 100, smoke: bool = False,
         json_out: Optional[str] = None) -> None:
    rows = {}
    for ctx in CTX[:2] if smoke else CTX:
        t0 = time.perf_counter()
        mk = lambda ctx=ctx: fixed_length(
            n_requests, ctx, 512, rate=1.0, seed=1)
        mv = ServingSimulator(LLAMA2_7B, L20,
                              ServeConfig.for_sim(policy="vllm")).run(mk())
        ml = ServingSimulator(LLAMA2_7B, L20,
                              ServeConfig.for_sim(policy="layerkv")).run(mk())
        mc = ServingSimulator(LLAMA2_7B, L20,
                              ServeConfig.for_sim(policy="layerkv",
                                        chunked=True)).run(mk())
        us = (time.perf_counter() - t0) * 1e6
        speedup = mv.mean_ttft / max(ml.mean_ttft, 1e-9)
        thr_gap = 1.0 - ml.throughput / max(mv.throughput, 1e-9)
        emit(f"fig4.ctx{ctx}", us,
             f"vllm_ttft_s={mv.mean_ttft:.3f};lkv_ttft_s={ml.mean_ttft:.3f};"
             f"lkv_chunked_ttft_s={mc.mean_ttft:.3f};"
             f"ttft_speedup_x={speedup:.2f};"
             f"chunked_speedup_x={mv.mean_ttft/max(mc.mean_ttft,1e-9):.2f};"
             f"vllm_tpot_ms={mv.mean_tpot*1e3:.1f};"
             f"lkv_tpot_ms={ml.mean_tpot*1e3:.1f};"
             f"lkv_chunked_tpot_ms={mc.mean_tpot*1e3:.1f};"
             f"thr_gap_pct={thr_gap*100:.1f}")
        rows[ctx] = {
            "vllm": {"mean_ttft_s": mv.mean_ttft, "p99_ttft_s": mv.p99_ttft,
                     "mean_tpot_ms": mv.mean_tpot * 1e3},
            "layerkv": {"mean_ttft_s": ml.mean_ttft,
                        "p99_ttft_s": ml.p99_ttft,
                        "mean_tpot_ms": ml.mean_tpot * 1e3},
            "layerkv_chunked": {"mean_ttft_s": mc.mean_ttft,
                                "p99_ttft_s": mc.p99_ttft,
                                "mean_tpot_ms": mc.mean_tpot * 1e3,
                                "chunk_iters": mc.chunk_iters},
        }
    if json_out:
        doc = {
            "benchmark": "fig4_context_sweep",
            "model": LLAMA2_7B.arch_id,
            "hw": L20.name,
            "n_requests": n_requests,
            "arms": ["vllm", "layerkv", "layerkv_chunked"],
            "by_context": rows,
        }
        with open(json_out, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
            f.write("\n")


if __name__ == "__main__":
    main(json_out="BENCH_chunked_prefill.json")
