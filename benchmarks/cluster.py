"""Cluster routing benchmark: replica count x routing policy on the
multi-tenant bursty workload (this repo's extension beyond the paper —
the paper serves ONE instance; at fleet scale the router decides which
queue a request joins, and with the PR 2 prefix cache being per-replica,
whether it lands where its template is already cached).

The sweep holds the AGGREGATE device pool fixed: a cluster of R replicas
gives each replica total/R device blocks (plus R-fold compute — that is
what buying R accelerators does), so `replicas=1` is the paper's single
instance with the whole pool and every R >= 2 row is the same silicon
budget split behind a router. Every arm serves identical
`workload.multi_tenant` traces (per-tenant shared-prefix templates,
Zipf-skewed popularity, bursty on-off arrivals), and each arm pools its
raw latency series over three seeds via `SimMetrics.merge` — the
committed numbers are not one lucky trace.

What the committed artifact (`BENCH_cluster.json`) shows (n=300 x 3
seeds, rate 80/s, 16 tenants, 90% share):

  * >= 2 replicas beat 1 at matched aggregate pool size under
    congestion (queueing delay, the paper's dominant TTFT term, is
    compute-bound: R queues drain R x faster) — 2.6x mean TTFT at R=2,
    6.7x at R=4;
  * at fixed replica count, `prefix_affinity` beats `round_robin` mean
    TTFT (1.27x at R=2, 1.28x at R=4; hit rate 0.69/0.57 vs 0.57/0.53):
    rendezvous dispatch keeps each tenant's template hot on ONE replica
    (suffix-only prefills, no cross-replica cache duplication) while
    its economics-priced spillover keeps the hot tenants from
    hotspotting;
  * `least_loaded` is load-aware but cache-oblivious (scatters every
    template across every replica's cache) and trails round_robin here;
    `slo_aware` sits at affinity's level at R=2 — its admission-ETA
    signal already prices cached work through `cached_hint`.

    PYTHONPATH=src python benchmarks/cluster.py
"""
from __future__ import annotations

import json
import os
import sys
import time
from typing import Optional

if __package__ in (None, ""):  # `python benchmarks/cluster.py`
    sys.path.insert(
        0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.common import emit
from repro.configs.llama2_7b import CONFIG as LLAMA2_7B
from repro.serving.cluster import ClusterSession
from repro.serving.costmodel import L20
from repro.serving.scheduler import ServeConfig
from repro.serving.sim import ServingSimulator, SimMetrics
from repro.serving.workload import multi_tenant

REPLICAS = [1, 2, 4]
ROUTERS = ["round_robin", "least_loaded", "prefix_affinity", "slo_aware"]
TOTAL_DEVICE_BLOCKS = 65536        # aggregate pool, split across replicas
WORKLOAD = dict(rate=80.0, n_tenants=16, share_ratio=0.9,
                prompt_len=1024, output_len=128, zipf_s=1.0,
                burst_on=3.0, burst_off=6.0, burst_cv=2.0)
SEEDS = (3, 7, 13)                # pooled per arm (SimMetrics.merge)


def _cluster(n_replicas: int, router: str) -> ClusterSession:
    sc = ServeConfig.for_sim(
        policy="layerkv", chunked=True, prefix_cache=True,
        num_device_blocks=TOTAL_DEVICE_BLOCKS // n_replicas)
    return ClusterSession(
        [ServingSimulator(LLAMA2_7B, L20, sc) for _ in range(n_replicas)],
        router=router)


def _one(n_replicas: int, router: str, n: int, seeds=SEEDS) -> dict:
    # one fresh cluster per seed; raw latency series are POOLED across
    # seeds (SimMetrics.merge) before means/percentiles, so the
    # committed numbers are not one lucky trace
    parts, per_seed, dispatched = [], {}, [0] * n_replicas
    peak = [0.0] * n_replicas
    for seed in seeds:
        cl = _cluster(n_replicas, router)
        cl.run(multi_tenant(n, seed=seed, **WORKLOAD))
        m = cl.metrics()
        parts.append(m)
        per_seed[seed] = round(m.mean_ttft, 4)
        for i, st in enumerate(cl.stats):
            dispatched[i] += st.dispatched
            peak[i] = max(peak[i], st.peak_occupancy)
    m = SimMetrics.merge(parts)
    return {
        "mean_ttft_s": m.mean_ttft,
        "p99_ttft_s": m.p99_ttft,
        "mean_tpot_ms": m.mean_tpot * 1e3,
        "prefix_hit_rate": m.prefix_hit_rate,
        "n_finished": m.n_requests,
        "preemptions": m.preemptions,
        "mean_ttft_s_by_seed": per_seed,
        "dispatched_per_replica": dispatched,
        "peak_occupancy_per_replica": [round(p, 3) for p in peak],
    }


def main(n_requests: int = 100, smoke: bool = False,
         json_out: Optional[str] = None) -> None:
    replicas = [1, 2] if smoke else REPLICAS
    routers = ["round_robin", "prefix_affinity"] if smoke else ROUTERS
    seeds = SEEDS[:1] if smoke else SEEDS
    rows = {}
    for n_rep in replicas:
        t0 = time.perf_counter()
        arms = {router: _one(n_rep, router, n_requests, seeds=seeds)
                for router in (routers if n_rep > 1 else ["round_robin"])}
        us = (time.perf_counter() - t0) * 1e6
        rows[n_rep] = arms
        if n_rep == 1:
            emit("cluster.r1.single", us,
                 f"ttft_s={arms['round_robin']['mean_ttft_s']:.3f};"
                 f"p99_s={arms['round_robin']['p99_ttft_s']:.3f};"
                 f"hit_rate={arms['round_robin']['prefix_hit_rate']:.2f}")
        else:
            rr, pa = arms["round_robin"], arms["prefix_affinity"]
            emit(f"cluster.r{n_rep}", us,
                 f"rr_ttft_s={rr['mean_ttft_s']:.3f};"
                 f"affinity_ttft_s={pa['mean_ttft_s']:.3f};"
                 f"affinity_speedup_x="
                 f"{rr['mean_ttft_s'] / max(pa['mean_ttft_s'], 1e-9):.2f};"
                 f"rr_hit={rr['prefix_hit_rate']:.2f};"
                 f"affinity_hit={pa['prefix_hit_rate']:.2f};"
                 f"scaleup_vs_r1_x="
                 f"{rows[replicas[0]]['round_robin']['mean_ttft_s'] / max(pa['mean_ttft_s'], 1e-9):.2f}")

    if json_out:
        doc = {
            "benchmark": "cluster_routing_sweep",
            "model": LLAMA2_7B.arch_id,
            "hw": L20.name,
            "n_requests": n_requests,
            "total_device_blocks": TOTAL_DEVICE_BLOCKS,
            "pool_split": "total/replicas per replica (matched aggregate)",
            "workload": WORKLOAD,
            "seeds": list(SEEDS),
            "routers": ROUTERS,
            "by_replicas": rows,
        }
        with open(json_out, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
            f.write("\n")


if __name__ == "__main__":
    main(n_requests=300, json_out="BENCH_cluster.json")
