"""Kernel microbenchmarks: Pallas (interpret on CPU) vs jnp oracle.

On this container the interesting output is CORRECTNESS + the HLO cost of
the jnp reference path (which is what the dry-run compiles); interpret-mode
wall time is not indicative of TPU performance.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.kernels import ref
from repro.kernels.flash_prefill import flash_attention_pallas
from repro.kernels.paged_attention import paged_attention_pallas
from repro.kernels.paged_prefill import paged_prefill_pallas


def main(smoke: bool = False) -> None:
    key = jax.random.PRNGKey(0)
    # flash prefill
    B, S, H, KV, D = 1, (128 if smoke else 512), 8, 2, 128
    q = jax.random.normal(key, (B, S, H, D), jnp.float32)
    k = jax.random.normal(key, (B, S, KV, D), jnp.float32)
    v = jax.random.normal(key, (B, S, KV, D), jnp.float32)
    ref_fn = jax.jit(lambda *a: ref.flash_attention_reference(*a))
    us_ref = timeit(lambda: ref_fn(q, k, v).block_until_ready())
    out_p = flash_attention_pallas(q, k, v)
    err = float(jnp.max(jnp.abs(out_p - ref_fn(q, k, v))))
    c = jax.jit(lambda *a: ref.mha_reference(*a)).lower(q, k, v).compile()
    cost = c.cost_analysis()
    if isinstance(cost, (list, tuple)):  # older jax returns [dict] per device
        cost = cost[0] if cost else {}
    flops = (cost or {}).get("flops", 0.0)
    emit("kernel.flash_prefill", us_ref,
         f"maxerr_vs_pallas={err:.2e};hlo_flops={flops:.3g};"
         f"shape=B{B}xS{S}xH{H}xKV{KV}xD{D}")

    # paged decode attention
    B, H, KV, D, NB, BS, MAXB = 8, 8, 2, 128, 128, 16, (4 if smoke else 16)
    q1 = jax.random.normal(key, (B, H, D), jnp.float32)
    pool = jax.random.normal(key, (NB, BS, 2, KV, D), jnp.float32)
    tab = jax.random.permutation(key, NB)[:B * MAXB].reshape(B, MAXB)
    tab = tab.astype(jnp.int32)
    kv_len = jnp.full((B,), BS * MAXB - 3, jnp.int32)
    pref = jax.jit(lambda *a: ref.paged_attention_reference(*a))
    us_ref = timeit(lambda: pref(q1, pool, tab, kv_len).block_until_ready())
    outp = paged_attention_pallas(q1, pool, tab, kv_len)
    err = float(jnp.max(jnp.abs(outp - pref(q1, pool, tab, kv_len))))
    emit("kernel.paged_attention", us_ref,
         f"maxerr_vs_pallas={err:.2e};"
         f"shape=B{B}xH{H}xKV{KV}xD{D}xBS{BS}xMAXB{MAXB}")

    # paged chunk-prefill: one chunk attending straight against the pool
    # vs the legacy gather-to-dense + flash path it replaces
    TQ, C = 32, 32
    H, KV, D, BS = 8, 2, 128, 16
    MAXB = 8 if smoke else 32
    NB = MAXB + 16
    ctx = MAXB * BS - C - 5          # chunk ends 5 tokens shy of the table
    pool = jax.random.normal(key, (NB, BS, 2, KV, D), jnp.float32)
    tab = jax.random.permutation(key, NB)[:MAXB][None].astype(jnp.int32)
    Tc = -(-C // TQ) * TQ
    qc = jax.random.normal(key, (Tc, H, D), jnp.float32)
    seg = jnp.zeros(Tc, jnp.int32)
    qpos = ctx + jnp.arange(Tc, dtype=jnp.int32)
    klen = jnp.asarray([ctx + C], jnp.int32)
    ppref = jax.jit(lambda *a: ref.paged_prefill_reference(*a, tq=TQ))

    def gather_dense():
        g = pool[tab[0]]
        k = g[:, :, 0].reshape(MAXB * BS, KV, D)[None]
        v = g[:, :, 1].reshape(MAXB * BS, KV, D)[None]
        return ref.flash_attention_reference(
            qc[None, :C], k, v, causal=True,
            kv_len=jnp.asarray([ctx + C]), q_offset=ctx)
    gd = jax.jit(gather_dense)
    us_gd = timeit(lambda: gd().block_until_ready())
    us_pp = timeit(
        lambda: ppref(qc, pool, tab, seg, qpos, klen).block_until_ready())
    outp = paged_prefill_pallas(qc, pool, tab, seg, qpos, klen, tq=TQ)
    err = float(jnp.max(jnp.abs(
        outp - ppref(qc, pool, tab, seg, qpos, klen))))
    emit("kernel.paged_prefill", us_pp,
         f"gather_dense_us={us_gd:.1f};maxerr_vs_pallas={err:.2e};"
         f"shape=C{C}xH{H}xKV{KV}xD{D}xBS{BS}xMAXB{MAXB}")

    # fused mixed step (one forward: chunk + decode batch) vs the two-call
    # executor baseline it replaces
    from repro.configs import get_smoke_config
    from repro.serving.executor import (MixedChunk, MixedDecode,
                                        PagedExecutor)
    cfg = dataclasses.replace(get_smoke_config("granite-3-2b"),
                              dtype="float32")
    BS, ctx, C, R = 16, (64 if smoke else 256), 24, 4
    L = cfg.n_layers
    nb = -(-(ctx + C) // BS)
    ex = PagedExecutor(cfg, None, L * nb * (R + 1) + 8, 16, BS,
                       rng=jax.random.PRNGKey(0))
    nxt = 0
    ctabs, dtabs = [], []
    for _ in range(L):
        ctabs.append(list(range(nxt, nxt + nb)))
        nxt += nb
    for _ in range(R):
        t = []
        for _ in range(L):
            t.append(list(range(nxt, nxt + nb)))
            nxt += nb
        dtabs.append(t)
    rng = np.random.RandomState(0)
    ctoks = [int(x) for x in rng.randint(0, cfg.vocab_size, C)]
    dtoks = [int(x) for x in rng.randint(0, cfg.vocab_size, R)]
    ks, vs = zip(*(ex.gather_layer("device", ctabs[l], kv_valid=ctx)
                   for l in range(L)))
    kbuf, vbuf = jnp.stack(ks), jnp.stack(vs)
    tables = np.zeros((L, R, nb), np.int32)
    for r in range(R):
        for l in range(L):
            tables[l, r] = dtabs[r][l]

    def two_call():
        logits, kc, vc = ex.prefill_chunk(ctoks, ctx, kbuf, vbuf)
        for l in range(L):
            ex.write_layer_slice("device", ctabs[l], ctx, kc[l], vc[l])
        ex.decode(dtoks, tables, [ctx] * R)
        logits.block_until_ready()

    def fused():
        ex.mixed_step(
            [MixedChunk(tokens=ctoks, offset=ctx, tables=ctabs,
                        tiers=[False] * L)],
            [MixedDecode(token=dtoks[r], ctx=ctx, tables=dtabs[r])
             for r in range(R)])
    us_two = timeit(two_call)
    us_fused = timeit(fused)
    emit("kernel.fused_mixed_step", us_fused,
         f"two_call_us={us_two:.1f};speedup={us_two / us_fused:.2f}x;"
         f"ctx{ctx}xC{C}xR{R}")


if __name__ == "__main__":
    main()
