"""Kernel microbenchmarks: Pallas (interpret on CPU) vs jnp oracle.

On this container the interesting output is CORRECTNESS + the HLO cost of
the jnp reference path (which is what the dry-run compiles); interpret-mode
wall time is not indicative of TPU performance.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.kernels import ref
from repro.kernels.flash_prefill import flash_attention_pallas
from repro.kernels.paged_attention import paged_attention_pallas


def main(smoke: bool = False) -> None:
    key = jax.random.PRNGKey(0)
    # flash prefill
    B, S, H, KV, D = 1, (128 if smoke else 512), 8, 2, 128
    q = jax.random.normal(key, (B, S, H, D), jnp.float32)
    k = jax.random.normal(key, (B, S, KV, D), jnp.float32)
    v = jax.random.normal(key, (B, S, KV, D), jnp.float32)
    ref_fn = jax.jit(lambda *a: ref.flash_attention_reference(*a))
    us_ref = timeit(lambda: ref_fn(q, k, v).block_until_ready())
    out_p = flash_attention_pallas(q, k, v)
    err = float(jnp.max(jnp.abs(out_p - ref_fn(q, k, v))))
    c = jax.jit(lambda *a: ref.mha_reference(*a)).lower(q, k, v).compile()
    cost = c.cost_analysis()
    if isinstance(cost, (list, tuple)):  # older jax returns [dict] per device
        cost = cost[0] if cost else {}
    flops = (cost or {}).get("flops", 0.0)
    emit("kernel.flash_prefill", us_ref,
         f"maxerr_vs_pallas={err:.2e};hlo_flops={flops:.3g};"
         f"shape=B{B}xS{S}xH{H}xKV{KV}xD{D}")

    # paged decode attention
    B, H, KV, D, NB, BS, MAXB = 8, 8, 2, 128, 128, 16, (4 if smoke else 16)
    q1 = jax.random.normal(key, (B, H, D), jnp.float32)
    pool = jax.random.normal(key, (NB, BS, 2, KV, D), jnp.float32)
    tab = jax.random.permutation(key, NB)[:B * MAXB].reshape(B, MAXB)
    tab = tab.astype(jnp.int32)
    kv_len = jnp.full((B,), BS * MAXB - 3, jnp.int32)
    pref = jax.jit(lambda *a: ref.paged_attention_reference(*a))
    us_ref = timeit(lambda: pref(q1, pool, tab, kv_len).block_until_ready())
    outp = paged_attention_pallas(q1, pool, tab, kv_len)
    err = float(jnp.max(jnp.abs(outp - pref(q1, pool, tab, kv_len))))
    emit("kernel.paged_attention", us_ref,
         f"maxerr_vs_pallas={err:.2e};"
         f"shape=B{B}xH{H}xKV{KV}xD{D}xBS{BS}xMAXB{MAXB}")


if __name__ == "__main__":
    main()
