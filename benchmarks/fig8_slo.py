"""Paper Figure 8: SLO violation rate vs arrival rate (Llama2-7B,
TTFT SLO 3000 ms / TPOT SLO 200 ms) incl. the scheduler ablation
(LayerKV w/o SLO-aware scheduler) and a layerkv+chunked arm (chunked
prefill with mixed batching, token-budget admission via Eq.1 slack).
"""
from __future__ import annotations

import time

from benchmarks.common import emit
from repro.configs.llama2_7b import CONFIG as LLAMA2_7B
from repro.serving.costmodel import L20
from repro.serving.scheduler import ServeConfig
from repro.serving.sim import ServingSimulator
from repro.serving.workload import sharegpt_like

RATES = [6.0, 8.0, 10.0, 12.0, 14.0]


def main(n_requests: int = 300, smoke: bool = False) -> None:
    for rate in RATES[:2] if smoke else RATES:
        t0 = time.perf_counter()
        mk = lambda rate=rate: sharegpt_like(
            n_requests, rate=rate, seed=13, tpot_slo=0.2, ttft_slo=3.0)
        mv = ServingSimulator(LLAMA2_7B, L20,
                              ServeConfig.for_sim(policy="vllm")).run(mk())
        ml = ServingSimulator(LLAMA2_7B, L20,
                              ServeConfig.for_sim(policy="layerkv",
                                        slo_aware=True)).run(mk())
        mn = ServingSimulator(LLAMA2_7B, L20,
                              ServeConfig.for_sim(policy="layerkv",
                                        slo_aware=False)).run(mk())
        mc = ServingSimulator(LLAMA2_7B, L20,
                              ServeConfig.for_sim(policy="layerkv", slo_aware=True,
                                        chunked=True)).run(mk())
        us = (time.perf_counter() - t0) * 1e6
        emit(f"fig8.rate{rate:g}", us,
             f"vllm_viol={mv.violation_rate:.3f};"
             f"lkv_viol={ml.violation_rate:.3f};"
             f"lkv_no_sched_viol={mn.violation_rate:.3f};"
             f"lkv_chunked_viol={mc.violation_rate:.3f};"
             f"improvement_pts={(mv.violation_rate-ml.violation_rate)*100:.1f}")


if __name__ == "__main__":
    main()
