"""Shared helpers for the benchmark suite."""
from __future__ import annotations

import time
from typing import Callable

ROWS = []


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    """Append + print one CSV row: name,us_per_call,derived."""
    row = f"{name},{us_per_call:.3f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def timeit(fn: Callable, *args, warmup: int = 1, iters: int = 5) -> float:
    """Median wall time per call in microseconds."""
    for _ in range(warmup):
        fn(*args)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn(*args)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6
