"""Paper Figure 1: TTFT/TPOT vs context length + queuing/prefill breakdown.

Llama2-7B on one L20, 1 req/s, 100 requests, output 512 (the paper's exact
methodology), vLLM policy — this is the MOTIVATION measurement showing
queuing delay dominating TTFT beyond ~1k context.
"""
from __future__ import annotations

import time

from benchmarks.common import emit
from repro.configs.llama2_7b import CONFIG as LLAMA2_7B
from repro.serving.costmodel import L20
from repro.serving.scheduler import ServeConfig
from repro.serving.sim import ServingSimulator
from repro.serving.workload import fixed_length

CTX = [128, 512, 1024, 2048, 4096, 8192, 16384]


def main(n_requests: int = 100, smoke: bool = False) -> None:
    for ctx in CTX[:2] if smoke else CTX:
        t0 = time.perf_counter()
        reqs = fixed_length(n_requests, ctx, 512, rate=1.0, seed=1)
        m = ServingSimulator(LLAMA2_7B, L20,
                             ServeConfig.for_sim(policy="vllm")).run(reqs)
        us = (time.perf_counter() - t0) * 1e6
        emit(f"fig1.ctx{ctx}", us,
             f"ttft_s={m.mean_ttft:.3f};tpot_ms={m.mean_tpot*1e3:.1f};"
             f"queuing_s={m.mean_queuing:.3f};prefill_s={m.mean_prefill:.3f};"
             f"queue_frac={m.mean_queuing/max(m.mean_ttft,1e-9):.3f}")


if __name__ == "__main__":
    main()
