"""Fault-tolerance benchmark: what replica failure COSTS and what the
recovery machinery SAVES (this repo's robustness extension beyond the
paper — the paper serves one healthy instance; a fleet loses replicas).

Four arms over the same 3-replica simulator cluster and the same bursty
multi-tenant trace, differing only in the injected `FaultPlan`
(serving/faults.py — deterministic, stamped on the shared virtual
clock, replayable):

  no_fault        the healthy baseline every other arm is held to
  crash_recover   replica 0 crashes mid-burst and revives cold 2s
                  later: its live work is salvaged + re-dispatched
                  (streamed tokens preserved, only the unstreamed
                  remainder recomputed)
  wedge_liveness  replica 0 freezes for 60s; the missing-heartbeat
                  detector (liveness_timeout=0.5) declares it dead and
                  recovery proceeds WITHOUT oracle knowledge of the
                  fault — the arm that prices detection, not just
                  repair
  dispatch_fail   a 2s transient dispatch-failure window: arrivals
                  retry with exponential backoff and all land (zero
                  sheds)

Every arm asserts LOSSLESSNESS inline (finished + shed == submitted,
and every finished request delivered exactly its requested tokens
across any number of kills) — under `REPRO_SANITIZE=1` (the CI smoke
invocation) the KV sanitizer additionally shadow-checks S1-S8 every
step and S9 at each kill-unwind. The committed artifact
(`BENCH_faults.json`, n=120 x 3 seeds pooled via `SimMetrics.merge`)
shows the headline: a crash-with-recovery costs 1.06x mean TTFT at
zero lost requests, the transient dispatch window costs 1.04x with
retries alone (no sheds), while the liveness arm pays 2.22x — its
kill is PERMANENT (detection carries no revival oracle), so the fleet
runs the tail of the burst one replica short.

    PYTHONPATH=src python benchmarks/faults.py [--smoke]
"""
from __future__ import annotations

import json
import os
import sys
import time
from typing import Optional

if __package__ in (None, ""):  # `python benchmarks/faults.py`
    sys.path.insert(
        0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.common import emit
from repro.configs.llama2_7b import CONFIG as LLAMA2_7B
from repro.serving.cluster import ClusterSession
from repro.serving.costmodel import L20
from repro.serving.faults import FaultPlan
from repro.serving.scheduler import ServeConfig
from repro.serving.sim import ServingSimulator, SimMetrics
from repro.serving.workload import multi_tenant

N_REPLICAS = 3
WORKLOAD = dict(rate=16.0, n_tenants=3, prompt_len=512, output_len=48)
SEEDS = (7, 11, 19)               # pooled per arm (SimMetrics.merge)
# fault stamps sit inside the trace's busy window (first arrivals land
# around t=4.5 for these seeds)
ARMS = {
    "no_fault": (None, None),
    "crash_recover": ("crash@5.2:r0:recover=2.0", None),
    "wedge_liveness": ("wedge@5.0:r0:dur=60.0", 0.5),
    "dispatch_fail": ("dispatch_fail@4.5:r0:dur=2.0", None),
}


def _cluster(spec: Optional[str],
             liveness: Optional[float]) -> ClusterSession:
    sc = ServeConfig.for_sim(
        policy="layerkv", chunked=True, prefix_cache=True,
        num_device_blocks=2048, num_host_blocks=1 << 14)
    plan = FaultPlan.parse(spec, n_replicas=N_REPLICAS) if spec else None
    return ClusterSession(
        [ServingSimulator(LLAMA2_7B, L20, sc) for _ in range(N_REPLICAS)],
        router="round_robin", fault_plan=plan, liveness_timeout=liveness)


def _one(arm: str, n: int, seeds=SEEDS) -> dict:
    spec, liveness = ARMS[arm]
    parts, kills, recoveries, log_lines = [], 0, 0, []
    for seed in seeds:
        cl = _cluster(spec, liveness)
        reqs = multi_tenant(n, seed=seed, **WORKLOAD)
        done = cl.run(reqs)
        m = cl.metrics()
        # losslessness is part of the benchmark's contract, not just a
        # test: nothing a fault arm reports is comparable if work leaked
        shed = len(cl.shed) + sum(len(c.shed) for c in cl.cores)
        assert len(done) + shed == len(reqs), \
            f"{arm} seed {seed}: {len(done)} done + {shed} shed " \
            f"!= {len(reqs)} submitted"
        assert all(r.tokens_out + r.tokens_salvaged
                   == WORKLOAD["output_len"] for r in done), \
            f"{arm} seed {seed}: token conservation violated"
        parts.append(m)
        kills += cl.n_kills
        recoveries += cl.n_recoveries
        log_lines.extend(cl.recovery_log)
    m = SimMetrics.merge(parts)
    return {
        "mean_ttft_s": m.mean_ttft,
        "p99_ttft_s": m.p99_ttft,
        "goodput_tok_s": m.goodput,
        "makespan_s": m.makespan,
        "n_finished": m.n_requests,
        "n_shed": m.n_shed,
        "n_retries": m.n_retries,
        "n_redispatched": m.n_redispatched,
        "replica_kills": kills,
        "replica_recoveries": recoveries,
        "recovery_log_lines": len(log_lines),
    }


def main(n_requests: int = 40, smoke: bool = False,
         json_out: Optional[str] = None) -> None:
    seeds = SEEDS[:1] if smoke else SEEDS
    rows = {}
    base: Optional[dict] = None
    for arm in ARMS:
        t0 = time.perf_counter()
        row = _one(arm, n_requests, seeds=seeds)
        us = (time.perf_counter() - t0) * 1e6
        rows[arm] = row
        if arm == "no_fault":
            base = row
            emit("faults.no_fault", us,
                 f"ttft_s={row['mean_ttft_s']:.3f};"
                 f"p99_s={row['p99_ttft_s']:.3f};"
                 f"goodput={row['goodput_tok_s']:.1f}")
        else:
            assert base is not None
            emit(f"faults.{arm}", us,
                 f"ttft_s={row['mean_ttft_s']:.3f};"
                 f"ttft_vs_healthy_x="
                 f"{row['mean_ttft_s'] / max(base['mean_ttft_s'], 1e-9):.2f};"
                 f"kills={row['replica_kills']};"
                 f"redispatched={row['n_redispatched']};"
                 f"retries={row['n_retries']};shed={row['n_shed']}")

    if json_out:
        doc = {
            "benchmark": "fault_tolerance_arms",
            "model": LLAMA2_7B.arch_id,
            "hw": L20.name,
            "n_requests": n_requests,
            "n_replicas": N_REPLICAS,
            "workload": WORKLOAD,
            "seeds": list(SEEDS),
            "arms": {arm: {"fault_plan": spec,
                           "liveness_timeout": liveness}
                     for arm, (spec, liveness) in ARMS.items()},
            "results": rows,
        }
        with open(json_out, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
            f.write("\n")


if __name__ == "__main__":
    ap_smoke = "--smoke" in sys.argv[1:]
    if ap_smoke:
        main(n_requests=8, smoke=True)
    else:
        main(n_requests=120, json_out="BENCH_faults.json")
