"""Deadline-aware KV competition benchmark: interactive-vs-batch mix
under device-pool overload, three scheduler arms per load level.

The workload is the KV-competition shape of arXiv 2503.13773 distilled
to its mechanism: WAVES of long-output batch requests (lax SLOs) arrive
near-simultaneously and park their growing KV on the small device pool,
while a Poisson stream of short interactive requests (tight first-token
deadlines, priority 1) lands mid-wave and must compete for blocks. Load
is swept as the batch wave size — past ~6 concurrent batch decoders the
pool is saturated when the interactive request arrives.

Arms (same traces, three schedulers):

  off       FCFS admission, no preemption — the pre-PR scheduler;
  deadline  `deadline` admission only: EDF with bounded priority aging
            reorders the waiting queue but never touches running work;
  preempt   deadline admission + lossless preemption: the controller
            pauses batch KV to HOST (layer-wise, zero recompute) and
            resumes it when the interactive burst passes.

What the committed artifact (`BENCH_preemption.json`, 24 batch + 12
interactive x 3 seeds, llama2-7b @ L20, 160-block pool) shows:

  * at overload (wave 6/8) the interactive deadline-violation rate
    falls 0.67/0.72 (off) -> 0.19 (deadline ordering) -> 0.00
    (preemption), p99 interactive TTFT from ~13s to <1s;
  * batch goodput pays < 1% for it (129.5 vs 130.4 tok/s at wave 6):
    paused KV resumes losslessly, so the only batch cost is the PCIe
    round trip, priced against victims' own deadline slack;
  * preemptions > 0 only in the `preempt` arm, and every request in
    every arm still finishes its full output (losslessness is asserted
    here, not just in the test suite).

    PYTHONPATH=src python benchmarks/preemption.py
"""
from __future__ import annotations

import json
import os
import random
import sys
import time
from typing import List, Optional

if __package__ in (None, ""):  # `python benchmarks/preemption.py`
    sys.path.insert(
        0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.common import emit
from repro.configs.llama2_7b import CONFIG as LLAMA2_7B
from repro.serving.costmodel import L20
from repro.serving.request import Request
from repro.serving.scheduler import ServeConfig
from repro.serving.sim import ServingSimulator, SimMetrics

NUM_DEVICE_BLOCKS = 160        # saturated by ~6 concurrent batch decoders
WAVE_SIZES = [4, 6, 8]         # load sweep: batch requests per wave
SEEDS = (3, 7, 13)             # pooled per arm (SimMetrics.merge)
ARMS = {
    "off": dict(admission="fcfs", preemption=False),
    "deadline": dict(admission="deadline", preemption=False),
    "preempt": dict(admission="deadline", preemption=True),
}


def kv_competition(n_batch: int, n_interactive: int, wave_size: int,
                   seed: int, wave_every: float = 6.0) -> List[Request]:
    """Batch waves + a tight-deadline interactive Poisson stream.

    Batch: `wave_size` requests arrive within 0.3s of each wave start
    (prompt ~400 tokens +-25%, 300 output tokens, lax 60s/10s SLOs) —
    long decodes whose KV occupies the pool. Interactive: Poisson at
    1 req/s from t=2 (prompt ~300 +-25%, 40 output tokens, 1s
    first-token deadline, priority 1) — landing while a wave holds the
    blocks. Arrival jitter and prompt lengths re-draw per seed."""
    rng = random.Random(seed)
    reqs: List[Request] = []
    i = wave = 0
    while i < n_batch:
        base = wave * wave_every
        for _ in range(min(wave_size, n_batch - i)):
            reqs.append(Request(
                rid=f"b{i}", prompt_len=int(400 * rng.uniform(0.75, 1.25)),
                output_len=300, arrival=base + rng.uniform(0.0, 0.3),
                priority=0, ttft_slo=60.0, tpot_slo=10.0))
            i += 1
        wave += 1
    t = 2.0
    for j in range(n_interactive):
        t += rng.expovariate(1.0)
        reqs.append(Request(
            rid=f"i{j}", prompt_len=int(300 * rng.uniform(0.75, 1.25)),
            output_len=40, arrival=t, priority=1,
            ttft_slo=1.0, tpot_slo=0.5))
    reqs.sort(key=lambda r: (r.arrival, r.rid))
    return reqs


def _one(arm_kw: dict, wave_size: int, n_batch: int, n_interactive: int,
         seeds=SEEDS) -> dict:
    parts, n_preempted, n_resumed = [], 0, 0
    for seed in seeds:
        sc = ServeConfig.for_sim(policy="layerkv", chunked=True,
                                 num_device_blocks=NUM_DEVICE_BLOCKS,
                                 block_size=16, **arm_kw)
        sim = ServingSimulator(LLAMA2_7B, L20, sc)
        m = sim.run(kv_competition(n_batch, n_interactive, wave_size, seed))
        # losslessness is part of the benchmark's claim, not just CI's
        assert all(r.tokens_out == r.output_len for r in sim.done)
        sim.finish()
        parts.append(m)
        n_preempted += sim.core.n_preempted
        n_resumed += sim.core.n_resumed
    m = SimMetrics.merge(parts)
    rep = m.class_report()
    return {
        "preemptions": n_preempted,
        "resumes": n_resumed,
        # SimMetrics.preemptions pools recompute + lossless events; the
        # lossless ones are counted separately above
        "recompute_preemptions": m.preemptions - n_preempted,
        "n_finished": m.n_requests,
        "goodput_tok_s": m.goodput,
        "by_class": {
            {0: "batch", 1: "interactive"}[k]: {
                "n": v["n"],
                "mean_ttft_s": v["mean_ttft"],
                "p99_ttft_s": v["p99_ttft"],
                "p99_tbt_s": v["p99_tbt"],
                "deadline_violation_rate": v["deadline_violation_rate"],
                "goodput_tok_s": v["goodput"],
            } for k, v in rep.items()},
    }


def main(n_requests: int = 100, smoke: bool = False,
         json_out: Optional[str] = None) -> None:
    waves = [6] if smoke else WAVE_SIZES
    seeds = SEEDS[:1] if smoke else SEEDS
    n_batch = min(max(n_requests * 2 // 3, 6), 24)
    n_int = min(max(n_requests - n_batch, 3), 12)
    rows: dict = {}
    for wave in waves:
        t0 = time.perf_counter()
        arms = {name: _one(kw, wave, n_batch, n_int, seeds=seeds)
                for name, kw in ARMS.items()}
        us = (time.perf_counter() - t0) * 1e6
        rows[wave] = arms
        off = arms["off"]["by_class"].get("interactive", {})
        pre = arms["preempt"]["by_class"].get("interactive", {})
        bat0 = arms["off"]["by_class"].get("batch", {})
        bat2 = arms["preempt"]["by_class"].get("batch", {})
        emit(f"preemption.wave{wave}", us,
             f"off_int_viol={off.get('deadline_violation_rate', 0):.2f};"
             f"preempt_int_viol={pre.get('deadline_violation_rate', 0):.2f};"
             f"off_int_p99ttft_s={off.get('p99_ttft_s', 0):.2f};"
             f"preempt_int_p99ttft_s={pre.get('p99_ttft_s', 0):.2f};"
             f"preemptions={arms['preempt']['preemptions']};"
             f"batch_goodput_ratio="
             f"{bat2.get('goodput_tok_s', 0) / max(bat0.get('goodput_tok_s', 0), 1e-9):.3f}")

    if json_out:
        doc = {
            "benchmark": "preemption_kv_competition",
            "model": LLAMA2_7B.arch_id,
            "hw": L20.name,
            "num_device_blocks": NUM_DEVICE_BLOCKS,
            "n_batch": n_batch,
            "n_interactive": n_int,
            "workload": "kv_competition waves (see benchmarks/preemption.py)",
            "seeds": list(seeds),
            "arms": {k: dict(v) for k, v in ARMS.items()},
            "by_wave_size": rows,
        }
        with open(json_out, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
            f.write("\n")


if __name__ == "__main__":
    main(n_requests=36, json_out="BENCH_preemption.json")
