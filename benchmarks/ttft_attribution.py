"""TTFT attribution sweep: WHERE time-to-first-token goes, by cause.

Figure 1 shows queuing delay dominating TTFT beyond ~1k context under
the vLLM baseline; this benchmark reproduces that claim from the
tracer's EXACT per-request decomposition instead of the two coarse
`queuing`/`prefill` stamps. Each (policy, context) cell runs the fig1
methodology (Llama2-7B on one L20, 1 req/s, output 512) with
`ServeConfig.trace` on, pools every finished request's
`Tracer.ttft_breakdown` — a cause-labelled partition whose intervals
sum to the measured TTFT bit-for-bit (asserted inline per cell) — and
reports the share of TTFT each cause group explains:

  queuing   arrival_sync + every gate:* cause + preempted +
            recompute_requeue (time the request was runnable but not
            running)
  prefill   prefill compute, including the layer-offload overlap
  stall     prefill_stall (chunk queue, no chunk this iteration) +
            recompute_lost (decode discarded by a recompute preemption)

and, the headline, the BLOCK-CONTENTION slice of queuing — the causes
that exist only because KV blocks were scarce (`gate:device_blocks`,
plus the recompute-preemption fallout `recompute_lost` /
`recompute_requeue` that block scarcity triggers).

What the committed artifact (`BENCH_ttft_attribution.json`) pins:
under `vllm` the block-contention share of TTFT RISES with context
(~0 at 512 tokens -> ~99% at 2048+: device blocks for all L layers
must be free before a prefill starts, so long prompts serialize behind
each other's KV) while under `layerkv` it stays ~0 at EVERY context —
the layer-wise gate admits on the retained-layer need and the paper's
Figure-1 blowup disappears. Past the saturation knee both arms spend
most of TTFT "queuing" in aggregate (1 req/s exceeds single-L20
capacity at long context), but the traces show they queue on different
gates at order-of-magnitude different TTFTs: vllm on the block gate at
664 s mean (ctx 2048), layerkv on the Alg.1 SLO pacing budget at 37 s.
That gate shift, not a faster prefill, is the paper's TTFT win — and
only cause-level attribution can show it.

    PYTHONPATH=src python benchmarks/ttft_attribution.py [--smoke]
"""
from __future__ import annotations

import json
import os
import sys
import time
from typing import Dict, Optional

if __package__ in (None, ""):  # `python benchmarks/ttft_attribution.py`
    sys.path.insert(
        0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.common import emit
from repro.configs.llama2_7b import CONFIG as LLAMA2_7B
from repro.serving.costmodel import L20
from repro.serving.scheduler import ServeConfig
from repro.serving.sim import ServingSimulator
from repro.serving.workload import fixed_length

CTX = [128, 512, 1024, 2048, 4096, 8192]
POLICIES = ("vllm", "layerkv")

_QUEUE = ("arrival_sync", "preempted", "recompute_requeue")
_STALL = ("prefill_stall", "recompute_lost")
# TTFT spent ONLY because KV blocks were scarce: the all-layer device
# gate, plus the recompute-preemption fallout that gate pressure causes
_BLOCK = ("gate:device_blocks", "recompute_lost", "recompute_requeue")


def _group(cause: str) -> str:
    if cause in _QUEUE or cause.startswith("gate:"):
        return "queuing"
    if cause in _STALL:
        return "stall"
    return "prefill"


def _one(policy: str, ctx: int, n: int) -> Dict[str, object]:
    sim = ServingSimulator(
        LLAMA2_7B, L20, ServeConfig.for_sim(policy=policy, trace=True))
    m = sim.run(fixed_length(n, ctx, 512, rate=1.0, seed=1))
    bks = sim.core.tracer.breakdowns()
    by_cause: Dict[str, float] = {}
    err = 0.0
    for r in sim.done:
        b = bks[r.rid]
        err = max(err, abs(sum(b.values()) - r.ttft))
        for cause, dt in b.items():
            by_cause[cause] = by_cause.get(cause, 0.0) + dt
    # the benchmark's numbers are only meaningful if the partition is
    # exact — the same contract tests/test_obs.py pins, asserted per cell
    assert err < 1e-9, f"{policy}/ctx{ctx}: partition off by {err}"
    total = sum(by_cause.values())
    shares = {"queuing": 0.0, "prefill": 0.0, "stall": 0.0}
    for cause, dt in by_cause.items():
        shares[_group(cause)] += dt / max(total, 1e-12)
    block = sum(by_cause.get(c, 0.0) for c in _BLOCK) \
        / max(total, 1e-12)
    return {
        "mean_ttft_s": m.mean_ttft,
        "p99_ttft_s": m.p99_ttft,
        "queuing_share": shares["queuing"],
        "block_contention_share": block,
        "prefill_share": shares["prefill"],
        "stall_share": shares["stall"],
        "by_cause_s": {c: by_cause[c] for c in sorted(by_cause)},
        "max_partition_err_s": err,
    }


def main(n_requests: int = 100, smoke: bool = False,
         json_out: Optional[str] = None) -> None:
    ctxs = CTX[:2] if smoke else CTX
    results: Dict[str, Dict[str, dict]] = {}
    for policy in POLICIES:
        results[policy] = {}
        for ctx in ctxs:
            t0 = time.perf_counter()
            row = _one(policy, ctx, n_requests)
            us = (time.perf_counter() - t0) * 1e6
            results[policy][str(ctx)] = row
            emit(f"ttft_attr.{policy}.ctx{ctx}", us,
                 f"ttft_s={row['mean_ttft_s']:.3f};"
                 f"queue_share={row['queuing_share']:.3f};"
                 f"block_share={row['block_contention_share']:.3f};"
                 f"prefill_share={row['prefill_share']:.3f};"
                 f"stall_share={row['stall_share']:.3f}")

    if json_out:
        doc = {
            "benchmark": "ttft_attribution",
            "model": LLAMA2_7B.arch_id,
            "hw": L20.name,
            "n_requests": n_requests,
            "rate_req_s": 1.0,
            "output_len": 512,
            "context_lengths": ctxs,
            "cause_groups": {
                "queuing": list(_QUEUE) + ["gate:*"],
                "block_contention": list(_BLOCK),
                "prefill": ["prefill"],
                "stall": list(_STALL),
            },
            "results": results,
        }
        with open(json_out, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
            f.write("\n")


if __name__ == "__main__":
    if "--smoke" in sys.argv[1:]:
        main(n_requests=8, smoke=True)
    else:
        main(json_out="BENCH_ttft_attribution.json")
