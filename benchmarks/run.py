"""Benchmark harness entry point — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick]

Prints ``name,us_per_call,derived`` CSV rows (us_per_call = harness wall
time for that experiment; `derived` carries the figure's metrics).
"""
import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer requests per experiment")
    ap.add_argument("--only", default="",
                    help="comma list: fig1,fig4,fig5,fig6,fig8,kernels")
    args = ap.parse_args()
    n = 40 if args.quick else 100
    only = set(args.only.split(",")) if args.only else None

    from benchmarks import (fig1_motivation, fig4_context_sweep,
                            fig5_parallelism, fig6_fig7_arrival, fig8_slo,
                            kernels_micro)

    print("name,us_per_call,derived")
    if not only or "fig1" in only:
        fig1_motivation.main(n_requests=n)
    if not only or "fig4" in only:
        fig4_context_sweep.main(n_requests=n)
    if not only or "fig5" in only:
        fig5_parallelism.main(n_requests=max(n - 20, 30))
    if not only or "fig6" in only:
        fig6_fig7_arrival.main(n_requests=n + 50 if not args.quick else n)
    if not only or "fig8" in only:
        fig8_slo.main(n_requests=n + 50 if not args.quick else n)
    if not only or "kernels" in only:
        kernels_micro.main()


if __name__ == "__main__":
    main()
