"""Benchmark harness entry point — one function per paper table/figure.

    PYTHONPATH=src python benchmarks/run.py [--quick|--smoke]

Prints ``name,us_per_call,derived`` CSV rows (us_per_call = harness wall
time for that experiment; `derived` carries the figure's metrics).

``--smoke`` runs every figure script at toy scale (a few requests, two
sweep points each) so CI can catch perf-script rot in minutes.
"""
import argparse
import os
import sys

# allow `python benchmarks/run.py` from the repo root (the CI invocation)
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer requests per experiment")
    ap.add_argument("--smoke", action="store_true",
                    help="toy scale: CI guard that every script still runs")
    ap.add_argument("--only", default="",
                    help="comma list: fig1,fig4,fig5,fig6,fig8,prefix,"
                         "fused,kernels,cluster,preemption,faults,ttft")
    args = ap.parse_args()
    n = 40 if args.quick else 100
    if args.smoke:
        n = 8
    smoke = args.smoke
    only = set(args.only.split(",")) if args.only else None

    from benchmarks import (cluster, faults, fig1_motivation,
                            fig4_context_sweep, fig5_parallelism,
                            fig6_fig7_arrival, fig8_slo, fused_step,
                            kernels_micro, preemption, prefix_cache,
                            ttft_attribution)

    print("name,us_per_call,derived")
    if not only or "fig1" in only:
        fig1_motivation.main(n_requests=n, smoke=smoke)
    if not only or "fig4" in only:
        fig4_context_sweep.main(n_requests=n, smoke=smoke)
    if not only or "fig5" in only:
        fig5_parallelism.main(n_requests=max(n - 20, 8), smoke=smoke)
    if not only or "fig6" in only:
        fig6_fig7_arrival.main(
            n_requests=n + 50 if not (args.quick or smoke) else n,
            smoke=smoke)
    if not only or "fig8" in only:
        fig8_slo.main(n_requests=n + 50 if not (args.quick or smoke) else n,
                      smoke=smoke)
    if not only or "prefix" in only:
        prefix_cache.main(n_requests=n, smoke=smoke)
    if not only or "fused" in only:
        fused_step.main(smoke=smoke)
    if not only or "cluster" in only:
        cluster.main(n_requests=n + 100 if not (args.quick or smoke) else n,
                     smoke=smoke)
    if not only or "preemption" in only:
        preemption.main(n_requests=36 if not (args.quick or smoke) else n,
                        smoke=smoke)
    if not only or "faults" in only:
        # repro-lint: disable=FAULT001 -- `faults` here is the benchmark
        # module, not a FaultPlan hook; the "only" test above is the guard
        faults.main(n_requests=40 if not (args.quick or smoke) else n,
                    smoke=smoke)
    if not only or "ttft" in only:
        ttft_attribution.main(n_requests=n, smoke=smoke)
    if not only or "kernels" in only:
        kernels_micro.main(smoke=smoke)


if __name__ == "__main__":
    main()
