"""Paper Figures 6+7: LayerKV vs vLLM across request arrival rates on the
ShareGPT-like workload — mean TTFT (Fig.6) and P99 TTFT (Fig.7) — plus a
layerkv+chunked arm (chunked prefill with mixed batching). The P99 row is
where chunking earns its keep: at high arrival rates the chunked arm's
tail TTFT sits below both exclusive-prefill baselines.
"""
from __future__ import annotations

import time

from benchmarks.common import emit
from repro.configs.llama2_7b import CONFIG as LLAMA2_7B
from repro.serving.costmodel import L20
from repro.serving.scheduler import ServeConfig
from repro.serving.sim import ServingSimulator
from repro.serving.workload import sharegpt_like

RATES = [2.0, 4.0, 8.0, 12.0, 16.0]


def main(n_requests: int = 300, smoke: bool = False) -> None:
    for rate in RATES[:2] if smoke else RATES:
        t0 = time.perf_counter()
        mk = lambda rate=rate: sharegpt_like(
            n_requests, rate=rate, seed=7)
        mv = ServingSimulator(LLAMA2_7B, L20,
                              ServeConfig.for_sim(policy="vllm")).run(mk())
        ml = ServingSimulator(LLAMA2_7B, L20,
                              ServeConfig.for_sim(policy="layerkv")).run(mk())
        mc = ServingSimulator(LLAMA2_7B, L20,
                              ServeConfig.for_sim(policy="layerkv",
                                        chunked=True)).run(mk())
        us = (time.perf_counter() - t0) * 1e6
        emit(f"fig6.rate{rate:g}", us,
             f"vllm_mean_ttft_s={mv.mean_ttft:.3f};"
             f"lkv_mean_ttft_s={ml.mean_ttft:.3f};"
             f"lkv_chunked_mean_ttft_s={mc.mean_ttft:.3f};"
             f"mean_speedup_x={mv.mean_ttft/max(ml.mean_ttft,1e-9):.2f};"
             f"thr_gap_pct={(1-ml.throughput/max(mv.throughput,1e-9))*100:.1f}")
        emit(f"fig7.rate{rate:g}", us,
             f"vllm_p99_ttft_s={mv.p99_ttft:.3f};"
             f"lkv_p99_ttft_s={ml.p99_ttft:.3f};"
             f"lkv_chunked_p99_ttft_s={mc.p99_ttft:.3f};"
             f"p99_speedup_x={mv.p99_ttft/max(ml.p99_ttft,1e-9):.2f};"
             f"chunked_p99_speedup_x={mv.p99_ttft/max(mc.p99_ttft,1e-9):.2f}")


if __name__ == "__main__":
    main()
