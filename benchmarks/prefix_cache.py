"""Prefix-cache benchmark: TTFT vs prefix-share ratio (this repo's
extension beyond the paper — heavy shared-system-prompt traffic), plus
the admission-ordering comparison under congestion.

Three arms per share ratio, all Llama2-7B on L20 at a congested arrival
rate:

  vllm              exclusive prefill, request-wise allocation (baseline)
  layerkv_chunked   the PR 1 arm: layer-wise + chunked prefill, no sharing
  layerkv_prefix    layerkv_chunked + ref-counted cross-request prefix
                    caching (content-addressed blocks, COW tails)

A second sweep (``admission``) pits the two `AdmissionPolicy`
implementations against each other on the layerkv_prefix arm, on a
congested mixed workload (30% cache-cold traffic): `prefix_aware`
admits cache-hitting requests first within a bounded aging window, so
mean TTFT drops vs strict `fcfs` while every cache-miss request still
gets served (max/mean miss TTFT reported — the no-starvation evidence).

``main(json_out=...)`` dumps the sweep to JSON; `BENCH_prefix_cache.json`
in the repo root is that artifact, committed so future PRs can diff the
perf trajectory. Per-arm prefix-hit-rate is reported (token-granular).

    PYTHONPATH=src python benchmarks/prefix_cache.py
"""
from __future__ import annotations

import json
import os
import statistics
import sys
import time
from typing import Optional

if __package__ in (None, ""):  # `python benchmarks/prefix_cache.py`
    sys.path.insert(
        0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.common import emit
from repro.configs.llama2_7b import CONFIG as LLAMA2_7B
from repro.serving.costmodel import L20
from repro.serving.scheduler import ServeConfig
from repro.serving.sim import ServingSimulator
from repro.serving.workload import shared_prefix

SHARE_RATIOS = [0.0, 0.25, 0.5, 0.75, 0.9]
ARMS = {
    "vllm": dict(policy="vllm", chunked=False, prefix_cache=False),
    "layerkv_chunked": dict(policy="layerkv", chunked=True,
                            prefix_cache=False),
    "layerkv_prefix": dict(policy="layerkv", chunked=True,
                           prefix_cache=True),
}

# congested mixed workload for the admission-ordering comparison
ADMISSION_RATE = 8.0
ADMISSION_UNIQUE_FRAC = 0.3
ADMISSION_AGE_FRAC = 2.0


def _one(arm_kw: dict, n: int, ratio: float, scenario: str):
    reqs = shared_prefix(n, rate=2.0, scenario=scenario, share_ratio=ratio,
                         prompt_len=1024, output_len=256, seed=13)
    return ServingSimulator(
        LLAMA2_7B, L20, ServeConfig.for_sim(**arm_kw)).run(reqs)


def _admission_arm(admission: str, n: int):
    reqs = shared_prefix(n, rate=ADMISSION_RATE, scenario="system_prompt",
                         share_ratio=0.5, prompt_len=1024, output_len=256,
                         seed=13, unique_frac=ADMISSION_UNIQUE_FRAC)
    sim = ServingSimulator(LLAMA2_7B, L20, ServeConfig.for_sim(
        policy="layerkv", chunked=True, prefix_cache=True,
        admission=admission, admission_age_frac=ADMISSION_AGE_FRAC))
    m = sim.run(reqs)
    miss = [r.ttft for r in sim.done if r.cached_prompt_len == 0]
    return {
        "mean_ttft_s": m.mean_ttft,
        "p99_ttft_s": m.p99_ttft,
        "prefix_hit_rate": m.prefix_hit_rate,
        "n_finished": m.n_requests,
        "n_miss": len(miss),
        "miss_mean_ttft_s": statistics.mean(miss) if miss else 0.0,
        "miss_max_ttft_s": max(miss) if miss else 0.0,
    }


def main(n_requests: int = 100, smoke: bool = False,
         json_out: Optional[str] = None,
         scenario: str = "system_prompt") -> None:
    ratios = [0.5] if smoke else SHARE_RATIOS
    rows = {}
    for ratio in ratios:
        t0 = time.perf_counter()
        ms = {name: _one(kw, n_requests, ratio, scenario)
              for name, kw in ARMS.items()}
        us = (time.perf_counter() - t0) * 1e6
        mb, mc, mp = ms["vllm"], ms["layerkv_chunked"], ms["layerkv_prefix"]
        emit(f"prefix_cache.share{int(ratio * 100)}", us,
             f"vllm_ttft_s={mb.mean_ttft:.3f};"
             f"lkv_chunked_ttft_s={mc.mean_ttft:.3f};"
             f"lkv_prefix_ttft_s={mp.mean_ttft:.3f};"
             f"prefix_speedup_x={mc.mean_ttft / max(mp.mean_ttft, 1e-9):.2f};"
             f"hit_rate={mp.prefix_hit_rate:.2f};"
             f"prefix_tpot_ms={mp.mean_tpot * 1e3:.1f}")
        rows[ratio] = {
            name: {"mean_ttft_s": m.mean_ttft, "p99_ttft_s": m.p99_ttft,
                   "mean_tpot_ms": m.mean_tpot * 1e3,
                   "prefix_hit_rate": m.prefix_hit_rate,
                   "prefix_hit_tokens": m.prefix_hit_tokens,
                   "preemptions": m.preemptions}
            for name, m in ms.items()
        }

    # ---- admission ordering under congestion (prefix_aware vs fcfs) ------
    t0 = time.perf_counter()
    adm = {name: _admission_arm(name, n_requests)
           for name in ("fcfs", "prefix_aware")}
    us = (time.perf_counter() - t0) * 1e6
    f, p = adm["fcfs"], adm["prefix_aware"]
    emit("prefix_cache.admission", us,
         f"fcfs_ttft_s={f['mean_ttft_s']:.3f};"
         f"prefix_aware_ttft_s={p['mean_ttft_s']:.3f};"
         f"admission_speedup_x="
         f"{f['mean_ttft_s'] / max(p['mean_ttft_s'], 1e-9):.2f};"
         f"miss_max_ttft_s={p['miss_max_ttft_s']:.2f};"
         f"served={p['n_finished']}")

    if json_out:
        doc = {
            "benchmark": "prefix_cache_share_sweep",
            "model": LLAMA2_7B.arch_id,
            "hw": L20.name,
            "scenario": scenario,
            "n_requests": n_requests,
            "arms": list(ARMS),
            "by_share_ratio": rows,
            "admission_under_congestion": {
                "workload": {
                    "scenario": "system_prompt", "share_ratio": 0.5,
                    "rate": ADMISSION_RATE, "prompt_len": 1024,
                    "output_len": 256,
                    "unique_frac": ADMISSION_UNIQUE_FRAC,
                    "admission_age_frac": ADMISSION_AGE_FRAC,
                },
                "arms": adm,
            },
        }
        with open(json_out, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
            f.write("\n")


if __name__ == "__main__":
    main(json_out="BENCH_prefix_cache.json")
