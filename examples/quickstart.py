"""Quickstart: build a model, train a few steps, then serve it through a
live `ServingSession` (submit online, stream tokens per iteration) — all
on CPU in under a minute.

    PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.serving.engine import LayerKVEngine
from repro.serving.request import Request
from repro.serving.scheduler import ServeConfig
from repro.serving.session import ServingSession
from repro.training.data import DataConfig
from repro.training.train_loop import train


def main():
    cfg = dataclasses.replace(get_smoke_config("granite-3-2b"),
                              dtype="float32")
    print(f"== arch {cfg.arch_id}: {cfg.n_layers}L d={cfg.d_model} "
          f"{cfg.n_heads}H(kv={cfg.n_kv_heads})")

    # --- 1. train a few steps on the synthetic pipeline ---------------------
    print("\n== training 60 steps ==")
    res = train(cfg, steps=60, dc=DataConfig(batch_size=8, seq_len=64),
                log_every=20)
    print(f"loss: {res.losses[0]:.3f} -> {res.final_loss:.3f}")

    # --- 2. serve requests through an online session ------------------------
    print("\n== serving 6 requests (layer-wise KV offloading) ==")
    rng = np.random.RandomState(0)
    eng = LayerKVEngine(cfg, None,
                        ServeConfig.for_engine(policy="layerkv",
                                               num_device_blocks=24,
                                               num_host_blocks=256,
                                               block_size=8),
                        rng=jax.random.PRNGKey(0))
    session = ServingSession(eng)
    handles = [
        session.submit(Request(rid=f"r{i}", prompt_len=32, output_len=8,
                               prompt=[int(t) for t in
                                       rng.randint(0, cfg.vocab_size, 32)]),
                       arrival=i * 0.01)
        for i in range(6)]

    # stream the first request token-by-token (the rest decode alongside)
    print("  streaming r0:", end="", flush=True)
    for tok in session.stream(handles[0]):
        print(f" {tok}", end="", flush=True)
    print()
    done = session.drain()                 # run the rest to completion
    for r in done:
        print(f"  {r.rid}: {len(r.generated)} tokens, "
              f"ttft={r.ttft*1e3:.1f}ms -> {r.generated[:6]}...")
    off = [t for t in eng.off.ledger.log if t.kind == "offload"]
    rel = [t for t in eng.off.ledger.log if t.kind == "reload"]
    print(f"layer-wise KV transfers: {len(off)} offloads, {len(rel)} reloads")


if __name__ == "__main__":
    main()
