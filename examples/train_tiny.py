"""Train a ~100M-parameter decoder for a few hundred steps on CPU.

    PYTHONPATH=src python examples/train_tiny.py [--steps 300]

(At ~3k tokens/s on a laptop-class CPU this takes a few minutes; pass
--steps 50 for a quick look.)
"""
import argparse

from repro.configs.base import ModelConfig
from repro.training.data import DataConfig
from repro.training.train_loop import train

# ~100M params: 12L d=768 12H GQA kv=4, SwiGLU, 32k vocab
TINY_100M = ModelConfig(
    arch_id="tiny-100m", family="dense", n_layers=12, d_model=768,
    n_heads=12, n_kv_heads=4, d_ff=2048, vocab_size=32000,
    pos_emb="rope", dtype="float32", source="examples/train_tiny")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="/tmp/tiny100m_ckpt")
    args = ap.parse_args()

    print(f"params ~= {TINY_100M.param_count()/1e6:.0f}M")
    res = train(TINY_100M, steps=args.steps,
                dc=DataConfig(batch_size=args.batch, seq_len=args.seq),
                ckpt_path=args.ckpt, ckpt_every=100, log_every=20)
    print(f"final loss {res.final_loss:.4f} "
          f"({res.tokens_per_s:.0f} tokens/s); checkpoint at {args.ckpt}")


if __name__ == "__main__":
    main()
