"""End-to-end serving driver: LayerKV vs request-wise (vLLM-style) policy
on the SAME model and workload, with real JAX execution + paged KV pools,
driven through `ServingSession` (online submit + drain).

Demonstrates the paper's two headline properties at smoke scale:
  1. losslessness — identical generated tokens under forced offloading;
  2. earlier admission — layer-wise allocation starts prefills sooner when
     the device pool is tight.

    PYTHONPATH=src python examples/serve_comparison.py
"""
import dataclasses
import statistics

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.serving.engine import LayerKVEngine
from repro.serving.request import Request
from repro.serving.scheduler import ServeConfig
from repro.serving.session import ServingSession


def make_workload(cfg, n=10, seed=0):
    rng = np.random.RandomState(seed)
    reqs = []
    for i in range(n):
        plen = int(rng.randint(32, 56))
        reqs.append(Request(
            rid=f"r{i}", prompt_len=plen, output_len=int(rng.randint(12, 24)),
            arrival=i * 0.002,
            prompt=[int(t) for t in rng.randint(0, cfg.vocab_size, plen)]))
    return reqs


def run(cfg, policy, blocks, seed=0):
    eng = LayerKVEngine(
        cfg, None,
        ServeConfig.for_engine(policy=policy, num_device_blocks=blocks,
                               num_host_blocks=512, block_size=8),
        rng=jax.random.PRNGKey(7))
    session = ServingSession(eng)
    for r in make_workload(cfg, seed=seed):
        session.submit(r, arrival=r.arrival)
    done = session.drain()
    return eng, {r.rid: r for r in done}


def main():
    cfg = dataclasses.replace(get_smoke_config("granite-3-2b"),
                              dtype="float32")
    # ground truth: request-wise with a roomy pool
    _, truth = run(cfg, "vllm", 1024)
    # tight pool: both policies under pressure
    eng_v, out_v = run(cfg, "vllm", 20)
    eng_l, out_l = run(cfg, "layerkv", 20)

    mismatches = sum(truth[r].generated != out_l[r].generated for r in truth)
    off = [t for t in eng_l.off.ledger.log if t.kind == "offload"]
    rel = [t for t in eng_l.off.ledger.log if t.kind == "reload"]
    print(f"losslessness: {len(truth) - mismatches}/{len(truth)} requests "
          f"identical under {len(off)} offloads / {len(rel)} reloads")

    tv = statistics.mean(r.ttft for r in out_v.values())
    tl = statistics.mean(r.ttft for r in out_l.values())
    print(f"mean TTFT  request-wise: {tv*1e6:10.1f} us")
    print(f"mean TTFT  layer-wise  : {tl*1e6:10.1f} us "
          f"({tv/max(tl,1e-12):.2f}x)")
    assert mismatches == 0


if __name__ == "__main__":
    main()
