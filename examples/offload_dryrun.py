"""LayerKV at pod scale: host-offloaded KV cache via memory kinds.

Lowers the chatglm3-6b decode step twice on the production mesh —
baseline (all KV in HBM) vs LayerKV-style (KV cache placed in
`pinned_host` memory, streamed layer-by-layer by XLA) — and prints the
per-device HBM/host split from `memory_analysis()`. This is the compiled-
scale rendering of the paper's offloading (see DESIGN.md §3).

    PYTHONPATH=src python examples/offload_dryrun.py
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import jax  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.specs import input_specs  # noqa: E402


def lower_decode(offload: bool):
    cfg = get_config("chatglm3-6b")
    mesh = make_production_mesh()
    fn, args, shardings, out_shardings = input_specs(cfg, "decode_32k", mesh)
    donate = (2,)
    if offload:
        p_sh, t_sh, c_sh = shardings

        def to_host(s):
            return s.with_memory_kind("pinned_host")

        keys = ("k", "v")  # offload the KV stacks, keep len/window on device
        c_sh = {k: (to_host(v) if k in keys else v) for k, v in c_sh.items()}
        shardings = (p_sh, t_sh, c_sh)
        # let XLA place outputs (mixed-memory output annotation of scalar
        # leaves trips an XLA RET_CHECK as of jax 0.8) and skip donation
        # across memory kinds
        out_shardings = None
        donate = ()
    with mesh:
        compiled = jax.jit(fn, in_shardings=shardings,
                           out_shardings=out_shardings,
                           donate_argnums=donate).lower(*args).compile()
    return compiled.memory_analysis()


def main():
    from repro.configs import get_config
    from repro.serving.costmodel import CostModel, TPU_V5E

    base = lower_decode(offload=False)
    off = lower_decode(offload=True)
    gib = 2**30
    cfg = get_config("chatglm3-6b")
    cm = CostModel(cfg, TPU_V5E)
    kv_per_chip = cm.kv_bytes(32768) * 128 / 256  # decode_32k batch / chips
    print("chatglm3-6b decode_32k on 16x16 (256 chips):")
    print(f"  baseline lowers+compiles: args/chip "
          f"{base.argument_size_in_bytes/gib:6.2f} GiB")
    print(f"  layerkv (KV in pinned_host shardings) lowers+compiles: "
          f"args/chip {off.argument_size_in_bytes/gib:6.2f} GiB")
    print(f"  KV cache per chip (the offloadable share): "
          f"{kv_per_chip/gib:.2f} GiB")
    print("  NOTE: the CPU stand-in backend folds pinned_host into one "
          "memory space, so memory_analysis() shows no host split here; "
          "on the TPU target the same in_shardings move the KV stacks to "
          "host DRAM and host_argument_size reports them (the paper's "
          "layer-wise offload at pod scale).")


if __name__ == "__main__":
    main()
