#!/usr/bin/env python3
"""Docs-consistency gate: the user-facing docs must keep up with the
config surface.

Dependency-free on purpose (stdlib `ast` only, no repo imports) so it
runs in any environment — including a CI step before the test deps are
even installed. Checks:

  1. every `ServeConfig` dataclass field (parsed from
     src/repro/serving/scheduler.py) is mentioned in README.md or
     docs/ARCHITECTURE.md;
  2. every admission policy name (class-level `name = "..."` in
     scheduler.py) and every routing policy name (same, in
     src/repro/serving/router.py) is mentioned;
  3. every repro-lint rule id (class-level `rule_id = "..."` in
     tools/analyze/rules.py) is documented;
  4. every trace event type and TTFT-attribution cause (the
     `EVENT_TYPES` / `ATTRIBUTION_CAUSES` tuple literals in
     src/repro/obs/trace.py) is documented — a tracer that emits
     vocabulary the docs don't explain is unreadable;
  5. every relative markdown link in the checked docs points at a file
     that exists (no rotting links).

Exit code 0 = consistent; nonzero prints what is missing.

    python tools/check_docs.py
"""
from __future__ import annotations

import ast
import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
DOCS = [ROOT / "README.md", ROOT / "docs" / "ARCHITECTURE.md"]
SCHEDULER = ROOT / "src" / "repro" / "serving" / "scheduler.py"
ROUTER = ROOT / "src" / "repro" / "serving" / "router.py"
LINT_RULES = ROOT / "tools" / "analyze" / "rules.py"
TRACE = ROOT / "src" / "repro" / "obs" / "trace.py"


def serveconfig_fields(path: Path) -> list:
    """Names of the ServeConfig dataclass fields, in source order."""
    tree = ast.parse(path.read_text())
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef) and node.name == "ServeConfig":
            return [st.target.id for st in node.body
                    if isinstance(st, ast.AnnAssign)
                    and isinstance(st.target, ast.Name)]
    raise SystemExit(f"ServeConfig dataclass not found in {path}")


def policy_names(path: Path) -> list:
    """Class-level `name = "..."` literals — the registry keys of
    AdmissionPolicy / RoutingPolicy subclasses (the '?' base-class
    placeholder is skipped)."""
    tree = ast.parse(path.read_text())
    names = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.ClassDef):
            continue
        for st in node.body:
            if (isinstance(st, ast.Assign)
                    and len(st.targets) == 1
                    and isinstance(st.targets[0], ast.Name)
                    and st.targets[0].id == "name"
                    and isinstance(st.value, ast.Constant)
                    and isinstance(st.value.value, str)
                    and st.value.value != "?"):
                names.append(st.value.value)
    return names


def lint_rule_ids(path: Path) -> list:
    """Class-level `rule_id = "..."` literals of registered repro-lint
    rules (the Rule base's placeholder is skipped)."""
    tree = ast.parse(path.read_text())
    ids = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.ClassDef):
            continue
        for st in node.body:
            if (isinstance(st, ast.Assign)
                    and len(st.targets) == 1
                    and isinstance(st.targets[0], ast.Name)
                    and st.targets[0].id == "rule_id"
                    and isinstance(st.value, ast.Constant)
                    and isinstance(st.value.value, str)
                    and not st.value.value.startswith("RULE")):
                ids.append(st.value.value)
    return ids


def tuple_literal(path: Path, name: str) -> list:
    """String members of a module-level `NAME = ("...", ...)` tuple."""
    tree = ast.parse(path.read_text())
    for node in tree.body:
        if (isinstance(node, ast.Assign)
                and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)
                and node.targets[0].id == name
                and isinstance(node.value, ast.Tuple)):
            return [el.value for el in node.value.elts
                    if isinstance(el, ast.Constant)
                    and isinstance(el.value, str)]
    raise SystemExit(f"tuple literal {name} not found in {path}")


# matches [text](target) but not images/anchors/URLs
_LINK = re.compile(r"(?<!!)\[[^\]]+\]\(([^)#][^)]*)\)")


def broken_links(doc: Path) -> list:
    rel = doc.relative_to(ROOT) if doc.is_relative_to(ROOT) else doc.name
    out = []
    for target in _LINK.findall(doc.read_text()):
        if "://" in target:
            continue
        path = target.split("#", 1)[0]
        if path and not (doc.parent / path).exists():
            out.append(f"{rel}: broken link -> {target}")
    return out


def main() -> int:
    missing_docs = [d for d in DOCS if not d.exists()]
    if missing_docs:
        for d in missing_docs:
            print(f"MISSING DOC: {d.relative_to(ROOT)}")
        return 1

    corpus = "\n".join(d.read_text() for d in DOCS)
    required = {
        "ServeConfig field": serveconfig_fields(SCHEDULER),
        "admission policy": policy_names(SCHEDULER),
        "routing policy": policy_names(ROUTER),
        "repro-lint rule": lint_rule_ids(LINT_RULES),
        "trace event type": tuple_literal(TRACE, "EVENT_TYPES"),
        "TTFT attribution cause": tuple_literal(TRACE,
                                                "ATTRIBUTION_CAUSES"),
    }
    errors = []
    for kind, names in required.items():
        if not names:
            errors.append(f"parser found no {kind} entries — check the "
                          f"source layout assumptions in tools/check_docs.py")
        for n in names:
            # a mention must be the exact token in backticks or a table
            # cell, not a substring of another word
            if not re.search(rf"(?<![A-Za-z0-9_]){re.escape(n)}(?![A-Za-z0-9_])",
                             corpus):
                errors.append(f"undocumented {kind}: {n!r} "
                              f"(add it to README.md or docs/ARCHITECTURE.md)")
    for d in DOCS:
        errors.extend(broken_links(d))

    if errors:
        print(f"docs check FAILED ({len(errors)} problem(s)):")
        for e in errors:
            print(f"  - {e}")
        return 1
    n_fields = len(required["ServeConfig field"])
    print(f"docs check OK: {n_fields} ServeConfig fields, "
          f"{len(required['admission policy'])} admission + "
          f"{len(required['routing policy'])} routing policies, "
          f"{len(required['repro-lint rule'])} lint rules, "
          f"{len(required['trace event type'])} trace event types + "
          f"{len(required['TTFT attribution cause'])} causes documented, "
          f"links resolve.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
