"""repro-lint framework: file contexts, rule base classes, suppression
handling and the runner.

Suppression grammar (a reason after ``--`` is mandatory; the runner
rejects bare disables and flags suppressions that match nothing):

    x = compute()  # repro-lint: disable=JIT001 -- width is pre-bucketed

    # repro-lint: disable=PHASE001 -- pause targets running work only
    if r in self.prefilling:
        ...

    # repro-lint: file-disable=SEAM001 -- generated file

A line-level suppression covers violations on its own line, or — when it
sits in a contiguous block of comment lines — violations on the first
non-comment line below the block.  A file-level suppression covers the
whole file.
"""

from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

SUPPRESS_RE = re.compile(
    r"#\s*repro-lint:\s*(?P<kind>disable|file-disable)="
    r"(?P<ids>[A-Z0-9]+(?:\s*,\s*[A-Z0-9]+)*)"
    r"(?:\s*--\s*(?P<reason>\S.*))?"
)
COMMENT_RE = re.compile(r"^\s*#")


@dataclasses.dataclass
class Violation:
    """One rule hit, reported as ``path:line: RULE message``."""

    rule_id: str
    path: Path
    line: int
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.rule_id} {self.message}"


@dataclasses.dataclass
class Suppression:
    rule_id: str
    path: Path
    line: int            # line the comment itself is on (1-based)
    file_level: bool
    reason: Optional[str]
    covers: int          # line whose violations it covers (line rules)
    used: bool = False


class FileContext:
    """Parsed view of one source file handed to every rule."""

    def __init__(self, path: Path, source: str) -> None:
        self.path = path
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=str(path))


class Rule:
    """Base class: per-file rules override ``check_file``."""

    rule_id = "RULE000"
    description = ""
    project_wide = False

    def interested(self, path: Path) -> bool:
        return path.suffix == ".py"

    def check_file(self, ctx: FileContext) -> List[Violation]:
        return []

    def check_project(
        self, ctxs: Sequence[FileContext]
    ) -> List[Violation]:
        return []

    def violation(
        self, ctx: FileContext, line: int, message: str
    ) -> Violation:
        return Violation(self.rule_id, ctx.path, line, message)


def _covered_line(lines: List[str], idx: int) -> int:
    """Line (1-based) covered by a suppression comment at ``idx``.

    For an own-line comment inside a contiguous comment block, that is
    the first non-comment line below the block; for a trailing comment,
    the line itself.
    """
    if not COMMENT_RE.match(lines[idx]):
        return idx + 1  # trailing comment on a code line
    j = idx
    while j < len(lines) and COMMENT_RE.match(lines[j]):
        j += 1
    return j + 1


def parse_suppressions(ctx: FileContext) -> Tuple[
    List[Suppression], List[Violation]
]:
    """Extract suppressions; malformed ones come back as violations."""
    sups: List[Suppression] = []
    errors: List[Violation] = []
    for i, line in enumerate(ctx.lines):
        m = SUPPRESS_RE.search(line)
        if m is None:
            if "repro-lint:" in line and COMMENT_RE.search(line):
                errors.append(Violation(
                    "LINT000", ctx.path, i + 1,
                    "malformed repro-lint comment (expected "
                    "'# repro-lint: disable=RULE -- reason')"))
            continue
        reason = m.group("reason")
        if not reason:
            errors.append(Violation(
                "LINT000", ctx.path, i + 1,
                "suppression without a reason: append "
                "' -- <why this is safe>'"))
            continue
        file_level = m.group("kind") == "file-disable"
        covers = 0 if file_level else _covered_line(ctx.lines, i)
        for rid in re.split(r"\s*,\s*", m.group("ids")):
            sups.append(Suppression(
                rid, ctx.path, i + 1, file_level, reason, covers))
    return sups, errors


def apply_suppressions(
    violations: List[Violation],
    sups_by_file: Dict[Path, List[Suppression]],
) -> Tuple[List[Violation], List[Violation]]:
    """Filter suppressed hits; also flag suppressions that match nothing."""
    kept: List[Violation] = []
    for v in violations:
        sups = sups_by_file.get(v.path, [])
        hit = False
        for s in sups:
            if s.rule_id != v.rule_id:
                continue
            if s.file_level or s.covers == v.line:
                s.used = True
                hit = True
        if not hit:
            kept.append(v)
    unused: List[Violation] = []
    for sups in sups_by_file.values():
        for s in sups:
            if not s.used:
                unused.append(Violation(
                    "LINT001", s.path, s.line,
                    f"unused suppression for {s.rule_id}: nothing to "
                    "disable here (stale comment?)"))
    return kept, unused


def collect_files(roots: Iterable[str]) -> List[Path]:
    files: List[Path] = []
    for root in roots:
        p = Path(root)
        if p.is_file():
            files.append(p)
        else:
            files.extend(sorted(p.rglob("*.py")))
    return files


def run_rules(
    rules: Sequence[Rule], roots: Iterable[str]
) -> List[Violation]:
    """Parse every file once, run all rules, resolve suppressions."""
    ctxs: List[FileContext] = []
    out: List[Violation] = []
    for path in collect_files(roots):
        try:
            ctxs.append(FileContext(path, path.read_text()))
        except (SyntaxError, UnicodeDecodeError) as exc:
            out.append(Violation(
                "LINT002", path, getattr(exc, "lineno", 1) or 1,
                f"could not parse file: {exc}"))
    sups_by_file: Dict[Path, List[Suppression]] = {}
    for ctx in ctxs:
        sups, errors = parse_suppressions(ctx)
        sups_by_file[ctx.path] = sups
        out.extend(errors)

    raw: List[Violation] = []
    for rule in rules:
        if rule.project_wide:
            raw.extend(rule.check_project(
                [c for c in ctxs if rule.interested(c.path)]))
        else:
            for ctx in ctxs:
                if rule.interested(ctx.path):
                    raw.extend(rule.check_file(ctx))

    kept, unused = apply_suppressions(raw, sups_by_file)
    out.extend(kept)
    out.extend(unused)
    out.sort(key=lambda v: (str(v.path), v.line, v.rule_id))
    return out
