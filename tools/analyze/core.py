"""repro-lint framework: file contexts, rule base classes, suppression
handling and the runner.

Suppression grammar (a reason after ``--`` is mandatory; the runner
rejects bare disables and flags suppressions that match nothing):

    x = compute()  # repro-lint: disable=JIT001 -- width is pre-bucketed

    # repro-lint: disable=PHASE001 -- pause targets running work only
    if r in self.prefilling:
        ...

    # repro-lint: file-disable=SEAM001 -- generated file

A line-level suppression covers violations on its own line, or — when it
sits in a contiguous block of comment lines — violations on the first
non-comment line below the block.  A file-level suppression covers the
whole file.
"""

from __future__ import annotations

import ast
import dataclasses
import io
import json
import re
import tokenize
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

SUPPRESS_RE = re.compile(
    r"#\s*repro-lint:\s*(?P<kind>disable|file-disable)="
    r"(?P<ids>[A-Z0-9]+(?:\s*,\s*[A-Z0-9]+)*)"
    r"(?:\s*--\s*(?P<reason>\S.*))?"
)
COMMENT_RE = re.compile(r"^\s*#")


@dataclasses.dataclass
class Violation:
    """One rule hit, reported as ``path:line: RULE message``."""

    rule_id: str
    path: Path
    line: int
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.rule_id} {self.message}"


@dataclasses.dataclass
class Suppression:
    rule_id: str
    path: Path
    line: int            # line the comment itself is on (1-based)
    file_level: bool
    reason: Optional[str]
    covers: int          # line whose violations it covers (line rules)
    used: bool = False


class FileContext:
    """Parsed view of one source file handed to every rule."""

    def __init__(self, path: Path, source: str) -> None:
        self.path = path
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=str(path))
        # real comments only, from the token stream: suppression-shaped
        # text inside STRING LITERALS (docstrings quoting the grammar,
        # lint tests building fixtures) must not parse as suppressions
        self.comments: Dict[int, str] = {}
        self.comment_only_lines: set = set()
        try:
            toks = tokenize.generate_tokens(io.StringIO(source).readline)
            for tok in toks:
                if tok.type != tokenize.COMMENT:
                    continue
                row, col = tok.start
                self.comments[row] = tok.string
                if not tok.line[:col].strip():
                    self.comment_only_lines.add(row)
        except tokenize.TokenError:
            pass


class Rule:
    """Base class: per-file rules override ``check_file``."""

    rule_id = "RULE000"
    description = ""
    project_wide = False

    def interested(self, path: Path) -> bool:
        return path.suffix == ".py"

    def check_file(self, ctx: FileContext) -> List[Violation]:
        return []

    def check_project(
        self, ctxs: Sequence[FileContext]
    ) -> List[Violation]:
        return []

    def violation(
        self, ctx: FileContext, line: int, message: str
    ) -> Violation:
        return Violation(self.rule_id, ctx.path, line, message)


def _covered_line(ctx: FileContext, row: int) -> int:
    """Line (1-based) covered by a suppression comment on ``row``.

    For an own-line comment inside a contiguous comment block, that is
    the first non-comment line below the block; for a trailing comment,
    the line itself.
    """
    if row not in ctx.comment_only_lines:
        return row  # trailing comment on a code line
    j = row
    while j in ctx.comment_only_lines:
        j += 1
    return j


def parse_suppressions(ctx: FileContext) -> Tuple[
    List[Suppression], List[Violation]
]:
    """Extract suppressions; malformed ones come back as violations."""
    sups: List[Suppression] = []
    errors: List[Violation] = []
    for row in sorted(ctx.comments):
        comment = ctx.comments[row]
        m = SUPPRESS_RE.search(comment)
        if m is None:
            if "repro-lint:" in comment:
                errors.append(Violation(
                    "LINT000", ctx.path, row,
                    "malformed repro-lint comment (expected "
                    "'# repro-lint: disable=RULE -- reason')"))
            continue
        reason = m.group("reason")
        if not reason:
            errors.append(Violation(
                "LINT000", ctx.path, row,
                "suppression without a reason: append "
                "' -- <why this is safe>'"))
            continue
        file_level = m.group("kind") == "file-disable"
        covers = 0 if file_level else _covered_line(ctx, row)
        for rid in re.split(r"\s*,\s*", m.group("ids")):
            sups.append(Suppression(
                rid, ctx.path, row, file_level, reason, covers))
    return sups, errors


def apply_suppressions(
    violations: List[Violation],
    sups_by_file: Dict[Path, List[Suppression]],
) -> Tuple[List[Violation], List[Violation]]:
    """Filter suppressed hits; also flag suppressions that match nothing."""
    kept: List[Violation] = []
    for v in violations:
        sups = sups_by_file.get(v.path, [])
        hit = False
        for s in sups:
            if s.rule_id != v.rule_id:
                continue
            if s.file_level or s.covers == v.line:
                s.used = True
                hit = True
        if not hit:
            kept.append(v)
    unused: List[Violation] = []
    for sups in sups_by_file.values():
        for s in sups:
            if not s.used:
                unused.append(Violation(
                    "LINT001", s.path, s.line,
                    f"unused suppression for {s.rule_id}: nothing to "
                    "disable here (stale comment?)"))
    return kept, unused


def collect_files(roots: Iterable[str]) -> List[Path]:
    """Expand roots to .py files. Directory walks skip any
    ``lint_corpus`` directory found BELOW the root (the known-bad twins
    MUST trip rules — linting them with the tree would fail every
    full-repo run); naming a corpus file or directory directly still
    lints it, which is how the corpus tests drive the rules."""
    files: List[Path] = []
    for root in roots:
        p = Path(root)
        if p.is_file():
            files.append(p)
        else:
            files.extend(sorted(
                f for f in p.rglob("*.py")
                if "lint_corpus" not in f.relative_to(p).parts))
    return files


class ResultCache:
    """Per-file result cache for PER-FILE rules, keyed on the file's
    (mtime_ns, size) and fingerprinted on the analyzer sources
    themselves — editing any rule invalidates everything. Project-wide
    rules (whose result depends on the whole file set) always rerun;
    they are cheap next to the model checker."""

    def __init__(self, path: Path) -> None:
        self.path = path
        self.fp = self._analyzer_fingerprint()
        self.files: Dict[str, dict] = {}
        self.dirty = False
        try:
            data = json.loads(path.read_text())
            if data.get("analyzer") == self.fp:
                self.files = data.get("files", {})
        except (OSError, ValueError):
            pass

    @staticmethod
    def _analyzer_fingerprint() -> str:
        here = Path(__file__).resolve().parent
        parts = []
        for f in sorted(here.glob("*.py")):
            st = f.stat()
            parts.append(f"{f.name}:{st.st_mtime_ns}:{st.st_size}")
        return "|".join(parts)

    @staticmethod
    def _key(path: Path) -> List[int]:
        st = path.stat()
        return [st.st_mtime_ns, st.st_size]

    def get(self, path: Path) -> Optional[List[Violation]]:
        entry = self.files.get(str(path))
        if entry is None or entry["key"] != self._key(path):
            return None
        return [Violation(r, path, ln, msg)
                for r, ln, msg in entry["violations"]]

    def put(self, path: Path, violations: List[Violation]) -> None:
        self.files[str(path)] = {
            "key": self._key(path),
            "violations": [
                [v.rule_id, v.line, v.message] for v in violations]}
        self.dirty = True

    def save(self) -> None:
        if not self.dirty:
            return
        try:
            self.path.write_text(json.dumps(
                {"analyzer": self.fp, "files": self.files}))
        except OSError:
            pass  # read-only checkout: run uncached


def run_rules(
    rules: Sequence[Rule], roots: Iterable[str],
    cache: Optional[ResultCache] = None,
) -> List[Violation]:
    """Parse every file once, run all rules, resolve suppressions."""
    ctxs: List[FileContext] = []
    out: List[Violation] = []
    for path in collect_files(roots):
        try:
            ctxs.append(FileContext(path, path.read_text()))
        except (SyntaxError, UnicodeDecodeError) as exc:
            out.append(Violation(
                "LINT002", path, getattr(exc, "lineno", 1) or 1,
                f"could not parse file: {exc}"))
    sups_by_file: Dict[Path, List[Suppression]] = {}
    for ctx in ctxs:
        sups, errors = parse_suppressions(ctx)
        sups_by_file[ctx.path] = sups
        out.extend(errors)

    raw: List[Violation] = []
    file_rules = [r for r in rules if not r.project_wide]
    for rule in rules:
        if rule.project_wide:
            raw.extend(rule.check_project(
                [c for c in ctxs if rule.interested(c.path)]))
    for ctx in ctxs:
        if not any(r.interested(ctx.path) for r in file_rules):
            continue
        cached = cache.get(ctx.path) if cache is not None else None
        if cached is not None:
            raw.extend(cached)
            continue
        mine: List[Violation] = []
        for rule in file_rules:
            if rule.interested(ctx.path):
                mine.extend(rule.check_file(ctx))
        if cache is not None:
            cache.put(ctx.path, mine)
        raw.extend(mine)
    if cache is not None:
        cache.save()

    kept, unused = apply_suppressions(raw, sups_by_file)
    out.extend(kept)
    out.extend(unused)
    out.sort(key=lambda v: (str(v.path), v.line, v.rule_id))
    return out
