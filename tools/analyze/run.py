#!/usr/bin/env python3
"""repro-lint CLI.

    python tools/analyze/run.py [PATHS...]     # default: src

Exit 0 when clean, 1 when any violation (including malformed or unused
suppressions) survives.  `--list-rules` prints the registered rule ids.

Output formats:  --format=text (default) renders `path:line: RULE msg`;
--format=github emits workflow commands GitHub renders as inline PR
annotations; --json prints a machine-readable array.

Per-file rule results are cached in tools/analyze/.cache.json keyed on
each file's mtime+size (and invalidated whenever any analyzer source
changes), so warm full-repo runs skip the expensive model-checker pass.
`--no-cache` forces everything to rerun.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from pathlib import Path

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

try:
    from tools.analyze.core import ResultCache, run_rules
    from tools.analyze.rules import ALL_RULES
except ImportError:
    from core import ResultCache, run_rules
    from rules import ALL_RULES

CACHE_PATH = Path(__file__).resolve().parent / ".cache.json"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="repro-lint", description=__doc__)
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files or directories to lint (default: src)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print registered rule ids and exit")
    ap.add_argument("--format", choices=("text", "github"),
                    default="text", dest="fmt",
                    help="text (default) or GitHub workflow commands")
    ap.add_argument("--json", action="store_true",
                    help="print violations as a JSON array (overrides "
                         "--format)")
    ap.add_argument("--no-cache", action="store_true",
                    help="disable the per-file result cache")
    args = ap.parse_args(argv)
    if args.list_rules:
        for rule in ALL_RULES:
            print(f"{rule.rule_id}  {rule.description}")
        return 0
    cache = None if args.no_cache else ResultCache(CACHE_PATH)
    violations = run_rules(ALL_RULES, args.paths or ["src"], cache)
    if args.json:
        print(json.dumps([
            {"rule": v.rule_id, "path": str(v.path), "line": v.line,
             "message": v.message} for v in violations], indent=2))
    else:
        for v in violations:
            if args.fmt == "github":
                print(f"::error file={v.path},line={v.line},"
                      f"title={v.rule_id}::{v.message}")
            else:
                print(v.render())
    if violations:
        print(f"repro-lint: {len(violations)} violation(s)",
              file=sys.stderr)
        return 1
    if not args.json:
        print(f"repro-lint: clean ({len(ALL_RULES)} rules)",
              file=sys.stderr)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
