#!/usr/bin/env python3
"""repro-lint CLI.

    python tools/analyze/run.py [PATHS...]     # default: src

Exit 0 when clean, 1 when any violation (including malformed or unused
suppressions) survives.  `--list-rules` prints the registered rule ids.
"""

from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

try:
    from tools.analyze.core import run_rules
    from tools.analyze.rules import ALL_RULES
except ImportError:
    from core import run_rules
    from rules import ALL_RULES


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="repro-lint", description=__doc__)
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files or directories to lint (default: src)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print registered rule ids and exit")
    args = ap.parse_args(argv)
    if args.list_rules:
        for rule in ALL_RULES:
            print(f"{rule.rule_id}  {rule.description}")
        return 0
    violations = run_rules(ALL_RULES, args.paths or ["src"])
    for v in violations:
        print(v.render())
    if violations:
        print(f"repro-lint: {len(violations)} violation(s)",
              file=sys.stderr)
        return 1
    print(f"repro-lint: clean ({len(ALL_RULES)} rules)",
          file=sys.stderr)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
